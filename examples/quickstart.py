"""Quickstart: distill a BNS solver for an analytic flow model in ~1 minute.

  PYTHONPATH=src python examples/quickstart.py

Steps (the whole paper in miniature):
  1. take a 'pre-trained' flow model — the exact mixture velocity field;
  2. generate (noise, sample) pairs with adaptive RK45 (the GT sampler);
  3. score every registered baseline solver (list_solvers) in NS form;
  4. optimize a Bespoke Non-Stationary solver (Algorithm 2) at NFE=8;
  5. print the PSNR leaderboard — BNS should win by several dB.
"""
import jax
import jax.numpy as jnp

from repro.core import ns_solver, schedulers, toy
from repro.core.bns import BNSTrainConfig, generate_pairs
from repro.solvers import SolverSpec, list_solvers

NFE = 8


def main():
    sched = schedulers.fm_ot()
    field = toy.mixture_field(sched, toy.two_moons_means(),
                              jnp.full((16,), 0.15), jnp.ones((16,)))

    print("generating RK45 ground-truth pairs...")
    train = generate_pairs(field, jax.random.PRNGKey(0), 256, (2,))
    val = generate_pairs(field, jax.random.PRNGKey(1), 256, (2,))

    scores = {}
    for info in list_solvers(baseline=True):
        scores[info.name] = SolverSpec(info.name, NFE).sampler(field).psnr(val)

    print(f"training BNS solver (NFE={NFE}, "
          f"{ns_solver.count_parameters(NFE)} parameters)...")
    spec = SolverSpec("midpoint", NFE, mode="bns")
    res = spec.distill(field, train, val,
                       BNSTrainConfig(iterations=800, val_every=100,
                                      batch_size=64),
                       log=lambda m: print("  " + m))
    scores["BNS (ours)"] = res.val_psnr

    print(f"\nPSNR @ {NFE} NFE (vs RK45 ground truth):")
    for name, s in sorted(scores.items(), key=lambda kv: kv[1]):
        print(f"  {name:12s} {s:6.2f} dB")
    assert scores["BNS (ours)"] == max(scores.values())
    print("\nBNS wins — the paper's headline result, reproduced.")


if __name__ == "__main__":
    main()
