"""End-to-end driver: train a flow-matching model on a real backbone from the
assigned pool for a few hundred steps, then BNS-distill a sampler and serve
batched generation requests.

  PYTHONPATH=src python examples/train_flow_lm.py [--arch yi-6b] [--steps 300]

This is the production path in miniature: launch.train (CFM, checkpoints) ->
RK45 GT generation -> SolverSpec.distill (Algorithm 2) -> SolverArtifact
save/load -> serving.FlowSampler.from_artifact (batched requests, exactly
NFE backbone forwards per batch).
"""
import argparse
import os
import tempfile

import jax

from repro.configs import get_config
from repro.core.bns import BNSTrainConfig
from repro.core.rk45 import rk45_solve
from repro.core.schedulers import fm_ot
from repro.data.synthetic import DataConfig, SyntheticTokens
from repro.launch.train import train
from repro.models import model as M
from repro.serving.engine import FlowSampler
from repro.solvers import SolverArtifact, SolverSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nfe", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    with tempfile.TemporaryDirectory() as ckpt:
        print(f"[1/4] training {args.arch} (smoke) flow model, "
              f"{args.steps} steps with checkpointing...")
        params, losses = train(args.arch, smoke=True, steps=args.steps,
                               batch=16, seq=16, lr=1e-3, ckpt_dir=ckpt,
                               ckpt_every=100)
        print(f"      CFM loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    print("[2/4] generating RK45 ground truth under the trained field...")
    data = SyntheticTokens(cfg, DataConfig(batch_size=24, seq_len=16, seed=5))
    cond = data.batch(0)
    field = M.velocity_field(params, cfg, fm_ot(), cond, cfg_scale=0.0)
    x0 = jax.random.normal(jax.random.PRNGKey(2), (24, 16, cfg.latent_dim))
    x1 = rk45_solve(field.fn, x0, rtol=1e-5, atol=1e-5).x1
    x0v = jax.random.normal(jax.random.PRNGKey(3), (24, 16, cfg.latent_dim))
    x1v = rk45_solve(field.fn, x0v, rtol=1e-5, atol=1e-5).x1

    print(f"[3/4] BNS distillation at NFE={args.nfe} (Algorithm 2)...")
    spec = SolverSpec("euler", args.nfe, mode="bns")
    res = spec.distill(field, (x0, x1), (x0v, x1v),
                       BNSTrainConfig(lr=1e-3, lr_schedule="cosine",
                                      iterations=300, val_every=50,
                                      batch_size=24),
                       log=lambda m: print("      " + m))
    base_psnr = SolverSpec("euler", args.nfe).sampler(field).psnr((x0v, x1v))
    print(f"      Euler {base_psnr:.2f} dB -> BNS {res.val_psnr:.2f} dB "
          f"({res.num_parameters} params, {res.wall_seconds:.0f}s)")

    print("[4/4] serving from the saved solver artifact...")
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "solver.msgpack")
        res.artifact(provenance={"arch": args.arch}).save(path)
        artifact = SolverArtifact.load(path)
    sampler = FlowSampler.from_artifact(artifact, params=params, cfg=cfg,
                                        sched=fm_ot())
    latents = sampler.sample(cond, jax.random.PRNGKey(7))
    tokens = sampler.nearest_tokens(latents)
    print(f"      sampled latents {latents.shape} -> tokens {tokens.shape}; "
          f"{args.nfe} backbone forwards per batch.")


if __name__ == "__main__":
    main()
