"""Gateway demo: many users, one anytime solver, coalesced batches.

Boots the smoke backbone with an (untrained — mechanics, not quality)
anytime solver serving budgets {2, 4}, starts the gateway's serving thread,
and fires 12 concurrent single-sample requests with mixed NFE budgets —
including an unserved budget 3, whose drift to a served budget comes back in
the response metadata. The batcher coalesces them into padded fixed-bucket
batches; a flush spanning both budgets rides the shared anytime trajectory
(one dispatch at max(budgets) forwards) when that is cheaper.

  PYTHONPATH=src python examples/gateway_demo.py
"""
import jax

from repro.configs import get_config
from repro.core.anytime import init_anytime
from repro.core.schedulers import fm_ot
from repro.data.synthetic import DataConfig, SyntheticTokens
from repro.models import model as M
from repro.serving import AnytimeFlowSampler, Gateway, Request
from repro.solvers import SolverArtifact, SolverSpec

BUDGETS = (2, 4)
REQUESTS = 12


def main():
    cfg = get_config("yi-6b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    data = SyntheticTokens(cfg, DataConfig(batch_size=4, seq_len=8))
    tokens = data.batch(0)["tokens"]
    artifact = SolverArtifact(
        spec=SolverSpec("midpoint", mode="anytime", budgets=BUDGETS),
        params=init_anytime(None, BUDGETS), val_psnr=0.0)
    sampler = AnytimeFlowSampler.from_artifact(artifact, params=params,
                                               cfg=cfg, sched=fm_ot())

    gateway = Gateway(sampler, max_batch=4, max_wait_ms=20.0,
                      mixed_budget_policy="auto")
    gateway.start()
    print(f"submitting {REQUESTS} requests at budgets cycling (2, 4, 3):")
    futures = [gateway.submit(Request(tokens=tokens[i % tokens.shape[0]],
                                      budget=(2, 4, 3)[i % 3],
                                      key=jax.random.PRNGKey(100 + i)))
               for i in range(REQUESTS)]
    gateway.shutdown()           # graceful drain, then stop the thread

    for i, fut in enumerate(futures):
        meta = fut.result().meta
        drift = ("" if meta["requested_budget"] == meta["served_budget"]
                 else f"  (drift: requested {meta['requested_budget']})")
        print(f"  request {i}: {meta['served_budget']} NFE, "
              f"batch {meta['batch_real']}/{meta['batch_padded']}"
              + (" [mixed]" if meta["mixed"] else "") + drift)
    s = gateway.stats()
    print(f"{s['completed']} samples in {s['batches']} batches "
          f"({s['mixed_batches']} mixed): {s['forwards']} backbone forwards "
          f"total, {s['nfe_per_request']:.2f} NFE/request, "
          f"occupancy {s['occupancy']:.2f}, "
          f"mean wait {s['mean_wait_ms']:.1f} ms")


if __name__ == "__main__":
    main()
