"""Taxonomy tour (Theorem 3.2 live): run every named solver family directly
AND as its NS-converted form, showing exact agreement — Euler, Midpoint,
Heun, RK4, Adams-Bashforth, DDIM, DPM++(2M), EDM, sigma0-preconditioned ST,
and a perturbed BST solver.

  PYTHONPATH=src python examples/solver_zoo.py
"""
import jax
import jax.numpy as jnp

from repro.core import ns_solver, schedulers, solvers, st_solvers, st_transform, taxonomy, toy
from repro.core.bst_solver import bst_euler_program, identity_bst, materialize_bst
from repro.core.exponential import ddim_program, dpm2m_program, exp_grid
from repro.solvers import list_solvers


def main():
    sched = schedulers.vp()
    field = toy.mixture_field(sched, toy.two_moons_means(),
                              jnp.full((16,), 0.15), jnp.ones((16,)))
    x0 = jax.random.normal(jax.random.PRNGKey(0), (16, 2))

    print(f"{'solver':20s} {'family':22s} {'n':>3s} {'max |direct-NS|':>16s}")
    cases = []
    for name in ["euler", "midpoint", "heun", "rk4", "ab2", "ab4"]:
        cases.append((name, "generic (RK/multistep)",
                      solvers.solver_program(name),
                      (solvers.grid_for_nfe(name, 8),)))
    cases.append(("ddim", "exponential (1st)", ddim_program,
                  (exp_grid(sched, 8), sched)))
    cases.append(("dpm++(2M)", "exponential multistep", dpm2m_program,
                  (exp_grid(sched, 8), sched)))
    cases.append(("edm+heun", "scale-time (VE)",
                  st_solvers.edm_program(solvers.heun_program, sched, 20.0),
                  (solvers.power_grid(4, 3.0),)))
    st = st_transform.scheduler_change_st(
        sched, st_transform.scaled_sigma(sched, 3.0))
    cases.append(("precond-euler s0=3", "scale-time",
                  st_solvers.st_program(solvers.euler_program, st),
                  (solvers.uniform_grid(8),)))
    cases.append(("bst-euler", "bespoke scale-time", bst_euler_program,
                  (materialize_bst(identity_bst(8)),)))

    for name, family, prog, args in cases:
        direct = taxonomy.run_direct(prog, field, x0, *args)
        ns = taxonomy.to_ns(prog, *args)
        alg1 = ns_solver.ns_sample(ns, field.fn, x0)
        err = float(jnp.max(jnp.abs(direct - alg1)))
        print(f"{name:20s} {family:22s} {ns.n:3d} {err:16.2e}")
    print("\nEvery family is a point in the Non-Stationary space (Fig. 3) — "
          "BNS optimizes over all of them at once.")

    print(f"\nregistry ({len(list_solvers())} solvers): "
          f"{'name':12s} {'family':14s} sigma0  grid")
    for info in list_solvers():
        print(f"  {info.name:12s} {info.family:14s} "
              f"{'yes' if info.supports_sigma0 else 'no ':3s}    "
              f"{info.grid_family}")


if __name__ == "__main__":
    main()
