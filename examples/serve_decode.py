"""Serving-substrate demo: batched autoregressive decode across architecture
families — KV-cache GQA (dense), recurrent state (RWKV6), and the hybrid
Mamba2+shared-attention state, plus the sliding-window ring buffer that makes
long_500k decode sub-quadratic for dense models — and the decode gateway's
continuous slot refill multiplexing mixed-length prompts onto one slot pool.

  PYTHONPATH=src python examples/serve_decode.py
"""
import jax

from repro.configs import get_config
from repro.models import model as M
from repro.serving.decode import DecodeGateway, DecodeRequest
from repro.serving.engine import DecodeEngine, greedy_demo

BATCH, STEPS = 4, 24


def demo(arch: str, window: int = 0, slots: int = 64):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = DecodeEngine(params=params, cfg=cfg, window=window)
    tokens, dt = greedy_demo(engine, BATCH, STEPS, slots)
    kind = f"window={window}" if window else \
        ("recurrent state" if cfg.family in ("ssm", "hybrid") else "full cache")
    print(f"  {arch:16s} [{cfg.family:6s}] {STEPS} tokens x {BATCH} seqs, "
          f"{kind}: {dt:.1f} ms/token  sample={tokens[0, :6].tolist()}")


GATEWAY_SLOTS = 2


def demo_gateway(arch: str = "yi-6b", max_slots: int = GATEWAY_SLOTS):
    """Mixed-length prompts through the continuous-batching decode gateway:
    a finished sequence frees its slot and the next prompt joins mid-flight
    (join_step > 0), bit-identical to decoding it alone."""
    cfg = get_config(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    gw = DecodeGateway(DecodeEngine(params=params, cfg=cfg),
                       max_slots=max_slots, cache_slots=64)
    gw.start()
    futs = [gw.submit(DecodeRequest(prompt=[1 + i, 2 + i], max_tokens=t))
            for i, t in enumerate((12, 4, 8))]
    gw.shutdown()
    for i, f in enumerate(futs):
        meta = f.result().meta
        print(f"  request {i}: {meta['new_tokens']} tokens, slot "
              f"{meta['slot']}, join_step {meta['join_step']}")
    s = gw.stats()
    print(f"  {s['completed']} sequences over {s['forwards']} engine steps "
          f"({max_slots} slots, occupancy {s['slot_occupancy']:.2f}, "
          f"{s['joins']} mid-flight joins)")


def main():
    print("batched greedy decode across the family zoo:")
    demo("yi-6b")
    demo("rwkv6-7b")
    demo("zamba2-2.7b")
    demo("qwen3-moe-30b-a3b")
    print("sliding-window ring buffer (long-context mechanism, window=8):")
    demo("yi-6b", window=8, slots=8)
    print(f"continuous decode batching ({GATEWAY_SLOTS} slots, "
          "mixed lengths):")
    demo_gateway()


if __name__ == "__main__":
    main()
