"""Serving-substrate demo: batched autoregressive decode across architecture
families — KV-cache GQA (dense), recurrent state (RWKV6), and the hybrid
Mamba2+shared-attention state, plus the sliding-window ring buffer that makes
long_500k decode sub-quadratic for dense models.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.serving.engine import DecodeEngine

BATCH, STEPS = 4, 24


def demo(arch: str, window: int = 0, slots: int = 64):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = DecodeEngine(params=params, cfg=cfg, window=window)
    state = engine.init_state(BATCH, slots)
    prompt = jnp.zeros((BATCH,), jnp.int32)
    t0 = time.time()
    tokens, _ = engine.greedy(prompt, state, STEPS)
    dt = (time.time() - t0) / STEPS * 1e3
    kind = f"window={window}" if window else \
        ("recurrent state" if cfg.family in ("ssm", "hybrid") else "full cache")
    print(f"  {arch:16s} [{cfg.family:6s}] {STEPS} tokens x {BATCH} seqs, "
          f"{kind}: {dt:.1f} ms/token  sample={tokens[0, :6].tolist()}")


def main():
    print("batched greedy decode across the family zoo:")
    demo("yi-6b")
    demo("rwkv6-7b")
    demo("zamba2-2.7b")
    demo("qwen3-moe-30b-a3b")
    print("sliding-window ring buffer (long-context mechanism, window=8):")
    demo("yi-6b", window=8, slots=8)


if __name__ == "__main__":
    main()
