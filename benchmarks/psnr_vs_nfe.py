"""Figure 4 / Table 4 reproduction (analytic-teacher scale): PSNR vs NFE for
BNS against BST and every baseline solver family, across the paper's three
pre-trained-model types (FM-OT, FM/v-CS, eps-VP schedulers).

Expected (paper): BNS > BST > DPM > RK-Midpoint/Euler in PSNR at low NFE, and
PSNR monotone in NFE. The 'pre-trained model' here is the closed-form
Gaussian-mixture velocity field (exact marginal flow) — solver behaviour, not
network capacity, is what this figure measures.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import schedulers, toy
from repro.core.bns import BNSTrainConfig, generate_pairs
from repro.solvers import SolverSpec, solver_names

SCHEDS = ["fm_ot", "fm_cs", "vp"]
NFES = [4, 8, 16]
BASELINES = solver_names(baseline=True)  # euler, midpoint, ddim, dpm2m


def make_field(sname: str):
    sched = schedulers.get_scheduler(sname)
    return toy.mixture_field(sched, toy.two_moons_means(),
                             jnp.full((16,), 0.15), jnp.ones((16,)))


def run(iterations: int = 3000, lr: float = 1e-3, log=print) -> list[dict]:
    rows = []
    for sname in SCHEDS:
        field = make_field(sname)
        train = generate_pairs(field, jax.random.PRNGKey(0), 256, (2,))
        val = generate_pairs(field, jax.random.PRNGKey(1), 256, (2,))
        for nfe in NFES:
            row = {"scheduler": sname, "nfe": nfe}
            for name in BASELINES:
                row[name] = SolverSpec(name, nfe).sampler(field).psnr(val)
            cfg = BNSTrainConfig(lr=lr, iterations=iterations, val_every=100,
                                 batch_size=64)
            t0 = time.time()
            row["bns"] = SolverSpec("midpoint", nfe, mode="bns") \
                .distill(field, train, val, cfg).val_psnr
            row["bns_train_s"] = round(time.time() - t0, 1)
            row["bst"] = SolverSpec("euler", nfe, mode="bst") \
                .distill(field, train, val, cfg).val_psnr
            rows.append(row)
            log(f"{sname} NFE={nfe}: " + " ".join(
                f"{k}={v:.2f}" for k, v in row.items()
                if isinstance(v, float) and k != "bns_train_s"))
    return rows


def check_paper_claims(rows: list[dict]) -> list[str]:
    """Validate the orderings the paper reports (Fig 4, Fig 11)."""
    notes = []
    for r in rows:
        runner_up = max(r[b] for b in BASELINES + ["bst"])
        # Paper Sec. 6: BNS "doesn't reach the extremely low NFE regime
        # (1-4)" — at NFE 4 we require parity with the trained-BST runner-up
        # (within 2 dB); at NFE >= 8 BNS must win outright.
        margin = 2.0 if r["nfe"] <= 4 else 0.0
        ok = r["bns"] > runner_up - margin
        notes.append(
            f"[{'PASS' if ok else 'FAIL'}] {r['scheduler']} NFE={r['nfe']}: "
            f"BNS {r['bns']:.2f} vs best-other {runner_up:.2f}"
            + (" (NFE<=4 parity band, paper Sec. 6 caveat)" if margin else ""))
        ok_bst = r["bst"] >= r["euler"] - 0.2
        notes.append(
            f"[{'PASS' if ok_bst else 'FAIL'}] {r['scheduler']} NFE={r['nfe']}: "
            f"BST {r['bst']:.2f} >= Euler {r['euler']:.2f} (trained >= init)")
    for sname in SCHEDS:
        per = [r["bns"] for r in rows if r["scheduler"] == sname]
        mono = all(b > a for a, b in zip(per, per[1:]))
        notes.append(f"[{'PASS' if mono else 'FAIL'}] {sname}: BNS PSNR "
                     f"monotone in NFE {['%.1f' % p for p in per]}")
    return notes


if __name__ == "__main__":
    rows = run()
    for n in check_paper_claims(rows):
        print(n)
