"""Gateway benchmark: batched-vs-unbatched serving throughput and latency
across request mixes.

Workload: single-sample requests over the analytic toy field (backbone
forwards are cheap, so the measurement isolates the serving layer: dispatch
count, coalescing, padding, mixed-budget routing). The unbatched baseline is
the same jit'd sampler invoked once per request at batch 1 — exactly what
PR 2's serving loop did; the gateway coalesces the identical request stream
into padded fixed-bucket batches.

Acceptance (ISSUE 3): >= 2x throughput over unbatched at --max-batch 8 on
the synthetic workload. ``--json out.json`` writes the summary the CI
workflow publishes as an artifact.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.serving import Gateway, Request
from repro.serving.toy import ToyAnytimeSampler

BUDGETS = (4, 8, 16)


MIXES = {
    "uniform8": lambda i: 8,
    "mixed": lambda i: BUDGETS[i % len(BUDGETS)],
    "skew16": lambda i: 16 if i % 4 else 4,
}


def _warmup(sampler, buckets, max_batch):
    """Compile every program either serving path can hit — per-(budget,
    bucket) sampler programs AND the gateway's own stack/pad ops — so the
    timed region measures serving, not first-call compilation."""
    for budget in sampler.budgets:
        for b in buckets:
            sampler.sample_from(None, jnp.zeros((b, 2)), budget)
    jax.tree.map(lambda x: x.block_until_ready(),
                 sampler.sample_all_from(None, jnp.zeros((buckets[-1], 2))))
    gw = Gateway(sampler, max_batch=max_batch, max_wait_ms=0.0)
    futures = [gw.submit(Request(budget=b, x0=jnp.zeros((2,))))
               for b in sampler.budgets for _ in range(max_batch)]
    for count in range(1, max_batch):             # partial buckets too
        futures.append(gw.submit(Request(budget=sampler.budgets[0],
                                         x0=jnp.zeros((2,)))))
        futures.append(gw.submit(Request(budget=sampler.budgets[-1],
                                         x0=jnp.zeros((2,)))))
    gw.drain()
    for f in futures:        # responses are host arrays — already synced
        f.result()


def run(requests: int = 64, max_batch: int = 8, log=print):
    buckets = [1]
    while buckets[-1] < max_batch:
        buckets.append(min(buckets[-1] * 2, max_batch))
    rows = []
    for mix, budget_of in MIXES.items():
        sampler = ToyAnytimeSampler()
        _warmup(sampler, buckets, max_batch)
        x0s = [jax.random.normal(jax.random.PRNGKey(1000 + i), (2,))
               for i in range(requests)]

        t0 = time.perf_counter()
        for i, x0 in enumerate(x0s):
            sampler.sample_from(None, x0[None],
                                budget_of(i)).block_until_ready()
        unbatched_s = time.perf_counter() - t0

        gw = Gateway(sampler, max_batch=max_batch, max_wait_ms=2.0)
        t0 = time.perf_counter()
        futures = [gw.submit(Request(budget=budget_of(i), x0=x0))
                   for i, x0 in enumerate(x0s)]
        gw.drain()
        for f in futures:    # responses are host arrays — already synced
            f.result()
        gateway_s = time.perf_counter() - t0

        stats = gw.stats()
        row = {
            "mix": mix,
            "requests": requests,
            "max_batch": max_batch,
            "unbatched_rps": requests / unbatched_s,
            "gateway_rps": requests / gateway_s,
            "speedup": unbatched_s / gateway_s,
            "unbatched_ms_per_req": unbatched_s / requests * 1e3,
            "gateway_ms_per_req": gateway_s / requests * 1e3,
            "batches": stats["batches"],
            "mixed_batches": stats["mixed_batches"],
            "occupancy": stats["occupancy"],
            "nfe_per_request": stats["nfe_per_request"],
        }
        rows.append(row)
        log(f"{mix}: unbatched {row['unbatched_rps']:.0f} rps -> gateway "
            f"{row['gateway_rps']:.0f} rps ({row['speedup']:.1f}x) in "
            f"{stats['batches']} batches ({stats['mixed_batches']} mixed, "
            f"occupancy {stats['occupancy']:.2f}, "
            f"{stats['nfe_per_request']:.2f} NFE/request)")
    return rows


def check_claims(rows):
    notes = []
    for r in rows:
        if r["mix"] == "uniform8" and r["max_batch"] == 8:
            ok = r["speedup"] >= 2.0
            notes.append(f"[{'PASS' if ok else 'FAIL'}] gateway >= 2x "
                         f"unbatched throughput at batch 8 "
                         f"(got {r['speedup']:.1f}x)")
    return notes


def metrics(rows):
    """Regression-gate metrics (benchmarks/regression.py schema).

    The gated throughput metric is ``nfe_per_request`` — backbone forwards
    per served request, the quantity that bounds real device throughput for
    a bespoke solver — plus padded-bucket ``occupancy``. Both are exact
    functions of the batch plan (observed bit-stable across runs), so the
    15% default tolerance is a real gate. Wall-clock ``speedup`` is NOT
    gated here: it swings 2-10x with runner load (same machine, same
    commit); its >=2x floor is enforced by ``--check`` in the serving CI
    job instead."""
    out = {}
    for r in rows:
        out[f"{r['mix']}.nfe_per_request"] = {
            "value": round(r["nfe_per_request"], 4), "higher_better": False}
        out[f"{r['mix']}.occupancy"] = {
            "value": round(r["occupancy"], 4), "higher_better": True}
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write the summary (rows + claims) to this path")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when an acceptance claim FAILs "
                         "(used by CI so a throughput regression is loud)")
    args = ap.parse_args()
    requests = 32 if args.quick else args.requests
    rows = run(requests=requests, max_batch=args.max_batch)
    notes = check_claims(rows)
    for n in notes:
        print(n)
    for r in rows:
        print(f"gateway/{r['mix']},{r['gateway_ms_per_req'] * 1e3:.1f},"
              f"speedup={r['speedup']:.2f};occupancy={r['occupancy']:.2f};"
              f"nfe_per_request={r['nfe_per_request']:.2f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "gateway", "rows": rows, "claims": notes,
                       "metrics": metrics(rows)}, f, indent=2)
        print(f"summary written to {args.json}")
    if args.check and any(n.startswith("[FAIL]") for n in notes):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
