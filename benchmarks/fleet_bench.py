"""Fleet federation: work stealing vs static affinity routing on p95 wait.

Parallel-host event simulation over the analytic toy field with a FAKE
clock — fully deterministic (no wall-clock, no compile noise: CI gates
these numbers against committed baselines). Four emulated hosts serve one
arrival schedule through a ``FleetGateway``; each host is an independent
"device" with its own ``busy_until`` horizon: a host only dispatches when
free, and a dispatch charges it (backbone forwards spent) x ``--step-ms``
of simulated busy time. Waits are stamped at dispatch on the shared clock,
so a request queued behind a busy shard pays for every batch ahead of it.

The workload is the fleet's worst case for static routing: affinity pins
each (budget, shape) key to one home host, and the ``skew16`` mix sends
~75% of traffic to a single key — its home saturates while the other
hosts idle. Static routing (stealer=None) can only watch the hot shard's
queue grow; work stealing migrates queued entries to hosts that are FREE
and EMPTY (the simulator passes explicit thieves — it knows device
busyness the queue snapshot cannot show) and serves them in parallel.
Stealing trades forwards for latency (two half batches cost two dispatch
budgets), so the uniform mix guards the other side: when affinity already
balances the fleet, stealing must not burn forwards or hurt p95.

Every simulated sample is also checked BIT-IDENTICAL against a single
``Gateway`` serving the same trace (the fleet acceptance invariant:
routing and migration never perturb a row).

Acceptance (ISSUE 6): work stealing strictly beats static routing on p95
wait under the skewed mix. ``--check`` exits non-zero when a claim FAILs;
``--json out.json`` writes the summary + regression metrics CI gates on.
"""
from __future__ import annotations

import argparse
import json
from collections import deque

import jax
import numpy as np

from repro.observability import TraceRecorder
from repro.serving import FleetGateway, Gateway, Request, WorkStealer
from repro.serving.toy import CountingToySampler, FakeClock

BUDGETS = (4, 8, 16)
HOSTS = 4

MIXES = {
    # the headline workload: one hot affinity key takes ~75% of traffic,
    # so its home host saturates while the rest of the fleet idles
    "skew16": lambda i: 16 if i % 4 else 4,
    # guard workload: every budget equally likely — affinity already
    # spreads the load, stealing must not make anything worse
    "uniform": lambda i: BUDGETS[i % len(BUDGETS)],
}


def schedule(mix: str, requests: int, inter_ms: float,
             burst: int) -> list[tuple[float, int, int]]:
    """Deterministic arrivals: an opening burst then a steady stream —
    (arrive_s, budget, request_id)."""
    budget_of = MIXES[mix]
    events = []
    for i in range(requests):
        t_ms = 0.0 if i < burst else (i - burst + 1) * inter_ms
        events.append((t_ms / 1e3, budget_of(i), i))
    return events


def _x0(i):
    return jax.random.normal(jax.random.PRNGKey(1000 + i), (2,))


def simulate(events, stealer, step_ms: float, max_batch: int,
             max_wait_ms: float, recorder=None):
    """Drive one fleet through the arrival schedule on parallel emulated
    hosts. Each host dispatches only while free; a dispatch charges its
    ``busy_until`` horizon by (forwards spent) x step_ms. Stealing moves
    queue bookkeeping only, so it costs zero simulated time."""
    clock = FakeClock()
    samplers = {f"h{i}": CountingToySampler(budgets=BUDGETS)
                for i in range(HOSTS)}
    # router seed 1 homes the three budget keys on three DISTINCT hosts,
    # so "uniform" really is a balanced fleet (the guard workload) and
    # "skew16" really is one hot shard — seed 0 happens to collide two
    # keys on one host, which would make both workloads imbalanced
    fleet = FleetGateway(
        {name: Gateway(s, max_batch=max_batch, max_wait_ms=max_wait_ms,
                       mixed_budget_policy="never", clock=clock)
         for name, s in samplers.items()},
        stealer=stealer, steal=stealer is not None, seed=1,
        recorder=recorder)
    hosts = {name: fleet._hosts[name].gateway for name in samplers}
    busy = {name: 0.0 for name in hosts}
    pending = deque(events)
    futures = {}

    def submit_due():
        while pending and pending[0][0] <= clock.t + 1e-12:
            _, budget, i = pending.popleft()
            futures[i] = fleet.submit(Request(budget=budget, x0=_x0(i)))

    idle_hop = max_wait_ms / 2e3
    while pending or any(gw.queue.depth() for gw in hosts.values()):
        submit_due()
        ran = 0
        for name in sorted(hosts):
            if busy[name] <= clock.t + 1e-12:
                before = samplers[name].forwards
                if hosts[name].pump():
                    busy[name] = clock.t + \
                        (samplers[name].forwards - before) * step_ms / 1e3
                    ran += 1
        # thieves are hosts that are FREE and EMPTY — the simulator knows
        # device busyness, which a queue-depth snapshot cannot show
        free = [n for n in hosts if busy[n] <= clock.t + 1e-12
                and hosts[n].queue.depth() == 0]
        if fleet.steal_round(thieves=free):
            continue                      # stolen entries dispatch this tick
        if ran:
            continue
        hops = [t for t in busy.values() if t > clock.t]
        if pending:
            hops.append(pending[0][0])
        nxt = min(hops) if hops else clock.t + idle_hop   # age stragglers
        clock.advance(max(nxt - clock.t, 1e-9))
    waits = np.array([futures[i].result().meta["wait_ms"]
                      for i in sorted(futures)])
    rows = [np.asarray(futures[i].result().latents) for i in sorted(futures)]
    return waits, rows, fleet.stats(), fleet.metrics_snapshot()


def oracle(events, max_batch: int, max_wait_ms: float):
    """The single-gateway reference for the bit-identity claim."""
    clock = FakeClock()
    gw = Gateway(CountingToySampler(budgets=BUDGETS), max_batch=max_batch,
                 max_wait_ms=max_wait_ms, mixed_budget_policy="never",
                 clock=clock)
    futures = [gw.submit(Request(budget=b, x0=_x0(i))) for _, b, i in events]
    clock.advance(1.0)
    gw.drain()
    return [np.asarray(f.result().latents) for f in futures]


def run(requests: int = 96, step_ms: float = 2.0, max_batch: int = 8,
        max_wait_ms: float = 12.0, inter_ms: float = 2.0, log=print,
        registry_out=None, trace_jsonl=None):
    """Arrival rate tuned so the skewed mix SATURATES the hot key's home
    host (partial aged flushes at budget 16 cannot keep up) while the
    four-host fleet has ample total capacity — exactly the regime work
    stealing exists for."""
    # a shard is a victim only once it holds a full batch it cannot flush:
    # shallower queues are cheaper to serve at home (denser batches) than
    # to migrate into extra dispatches on the thief
    stealer = WorkStealer(min_queue=max_batch, max_steal=max_batch // 2)
    rows = []
    for mix in MIXES:
        events = schedule(mix, requests, inter_ms, burst=max_batch)
        static_waits, static_rows, static_stats, _ = simulate(
            events, None, step_ms, max_batch, max_wait_ms)
        # the skewed steal run carries a trace recorder so a STOLEN
        # request's hop chain (submit -> route -> steal -> inject ->
        # dispatch -> settle) is reconstructable from the JSONL export
        recorder = (TraceRecorder()
                    if trace_jsonl and mix == "skew16" else None)
        steal_waits, steal_rows, steal_stats, steal_snap = simulate(
            events, stealer, step_ms, max_batch, max_wait_ms,
            recorder=recorder)
        if recorder is not None:
            n = recorder.export_jsonl(trace_jsonl)
            log(f"skew16 steal-run trace: {n} events -> {trace_jsonl}")
        if registry_out is not None:
            registry_out[mix] = steal_snap
        hist = steal_snap["wait_ms"]
        ref = oracle(events, max_batch, max_wait_ms)
        bit_identical = all(
            np.array_equal(a, r) and np.array_equal(b, r)
            for a, b, r in zip(static_rows, steal_rows, ref))
        row = {
            "mix": mix,
            "requests": requests,
            "hosts": HOSTS,
            "step_ms": step_ms,
            "static_p95_wait_ms": float(np.percentile(static_waits, 95)),
            "steal_p95_wait_ms": float(np.percentile(steal_waits, 95)),
            "static_mean_wait_ms": float(static_waits.mean()),
            "steal_mean_wait_ms": float(steal_waits.mean()),
            "p95_ratio": float(np.percentile(static_waits, 95)
                               / max(np.percentile(steal_waits, 95), 1e-9)),
            "static_forwards": static_stats["forwards"],
            "steal_forwards": steal_stats["forwards"],
            "forwards_ratio": steal_stats["forwards"]
            / max(static_stats["forwards"], 1),
            "steals": steal_stats["steals"],
            "steal_rounds": steal_stats["steal_rounds"],
            "steal_share": steal_stats["steals"] / requests,
            "bit_identical": bit_identical,
            "steal_p95_wait_ms_registry": float(hist["p95"]),
            "wait_hist_count": int(hist["count"]),
        }
        rows.append(row)
        log(f"{mix}: p95 wait {row['static_p95_wait_ms']:.1f}ms (static) -> "
            f"{row['steal_p95_wait_ms']:.1f}ms (stealing, "
            f"{row['p95_ratio']:.1f}x better); forwards "
            f"{row['static_forwards']} -> {row['steal_forwards']} "
            f"({row['steals']} steals in {row['steal_rounds']} rounds, "
            f"bit_identical={row['bit_identical']})")
    return rows


def check_claims(rows):
    notes = []
    for r in rows:
        ok = r["bit_identical"]
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {r['mix']}: fleet "
                     f"samples (static AND stealing) bit-identical to the "
                     f"single-gateway oracle")
        ok = r["wait_hist_count"] == r["requests"]
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {r['mix']}: fleet-"
                     f"merged wait histogram count == settled requests "
                     f"({r['wait_hist_count']} vs {r['requests']})")
        if r["mix"] == "skew16":
            ok = r["p95_ratio"] > 1.0
            notes.append(f"[{'PASS' if ok else 'FAIL'}] work stealing "
                         f"strictly beats static routing on p95 wait under "
                         f"the skewed mix (got {r['p95_ratio']:.2f}x)")
            ok = r["steals"] > 0
            notes.append(f"[{'PASS' if ok else 'FAIL'}] the imbalanced mix "
                         f"actually triggered stealing "
                         f"({r['steals']} entries)")
        elif r["mix"] == "uniform":
            ok = r["p95_ratio"] >= 0.9
            notes.append(f"[{'PASS' if ok else 'FAIL'}] stealing does not "
                         f"hurt p95 when affinity already balances the "
                         f"fleet (ratio {r['p95_ratio']:.2f})")
            ok = r["forwards_ratio"] <= 1.25
            notes.append(f"[{'PASS' if ok else 'FAIL'}] stealing stays "
                         f"within 25% of static forwards on the uniform "
                         f"mix (ratio {r['forwards_ratio']:.3f})")
    return notes


def metrics(rows):
    """Regression-gate metrics (benchmarks/regression.py schema). The
    simulation is deterministic, so the default 15% tolerance is slack."""
    out = {}
    for r in rows:
        out[f"{r['mix']}.p95_ratio"] = {
            "value": round(r["p95_ratio"], 4), "higher_better": True}
        out[f"{r['mix']}.forwards_ratio"] = {
            "value": round(r["forwards_ratio"], 4), "higher_better": False}
        out[f"{r['mix']}.wait_hist_count"] = {
            "value": r["wait_hist_count"], "higher_better": True}
        if r["mix"] == "skew16":
            out["skew16.steal_share"] = {
                "value": round(r["steal_share"], 4), "higher_better": True}
            out["skew16.steal_p95_wait_ms_registry"] = {
                "value": round(r["steal_p95_wait_ms_registry"], 4),
                "higher_better": False}
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--step-ms", type=float, default=2.0)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write the summary (rows + claims + metrics) here")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when an acceptance claim FAILs")
    args = ap.parse_args()
    requests = 48 if args.quick else args.requests
    rows = run(requests=requests, step_ms=args.step_ms)
    notes = check_claims(rows)
    for n in notes:
        print(n)
    for r in rows:
        print(f"fleet/{r['mix']},{r['steal_p95_wait_ms'] * 1e3:.1f},"
              f"p95_ratio={r['p95_ratio']:.2f};"
              f"forwards_ratio={r['forwards_ratio']:.3f};"
              f"steal_share={r['steal_share']:.2f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "fleet", "rows": rows, "claims": notes,
                       "metrics": metrics(rows)}, f, indent=2)
        print(f"summary written to {args.json}")
    if args.check and any(n.startswith("[FAIL]") for n in notes):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
