"""Continuous batching vs flush-only: tail latency and NFE per request.

Event-driven simulation over the analytic toy field with a FAKE clock —
time advances by (backbone forwards spent) x ``--step-ms``, so the
measurement is fully deterministic (no wall-clock, no compile noise, no
machine variance: CI compares these numbers against committed baselines at
tight tolerance). Both gateways see the identical arrival schedule of
single-sample requests at mixed NFE budgets.

What the flush-only gateway cannot do: a request arriving one tick after a
flush waits out ``max_wait_ms`` (or a full bucket) while a long in-flight
dispatch holds the device. The continuous gateway admits it into the
in-flight anytime trajectory at the next exit boundary — its wait ends at
admission, and its prefix costs only the boundary it joins at.

Measurement is conservative for the baseline: the flush gateway plans every
ready batch at the same instant before the simulated execution time
elapses, so its recorded waits UNDERSTATE what a real serial device would
show; the continuous gateway pays its leg-by-leg schedule in full.

Acceptance (ISSUE 4): on the mixed-budget workload, p95 wait >= 1.5x lower
than flush-only with no more total backbone forwards. ``--check`` exits
non-zero when a claim FAILs; ``--json out.json`` writes the summary +
regression metrics CI publishes and gates on.

The MULTIMODAL scenario (ISSUE 10) drives the three proxy workloads'
native request lengths — taxonomy text (5/7/8 rows), audio infill
(10/13/16), image latents (16) — through ONE ContinuousGateway twice over
the identical arrival schedule: once grouping by exact shape (six
fragmented groups, ``tiers=None``) and once under a two-rung
``ShapeLadder`` (text on the short rung, audio + image sharing the long
one). Acceptance: the tiered pool reaches strictly higher slot occupancy
at no more total forwards, and every tiered sample is bit-identical to
the direct sampler at its native shape (padding cropped on settle).
"""
from __future__ import annotations

import argparse
import json
from collections import deque

import jax
import numpy as np

from repro.observability import bucket_bounds_at
from repro.serving import ContinuousGateway, Gateway, Request, ShapeLadder
from repro.serving.toy import FakeClock, ToyAnytimeSampler

try:                                    # via run.py (repo root on sys.path)
    from benchmarks.audio_proxy import REQUEST_LENGTHS as AUDIO_LENGTHS
    from benchmarks.t2i_proxy import REQUEST_LENGTHS as IMAGE_LENGTHS
    from benchmarks.taxonomy_bench import REQUEST_LENGTHS as TEXT_LENGTHS
except ImportError:                     # run directly as a script
    from audio_proxy import REQUEST_LENGTHS as AUDIO_LENGTHS
    from t2i_proxy import REQUEST_LENGTHS as IMAGE_LENGTHS
    from taxonomy_bench import REQUEST_LENGTHS as TEXT_LENGTHS

BUDGETS = (4, 8, 16)
# multimodal tier ladder: text rides the short rung, audio + image share
# the long one — six native lengths collapse onto two slot pools
TIER_RUNGS = (8, 16)
MODALITIES = (("text", TEXT_LENGTHS), ("audio", AUDIO_LENGTHS),
              ("image", IMAGE_LENGTHS))


class ToyCarrySampler(ToyAnytimeSampler):
    """Eager shared toy sampler whose every batch-level velocity evaluation
    ticks the fake clock by ``step_ms``, so queue waits accumulate through
    simulated EXECUTION — a request arriving while a long dispatch runs
    pays for it, under either gateway. The simulation meters forwards, not
    wall time, so nothing is jitted."""

    def __init__(self, budgets=BUDGETS, seed=0, jitter=0.1):
        super().__init__(budgets=budgets, seed=seed, jitter=jitter,
                         jit=False)
        self.tick = None          # set by the simulator

    def on_forward(self):
        if self.tick is not None:
            self.tick()


MIXES = {
    # the headline workload: all three budgets interleaved, so flush-only
    # either fragments into per-budget partials or waits out max_wait
    "mixed": lambda i: BUDGETS[i % len(BUDGETS)],
    # top-heavy: most requests ride long trajectories, joiners everywhere
    "skew16": lambda i: 16 if i % 4 else 4,
}


def schedule(mix: str, requests: int, inter_ms: float,
             burst: int) -> list[tuple[float, int, int]]:
    """Deterministic arrivals: an opening burst (fills the first trajectory
    or bucket) then a steady stream — (arrive_s, budget, request_id)."""
    budget_of = MIXES[mix]
    events = []
    for i in range(requests):
        t_ms = 0.0 if i < burst else (i - burst + 1) * inter_ms
        events.append((t_ms / 1e3, budget_of(i), i))
    return events


def schedule_multimodal(requests: int, inter_ms: float, burst: int):
    """Interleaved multi-modal arrivals: modalities round-robin and each
    cycles its proxy workload's native REQUEST_LENGTHS, budgets cycling
    the grid — (arrive_s, budget, request_id, rows). The stream mixes six
    distinct x0 shapes, so exact-shape grouping fragments while a
    two-rung ladder keeps two pools full."""
    events = []
    for i in range(requests):
        _, lengths = MODALITIES[i % len(MODALITIES)]
        rows = lengths[(i // len(MODALITIES)) % len(lengths)]
        t_ms = 0.0 if i < burst else (i - burst + 1) * inter_ms
        events.append((t_ms / 1e3, BUDGETS[i % len(BUDGETS)], i, rows))
    return events


def simulate(make_gateway, events, step_ms: float):
    """Drive one gateway through the arrival schedule. Execution advances
    the clock from INSIDE the sampler (one tick per batch-level forward),
    so a dispatch's cost is on the clock before the next plan runs; the
    loop only hops time when the gateway is idle (to the next arrival, or
    in small steps to age out partial batches)."""
    clock = FakeClock()
    sampler = ToyCarrySampler()
    gw = make_gateway(sampler, clock)
    pending = deque(events)
    futures = []

    def submit_due():
        while pending and pending[0][0] <= clock.t + 1e-12:
            ev = pending.popleft()
            budget, i = ev[1], ev[2]
            # multimodal events carry a native row count: x0 is (rows, 2)
            shape = (ev[3], 2) if len(ev) > 3 else (2,)
            x0 = jax.random.normal(jax.random.PRNGKey(1000 + i), shape)
            futures.append(gw.submit(Request(budget=budget, x0=x0)))

    def tick():
        # clients are asynchronous: arrivals land DURING a dispatch (submit
        # is thread-safe and lock-free wrt planning), so a request due
        # mid-leg is visible to the very next boundary's join plan — for
        # the flush gateway, to the very next batch plan
        clock.advance(step_ms / 1e3)
        submit_due()

    sampler.tick = tick
    idle_hop = min(step_ms, gw.scheduler.max_wait_s * 1e3) / 2e3
    while pending or gw.queue.depth() or getattr(gw, "_traj", None):
        submit_due()
        if gw.pump() == 0:
            if pending and pending[0][0] > clock.t:
                clock.advance(pending[0][0] - clock.t)   # hop to next arrival
            else:
                clock.advance(idle_hop)                  # age the stragglers
    resps = [f.result() for f in futures]
    waits = np.array([r.meta["wait_ms"] for r in resps])
    return waits, gw.stats(), gw.metrics.snapshot(), resps


def run(requests: int = 96, max_slots: int = 8, step_ms: float = 2.0,
        max_wait_ms: float = 12.0, inter_ms: float = 6.0, max_leg: int = 4,
        log=print, registry_out=None):
    """Moderate steady load (service keeps up with arrivals; buckets do NOT
    fill before ``max_wait_ms``): the regime continuous batching targets —
    flush-only ages out partial batches while requests that could join an
    in-flight trajectory sit in the queue. At saturation both gateways
    degenerate to full buckets and the gap closes (skew16 shows flush-only
    already near-optimal when one budget dominates)."""
    rows = []
    for mix in MIXES:
        events = schedule(mix, requests, inter_ms, burst=max_slots)
        flush_waits, flush_stats, flush_snap, _ = simulate(
            lambda sampler, clock: Gateway(sampler, max_batch=max_slots,
                                           max_wait_ms=max_wait_ms,
                                           clock=clock),
            events, step_ms)
        cont_waits, cont_stats, cont_snap, _ = simulate(
            lambda sampler, clock: ContinuousGateway(
                sampler, max_slots=max_slots, max_wait_ms=max_wait_ms,
                clock=clock, max_leg=max_leg),
            events, step_ms)
        if registry_out is not None:
            registry_out[mix] = {"flush": flush_snap, "cont": cont_snap}
        # the registry's interpolated p95 must agree with the exact
        # per-request percentile to within one histogram bucket width
        hist = cont_snap["wait_ms"]
        lo, hi = bucket_bounds_at(hist["bounds"], hist["buckets"], 95.0)
        width = float(hi - lo) if np.isfinite(hi) else float("inf")
        row = {
            "mix": mix,
            "requests": requests,
            "max_slots": max_slots,
            "step_ms": step_ms,
            "flush_p95_wait_ms": float(np.percentile(flush_waits, 95)),
            "cont_p95_wait_ms": float(np.percentile(cont_waits, 95)),
            "flush_mean_wait_ms": float(flush_waits.mean()),
            "cont_mean_wait_ms": float(cont_waits.mean()),
            "p95_ratio": float(np.percentile(flush_waits, 95)
                               / max(np.percentile(cont_waits, 95), 1e-9)),
            "flush_forwards": flush_stats["forwards"],
            "cont_forwards": cont_stats["forwards"],
            "forwards_ratio": cont_stats["forwards"]
            / max(flush_stats["forwards"], 1),
            "flush_nfe_per_request": flush_stats["nfe_per_request"],
            "cont_nfe_per_request": cont_stats["nfe_per_request"],
            "joins": cont_stats["joins"],
            "join_rate": cont_stats["join_rate"],
            "trajectories": cont_stats["trajectories"],
            "slot_occupancy": cont_stats["slot_occupancy"],
            "cont_p95_wait_ms_registry": float(hist["p95"]),
            "registry_p95_bucket_width": width,
            "registry_p95_delta": float(
                abs(hist["p95"] - np.percentile(cont_waits, 95))),
            "wait_hist_count": int(hist["count"]),
        }
        rows.append(row)
        log(f"{mix}: p95 wait {row['flush_p95_wait_ms']:.1f}ms (flush) -> "
            f"{row['cont_p95_wait_ms']:.1f}ms (continuous, "
            f"{row['p95_ratio']:.1f}x better); forwards "
            f"{row['flush_forwards']} -> {row['cont_forwards']} "
            f"({row['joins']} joins, join_rate {row['join_rate']:.2f}, "
            f"slot_occupancy {row['slot_occupancy']:.2f})")
    rows.append(run_multimodal(requests=requests, max_slots=max_slots,
                               step_ms=step_ms, max_wait_ms=max_wait_ms,
                               inter_ms=inter_ms, max_leg=max_leg, log=log,
                               registry_out=registry_out))
    return rows


def run_multimodal(requests: int = 96, max_slots: int = 8,
                   step_ms: float = 2.0, max_wait_ms: float = 12.0,
                   inter_ms: float = 6.0, max_leg: int = 4, log=print,
                   registry_out=None):
    """ISSUE 10 tentpole gate: the three proxy workloads' native request
    shapes through ONE ContinuousGateway, exact-shape grouping vs the
    two-rung tier ladder, identical arrival schedule. The row reuses the
    generic field names — the baseline ("flush") arm here is exact-shape
    grouping, the "cont" arm is the tiered pool — so the CSV line,
    registry-p95 claims, and regression metrics apply unchanged."""
    events = schedule_multimodal(requests, inter_ms, burst=max_slots)

    def make(tiers):
        return lambda sampler, clock: ContinuousGateway(
            sampler, max_slots=max_slots, max_wait_ms=max_wait_ms,
            clock=clock, max_leg=max_leg, tiers=tiers)

    exact_waits, exact_stats, exact_snap, exact_resps = simulate(
        make(None), events, step_ms)
    tier_waits, tier_stats, tier_snap, tier_resps = simulate(
        make(ShapeLadder(TIER_RUNGS)), events, step_ms)

    # bit-identity: every sample from BOTH arms must equal the direct
    # sampler at the request's NATIVE shape (tier padding cropped away)
    oracle = ToyCarrySampler()
    mismatches = 0
    for (_, budget, i, rows_n), er, tr in zip(events, exact_resps,
                                              tier_resps):
        x0 = jax.random.normal(jax.random.PRNGKey(1000 + i), (rows_n, 2))
        want = np.asarray(oracle.sample_from(None, x0[None],
                                             oracle.resolve_budget(budget))[0])
        for got in (np.asarray(er.latents), np.asarray(tr.latents)):
            if got.shape != want.shape or not np.array_equal(got, want):
                mismatches += 1

    hist = tier_snap["wait_ms"]
    lo, hi = bucket_bounds_at(hist["bounds"], hist["buckets"], 95.0)
    width = float(hi - lo) if np.isfinite(hi) else float("inf")
    row = {
        "mix": "multimodal",
        "requests": requests,
        "max_slots": max_slots,
        "step_ms": step_ms,
        "tier_rungs": list(TIER_RUNGS),
        "exact_shape_groups": len({ev[3] for ev in events}),
        # generic names: flush_* = exact-shape arm, cont_* = tiered arm
        "flush_p95_wait_ms": float(np.percentile(exact_waits, 95)),
        "cont_p95_wait_ms": float(np.percentile(tier_waits, 95)),
        "flush_mean_wait_ms": float(exact_waits.mean()),
        "cont_mean_wait_ms": float(tier_waits.mean()),
        "p95_ratio": float(np.percentile(exact_waits, 95)
                           / max(np.percentile(tier_waits, 95), 1e-9)),
        "flush_forwards": exact_stats["forwards"],
        "cont_forwards": tier_stats["forwards"],
        "forwards_ratio": tier_stats["forwards"]
        / max(exact_stats["forwards"], 1),
        "flush_nfe_per_request": exact_stats["nfe_per_request"],
        "cont_nfe_per_request": tier_stats["nfe_per_request"],
        "joins": tier_stats["joins"],
        "join_rate": tier_stats["join_rate"],
        "trajectories": tier_stats["trajectories"],
        "exact_trajectories": exact_stats["trajectories"],
        "slot_occupancy": tier_stats["slot_occupancy"],
        "exact_slot_occupancy": exact_stats["slot_occupancy"],
        "occupancy_gain": tier_stats["slot_occupancy"]
        / max(exact_stats["slot_occupancy"], 1e-9),
        "mismatches": mismatches,
        "tier_occupancy_gauges": {
            k: v for k, v in tier_snap.items()
            if k.startswith("tier_occupancy{")},
        "cont_p95_wait_ms_registry": float(hist["p95"]),
        "registry_p95_bucket_width": width,
        "registry_p95_delta": float(
            abs(hist["p95"] - np.percentile(tier_waits, 95))),
        "wait_hist_count": int(hist["count"]),
    }
    if registry_out is not None:
        registry_out["multimodal"] = {"exact": exact_snap,
                                      "tiered": tier_snap}
    log(f"multimodal: slot_occupancy {row['exact_slot_occupancy']:.2f} "
        f"(exact-shape, {row['exact_shape_groups']} groups) -> "
        f"{row['slot_occupancy']:.2f} (tiered, {len(TIER_RUNGS)} rungs, "
        f"{row['occupancy_gain']:.2f}x); forwards {row['flush_forwards']} "
        f"-> {row['cont_forwards']}; trajectories "
        f"{row['exact_trajectories']} -> {row['trajectories']}; p95 wait "
        f"{row['flush_p95_wait_ms']:.1f}ms -> "
        f"{row['cont_p95_wait_ms']:.1f}ms; {row['mismatches']} bit-exact "
        f"mismatches")
    return row


def check_claims(rows):
    notes = []
    for r in rows:
        if r["mix"] == "mixed":
            ok = r["p95_ratio"] >= 1.5
            notes.append(f"[{'PASS' if ok else 'FAIL'}] continuous p95 wait "
                         f">= 1.5x better than flush-only at mixed budgets "
                         f"(got {r['p95_ratio']:.2f}x)")
            ok = r["forwards_ratio"] <= 1.05
            notes.append(f"[{'PASS' if ok else 'FAIL'}] continuous spends "
                         f"no more backbone forwards than flush-only at "
                         f"mixed budgets (ratio {r['forwards_ratio']:.3f})")
        elif r["mix"] == "skew16":
            # flush-only is near-optimal when one budget dominates (full
            # single-budget buckets); continuous must not burn forwards
            ok = r["forwards_ratio"] <= 1.10
            notes.append(f"[{'PASS' if ok else 'FAIL'}] continuous stays "
                         f"within 10% of flush-only forwards on the "
                         f"skew16 workload (ratio {r['forwards_ratio']:.3f})")
        elif r["mix"] == "multimodal":
            ok = r["slot_occupancy"] > r["exact_slot_occupancy"]
            notes.append(f"[{'PASS' if ok else 'FAIL'}] multimodal: tiered "
                         f"pool reaches strictly higher slot occupancy "
                         f"than exact-shape grouping "
                         f"({r['slot_occupancy']:.3f} vs "
                         f"{r['exact_slot_occupancy']:.3f})")
            ok = r["cont_forwards"] <= r["flush_forwards"]
            notes.append(f"[{'PASS' if ok else 'FAIL'}] multimodal: tiered "
                         f"pool spends no more total forwards than "
                         f"exact-shape grouping ({r['cont_forwards']} vs "
                         f"{r['flush_forwards']})")
            ok = r["mismatches"] == 0
            notes.append(f"[{'PASS' if ok else 'FAIL'}] multimodal: every "
                         f"sample bit-identical to the direct sampler at "
                         f"its native shape, both arms "
                         f"({r['mismatches']} mismatches)")
        ok = (r["registry_p95_delta"]
              <= r["registry_p95_bucket_width"] + 1e-9)
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {r['mix']}: registry "
                     f"histogram p95 within one bucket width of "
                     f"np.percentile (delta {r['registry_p95_delta']:.2f}ms"
                     f" <= width {r['registry_p95_bucket_width']:.2f}ms)")
        ok = r["wait_hist_count"] == r["requests"]
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {r['mix']}: wait "
                     f"histogram count == settled requests "
                     f"({r['wait_hist_count']} vs {r['requests']})")
    return notes


def metrics(rows):
    """Regression-gate metrics (benchmarks/regression.py schema). The
    simulation is deterministic, so the default 15% tolerance is slack."""
    out = {}
    for r in rows:
        out[f"{r['mix']}.p95_ratio"] = {
            "value": round(r["p95_ratio"], 4), "higher_better": True}
        out[f"{r['mix']}.forwards_ratio"] = {
            "value": round(r["forwards_ratio"], 4), "higher_better": False}
        out[f"{r['mix']}.join_rate"] = {
            "value": round(r["join_rate"], 4), "higher_better": True}
        # deterministic registry metrics: the histogram count is exact and
        # the interpolated p95 rides the same fake clock as the waits
        out[f"{r['mix']}.wait_hist_count"] = {
            "value": r["wait_hist_count"], "higher_better": True}
        out[f"{r['mix']}.cont_p95_wait_ms_registry"] = {
            "value": round(r["cont_p95_wait_ms_registry"], 4),
            "higher_better": False}
        if r["mix"] == "multimodal":
            out["multimodal.occupancy_gain"] = {
                "value": round(r["occupancy_gain"], 4),
                "higher_better": True}
            out["multimodal.slot_occupancy"] = {
                "value": round(r["slot_occupancy"], 4),
                "higher_better": True}
            out["multimodal.mismatches"] = {
                "value": r["mismatches"], "higher_better": False}
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--step-ms", type=float, default=2.0)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write the summary (rows + claims + metrics) here")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when an acceptance claim FAILs")
    args = ap.parse_args()
    requests = 48 if args.quick else args.requests
    rows = run(requests=requests, max_slots=args.max_slots,
               step_ms=args.step_ms)
    notes = check_claims(rows)
    for n in notes:
        print(n)
    for r in rows:
        print(f"continuous/{r['mix']},{r['cont_p95_wait_ms'] * 1e3:.1f},"
              f"p95_ratio={r['p95_ratio']:.2f};"
              f"forwards_ratio={r['forwards_ratio']:.3f};"
              f"join_rate={r['join_rate']:.2f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "continuous", "rows": rows, "claims": notes,
                       "metrics": metrics(rows)}, f, indent=2)
        print(f"summary written to {args.json}")
    if args.check and any(n.startswith("[FAIL]") for n in notes):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
