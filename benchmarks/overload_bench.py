"""SLO scheduling vs FIFO under overload: goodput and deadline-hit-rate.

Open-loop Poisson load generator on the fake clock: arrivals are drawn
once (seeded exponential inter-arrival gaps at >= 10x the gateway's
derived service capacity) and the IDENTICAL schedule is driven through
two arms of the same gateway:

* FIFO  — ``slo=None``: the legacy planner. Deadlines are still recorded
  (goodput / deadline_misses tick at settle), but nothing is rejected,
  shed, or reordered. Under overload the queue grows without bound and
  every request past the first few batches settles LATE.
* SLO   — ``slo=SLOConfig()``: fast-reject admission control (the cost
  model self-calibrates from the registry's observed dispatch-time
  histograms — simulated milliseconds here, so the bench is fully
  deterministic), queue shedding, urgency-ordered planning, and (on the
  continuous tier) exit-boundary preemption.

Why SLO wins goodput at FEWER forwards: FIFO's backlog means a request
arriving at time t waits behind everything accepted before it, so only
the earliest arrivals ever settle inside their deadline — yet the device
still burns forwards serving the hopeless tail. Admission control keeps
the queue no deeper than the deadline can absorb, so the device spends
its whole life serving requests that still can win: every service slot
lands a goodput unit instead of a late miss.

Acceptance (ISSUE 9, generator driven to 100x by ISSUE 10): at >= 100x
capacity offered load, the SLO arm achieves strictly higher goodput AND
deadline-hit-rate than FIFO at no more total backbone forwards, on both
the flush and continuous tiers, and its settled p99 queue wait stays
within one dispatch quantum of the deadline (admitted work can land at
most one service grain late) while FIFO's blows out with the backlog by
>= 10x. A third, deterministic ``preempt`` trace pins down the
exit-boundary preemption path: a best-effort tier seizes every slot,
an interactive tier arrives mid-leg, and only the SLO arm's preemption
serves the interactive deadlines — with the paused victims resuming
from their saved carries so both arms still complete everything. The
SLO arm
also exercises the admission cost model's calibration loop: every
deadline-carrying settle records |estimated - actual| wait into
``cost_est_error_ms``, and the row reports the sample count plus
mean/p95 error (report-only — the gate is that calibration HAPPENS, not
a particular model quality). ``--check`` exits non-zero when a claim
FAILs; ``--json out.json`` writes the summary + regression metrics CI
publishes and gates on.

At 100x the pre-calibration window is the whole ballgame: hundreds of
requests arrive before the FIRST dispatch seeds the cost histograms, so
an optimistic ``default_cost_ms=0`` admits a doomed backlog that eats
the entire deadline. The SLO arm therefore seeds the model with
``default_cost_ms`` = one derived dispatch cost (the knob ``serve.py``
exposes as ``--slo-default-cost-ms``), which keeps admission honest
until live histograms take over.
"""
from __future__ import annotations

import argparse
import json
from collections import deque

import jax
import numpy as np

try:                                    # via run.py (repo root on sys.path)
    from benchmarks.continuous_bench import ToyCarrySampler
except ImportError:                     # run directly as a script
    from continuous_bench import ToyCarrySampler

from repro.serving import (
    AdmissionRejected,
    ContinuousGateway,
    Gateway,
    Request,
    SLOConfig,
)
from repro.serving.toy import FakeClock

# Short budget grid: the worst single dispatch (budget-8 bucket = 8
# forwards x step_ms = 8 simulated ms) must sit WELL under the deadline,
# or service granularity — not scheduling policy — decides who settles
# late. deadline ~ 2.5x the worst dispatch; the arrival window (requests
# x gap) ~ 2-3.5x the deadline, so overload is SUSTAINED: FIFO's backlog
# outlives the deadline while admission control keeps serving fresh,
# still-feasible arrivals for the whole window. At 100x the per-request
# gap is ~6us, so holding that window takes ~12k requests — the request
# defaults scale WITH the overload factor (requests ~ overload x 120
# keeps the window fixed; shrinking only the gap would collapse the run
# into a single sub-deadline burst where FIFO ties by construction).
BUDGETS = (2, 4, 8)
MAX_BATCH = 8
STEP_MS = 1.0
MAX_WAIT_MS = 12.0
DEADLINE_MS = 20.0
OVERLOAD = 100.0                        # offered load / derived capacity
# admission cost model seed: one derived dispatch (mean budget x step) —
# what serve.py's --slo-default-cost-ms plumbs through. 0 would accept
# every pre-calibration arrival; at 100x that backlog alone eats the
# deadline before the first histogram sample lands.
DEFAULT_COST_MS = sum(BUDGETS) / len(BUDGETS) * STEP_MS


def capacity_ms_per_request(step_ms: float = STEP_MS,
                            max_batch: int = MAX_BATCH) -> float:
    """Derived steady-state service time per request: full single-budget
    buckets amortize a budget-b dispatch (b forwards x step_ms) over
    max_batch rows; the budget mix cycles the grid."""
    mean_budget = sum(BUDGETS) / len(BUDGETS)
    return mean_budget * step_ms / max_batch


def schedule(requests: int, seed: int = 0,
             overload: float = OVERLOAD) -> list[tuple[float, int, int]]:
    """Open-loop Poisson arrivals at ``overload``x capacity:
    (arrive_s, budget, request_id), budgets cycling the grid. Seeded —
    both arms replay the identical trace."""
    rng = np.random.default_rng(seed)
    mean_gap_s = capacity_ms_per_request() / overload / 1e3
    gaps = rng.exponential(mean_gap_s, requests)
    t = np.cumsum(gaps) - gaps[0]       # first arrival at t=0
    return [(float(t[i]), BUDGETS[i % len(BUDGETS)], i)
            for i in range(requests)]


def simulate(make_gateway, events, deadline_of,
             priority_of=lambda i: 0, step_ms: float = STEP_MS):
    """Drive one arm through the arrival schedule (the continuous_bench
    loop plus admission): execution ticks the clock from inside the
    sampler, arrivals land mid-dispatch, rejected submits never enter the
    queue, and the run drains to the last settled future.
    ``deadline_of(i)`` is per-request (None = best-effort, skips
    admission and goodput accounting)."""
    clock = FakeClock()
    sampler = ToyCarrySampler(budgets=BUDGETS)
    gw = make_gateway(sampler, clock)
    pending = deque(events)
    futures = []

    def submit_due():
        while pending and pending[0][0] <= clock.t + 1e-12:
            _, budget, i = pending.popleft()
            x0 = jax.random.normal(jax.random.PRNGKey(2000 + i), (2,))
            try:
                futures.append(gw.submit(Request(
                    budget=budget, x0=x0, deadline_ms=deadline_of(i),
                    priority=priority_of(i))))
            except AdmissionRejected:
                pass                    # counted by the gateway

    def tick():
        clock.advance(step_ms / 1e3)
        submit_due()

    sampler.tick = tick
    idle_hop = min(step_ms, gw.scheduler.max_wait_s * 1e3) / 2e3
    while pending or gw.queue.depth() or getattr(gw, "_traj", None):
        submit_due()
        if gw.pump() == 0:
            if pending and pending[0][0] > clock.t:
                clock.advance(pending[0][0] - clock.t)
            else:
                clock.advance(idle_hop)
    for f in futures:
        try:
            f.result(timeout=1)
        except Exception:
            pass                        # shed: DeadlineExceeded
    return gw.stats(), gw.metrics.snapshot()


SCENARIOS = {
    # flush gateway: admission + shedding + deadline-pressure planning.
    # Cost model = one full dispatch per batch ahead, so the seed is the
    # derived dispatch cost (mean budget x step) and the slack absorbs
    # one worst bucket.
    "flush": {
        "make": lambda slo: (lambda sampler, clock: Gateway(
            sampler, max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
            clock=clock, slo=slo)),
        # uniform best-effort traffic: the win is pure admission control
        "priority_of": lambda i: 0,
        "slo": lambda: SLOConfig(slack_ms=8.0,
                                 default_cost_ms=DEFAULT_COST_MS),
    },
    # continuous gateway: + urgency-ordered joins. Slots refill at every
    # exit boundary, so the per-settle cost sits far below a full
    # dispatch — the seed is the first exit boundary's leg (2 forwards x
    # step) and the live model takes over from the registry's observed
    # device-time-per-settle after the first settle.
    "continuous": {
        "make": lambda slo: (lambda sampler, clock: ContinuousGateway(
            sampler, max_slots=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
            clock=clock, max_leg=4, slo=slo)),
        "priority_of": lambda i: 1 if i % 4 == 0 else 0,
        "slo": lambda: SLOConfig(slack_ms=6.0, default_cost_ms=2.0),
    },
}


def run_preempt(deadline_ms: float = 10.0, log=print, registry_out=None):
    """Deterministic slot-contention trace for the exit-boundary
    preemption claim: 8 best-effort budget-8 requests (NO deadline —
    they bypass admission and seize every slot at t=0), then 4
    interactive budget-4 requests with a tight deadline land mid-leg.
    No slot frees until the best-effort tier exits at budget 8, which is
    past the interactive deadline — so FIFO misses all four, while the
    SLO arm preempts four occupants at the first exit boundary (budget
    2), serves the interactive tier to its budget-4 exit in-deadline,
    and resumes the paused victims from their saved carry at the next
    boundary. Poisson arrivals almost never reach a full-slot boundary
    with an urgent request still queued (urgency-ordered joins seat the
    priority tier first), so the mechanism gets its own trace where the
    contention is structural, not sampled."""
    events = [(0.0, 8, i) for i in range(8)]
    events += [(1.5e-3, 4, 8 + k) for k in range(4)]
    deadline_of = lambda i: None if i < 8 else deadline_ms  # noqa: E731
    priority_of = lambda i: 0 if i < 8 else 1               # noqa: E731
    scen = SCENARIOS["continuous"]
    fifo, fifo_snap = simulate(scen["make"](None), events, deadline_of,
                               priority_of)
    slo, slo_snap = simulate(scen["make"](scen["slo"]()), events,
                             deadline_of, priority_of)
    if registry_out is not None:
        registry_out["preempt"] = {"fifo": fifo_snap, "slo": slo_snap}
    row = {
        "scenario": "preempt",
        "requests": 4,              # deadline-carrying (interactive) tier
        "deadline_ms": deadline_ms,
        "fifo_goodput": fifo["goodput"],
        "slo_goodput": slo["goodput"],
        "fifo_hit_rate": fifo["deadline_hit_rate"],
        "slo_hit_rate": slo["deadline_hit_rate"],
        "fifo_preemptions": fifo["preemptions"],
        "slo_preemptions": slo["preemptions"],
        "fifo_accounted": (fifo["goodput"] + fifo["deadline_misses"]
                           + fifo["rejected"]),
        "slo_accounted": (slo["goodput"] + slo["deadline_misses"]
                          + slo["rejected"]),
        "fifo_completed": fifo["completed"],
        "slo_completed": slo["completed"],
    }
    log(f"preempt: interactive goodput {row['fifo_goodput']}/4 (fifo) -> "
        f"{row['slo_goodput']}/4 (slo); preemptions "
        f"{row['fifo_preemptions']} -> {row['slo_preemptions']}; "
        f"completed {row['fifo_completed']} -> {row['slo_completed']}")
    return row


def run(requests: int = 14400, deadline_ms: float = DEADLINE_MS,
        overload: float = OVERLOAD, log=print, registry_out=None):
    events = schedule(requests, overload=overload)
    rows = []
    for name, scen in SCENARIOS.items():
        fifo, fifo_snap = simulate(scen["make"](None), events,
                                   lambda i: deadline_ms,
                                   scen["priority_of"])
        slo, slo_snap = simulate(
            scen["make"](scen["slo"]()), events, lambda i: deadline_ms,
            scen["priority_of"])
        if registry_out is not None:
            registry_out[name] = {"fifo": fifo_snap, "slo": slo_snap}
        cfg = scen["slo"]()
        row = {
            "scenario": name,
            "requests": requests,
            "overload": overload,
            "deadline_ms": deadline_ms,
            "fifo_goodput": fifo["goodput"],
            "slo_goodput": slo["goodput"],
            "goodput_ratio": slo["goodput"] / max(fifo["goodput"], 1),
            "fifo_hit_rate": fifo["deadline_hit_rate"],
            "slo_hit_rate": slo["deadline_hit_rate"],
            "fifo_forwards": fifo["forwards"],
            "slo_forwards": slo["forwards"],
            "forwards_ratio": slo["forwards"] / max(fifo["forwards"], 1),
            "slo_rejected": slo["rejected"],
            "slo_deadline_misses": slo["deadline_misses"],
            "fifo_deadline_misses": fifo["deadline_misses"],
            "slo_preemptions": slo["preemptions"],
            "fifo_accounted": (fifo["goodput"] + fifo["deadline_misses"]
                               + fifo["rejected"]),
            "slo_accounted": (slo["goodput"] + slo["deadline_misses"]
                              + slo["rejected"]),
            # settled-request queue-wait tail: FIFO serves its whole
            # backlog eventually, so its p99 wait scales with the window;
            # admission keeps the SLO arm's tail inside the deadline
            "fifo_wait_p99_ms": fifo["wait_p99_ms"],
            "slo_wait_p99_ms": slo["wait_p99_ms"],
            # admission cost model calibration (satellite: estimate vs
            # actual settle time, |error| in ms over settled requests)
            "slo_slack_ms": cfg.slack_ms,
            "slo_default_cost_ms": cfg.default_cost_ms,
            "slo_cost_est_samples": slo["cost_est_samples"],
            "slo_cost_est_error_mean_ms": slo["cost_est_error_mean_ms"],
            "slo_cost_est_error_p95_ms": slo["cost_est_error_p95_ms"],
        }
        rows.append(row)
        log(f"{name}: goodput {row['fifo_goodput']} (fifo) -> "
            f"{row['slo_goodput']} (slo, {row['goodput_ratio']:.2f}x); "
            f"hit rate {row['fifo_hit_rate']:.2f} -> "
            f"{row['slo_hit_rate']:.2f}; forwards {row['fifo_forwards']} "
            f"-> {row['slo_forwards']} "
            f"({row['forwards_ratio']:.2f}x); "
            f"{row['slo_rejected']} rejected, "
            f"{row['slo_preemptions']} preemptions; p99 wait "
            f"{row['fifo_wait_p99_ms']:.0f}ms -> "
            f"{row['slo_wait_p99_ms']:.0f}ms; cost model "
            f"|est-actual| mean {row['slo_cost_est_error_mean_ms']:.1f}ms "
            f"over {row['slo_cost_est_samples']} settles")
    rows.append(run_preempt(log=log, registry_out=registry_out))
    return rows


def check_claims(rows):
    notes = []
    for r in rows:
        s = r["scenario"]
        if s == "preempt":
            ok = r["slo_preemptions"] > 0 and r["fifo_preemptions"] == 0
            notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: full-slot "
                         f"exit boundary preempts for the urgent tier "
                         f"under SLO and never under FIFO "
                         f"({r['slo_preemptions']} vs "
                         f"{r['fifo_preemptions']} preemptions)")
            ok = (r["slo_goodput"] == r["requests"]
                  and r["fifo_goodput"] < r["requests"])
            notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: preemption "
                         f"serves every interactive deadline FIFO misses "
                         f"({r['slo_goodput']}/{r['requests']} vs "
                         f"{r['fifo_goodput']}/{r['requests']} in-deadline)")
            ok = (r["fifo_completed"] == r["slo_completed"]
                  and r["fifo_accounted"] == r["requests"]
                  and r["slo_accounted"] == r["requests"])
            notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: preempted "
                         f"victims resume and settle — both arms complete "
                         f"all {r['fifo_completed']} requests and account "
                         f"every deadline")
            continue
        ok = r["overload"] >= 100.0
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: offered load >= "
                     f"100x derived capacity (got {r['overload']:.0f}x)")
        ok = r["slo_goodput"] > r["fifo_goodput"]
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: SLO goodput "
                     f"strictly beats FIFO under overload "
                     f"({r['slo_goodput']} vs {r['fifo_goodput']})")
        ok = r["slo_hit_rate"] > r["fifo_hit_rate"]
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: SLO deadline-hit-"
                     f"rate strictly beats FIFO ({r['slo_hit_rate']:.3f} "
                     f"vs {r['fifo_hit_rate']:.3f})")
        ok = r["slo_forwards"] <= r["fifo_forwards"]
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: SLO spends no "
                     f"more total forwards than FIFO "
                     f"({r['slo_forwards']} vs {r['fifo_forwards']})")
        ok = (r["fifo_accounted"] == r["requests"]
              and r["slo_accounted"] == r["requests"])
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: every offered "
                     f"deadline request is accounted (goodput + misses + "
                     f"rejected == {r['requests']}) in both arms")
        # settled requests include accepted-but-late stragglers, so the
        # attainable bound is deadline + one worst dispatch quantum (a
        # request admitted feasibly can still land one service grain
        # past the line) — FIFO's p99 is the whole backlog, orders of
        # magnitude out
        bound = r["deadline_ms"] + max(BUDGETS) * STEP_MS
        ok = (r["slo_wait_p99_ms"] <= bound
              and r["slo_wait_p99_ms"] < r["fifo_wait_p99_ms"] / 10)
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: SLO settled p99 "
                     f"queue wait stays within deadline + one dispatch "
                     f"quantum, >=10x under FIFO's "
                     f"({r['slo_wait_p99_ms']:.0f}ms vs bound "
                     f"{bound:.0f}ms, FIFO {r['fifo_wait_p99_ms']:.0f}ms)")
        ok = r["slo_cost_est_samples"] > 0
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: admission cost "
                     f"model calibrated against actual settle times "
                     f"({r['slo_cost_est_samples']} samples, mean error "
                     f"{r['slo_cost_est_error_mean_ms']:.1f}ms, p95 "
                     f"{r['slo_cost_est_error_p95_ms']:.1f}ms)")
    return notes


def metrics(rows):
    """Regression-gate metrics (benchmarks/regression.py schema). The
    simulation is deterministic (seeded Poisson, fake clock), so the
    default 15% tolerance is slack."""
    out = {}
    for r in rows:
        s = r["scenario"]
        if s == "preempt":
            out[f"{s}.slo_preemptions"] = {
                "value": r["slo_preemptions"], "higher_better": True}
            out[f"{s}.slo_goodput"] = {
                "value": r["slo_goodput"], "higher_better": True}
            continue
        out[f"{s}.slo_goodput"] = {
            "value": r["slo_goodput"], "higher_better": True}
        out[f"{s}.goodput_ratio"] = {
            "value": round(r["goodput_ratio"], 4), "higher_better": True}
        out[f"{s}.slo_hit_rate"] = {
            "value": round(r["slo_hit_rate"], 4), "higher_better": True}
        out[f"{s}.forwards_ratio"] = {
            "value": round(r["forwards_ratio"], 4), "higher_better": False}
        out[f"{s}.slo_accounted"] = {
            "value": r["slo_accounted"], "higher_better": True}
        out[f"{s}.slo_wait_p99_ms"] = {
            "value": round(r["slo_wait_p99_ms"], 4),
            "higher_better": False}
        # calibration quality is a model diagnostic, not a perf claim:
        # tracked on every run, never failing the job
        out[f"{s}.slo_cost_est_error_mean_ms"] = {
            "value": round(r["slo_cost_est_error_mean_ms"], 4),
            "higher_better": False, "gate": False}
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=14400)
    ap.add_argument("--overload", type=float, default=OVERLOAD)
    ap.add_argument("--deadline-ms", type=float, default=DEADLINE_MS)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write the summary (rows + claims + metrics) here")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when an acceptance claim FAILs")
    args = ap.parse_args()
    requests = 10800 if args.quick else args.requests
    rows = run(requests=requests, deadline_ms=args.deadline_ms,
               overload=args.overload)
    notes = check_claims(rows)
    for n in notes:
        print(n)
    for r in rows:
        if r["scenario"] == "preempt":
            print(f"overload/preempt,{r['slo_goodput']:.1f},"
                  f"preemptions={r['slo_preemptions']};"
                  f"hit_rate={r['slo_hit_rate']:.3f}")
            continue
        print(f"overload/{r['scenario']},{r['slo_goodput']:.1f},"
              f"goodput_ratio={r['goodput_ratio']:.2f};"
              f"hit_rate={r['slo_hit_rate']:.3f};"
              f"forwards_ratio={r['forwards_ratio']:.3f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "overload", "rows": rows, "claims": notes,
                       "metrics": metrics(rows)}, f, indent=2)
        print(f"summary written to {args.json}")
    if args.check and any(n.startswith("[FAIL]") for n in notes):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
