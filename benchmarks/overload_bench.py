"""SLO scheduling vs FIFO under overload: goodput and deadline-hit-rate.

Open-loop Poisson load generator on the fake clock: arrivals are drawn
once (seeded exponential inter-arrival gaps at >= 10x the gateway's
derived service capacity) and the IDENTICAL schedule is driven through
two arms of the same gateway:

* FIFO  — ``slo=None``: the legacy planner. Deadlines are still recorded
  (goodput / deadline_misses tick at settle), but nothing is rejected,
  shed, or reordered. Under overload the queue grows without bound and
  every request past the first few batches settles LATE.
* SLO   — ``slo=SLOConfig()``: fast-reject admission control (the cost
  model self-calibrates from the registry's observed dispatch-time
  histograms — simulated milliseconds here, so the bench is fully
  deterministic), queue shedding, urgency-ordered planning, and (on the
  continuous tier) exit-boundary preemption.

Why SLO wins goodput at FEWER forwards: FIFO's backlog means a request
arriving at time t waits behind everything accepted before it, so only
the earliest arrivals ever settle inside their deadline — yet the device
still burns forwards serving the hopeless tail. Admission control keeps
the queue no deeper than the deadline can absorb, so the device spends
its whole life serving requests that still can win: every service slot
lands a goodput unit instead of a late miss.

Acceptance (ISSUE 9): at >= 10x capacity offered load, the SLO arm
achieves strictly higher goodput AND deadline-hit-rate than FIFO at no
more total backbone forwards, on both the flush and continuous tiers.
``--check`` exits non-zero when a claim FAILs; ``--json out.json`` writes
the summary + regression metrics CI publishes and gates on.
"""
from __future__ import annotations

import argparse
import json
from collections import deque

import jax
import numpy as np

try:                                    # via run.py (repo root on sys.path)
    from benchmarks.continuous_bench import ToyCarrySampler
except ImportError:                     # run directly as a script
    from continuous_bench import ToyCarrySampler

from repro.serving import (
    AdmissionRejected,
    ContinuousGateway,
    Gateway,
    Request,
    SLOConfig,
)
from repro.serving.toy import FakeClock

# Short budget grid: the worst single dispatch (budget-8 bucket = 8
# forwards x step_ms = 8 simulated ms) must sit WELL under the deadline,
# or service granularity — not scheduling policy — decides who settles
# late. deadline ~ 2.5x the worst dispatch; the arrival window (requests
# x gap) ~ 2-3.5x the deadline, so overload is SUSTAINED: FIFO's backlog
# outlives the deadline while admission control keeps serving fresh,
# still-feasible arrivals for the whole window.
BUDGETS = (2, 4, 8)
MAX_BATCH = 8
STEP_MS = 1.0
MAX_WAIT_MS = 12.0
DEADLINE_MS = 20.0
OVERLOAD = 10.0                         # offered load / derived capacity


def capacity_ms_per_request(step_ms: float = STEP_MS,
                            max_batch: int = MAX_BATCH) -> float:
    """Derived steady-state service time per request: full single-budget
    buckets amortize a budget-b dispatch (b forwards x step_ms) over
    max_batch rows; the budget mix cycles the grid."""
    mean_budget = sum(BUDGETS) / len(BUDGETS)
    return mean_budget * step_ms / max_batch


def schedule(requests: int, seed: int = 0,
             overload: float = OVERLOAD) -> list[tuple[float, int, int]]:
    """Open-loop Poisson arrivals at ``overload``x capacity:
    (arrive_s, budget, request_id), budgets cycling the grid. Seeded —
    both arms replay the identical trace."""
    rng = np.random.default_rng(seed)
    mean_gap_s = capacity_ms_per_request() / overload / 1e3
    gaps = rng.exponential(mean_gap_s, requests)
    t = np.cumsum(gaps) - gaps[0]       # first arrival at t=0
    return [(float(t[i]), BUDGETS[i % len(BUDGETS)], i)
            for i in range(requests)]


def simulate(make_gateway, events, deadline_ms: float,
             priority_of=lambda i: 0, step_ms: float = STEP_MS):
    """Drive one arm through the arrival schedule (the continuous_bench
    loop plus admission): execution ticks the clock from inside the
    sampler, arrivals land mid-dispatch, rejected submits never enter the
    queue, and the run drains to the last settled future."""
    clock = FakeClock()
    sampler = ToyCarrySampler(budgets=BUDGETS)
    gw = make_gateway(sampler, clock)
    pending = deque(events)
    futures = []

    def submit_due():
        while pending and pending[0][0] <= clock.t + 1e-12:
            _, budget, i = pending.popleft()
            x0 = jax.random.normal(jax.random.PRNGKey(2000 + i), (2,))
            try:
                futures.append(gw.submit(Request(
                    budget=budget, x0=x0, deadline_ms=deadline_ms,
                    priority=priority_of(i))))
            except AdmissionRejected:
                pass                    # counted by the gateway

    def tick():
        clock.advance(step_ms / 1e3)
        submit_due()

    sampler.tick = tick
    idle_hop = min(step_ms, gw.scheduler.max_wait_s * 1e3) / 2e3
    while pending or gw.queue.depth() or getattr(gw, "_traj", None):
        submit_due()
        if gw.pump() == 0:
            if pending and pending[0][0] > clock.t:
                clock.advance(pending[0][0] - clock.t)
            else:
                clock.advance(idle_hop)
    for f in futures:
        try:
            f.result(timeout=1)
        except Exception:
            pass                        # shed: DeadlineExceeded
    return gw.stats()


SCENARIOS = {
    # flush gateway: admission + shedding + deadline-pressure planning
    "flush": {
        "make": lambda slo: (lambda sampler, clock: Gateway(
            sampler, max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
            clock=clock, slo=slo)),
        # uniform best-effort traffic: the win is pure admission control
        "priority_of": lambda i: 0,
    },
    # continuous gateway: + urgency-ordered joins and exit-boundary
    # preemption (every 4th request is a priority tier)
    "continuous": {
        "make": lambda slo: (lambda sampler, clock: ContinuousGateway(
            sampler, max_slots=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
            clock=clock, max_leg=4, slo=slo)),
        "priority_of": lambda i: 1 if i % 4 == 0 else 0,
    },
}


def run(requests: int = 1200, deadline_ms: float = DEADLINE_MS,
        overload: float = OVERLOAD, log=print):
    events = schedule(requests, overload=overload)
    rows = []
    for name, scen in SCENARIOS.items():
        fifo = simulate(scen["make"](None), events, deadline_ms,
                        scen["priority_of"])
        slo = simulate(scen["make"](SLOConfig()), events, deadline_ms,
                       scen["priority_of"])
        row = {
            "scenario": name,
            "requests": requests,
            "overload": overload,
            "deadline_ms": deadline_ms,
            "fifo_goodput": fifo["goodput"],
            "slo_goodput": slo["goodput"],
            "goodput_ratio": slo["goodput"] / max(fifo["goodput"], 1),
            "fifo_hit_rate": fifo["deadline_hit_rate"],
            "slo_hit_rate": slo["deadline_hit_rate"],
            "fifo_forwards": fifo["forwards"],
            "slo_forwards": slo["forwards"],
            "forwards_ratio": slo["forwards"] / max(fifo["forwards"], 1),
            "slo_rejected": slo["rejected"],
            "slo_deadline_misses": slo["deadline_misses"],
            "fifo_deadline_misses": fifo["deadline_misses"],
            "slo_preemptions": slo["preemptions"],
            "fifo_accounted": (fifo["goodput"] + fifo["deadline_misses"]
                               + fifo["rejected"]),
            "slo_accounted": (slo["goodput"] + slo["deadline_misses"]
                              + slo["rejected"]),
        }
        rows.append(row)
        log(f"{name}: goodput {row['fifo_goodput']} (fifo) -> "
            f"{row['slo_goodput']} (slo, {row['goodput_ratio']:.2f}x); "
            f"hit rate {row['fifo_hit_rate']:.2f} -> "
            f"{row['slo_hit_rate']:.2f}; forwards {row['fifo_forwards']} "
            f"-> {row['slo_forwards']} "
            f"({row['forwards_ratio']:.2f}x); "
            f"{row['slo_rejected']} rejected, "
            f"{row['slo_preemptions']} preemptions")
    return rows


def check_claims(rows):
    notes = []
    for r in rows:
        s = r["scenario"]
        ok = r["overload"] >= 10.0
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: offered load >= "
                     f"10x derived capacity (got {r['overload']:.0f}x)")
        ok = r["slo_goodput"] > r["fifo_goodput"]
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: SLO goodput "
                     f"strictly beats FIFO under overload "
                     f"({r['slo_goodput']} vs {r['fifo_goodput']})")
        ok = r["slo_hit_rate"] > r["fifo_hit_rate"]
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: SLO deadline-hit-"
                     f"rate strictly beats FIFO ({r['slo_hit_rate']:.3f} "
                     f"vs {r['fifo_hit_rate']:.3f})")
        ok = r["slo_forwards"] <= r["fifo_forwards"]
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: SLO spends no "
                     f"more total forwards than FIFO "
                     f"({r['slo_forwards']} vs {r['fifo_forwards']})")
        ok = (r["fifo_accounted"] == r["requests"]
              and r["slo_accounted"] == r["requests"])
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: every offered "
                     f"deadline request is accounted (goodput + misses + "
                     f"rejected == {r['requests']}) in both arms")
        if s == "continuous":
            ok = r["slo_preemptions"] > 0
            notes.append(f"[{'PASS' if ok else 'FAIL'}] {s}: priority "
                         f"tier exercises exit-boundary preemption "
                         f"({r['slo_preemptions']} preemptions)")
    return notes


def metrics(rows):
    """Regression-gate metrics (benchmarks/regression.py schema). The
    simulation is deterministic (seeded Poisson, fake clock), so the
    default 15% tolerance is slack."""
    out = {}
    for r in rows:
        s = r["scenario"]
        out[f"{s}.slo_goodput"] = {
            "value": r["slo_goodput"], "higher_better": True}
        out[f"{s}.goodput_ratio"] = {
            "value": round(r["goodput_ratio"], 4), "higher_better": True}
        out[f"{s}.slo_hit_rate"] = {
            "value": round(r["slo_hit_rate"], 4), "higher_better": True}
        out[f"{s}.forwards_ratio"] = {
            "value": round(r["forwards_ratio"], 4), "higher_better": False}
        out[f"{s}.slo_accounted"] = {
            "value": r["slo_accounted"], "higher_better": True}
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=1200)
    ap.add_argument("--overload", type=float, default=OVERLOAD)
    ap.add_argument("--deadline-ms", type=float, default=DEADLINE_MS)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write the summary (rows + claims + metrics) here")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when an acceptance claim FAILs")
    args = ap.parse_args()
    requests = 720 if args.quick else args.requests
    rows = run(requests=requests, deadline_ms=args.deadline_ms,
               overload=args.overload)
    notes = check_claims(rows)
    for n in notes:
        print(n)
    for r in rows:
        print(f"overload/{r['scenario']},{r['slo_goodput']:.1f},"
              f"goodput_ratio={r['goodput_ratio']:.2f};"
              f"hit_rate={r['slo_hit_rate']:.3f};"
              f"forwards_ratio={r['forwards_ratio']:.3f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "overload", "rows": rows, "claims": notes,
                       "metrics": metrics(rows)}, f, indent=2)
        print(f"summary written to {args.json}")
    if args.check and any(n.startswith("[FAIL]") for n in notes):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
