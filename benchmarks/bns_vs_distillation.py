"""Table 3 reproduction: BNS solver-distillation cost accounting vs
Progressive Distillation (Salimans & Ho 2022; Meng et al. 2023).

The PD numbers are the published ones (Appendix D.4 arithmetic, reproduced
here exactly); the BNS numbers come from our actual training configuration
(Appendix D.1: 15k/30k iterations, batch 40, + training-set generation cost).
"""
from __future__ import annotations

from repro.core.ns_solver import count_parameters


def pd_forwards_cifar10(steps: int) -> int:
    updates = {8: 500_000, 4: 550_000}[steps]
    return updates * 128 * 3              # batch 128, 2 teacher + 1 student


def pd_forwards_imagenet64(steps: int) -> int:
    updates = {16: 300_000, 8: 350_000, 4: 400_000}[steps]
    return updates * 2048 * 3


def bns_forwards(nfe: int, iterations: int, batch: int, genset: int) -> int:
    return iterations * batch * nfe + genset


ROWS = [
    # dataset, nfe, method, forwards, train-set size, params
    ("CIFAR10", 4, "PD", pd_forwards_cifar10(4), 50_000, ">50m"),
    ("CIFAR10", 8, "PD", pd_forwards_cifar10(8), 50_000, ">50m"),
    ("CIFAR10", 4, "BNS", bns_forwards(4, 30_000, 40, 85_000), 520,
     count_parameters(4)),
    ("CIFAR10", 8, "BNS", bns_forwards(8, 30_000, 40, 85_000), 520,
     count_parameters(8)),
    ("ImageNet-64", 4, "PD", pd_forwards_imagenet64(4), 1_200_000, ">200m"),
    ("ImageNet-64", 8, "PD", pd_forwards_imagenet64(8), 1_200_000, ">200m"),
    ("ImageNet-64", 16, "PD", pd_forwards_imagenet64(16), 1_200_000, ">200m"),
    ("ImageNet-64", 4, "BNS", bns_forwards(4, 15_000, 40, 90_000), 520,
     count_parameters(4)),
    ("ImageNet-64", 8, "BNS", bns_forwards(8, 15_000, 40, 90_000), 520,
     count_parameters(8)),
    ("ImageNet-64", 16, "BNS", bns_forwards(16, 15_000, 40, 90_000), 520,
     count_parameters(16)),
]

# Paper Table 3 forward counts (in millions) for validation.
PAPER = {
    ("CIFAR10", 4, "PD"): 211e6, ("CIFAR10", 8, "PD"): 192e6,
    ("CIFAR10", 4, "BNS"): 4.9e6, ("CIFAR10", 8, "BNS"): 9.7e6,
    ("ImageNet-64", 4, "PD"): 2457e6, ("ImageNet-64", 8, "PD"): 2150e6,
    ("ImageNet-64", 16, "PD"): 1843e6,
    ("ImageNet-64", 4, "BNS"): 2.5e6, ("ImageNet-64", 8, "BNS"): 4.9e6,
    ("ImageNet-64", 16, "BNS"): 9.7e6,
}


def run(log=print):
    rows_out = []
    for ds, nfe, method, fwd, ts, params in ROWS:
        paper = PAPER[(ds, nfe, method)]
        rel = fwd / paper
        ok = 0.85 < rel < 1.15
        rows_out.append({"dataset": ds, "nfe": nfe, "method": method,
                         "forwards": fwd, "paper_forwards": paper,
                         "match": ok, "train_set": ts, "params": params})
        log(f"[{'PASS' if ok else 'FAIL'}] {ds} {method} NFE={nfe}: "
            f"{fwd/1e6:.1f}m forwards (paper {paper/1e6:.0f}m), "
            f"train set {ts}, params {params}")
    ratio = pd_forwards_imagenet64(16) / bns_forwards(16, 15_000, 40, 90_000)
    log(f"ImageNet-64 NFE16: BNS uses {1/ratio:.2%} of PD's forwards "
        f"(paper: ~0.5%)")
    return rows_out


if __name__ == "__main__":
    run()
