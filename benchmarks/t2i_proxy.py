"""Table 2 / Table 5 reproduction (proxy scale): conditional generation with
CFG on a REAL backbone from the assigned pool.

Pipeline (the paper's, end to end):
  1. train a flow-matching model (yi-6b smoke backbone) on the synthetic
     token stream (launch.train);
  2. generate RK45 ground-truth latents for held-out conditioning, under
     classifier-free guidance w;
  3. evaluate RK-Euler / RK-Midpoint baselines at each NFE;
  4. train BNS solvers (with sigma0 preconditioning at high w, as the paper
     prescribes) and compare PSNR;
  5. Table 5 ablation: BNS vs its own initialization solver.
"""
from __future__ import annotations

import jax

from repro.configs import get_config
from repro.core.bns import BNSTrainConfig
from repro.core.rk45 import rk45_solve
from repro.core.schedulers import fm_ot
from repro.data.synthetic import DataConfig, SyntheticTokens
from repro.launch.train import train
from repro.models import model as M
from repro.solvers import SolverSpec, solver_names

ARCH = "yi-6b"
SEQ, BATCH = 16, 32
NFES = [8, 12]
BASELINES = solver_names(family="generic", baseline=True)  # euler, midpoint
# serving mix (continuous_bench multimodal scenario): image latents come
# at this workload's fixed grid resolution — same tier as the longest
# audio clips, so the two modalities share one slot pool under a ladder
REQUEST_LENGTHS = (SEQ,)


def build_field(params, cfg, batch, w):
    return M.velocity_field(params, cfg, fm_ot(), batch, cfg_scale=w)


def make_pairs(field, key, num, latent_dim):
    x0 = jax.random.normal(key, (num, SEQ, latent_dim))
    x1 = jax.jit(lambda x: rk45_solve(field.fn, x, rtol=1e-5, atol=1e-5).x1)(x0)
    return x0, x1


def run(w: float = 2.0, train_steps: int = 250, bns_iters: int = 400,
        log=print) -> list[dict]:
    cfg = get_config(ARCH, smoke=True)
    params, losses = train(ARCH, smoke=True, steps=train_steps, batch=16,
                           seq=SEQ, lr=1e-3, log=lambda *_: None)
    log(f"backbone CFM loss: {losses[0]:.3f} -> {losses[-1]:.3f}")

    data = SyntheticTokens(cfg, DataConfig(batch_size=BATCH, seq_len=SEQ,
                                           seed=99))
    cond = data.batch(0)
    field = build_field(params, cfg, cond, w)
    train_pairs = make_pairs(field, jax.random.PRNGKey(10), BATCH,
                             cfg.latent_dim)
    val_pairs = make_pairs(field, jax.random.PRNGKey(11), BATCH,
                           cfg.latent_dim)

    rows = []
    for nfe in NFES:
        row = {"w": w, "nfe": nfe}
        for name in BASELINES:
            row[name] = SolverSpec(name, nfe).sampler(field).psnr(val_pairs)
        # initial solver = preconditioned Euler (Table 5's 'Initial Solver')
        sigma0 = 1.0 if w == 0.0 else 2.0
        spec = SolverSpec("euler", nfe, sigma0=sigma0, cfg_scale=w, mode="bns")
        row["initial_solver"] = spec.sampler(field).psnr(val_pairs)
        cfg_bns = BNSTrainConfig(lr=1e-3, lr_schedule="cosine",
                                 iterations=bns_iters, val_every=50,
                                 batch_size=BATCH)
        row["bns"] = spec.distill(field, train_pairs, val_pairs,
                                  cfg_bns).val_psnr
        rows.append(row)
        log(f"w={w} NFE={nfe}: euler={row['euler']:.2f} "
            f"midpoint={row['midpoint']:.2f} init={row['initial_solver']:.2f} "
            f"BNS={row['bns']:.2f}")
    return rows


def check_paper_claims(rows, log=print):
    notes = []
    for r in rows:
        ok = r["bns"] > max(r["euler"], r["midpoint"], r["initial_solver"])
        notes.append(f"[{'PASS' if ok else 'FAIL'}] w={r['w']} NFE={r['nfe']}: "
                     f"BNS beats RK baselines and its own init "
                     f"(Table 2 + Table 5 pattern)")
    return notes


if __name__ == "__main__":
    rows = run()
    for n in check_paper_claims(rows):
        print(n)
