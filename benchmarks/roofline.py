"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
from the dry-run artifacts in experiments/dryrun/*.json.

    compute    = HLO_FLOPs_per_device / peak_FLOPs            [s]
    memory     = HLO_bytes_per_device / HBM_bw                [s]
    collective = wire_bytes_per_device / ICI_link_bw          [s]

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
Wire bytes: all-reduce counts 2x (reduce-scatter + all-gather phases); other
collectives 1x of their output bytes.

MODEL_FLOPS = 6 N D (train) / 2 N D (prefill/decode), N_active for MoE —
the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one token per sequence
    "long_500k": 1,
}


def wire_bytes(coll: dict) -> float:
    total = 0.0
    for kind, v in coll.items():
        if kind.endswith("_count"):
            continue
        factor = 2.0 if kind == "all-reduce" else 1.0
        total += factor * v
    return total


SHAPE_INFO = {
    "train_4k": (4096, 256, 3.0),     # (seq, batch, fwd+bwd multiplier)
    "prefill_32k": (32768, 32, 1.0),
    "decode_32k": (32768, 128, 1.0),
    "long_500k": (524288, 1, 1.0),
}


def model_flops(rec: dict) -> float:
    """Useful FLOPs: 2*N_active per token (matmuls) + attention score/value
    FLOPs (4 * L * H*hd * S per query token for full attention; window-capped
    for long_500k; O(1)-state for SSM/linear attention)."""
    from repro.configs import get_config

    cfg = get_config(rec["arch"])
    shape = rec["shape"]
    seq, batch, bwd = SHAPE_INFO[shape]
    n = rec.get("active_param_count") or rec["param_count"]
    q_tokens = batch if shape.startswith(("decode", "long")) else seq * batch
    flops = 2.0 * n * q_tokens
    # attention context length per query token
    if cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
        ctx = seq / 2 if shape in ("train_4k", "prefill_32k") else seq
        if shape == "long_500k" and cfg.sliding_window:
            ctx = min(ctx, cfg.sliding_window)
        layers = (cfg.n_layers if cfg.family != "hybrid"
                  else cfg.n_layers // cfg.hybrid_attn_every)
        flops += 4.0 * layers * cfg.n_heads * cfg.resolved_head_dim * ctx * q_tokens
    return bwd * flops / rec["devices"]   # per device


def analyze_record(rec: dict) -> dict:
    if rec.get("status") != "ok":
        return rec
    compute = rec["flops"] / PEAK_FLOPS
    memory = rec["bytes"] / HBM_BW
    collective = wire_bytes(rec.get("collectives", {})) / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    return {
        **rec,
        "t_compute": compute,
        "t_memory": memory,
        "t_collective": collective,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / rec["flops"] if rec["flops"] else 0.0,
    }


def load_all(outdir: str = "experiments/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(path) as f:
            recs.append(analyze_record(json.load(f)))
    return recs


def fmt_s(x: float) -> str:
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def table(recs: list[dict], mesh: str = "pod16x16") -> str:
    lines = ["| arch | shape | compute | memory | collective | dominant | "
             "model/HLO flops |",
             "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — |")
            continue
        if r.get("status") != "ok":
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute'])} | "
            f"{fmt_s(r['t_memory'])} | {fmt_s(r['t_collective'])} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} |")
    return "\n".join(lines)


def comparison_table(recs: list[dict]) -> str:
    """Baseline vs seq-par-optimized (mesh tag pod16x16-opt) dominant terms."""
    base = {(r["arch"], r["shape"]): r for r in recs
            if r.get("mesh") == "pod16x16" and r.get("status") == "ok"}
    opt = {(r["arch"], r["shape"]): r for r in recs
           if r.get("mesh") == "pod16x16-opt" and r.get("status") == "ok"}
    lines = ["| arch | shape | baseline dominant | optimized dominant | speedup |",
             "|---|---|---|---|---|"]
    for key in sorted(opt):
        b, o = base.get(key), opt[key]
        if b is None:
            continue
        bd = max(b["t_compute"], b["t_memory"], b["t_collective"])
        od = max(o["t_compute"], o["t_memory"], o["t_collective"])
        lines.append(f"| {key[0]} | {key[1]} | {fmt_s(bd)} ({b['dominant']}) | "
                     f"{fmt_s(od)} ({o['dominant']}) | {bd/od:.1f}x |")
    return "\n".join(lines)


def main():
    recs = load_all()
    print(table(recs))
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.md", "w") as f:
        f.write("# Roofline terms (single pod, 16x16 = 256 chips)\n\n")
        f.write(table(recs, "pod16x16"))
        f.write("\n\n# Multi-pod (2x16x16 = 512 chips)\n\n")
        f.write(table(recs, "pod2x16x16"))
        f.write("\n\n# Baseline vs optimized (seq-parallel attention fleet-wide)\n\n")
        f.write(comparison_table(recs))
        f.write("\n")
    # CSV lines for benchmarks/run.py convention
    for r in recs:
        if r.get("status") == "ok":
            dom = {"compute": r["t_compute"], "memory": r["t_memory"],
                   "collective": r["t_collective"]}[r["dominant"]]
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
                  f"{dom*1e6:.1f},dominant={r['dominant']}")


if __name__ == "__main__":
    main()
