"""Kernel benchmark: correctness vs ref.py oracles (interpret mode — TPU is
the target, this container is CPU) plus wall-time of the pure-jnp reference
paths and the modeled VMEM/arithmetic-intensity figures used in §Perf.

``--json out.json`` writes the summary + regression metrics the CI
bench-regression job gates against committed baselines.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gla_scan.gla_scan import gla_scan
from repro.kernels.gla_scan.ref import gla_ref
from repro.kernels.ns_update.ns_update import ns_update_nd
from repro.kernels.ns_update.ref import ns_update_ref


def _time(fn, *args, reps=10):
    out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.time() - t0) / reps * 1e6


def run(log=print):
    rows = []
    key = jax.random.PRNGKey(0)

    # --- ns_update: memory-bound; intensity ~ 0.5 flop/byte ------------------
    n, B, D = 16, 8, 4096
    ks = jax.random.split(key, 4)
    x0 = jax.random.normal(ks[0], (B, D), jnp.bfloat16)
    u = jax.random.normal(ks[1], (n, B, D), jnp.bfloat16)
    a, w = jax.random.normal(ks[2], ()), jax.random.normal(ks[3], (n,))
    out = ns_update_nd(x0, u, a, w, interpret=True)
    ref = ns_update_ref(x0, u, a, w)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    us = _time(jax.jit(ns_update_ref), x0, u, a, w)
    bytes_moved = (n + 2) * B * D * 2
    rows.append(("kernels/ns_update", us,
                 f"err={err:.1e};hbm_bytes={bytes_moved};vmem_tile=344KiB"))
    log(f"ns_update: max_err={err:.2e} ref={us:.0f}us "
        f"(fused: 1 HBM pass = {bytes_moved/1e6:.1f}MB)")

    # --- ns_update at gateway serving batch sizes ---------------------------
    # The gateway pads coalesced batches to fixed buckets; sweep those bucket
    # sizes on latent-sequence rows (B, S, C) and check the kernel against
    # the tensordot update it replaces (make_update_fn threads it through
    # AnytimeFlowSampler/gateway execution). Timings are the jnp reference
    # (interpret-mode kernel timing is meaningless off-TPU); the derived
    # column carries the fused one-pass HBM cost model.
    n2, S, C = 8, 16, 256
    for Bs in (1, 8, 64):
        ks = jax.random.split(jax.random.PRNGKey(Bs), 4)
        x0b = jax.random.normal(ks[0], (Bs, S, C))
        ub = jax.random.normal(ks[1], (n2, Bs, S, C))
        ab, wb = jax.random.normal(ks[2], ()), jax.random.normal(ks[3], (n2,))
        outb = ns_update_nd(x0b, ub, ab, wb, interpret=True)
        refb = ns_update_ref(x0b, ub, ab, wb)
        errb = float(jnp.max(jnp.abs(outb - refb)))
        usb = _time(jax.jit(ns_update_ref), x0b, ub, ab, wb)
        fused = (n2 + 2) * Bs * S * C * 4
        rows.append((f"kernels/ns_update_serve_b{Bs}", usb,
                     f"err={errb:.1e};fused_hbm_bytes={fused}"))
        log(f"ns_update serve bucket B={Bs}: max_err={errb:.2e} "
            f"tensordot={usb:.0f}us (fused: 1 HBM pass = {fused/1e6:.1f}MB)")

    # --- flash attention ------------------------------------------------------
    Bq, H, KV, L, hd = 1, 8, 2, 512, 128
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (Bq, H, L, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (Bq, KV, L, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (Bq, KV, L, hd), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    us = _time(jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True)),
               q, k, v)
    flops = 4 * Bq * H * L * L * hd / 2
    rows.append(("kernels/flash_attention", us,
                 f"err={err:.1e};flops={flops:.3g};no_LxL_materialization"))
    log(f"flash_attention: max_err={err:.2e} ref={us:.0f}us")

    # --- gla_scan --------------------------------------------------------------
    B2, L2, H2, dk, dv = 2, 512, 4, 64, 64
    ks = jax.random.split(key, 4)
    q2 = jax.random.normal(ks[0], (B2, L2, H2, dk))
    k2 = jax.random.normal(ks[1], (B2, L2, H2, dk))
    v2 = jax.random.normal(ks[2], (B2, L2, H2, dv))
    ld = -jnp.abs(jax.random.normal(ks[3], (B2, L2, H2, dk))) * 0.5
    o, s = gla_scan(q2, k2, v2, ld, inclusive=False, chunk=64, interpret=True)
    o_ref, s_ref = gla_ref(q2, k2, v2, ld, inclusive=False)
    err = float(jnp.max(jnp.abs(o - o_ref)))
    us = _time(jax.jit(lambda *a: gla_ref(*a, inclusive=False)), q2, k2, v2, ld)
    cube = 64 * 64 * dk * 4
    rows.append(("kernels/gla_scan", us,
                 f"err={err:.1e};vmem_cube={cube}B;"
                 f"hbm_cube_saved={B2*H2*(L2//64)*cube}B"))
    log(f"gla_scan: max_err={err:.2e} ref(recurrent)={us:.0f}us "
        f"(decay cube stays in VMEM: saves "
        f"{B2*H2*(L2//64)*cube/1e6:.0f}MB HBM per layer)")
    return rows


def metrics(rows):
    """Regression metrics (benchmarks/regression.py schema). These are
    absolute wall-clock timings of the reference paths — they vary
    several-fold across runner hardware, so they are REPORT-ONLY
    (``gate: false``): tracked on the BENCH_* artifact trajectory without
    ever failing the job on a hardware difference."""
    out = {}
    for name, us, derived in rows:
        key = name.removeprefix("kernels/") + ".us"
        out[key] = {"value": round(us, 1), "higher_better": False,
                    "gate": False}
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write the summary (rows + metrics) to this path")
    args = ap.parse_args()
    rows = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "kernel",
                       "rows": [{"name": n, "us": us, "derived": d}
                                for n, us, d in rows],
                       "metrics": metrics(rows)}, f, indent=2)
        print(f"summary written to {args.json}")


if __name__ == "__main__":
    main()
