"""Figure 3 / Theorem 3.2 benchmark: every solver family converts to NS
parameters with numerically-exact trajectory agreement, plus Algorithm-1
runtime per call (the sampling engine's inner loop cost).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import ns_solver, schedulers, solvers, st_solvers, st_transform, taxonomy, toy
from repro.core.bst_solver import bst_euler_program, identity_bst, materialize_bst
from repro.core.exponential import ddim_program, dpm2m_program
from repro.solvers import build_ns, get_solver

# serving mix (continuous_bench multimodal scenario): the text workload's
# requests are SHORT variable-length sequences of this bench's toy points
# — half the flow SEQ and under, so they land on a lower tier rung than
# the audio/image latents and fill the pool's short tier
REQUEST_LENGTHS = (5, 7, 8)


def run(log=print):
    sched = schedulers.fm_ot()
    field = toy.mixture_field(sched, toy.two_moons_means(),
                              jnp.full((16,), 0.15), jnp.ones((16,)))
    x0 = jax.random.normal(jax.random.PRNGKey(0), (64, 2))
    rows = []

    # registered solvers: direct program run vs the registry's NS build
    cases = []
    for name in ["euler", "midpoint", "heun", "rk4", "ab2", "ab4"]:
        grid = get_solver(name).default_grid(8, field)
        cases.append((name, solvers.solver_program(name), (grid,),
                      build_ns(name, 8, field)))
    for name, prog in [("ddim", ddim_program), ("dpm2m", dpm2m_program)]:
        cases.append((name, prog, (get_solver(name).default_grid(8, field),
                                   sched), build_ns(name, 8, field)))
    # bespoke constructions outside the registry: convert via taxonomy directly
    st = st_transform.scheduler_change_st(sched, st_transform.scaled_sigma(sched, 3.0))
    cases.append(("st_euler_sigma3", st_solvers.st_program(solvers.euler_program, st),
                  (solvers.uniform_grid(8),), None))
    cases.append(("edm_heun", st_solvers.edm_program(solvers.heun_program, sched, 20.0),
                  (solvers.power_grid(4, 3.0),), None))
    cases.append(("bst_euler", bst_euler_program,
                  (materialize_bst(identity_bst(8)),), None))

    for name, prog, args, ns in cases:
        direct = taxonomy.run_direct(prog, field, x0, *args)
        if ns is None:
            ns = taxonomy.to_ns(prog, *args)
        sample = jax.jit(lambda x, p=ns: ns_solver.ns_sample(p, field.fn, x))
        out = sample(x0)
        err = float(jnp.max(jnp.abs(out - direct)))
        out.block_until_ready()
        t0 = time.time()
        for _ in range(20):
            sample(x0).block_until_ready()
        us = (time.time() - t0) / 20 * 1e6
        rows.append({"solver": name, "n": ns.n, "max_err": err,
                     "alg1_us_per_call": us})
        log(f"[{'PASS' if err < 1e-3 else 'FAIL'}] {name:16s} -> NS(n={ns.n}) "
            f"max|direct - Alg.1| = {err:.2e}  ({us:.0f} us/call)")
    return rows


if __name__ == "__main__":
    run()
