"""Decode gateway: continuous slot refill vs run-to-completion batching.

Fully deterministic fake-clock simulation over ``ToyDecodeEngine`` (state =
per-slot positions; one ``on_step`` tick per engine step, so simulated time
is exactly wall-steps x ``--step-ms`` — no wall clock, no compile noise; CI
compares the numbers against committed baselines).

Both gateways are the SAME ``DecodeGateway`` serving the identical request
list; the only difference is admission policy:

* ``refill=True`` (continuous) — a finished sequence frees its state slot
  and the next queued prompt is admitted at the very next engine step.
* ``refill=False`` (run-to-completion) — new sequences wait until EVERY
  slot is free, so each wave costs ``max(lengths in the wave)`` wall-steps:
  the PR 3-style flush baseline transplanted to decode.

At mixed output lengths continuous refill must STRICTLY beat
run-to-completion on total wall-steps (every engine invocation is one
backbone forward, so wall-steps IS the serving cost); at uniform lengths
the two coincide and continuous must never be worse. Every simulated
sequence's tokens are also checked against the solo-decode oracle — the
refill machinery may not change a single token.

Two further comparisons ride the same simulation:

* CHUNKED PREFILL — the continuous gateway is also run with
  ``prefill_chunk=0`` (legacy token-by-token teacher forcing). A prefill
  call consumes a whole chunk of prompt tokens in ONE engine invocation,
  so at the workload's mixed prompt lengths (1-24 tokens) chunking must
  STRICTLY reduce total wall-steps; ``prefill_calls``/``prefill_tokens``
  break the saving out.
* PAGED KV — a paged run (``page_size=8``) exercises the gateway's
  ``PageAllocator`` for real: pages are reserved at admission and freed at
  finish, so ``peak_kv_per_slot`` (high-water pages x page_size /
  max_slots) must come in UNDER the dense per-slot allocation
  (cache_slots), and tokens must still match the oracle exactly.

``--check`` exits non-zero when a claim FAILs; ``--json out.json`` writes
the summary + regression metrics CI publishes and gates on
(``benchmarks/regression.py`` + ``benchmarks/baselines/decode_bench.json``).
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.serving.decode import DecodeGateway, DecodeRequest
from repro.serving.toy import FakeClock, ToyDecodeEngine

# output-length mixes (cycled per request): the mixed workload is the
# headline — short sequences finish early and strand run-to-completion
# slots; uniform is the honest control where refill cannot win
MIXES = {
    "mixed": (32, 4, 16, 8),
    "uniform": (16, 16, 16, 16),
}


# prompt lengths (cycled per request): mixed so chunked prefill has real
# work — a 24-token prompt costs 23 teacher-forced wall-steps but one
# prefill call. Max prompt 24 + max output 32 - 1 = 55 < cache_slots 64.
PROMPT_LENS = (2, 24, 6, 1, 12, 18)

CACHE_SLOTS = 64       # dense per-slot KV allocation the paged run must beat
PAGE_SIZE = 8          # page granularity for the paged simulation


def workload(requests: int, mix: str):
    """Deterministic request list: varied prompt lengths (``PROMPT_LENS``
    cycled) and the mix's cycled max_tokens."""
    lens = MIXES[mix]
    out = []
    for i in range(requests):
        plen = PROMPT_LENS[i % len(PROMPT_LENS)]
        prompt = [(7 * i + 3 + j) % 97 for j in range(plen)]
        out.append((prompt, lens[i % len(lens)]))
    return out


def simulate(requests: int, mix: str, max_slots: int, step_ms: float,
             refill: bool, prefill_chunk: int = 64, page_size: int = 0):
    """Drive one gateway to completion over the whole (saturated) queue."""
    clock = FakeClock()
    engine = ToyDecodeEngine(on_step=lambda: clock.advance(step_ms / 1e3),
                             page_size=page_size)
    gw = DecodeGateway(engine, max_slots=max_slots, cache_slots=CACHE_SLOTS,
                       refill=refill, prefill_chunk=prefill_chunk,
                       clock=clock)
    futures, oracle = [], []
    for prompt, max_tokens in workload(requests, mix):
        futures.append(gw.submit(DecodeRequest(prompt=prompt,
                                               max_tokens=max_tokens)))
        oracle.append(engine.solo_tokens(prompt, max_tokens))
    while not all(f.done() for f in futures):
        gw.pump()
    matches = sum(f.result().tokens.tolist() == o
                  for f, o in zip(futures, oracle))
    waits = np.array([f.result().meta["wait_ms"] for f in futures])
    s = gw.stats()
    snap = gw.metrics.snapshot()
    return {
        "snapshot": snap,
        "p95_wait_ms_registry": float(snap["wait_ms"]["p95"]),
        "wait_hist_count": int(snap["wait_ms"]["count"]),
        "wall_steps": s["forwards"],
        "occupancy": s["slot_occupancy"],
        "p95_wait_ms": float(np.percentile(waits, 95)),
        "mean_wait_ms": float(waits.mean()),
        "tokens_out": s["tokens_out"],
        "tokens_per_s": s["tokens_per_s"],
        "prefill_calls": s["prefill_calls"],
        "prefill_tokens": s["prefill_tokens"],
        "peak_kv_per_slot": s.get("peak_kv_per_slot", 0.0),
        "joins": s["joins"],
        "matches": matches,
    }


def run(requests: int = 64, max_slots: int = 8, step_ms: float = 2.0,
        log=print, registry_out=None):
    rows = []
    for mix in MIXES:
        cont = simulate(requests, mix, max_slots, step_ms, refill=True)
        rtc = simulate(requests, mix, max_slots, step_ms, refill=False)
        # teacher-forced control: continuous refill, but prompts fed one
        # token per wall-step (the pre-chunked-prefill gateway)
        tf = simulate(requests, mix, max_slots, step_ms, refill=True,
                      prefill_chunk=0)
        # paged control: same chunked/continuous gateway over a page pool
        paged = simulate(requests, mix, max_slots, step_ms, refill=True,
                         page_size=PAGE_SIZE)
        if registry_out is not None:
            registry_out[mix] = cont["snapshot"]
        row = {
            "mix": mix,
            "requests": requests,
            "max_slots": max_slots,
            "step_ms": step_ms,
            "rtc_wall_steps": rtc["wall_steps"],
            "cont_wall_steps": cont["wall_steps"],
            "tf_wall_steps": tf["wall_steps"],
            "wall_step_ratio": rtc["wall_steps"]
            / max(cont["wall_steps"], 1),
            "prefill_ratio": tf["wall_steps"] / max(cont["wall_steps"], 1),
            "prefill_calls": cont["prefill_calls"],
            "prefill_tokens": cont["prefill_tokens"],
            "rtc_occupancy": rtc["occupancy"],
            "cont_occupancy": cont["occupancy"],
            "rtc_p95_wait_ms": rtc["p95_wait_ms"],
            "cont_p95_wait_ms": cont["p95_wait_ms"],
            "cont_tokens_per_s": cont["tokens_per_s"],
            "joins": cont["joins"],
            "tokens_out": cont["tokens_out"],
            "rtc_tokens_out": rtc["tokens_out"],
            "cont_matches": cont["matches"],
            "rtc_matches": rtc["matches"],
            "paged_matches": paged["matches"],
            "paged_wall_steps": paged["wall_steps"],
            "paged_peak_kv_per_slot": paged["peak_kv_per_slot"],
            "cache_slots": CACHE_SLOTS,
            "page_size": PAGE_SIZE,
            "cont_p95_wait_ms_registry": cont["p95_wait_ms_registry"],
            "wait_hist_count": cont["wait_hist_count"],
        }
        rows.append(row)
        log(f"{mix}: wall-steps {row['rtc_wall_steps']} (run-to-completion)"
            f" -> {row['cont_wall_steps']} (continuous, "
            f"{row['wall_step_ratio']:.2f}x fewer); teacher-forced prefill "
            f"{row['tf_wall_steps']} -> chunked {row['cont_wall_steps']} "
            f"({row['prefill_ratio']:.2f}x fewer, {row['prefill_calls']} "
            f"prefill calls / {row['prefill_tokens']} tokens); occupancy "
            f"{row['rtc_occupancy']:.2f} -> {row['cont_occupancy']:.2f}; "
            f"p95 wait {row['rtc_p95_wait_ms']:.0f}ms -> "
            f"{row['cont_p95_wait_ms']:.0f}ms; {row['joins']} joins; paged "
            f"peak KV/slot {row['paged_peak_kv_per_slot']:.1f} vs dense "
            f"{CACHE_SLOTS}")
    return rows


def check_claims(rows):
    notes = []
    for r in rows:
        n = r["requests"]
        ok = (r["cont_matches"] == n and r["rtc_matches"] == n
              and r["paged_matches"] == n)
        notes.append(f"[{'PASS' if ok else 'FAIL'}] {r['mix']}: every served "
                     f"sequence matches the solo-decode oracle "
                     f"({r['cont_matches']}/{n} continuous, "
                     f"{r['rtc_matches']}/{n} run-to-completion, "
                     f"{r['paged_matches']}/{n} paged)")
        if r["mix"] == "mixed":
            ok = r["wall_step_ratio"] > 1.0
            notes.append(f"[{'PASS' if ok else 'FAIL'}] continuous slot "
                         f"refill STRICTLY beats run-to-completion on total "
                         f"wall-steps at mixed output lengths "
                         f"(got {r['wall_step_ratio']:.2f}x)")
            ok = r["prefill_ratio"] > 1.0
            notes.append(f"[{'PASS' if ok else 'FAIL'}] chunked prefill "
                         f"STRICTLY reduces wall-steps vs teacher-forced "
                         f"prompt feeding at mixed prompt lengths "
                         f"(got {r['prefill_ratio']:.2f}x)")
            ok = r["paged_peak_kv_per_slot"] < r["cache_slots"]
            notes.append(f"[{'PASS' if ok else 'FAIL'}] paged KV peak "
                         f"resident memory per slot beats the dense "
                         f"allocation ({r['paged_peak_kv_per_slot']:.1f} < "
                         f"{r['cache_slots']} cache slots)")
            ok = r["joins"] > 0
            notes.append(f"[{'PASS' if ok else 'FAIL'}] mixed workload "
                         f"exercises mid-flight admission "
                         f"({r['joins']} joins)")
        else:
            ok = r["wall_step_ratio"] >= 1.0 - 1e-9
            notes.append(f"[{'PASS' if ok else 'FAIL'}] continuous is never "
                         f"worse at uniform lengths "
                         f"(got {r['wall_step_ratio']:.2f}x)")
    return notes


def metrics(rows):
    """Regression-gate metrics (benchmarks/regression.py schema). The
    simulation is deterministic, so the default 15% tolerance is slack."""
    out = {}
    for r in rows:
        out[f"{r['mix']}.wall_step_ratio"] = {
            "value": round(r["wall_step_ratio"], 4), "higher_better": True}
        out[f"{r['mix']}.prefill_ratio"] = {
            "value": round(r["prefill_ratio"], 4), "higher_better": True}
        out[f"{r['mix']}.cont_occupancy"] = {
            "value": round(r["cont_occupancy"], 4), "higher_better": True}
        out[f"{r['mix']}.wait_hist_count"] = {
            "value": r["wait_hist_count"], "higher_better": True}
    mixed = next(r for r in rows if r["mix"] == "mixed")
    out["mixed.joins"] = {"value": mixed["joins"], "higher_better": True}
    out["mixed.paged_kv_per_slot"] = {
        "value": round(mixed["paged_peak_kv_per_slot"], 4),
        "higher_better": False}
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--step-ms", type=float, default=2.0)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write the summary (rows + claims + metrics) here")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero when an acceptance claim FAILs")
    args = ap.parse_args()
    requests = 32 if args.quick else args.requests
    rows = run(requests=requests, max_slots=args.max_slots,
               step_ms=args.step_ms)
    notes = check_claims(rows)
    for n in notes:
        print(n)
    for r in rows:
        print(f"decode/{r['mix']},{r['cont_wall_steps']},"
              f"wall_step_ratio={r['wall_step_ratio']:.2f};"
              f"prefill_ratio={r['prefill_ratio']:.2f};"
              f"occupancy={r['cont_occupancy']:.2f};joins={r['joins']};"
              f"paged_kv_per_slot={r['paged_peak_kv_per_slot']:.1f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "decode", "rows": rows, "claims": notes,
                       "metrics": metrics(rows)}, f, indent=2)
        print(f"summary written to {args.json}")
    if args.check and any(n.startswith("[FAIL]") for n in notes):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
