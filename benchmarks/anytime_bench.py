"""Beyond-paper benchmark: Anytime-BNS (the paper's Sec. 6 open question —
can a single solver serve multiple NFE budgets?).

Compares one jointly-trained solver with non-monotone nested grid against
(i) dedicated per-NFE BNS solvers and (ii) the untrained generic baseline,
at budgets {4, 8, 16} on the FM-OT analytic teacher.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import schedulers, toy
from repro.core.anytime import evaluate_anytime
from repro.core.bns import BNSTrainConfig, generate_pairs
from repro.solvers import SolverSpec

BUDGETS = (4, 8, 16)


def run(iterations: int = 10_000, dedicated_iters: int = 3000, log=print):
    sched = schedulers.fm_ot()
    field = toy.mixture_field(sched, toy.two_moons_means(),
                              jnp.full((16,), 0.15), jnp.ones((16,)))
    train = generate_pairs(field, jax.random.PRNGKey(0), 256, (2,))
    val = generate_pairs(field, jax.random.PRNGKey(1), 256, (2,))

    cfg = BNSTrainConfig(iterations=iterations, lr=1.5e-3, val_every=500,
                         batch_size=64)
    res = SolverSpec("midpoint", mode="anytime", budgets=BUDGETS) \
        .distill(field, train, val, cfg)
    anytime_scores = evaluate_anytime(res.params, BUDGETS, field, val)

    rows = []
    for m in BUDGETS:
        ded = SolverSpec("midpoint", m, mode="bns").distill(
            field, train, val,
            BNSTrainConfig(iterations=dedicated_iters, lr=1e-3,
                           val_every=300, batch_size=64))
        bp = SolverSpec("midpoint", m).sampler(field).psnr(val)
        rows.append({"nfe": m, "anytime": anytime_scores[m],
                     "dedicated": ded.val_psnr, "midpoint": bp})
        log(f"anytime NFE={m}: shared={anytime_scores[m]:.2f} "
            f"dedicated={ded.val_psnr:.2f} midpoint={bp:.2f} "
            f"(shared solver: {res.num_parameters} params total)")
    return rows, res.num_parameters


def check_claims(rows):
    notes = []
    for r in rows:
        ok = r["anytime"] > r["midpoint"]
        notes.append(f"[{'PASS' if ok else 'FAIL'}] anytime NFE={r['nfe']}: "
                     f"shared solver beats the generic baseline")
    return notes


def serve_bench(iterations: int = 600, log=print):
    """Anytime SERVING: one zoo-cached artifact, every budget via its
    extracted m-step solver.

    Measures (a) the cold zoo ``get`` (distills once) against the warm one
    (memory hit — must perform zero distillation), and (b) per-budget
    sampling latency of the extracted solvers. Returns csv-ready rows.
    """
    import time

    from repro.serving import SolverZoo
    from repro.solvers import Sampler

    sched = schedulers.fm_ot()
    field = toy.mixture_field(sched, toy.two_moons_means(),
                              jnp.full((16,), 0.15), jnp.ones((16,)))
    train = generate_pairs(field, jax.random.PRNGKey(0), 128, (2,))
    val = generate_pairs(field, jax.random.PRNGKey(1), 128, (2,))
    spec = SolverSpec("midpoint", mode="anytime", budgets=BUDGETS)
    cfg = BNSTrainConfig(iterations=iterations, lr=1.5e-3, val_every=200,
                         batch_size=64)

    zoo = SolverZoo(capacity=4)
    t0 = time.time()
    art = zoo.get(spec, field=field, train_pairs=train, val_pairs=val,
                  train_cfg=cfg)
    cold_s = time.time() - t0
    t0 = time.time()
    assert zoo.get(spec) is art
    warm_s = time.time() - t0
    assert zoo.stats.distills == 1 and zoo.stats.hits == 1
    log(f"zoo: cold get (distill) {cold_s:.1f}s, warm get (hit) "
        f"{warm_s*1e6:.0f}us — a cache hit skips distillation entirely")

    rows = [{"name": "zoo_hit", "us": warm_s * 1e6,
             "derived": f"cold_s={cold_s:.1f};distills={zoo.stats.distills}"}]
    x0 = val[0]
    for m in BUDGETS:
        sampler = Sampler(art.ns_at_budget(m), field)
        sampler(x0)                      # compile
        t0 = time.time()
        reps = 20
        for _ in range(reps):
            sampler(x0).block_until_ready()
        us = (time.time() - t0) / reps * 1e6
        log(f"serve NFE={m}: {us:.0f}us per batch of {x0.shape[0]} "
            f"(extracted {m}-step solver)")
        rows.append({"name": f"nfe{m}", "us": us,
                     "derived": f"psnr={art.val_psnr:.2f}"})
    return rows


if __name__ == "__main__":
    rows, _ = run()
    for n in check_claims(rows):
        print(n)
    for r in serve_bench():
        print(f"anytime_serving/{r['name']},{r['us']:.1f},{r['derived']}")
