"""Beyond-paper benchmark: Anytime-BNS (the paper's Sec. 6 open question —
can a single solver serve multiple NFE budgets?).

Compares one jointly-trained solver with non-monotone nested grid against
(i) dedicated per-NFE BNS solvers and (ii) the untrained generic baseline,
at budgets {4, 8, 16} on the FM-OT analytic teacher.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import schedulers, toy
from repro.core.anytime import evaluate_anytime
from repro.core.bns import BNSTrainConfig, generate_pairs
from repro.solvers import SolverSpec

BUDGETS = (4, 8, 16)


def run(iterations: int = 10_000, dedicated_iters: int = 3000, log=print):
    sched = schedulers.fm_ot()
    field = toy.mixture_field(sched, toy.two_moons_means(),
                              jnp.full((16,), 0.15), jnp.ones((16,)))
    train = generate_pairs(field, jax.random.PRNGKey(0), 256, (2,))
    val = generate_pairs(field, jax.random.PRNGKey(1), 256, (2,))

    cfg = BNSTrainConfig(iterations=iterations, lr=1.5e-3, val_every=500,
                         batch_size=64)
    res = SolverSpec("midpoint", mode="anytime", budgets=BUDGETS) \
        .distill(field, train, val, cfg)
    anytime_scores = evaluate_anytime(res.params, BUDGETS, field, val)

    rows = []
    for m in BUDGETS:
        ded = SolverSpec("midpoint", m, mode="bns").distill(
            field, train, val,
            BNSTrainConfig(iterations=dedicated_iters, lr=1e-3,
                           val_every=300, batch_size=64))
        bp = SolverSpec("midpoint", m).sampler(field).psnr(val)
        rows.append({"nfe": m, "anytime": anytime_scores[m],
                     "dedicated": ded.val_psnr, "midpoint": bp})
        log(f"anytime NFE={m}: shared={anytime_scores[m]:.2f} "
            f"dedicated={ded.val_psnr:.2f} midpoint={bp:.2f} "
            f"(shared solver: {res.num_parameters} params total)")
    return rows, res.num_parameters


def check_claims(rows):
    notes = []
    for r in rows:
        ok = r["anytime"] > r["midpoint"]
        notes.append(f"[{'PASS' if ok else 'FAIL'}] anytime NFE={r['nfe']}: "
                     f"shared solver beats the generic baseline")
    return notes


if __name__ == "__main__":
    rows, _ = run()
    for n in check_claims(rows):
        print(n)
