"""Figure 6 / Sec 5.4 reproduction (proxy scale): SNR vs NFE for the
enc-dec audio backbone (whisper-medium smoke), conditioned on stub frame
embeddings — the paper's speech-infill setting with Encodec features swapped
for our latent sequences.

Expected: BNS SNR above every baseline at each NFE (paper: +1-3 dB over
runner-up across all 8 datasets).
"""
from __future__ import annotations

import jax

from repro.configs import get_config
from repro.core.bns import BNSTrainConfig
from repro.core.rk45 import rk45_solve
from repro.core.schedulers import fm_ot
from repro.data.synthetic import DataConfig, SyntheticTokens
from repro.launch.train import train
from repro.models import model as M
from repro.solvers import SolverSpec, solver_names

ARCH = "whisper-medium"
SEQ, BATCH = 16, 24
NFES = [8, 16]
BASELINES = solver_names(family="generic", baseline=True)  # euler, midpoint
# serving mix (continuous_bench multimodal scenario): audio clips arrive
# at VARIABLE lengths up to this workload's SEQ — infill requests trim
# the tail — so the live gateway sees near-shapes that only a tier
# ladder can batch together
REQUEST_LENGTHS = (SEQ - 6, SEQ - 3, SEQ)


def run(train_steps: int = 200, bns_iters: int = 300, log=print):
    cfg = get_config(ARCH, smoke=True)
    params, losses = train(ARCH, smoke=True, steps=train_steps, batch=8,
                           seq=SEQ, lr=1e-3, log=lambda *_: None)
    log(f"audio backbone CFM loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    data = SyntheticTokens(cfg, DataConfig(batch_size=BATCH, seq_len=SEQ,
                                           seed=7))
    cond = data.batch(0)
    field = M.velocity_field(params, cfg, fm_ot(), cond, cfg_scale=0.0)
    x0 = jax.random.normal(jax.random.PRNGKey(3), (BATCH, SEQ, cfg.latent_dim))
    x1 = jax.jit(lambda x: rk45_solve(field.fn, x, rtol=1e-5, atol=1e-5).x1)(x0)
    x0v = jax.random.normal(jax.random.PRNGKey(4), (BATCH, SEQ, cfg.latent_dim))
    x1v = jax.jit(lambda x: rk45_solve(field.fn, x, rtol=1e-5, atol=1e-5).x1)(x0v)

    rows = []
    for nfe in NFES:
        row = {"nfe": nfe}
        for name in BASELINES:
            # SNR(dB) wrt RK45 ground truth == PSNR with max_val = rms(signal)
            row[name] = SolverSpec(name, nfe).sampler(field).psnr((x0v, x1v))
        cfg_bns = BNSTrainConfig(lr=1e-3, lr_schedule="cosine",
                                 iterations=bns_iters, val_every=50,
                                 batch_size=BATCH)
        row["bns"] = SolverSpec("midpoint", nfe, mode="bns") \
            .distill(field, (x0, x1), (x0v, x1v), cfg_bns).val_psnr
        rows.append(row)
        log(f"audio NFE={nfe}: euler={row['euler']:.2f} "
            f"midpoint={row['midpoint']:.2f} BNS={row['bns']:.2f}")
    return rows


def check_paper_claims(rows):
    return [f"[{'PASS' if r['bns'] > max(r['euler'], r['midpoint']) else 'FAIL'}]"
            f" audio NFE={r['nfe']}: BNS above runner-up (Fig 6 pattern)"
            for r in rows]


if __name__ == "__main__":
    for n in check_paper_claims(run()):
        print(n)
