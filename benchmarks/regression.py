"""Benchmark perf-regression gating: compare a fresh run against committed
baselines and fail loudly on real regressions.

Schema (one JSON per bench, ``benchmarks/baselines/{name}_bench.json``):

    {"bench": "gateway",
     "metrics": {"uniform8.speedup": {"value": 6.1,
                                      "higher_better": true,
                                      "tolerance": 0.15}}}

Each bench module owns a ``metrics(rows)`` helper producing that mapping,
so the gate tracks whatever the bench considers its headline numbers.
``tolerance`` is the per-metric relative slack (default 15% — the ISSUE's
regression budget). Only metrics that are DETERMINISTIC functions of the
code (batch plans, simulated ratios, forward counts) should gate: absolute
wall-clock timings vary several-fold across runner hardware and load, so
they carry ``"gate": false`` — tracked and reported on every run (the
BENCH_* artifact trajectory) but never failing the job. A gated metric
present in the baseline but missing from the fresh run FAILs (a silently
dropped benchmark is a regression too); new fresh metrics not in the
baseline are reported but never fail — they start their trajectory on the
next baseline refresh.
"""
from __future__ import annotations

import json
import os

DEFAULT_TOLERANCE = 0.15


def compare(fresh: dict, baseline: dict,
            default_tolerance: float = DEFAULT_TOLERANCE) -> list[str]:
    """PASS/FAIL notes for every baseline metric vs the fresh run."""
    bench = baseline.get("bench", "?")
    notes = []
    fresh_metrics = fresh.get("metrics", {})
    for name in sorted(baseline.get("metrics", {})):
        spec = baseline["metrics"][name]
        gated = bool(spec.get("gate", True))
        got = fresh_metrics.get(name)
        if got is None:
            tag = "FAIL" if gated else "INFO"
            notes.append(f"[{tag}] {bench}/{name}: metric missing from the "
                         f"fresh run (baseline {spec['value']:.4g})")
            continue
        base_v, new_v = float(spec["value"]), float(got["value"])
        tol = float(spec.get("tolerance", default_tolerance))
        higher = bool(spec.get("higher_better", True))
        if base_v == 0.0:
            ok, rel = True, 0.0
        elif higher:
            rel = (base_v - new_v) / abs(base_v)
            ok = new_v >= base_v * (1.0 - tol)
        else:
            rel = (new_v - base_v) / abs(base_v)
            ok = new_v <= base_v * (1.0 + tol)
        arrow = "worse" if rel > 0 else "better"
        tag = ("PASS" if ok else "FAIL") if gated else "INFO"
        notes.append(f"[{tag}] {bench}/{name}: "
                     f"{new_v:.4g} vs baseline {base_v:.4g} "
                     f"({abs(rel) * 100:.1f}% {arrow}"
                     + (f", tol {tol * 100:.0f}%)" if gated
                        else ", report-only)"))
    for name in sorted(set(fresh_metrics) - set(baseline.get("metrics", {}))):
        notes.append(f"[NEW ] {bench}/{name}: {fresh_metrics[name]['value']:.4g} "
                     f"(not in baseline yet)")
    return notes


def check_against(summaries: dict[str, dict], baseline_dir: str,
                  log=print) -> bool:
    """Gate every fresh summary against ``{baseline_dir}/{name}_bench.json``.
    Returns True when nothing regressed. A bench with no committed baseline
    is reported and skipped (its fresh JSON seeds the baseline)."""
    ok = True
    for name, fresh in sorted(summaries.items()):
        path = os.path.join(baseline_dir, f"{name}_bench.json")
        if not os.path.exists(path):
            log(f"[SKIP] {name}: no baseline at {path} "
                f"(commit the fresh JSON to start the trajectory)")
            continue
        with open(path) as f:
            baseline = json.load(f)
        for note in compare(fresh, baseline):
            log(note)
            if note.startswith("[FAIL]"):
                ok = False
    return ok


def write_summaries(summaries: dict[str, dict], out_dir: str,
                    log=print) -> None:
    """Write one summary JSON per bench, plus ``registry_snapshots.json``
    collecting any ``"registry"`` payloads (raw MetricsRegistry snapshots
    the telemetry-enabled benches attach). The registry rides the CI
    artifact but is popped from the per-bench files so a fresh summary
    stays byte-shaped like a committed baseline."""
    os.makedirs(out_dir, exist_ok=True)
    registries = {}
    for name, summary in sorted(summaries.items()):
        summary = dict(summary)
        reg = summary.pop("registry", None)
        if reg:
            registries[name] = reg
        path = os.path.join(out_dir, f"{name}_bench.json")
        with open(path, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
        log(f"bench summary written to {path}")
    if registries:
        path = os.path.join(out_dir, "registry_snapshots.json")
        with open(path, "w") as f:
            json.dump(registries, f, indent=2, sort_keys=True)
        log(f"registry snapshots written to {path}")
