"""Benchmark harness (deliverable d): one module per paper table/figure.

  psnr_vs_nfe          — Figure 4 / Table 4 (+ Fig 11 BNS-vs-BST ablation)
  t2i_proxy            — Table 2 / Table 5 (CFG + preconditioning ablation)
  audio_proxy          — Figure 6 (enc-dec backbone SNR vs NFE)
  bns_vs_distillation  — Table 3 (forwards/params accounting vs PD)
  taxonomy_bench       — Figure 3 / Theorem 3.2 (exact NS conversions)
  kernel_bench         — Pallas kernels vs ref oracles
  gateway_bench        — serving gateway: batched vs unbatched throughput
  roofline             — §Roofline terms from the dry-run artifacts

Prints ``name,us_per_call,derived`` CSV lines; paper-claim PASS/FAIL notes go
to log lines prefixed with '#'.
"""
from __future__ import annotations

import sys
import time


def log(msg: str) -> None:
    print(f"# {msg}", flush=True)


def main() -> None:
    quick = "--quick" in sys.argv
    csv: list[tuple[str, float, str]] = []

    from benchmarks import taxonomy_bench
    t0 = time.time()
    for r in taxonomy_bench.run(log=log):
        csv.append((f"taxonomy/{r['solver']}", r["alg1_us_per_call"],
                    f"max_err={r['max_err']:.1e}"))
    log(f"taxonomy_bench done in {time.time()-t0:.0f}s")

    from benchmarks import bns_vs_distillation
    for r in bns_vs_distillation.run(log=log):
        csv.append((f"table3/{r['dataset']}/{r['method']}/nfe{r['nfe']}",
                    0.0, f"forwards={r['forwards']};match={r['match']}"))

    from benchmarks import kernel_bench
    for name, us, derived in kernel_bench.run(log=log):
        csv.append((name, us, derived))

    from benchmarks import psnr_vs_nfe
    t0 = time.time()
    rows = psnr_vs_nfe.run(iterations=300 if quick else 3000, log=log)
    for note in psnr_vs_nfe.check_paper_claims(rows):
        log(note)
    for r in rows:
        csv.append((f"fig4/{r['scheduler']}/nfe{r['nfe']}",
                    r["bns_train_s"] * 1e6,
                    f"bns={r['bns']:.2f};bst={r['bst']:.2f};"
                    f"midpoint={r['midpoint']:.2f};dpm2m={r['dpm2m']:.2f}"))
    log(f"psnr_vs_nfe done in {time.time()-t0:.0f}s")

    from benchmarks import t2i_proxy
    t0 = time.time()
    rows = t2i_proxy.run(train_steps=100 if quick else 250,
                         bns_iters=150 if quick else 400, log=log)
    for note in t2i_proxy.check_paper_claims(rows):
        log(note)
    for r in rows:
        csv.append((f"table2/w{r['w']}/nfe{r['nfe']}", 0.0,
                    f"bns={r['bns']:.2f};init={r['initial_solver']:.2f};"
                    f"euler={r['euler']:.2f}"))
    log(f"t2i_proxy done in {time.time()-t0:.0f}s")

    from benchmarks import audio_proxy
    t0 = time.time()
    rows = audio_proxy.run(train_steps=80 if quick else 200,
                           bns_iters=120 if quick else 300, log=log)
    for note in audio_proxy.check_paper_claims(rows):
        log(note)
    for r in rows:
        csv.append((f"fig6/audio/nfe{r['nfe']}", 0.0,
                    f"bns={r['bns']:.2f};midpoint={r['midpoint']:.2f}"))
    log(f"audio_proxy done in {time.time()-t0:.0f}s")

    from benchmarks import anytime_bench
    t0 = time.time()
    rows, nparams = anytime_bench.run(
        iterations=1500 if quick else 10_000,
        dedicated_iters=500 if quick else 3000, log=log)
    for note in anytime_bench.check_claims(rows):
        log(note)
    for r in rows:
        csv.append((f"anytime/nfe{r['nfe']}", 0.0,
                    f"shared={r['anytime']:.2f};dedicated={r['dedicated']:.2f};"
                    f"params={nparams}"))
    for r in anytime_bench.serve_bench(iterations=200 if quick else 600,
                                       log=log):
        csv.append((f"anytime_serving/{r['name']}", r["us"], r["derived"]))
    log(f"anytime_bench done in {time.time()-t0:.0f}s")

    from benchmarks import gateway_bench
    t0 = time.time()
    g_rows = gateway_bench.run(requests=32 if quick else 64, log=log)
    for note in gateway_bench.check_claims(g_rows):
        log(note)
    for r in g_rows:
        csv.append((f"gateway/{r['mix']}", r["gateway_ms_per_req"] * 1e3,
                    f"speedup={r['speedup']:.2f};"
                    f"occupancy={r['occupancy']:.2f};"
                    f"nfe_per_request={r['nfe_per_request']:.2f}"))
    log(f"gateway_bench done in {time.time()-t0:.0f}s")

    try:
        import os

        from benchmarks import roofline
        recs = roofline.load_all()
        os.makedirs("experiments", exist_ok=True)
        with open("experiments/roofline.md", "w") as f:
            f.write("# Roofline terms (single pod, 16x16 = 256 chips)\n\n")
            f.write(roofline.table(recs, "pod16x16"))
            f.write("\n\n# Multi-pod (2x16x16 = 512 chips)\n\n")
            f.write(roofline.table(recs, "pod2x16x16"))
            f.write("\n")
        for r in recs:
            if r.get("status") == "ok" and r.get("mesh") == "pod16x16":
                dom = {"compute": r["t_compute"], "memory": r["t_memory"],
                       "collective": r["t_collective"]}[r["dominant"]]
                csv.append((f"roofline/{r['arch']}/{r['shape']}", dom * 1e6,
                            f"dominant={r['dominant']};"
                            f"useful={r['useful_ratio']:.2f}"))
        log("roofline table written to experiments/roofline.md")
    except Exception as e:  # dry-run artifacts may not exist yet
        log(f"roofline skipped: {e}")

    print("name,us_per_call,derived")
    for name, us, derived in csv:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
