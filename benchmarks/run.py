"""Benchmark harness (deliverable d): one module per paper table/figure.

  psnr_vs_nfe          — Figure 4 / Table 4 (+ Fig 11 BNS-vs-BST ablation)
  t2i_proxy            — Table 2 / Table 5 (CFG + preconditioning ablation)
  audio_proxy          — Figure 6 (enc-dec backbone SNR vs NFE)
  bns_vs_distillation  — Table 3 (forwards/params accounting vs PD)
  taxonomy_bench       — Figure 3 / Theorem 3.2 (exact NS conversions)
  kernel_bench         — Pallas kernels vs ref oracles
  gateway_bench        — serving gateway: batched vs unbatched throughput
  continuous_bench     — continuous batching vs flush-only (p95 wait, NFE)
  decode_bench         — decode gateway: continuous slot refill vs
                         run-to-completion batching (wall-steps)
  fleet_bench          — fleet federation: work stealing vs static
                         affinity routing (p95 wait, parallel hosts)
  overload_bench       — SLO scheduling vs FIFO under sustained overload
                         (goodput, deadline-hit-rate, forwards)
  roofline             — §Roofline terms from the dry-run artifacts

Prints ``name,us_per_call,derived`` CSV lines; paper-claim PASS/FAIL notes go
to log lines prefixed with '#'.

Regression gating (CI bench-regression job):

  python benchmarks/run.py --quick \\
      --only gateway,kernel,continuous,decode,fleet,overload \\
      --json-dir bench-fresh --check-against benchmarks/baselines

runs just the gated benches, writes their fresh summary JSONs, and exits
non-zero when any baseline metric regressed beyond its tolerance (see
``benchmarks/regression.py``). The fresh JSONs are uploaded as a CI
artifact — commit them to ``benchmarks/baselines/`` to advance the
baseline trajectory.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# `python benchmarks/run.py` puts benchmarks/ itself on sys.path, not the
# repo root — the `from benchmarks import ...` section imports need the
# root (and the src tree saves callers exporting PYTHONPATH by hand)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def log(msg: str) -> None:
    print(f"# {msg}", flush=True)


def _timed(name):
    def wrap(fn):
        def inner(quick, csv, summaries):
            t0 = time.time()
            fn(quick, csv, summaries)
            log(f"{name} done in {time.time()-t0:.0f}s")
        return inner
    return wrap


@_timed("taxonomy_bench")
def _taxonomy(quick, csv, summaries):
    from benchmarks import taxonomy_bench
    for r in taxonomy_bench.run(log=log):
        csv.append((f"taxonomy/{r['solver']}", r["alg1_us_per_call"],
                    f"max_err={r['max_err']:.1e}"))


@_timed("bns_vs_distillation")
def _table3(quick, csv, summaries):
    from benchmarks import bns_vs_distillation
    for r in bns_vs_distillation.run(log=log):
        csv.append((f"table3/{r['dataset']}/{r['method']}/nfe{r['nfe']}",
                    0.0, f"forwards={r['forwards']};match={r['match']}"))


@_timed("kernel_bench")
def _kernel(quick, csv, summaries):
    from benchmarks import kernel_bench
    rows = kernel_bench.run(log=log)
    csv.extend(rows)
    summaries["kernel"] = {"bench": "kernel",
                           "rows": [{"name": n, "us": us, "derived": d}
                                    for n, us, d in rows],
                           "metrics": kernel_bench.metrics(rows)}


@_timed("psnr_vs_nfe")
def _fig4(quick, csv, summaries):
    from benchmarks import psnr_vs_nfe
    rows = psnr_vs_nfe.run(iterations=300 if quick else 3000, log=log)
    for note in psnr_vs_nfe.check_paper_claims(rows):
        log(note)
    for r in rows:
        csv.append((f"fig4/{r['scheduler']}/nfe{r['nfe']}",
                    r["bns_train_s"] * 1e6,
                    f"bns={r['bns']:.2f};bst={r['bst']:.2f};"
                    f"midpoint={r['midpoint']:.2f};dpm2m={r['dpm2m']:.2f}"))


@_timed("t2i_proxy")
def _t2i(quick, csv, summaries):
    from benchmarks import t2i_proxy
    rows = t2i_proxy.run(train_steps=100 if quick else 250,
                         bns_iters=150 if quick else 400, log=log)
    for note in t2i_proxy.check_paper_claims(rows):
        log(note)
    for r in rows:
        csv.append((f"table2/w{r['w']}/nfe{r['nfe']}", 0.0,
                    f"bns={r['bns']:.2f};init={r['initial_solver']:.2f};"
                    f"euler={r['euler']:.2f}"))


@_timed("audio_proxy")
def _audio(quick, csv, summaries):
    from benchmarks import audio_proxy
    rows = audio_proxy.run(train_steps=80 if quick else 200,
                           bns_iters=120 if quick else 300, log=log)
    for note in audio_proxy.check_paper_claims(rows):
        log(note)
    for r in rows:
        csv.append((f"fig6/audio/nfe{r['nfe']}", 0.0,
                    f"bns={r['bns']:.2f};midpoint={r['midpoint']:.2f}"))


@_timed("anytime_bench")
def _anytime(quick, csv, summaries):
    from benchmarks import anytime_bench
    rows, nparams = anytime_bench.run(
        iterations=1500 if quick else 10_000,
        dedicated_iters=500 if quick else 3000, log=log)
    for note in anytime_bench.check_claims(rows):
        log(note)
    for r in rows:
        csv.append((f"anytime/nfe{r['nfe']}", 0.0,
                    f"shared={r['anytime']:.2f};dedicated={r['dedicated']:.2f};"
                    f"params={nparams}"))
    for r in anytime_bench.serve_bench(iterations=200 if quick else 600,
                                       log=log):
        csv.append((f"anytime_serving/{r['name']}", r["us"], r["derived"]))


@_timed("gateway_bench")
def _gateway(quick, csv, summaries):
    from benchmarks import gateway_bench
    rows = gateway_bench.run(requests=32 if quick else 64, log=log)
    notes = gateway_bench.check_claims(rows)
    for note in notes:
        log(note)
    for r in rows:
        csv.append((f"gateway/{r['mix']}", r["gateway_ms_per_req"] * 1e3,
                    f"speedup={r['speedup']:.2f};"
                    f"occupancy={r['occupancy']:.2f};"
                    f"nfe_per_request={r['nfe_per_request']:.2f}"))
    summaries["gateway"] = {"bench": "gateway", "rows": rows,
                            "claims": notes,
                            "metrics": gateway_bench.metrics(rows)}


@_timed("decode_bench")
def _decode(quick, csv, summaries):
    from benchmarks import decode_bench
    registry: dict = {}
    rows = decode_bench.run(requests=32 if quick else 64, log=log,
                            registry_out=registry)
    notes = decode_bench.check_claims(rows)
    for note in notes:
        log(note)
    for r in rows:
        csv.append((f"decode/{r['mix']}", float(r["cont_wall_steps"]),
                    f"wall_step_ratio={r['wall_step_ratio']:.2f};"
                    f"occupancy={r['cont_occupancy']:.2f};"
                    f"joins={r['joins']}"))
    summaries["decode"] = {"bench": "decode", "rows": rows,
                           "claims": notes,
                           "metrics": decode_bench.metrics(rows),
                           "registry": registry}


@_timed("continuous_bench")
def _continuous(quick, csv, summaries):
    from benchmarks import continuous_bench
    registry: dict = {}
    rows = continuous_bench.run(requests=48 if quick else 96, log=log,
                                registry_out=registry)
    notes = continuous_bench.check_claims(rows)
    for note in notes:
        log(note)
    for r in rows:
        csv.append((f"continuous/{r['mix']}", r["cont_p95_wait_ms"] * 1e3,
                    f"p95_ratio={r['p95_ratio']:.2f};"
                    f"forwards_ratio={r['forwards_ratio']:.3f};"
                    f"join_rate={r['join_rate']:.2f}"))
    summaries["continuous"] = {"bench": "continuous", "rows": rows,
                               "claims": notes,
                               "metrics": continuous_bench.metrics(rows),
                               "registry": registry}


@_timed("fleet_bench")
def _fleet(quick, csv, summaries):
    from benchmarks import fleet_bench
    registry: dict = {}
    rows = fleet_bench.run(requests=48 if quick else 96, log=log,
                           registry_out=registry)
    notes = fleet_bench.check_claims(rows)
    for note in notes:
        log(note)
    for r in rows:
        csv.append((f"fleet/{r['mix']}", r["steal_p95_wait_ms"] * 1e3,
                    f"p95_ratio={r['p95_ratio']:.2f};"
                    f"forwards_ratio={r['forwards_ratio']:.3f};"
                    f"steal_share={r['steal_share']:.2f}"))
    summaries["fleet"] = {"bench": "fleet", "rows": rows,
                          "claims": notes,
                          "metrics": fleet_bench.metrics(rows),
                          "registry": registry}


@_timed("overload_bench")
def _overload(quick, csv, summaries):
    from benchmarks import overload_bench
    registry: dict = {}
    rows = overload_bench.run(requests=10800 if quick else 14400, log=log,
                              registry_out=registry)
    notes = overload_bench.check_claims(rows)
    for note in notes:
        log(note)
    for r in rows:
        if r["scenario"] == "preempt":
            csv.append(("overload/preempt", float(r["slo_goodput"]),
                        f"preemptions={r['slo_preemptions']};"
                        f"hit_rate={r['slo_hit_rate']:.3f}"))
            continue
        csv.append((f"overload/{r['scenario']}", float(r["slo_goodput"]),
                    f"goodput_ratio={r['goodput_ratio']:.2f};"
                    f"hit_rate={r['slo_hit_rate']:.3f};"
                    f"forwards_ratio={r['forwards_ratio']:.3f}"))
    summaries["overload"] = {"bench": "overload", "rows": rows,
                             "claims": notes,
                             "metrics": overload_bench.metrics(rows),
                             "registry": registry}


def _roofline(quick, csv, summaries):
    try:
        import os

        from benchmarks import roofline
        recs = roofline.load_all()
        os.makedirs("experiments", exist_ok=True)
        with open("experiments/roofline.md", "w") as f:
            f.write("# Roofline terms (single pod, 16x16 = 256 chips)\n\n")
            f.write(roofline.table(recs, "pod16x16"))
            f.write("\n\n# Multi-pod (2x16x16 = 512 chips)\n\n")
            f.write(roofline.table(recs, "pod2x16x16"))
            f.write("\n")
        for r in recs:
            if r.get("status") == "ok" and r.get("mesh") == "pod16x16":
                dom = {"compute": r["t_compute"], "memory": r["t_memory"],
                       "collective": r["t_collective"]}[r["dominant"]]
                csv.append((f"roofline/{r['arch']}/{r['shape']}", dom * 1e6,
                            f"dominant={r['dominant']};"
                            f"useful={r['useful_ratio']:.2f}"))
        log("roofline table written to experiments/roofline.md")
    except Exception as e:  # dry-run artifacts may not exist yet
        log(f"roofline skipped: {e}")


SECTIONS = {
    "taxonomy": _taxonomy,
    "table3": _table3,
    "kernel": _kernel,
    "fig4": _fig4,
    "t2i": _t2i,
    "audio": _audio,
    "anytime": _anytime,
    "gateway": _gateway,
    "continuous": _continuous,
    "decode": _decode,
    "fleet": _fleet,
    "overload": _overload,
    "roofline": _roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated section names "
                         f"({','.join(SECTIONS)}); default: all")
    ap.add_argument("--json-dir", default=None,
                    help="write each gated bench's summary JSON here")
    ap.add_argument("--check-against", default=None,
                    help="baselines directory; exit non-zero on any metric "
                         "regressing beyond its tolerance")
    args = ap.parse_args()
    names = list(SECTIONS)
    if args.only:
        names = [n.strip() for n in args.only.split(",") if n.strip()]
        unknown = [n for n in names if n not in SECTIONS]
        if unknown:
            raise SystemExit(f"unknown sections {unknown}; "
                             f"choose from {list(SECTIONS)}")
    csv: list[tuple[str, float, str]] = []
    summaries: dict[str, dict] = {}
    for name in names:
        SECTIONS[name](args.quick, csv, summaries)

    print("name,us_per_call,derived")
    for name, us, derived in csv:
        print(f"{name},{us:.1f},{derived}")

    if args.json_dir or args.check_against:
        from benchmarks import regression
        if args.json_dir:
            regression.write_summaries(summaries, args.json_dir, log=log)
        if args.check_against:
            if not regression.check_against(summaries, args.check_against,
                                            log=log):
                raise SystemExit(1)


if __name__ == "__main__":
    main()
