"""repro.serving — TWO engines behind one gateway front-end.

The serving stack batches both of the repo's engines through the same
queue/batcher machinery (``GatewayBase``: intake, serve thread, drain,
stats):

* FLOW — ``FlowSampler`` / ``AnytimeFlowSampler`` (the paper's product:
  m-forward BNS sampling, budget-routed multi-NFE serving from one
  artifact), fronted by ``Gateway`` (budget-coalescing padded flush
  batches) and ``ContinuousGateway`` (requests join in-flight anytime
  trajectories at exit boundaries).
* DECODE — ``DecodeEngine`` (autoregressive decode with KV-cache /
  recurrent state, jit'd multi-token ``greedy`` plus the slot-masked
  ``step_slots`` API), fronted by ``DecodeGateway`` (continuous batching
  over per-sequence state slots: finished sequences free their row, queued
  sequences are admitted at the next engine step, per-slot stop
  conditions).

Module map:

``engine``  — ``FlowSampler``, ``AnytimeFlowSampler``, ``DecodeEngine``;
``zoo``     — ``SolverZoo``, the LRU SolverSpec -> SolverArtifact cache with
              directory scan, lazy distill-on-miss, preload and spill;
``gateway`` — ``GatewayBase``/``Gateway``/``BatchScheduler``: async request
              queue, budget-coalescing padded batches, mixed-budget shared-
              trajectory dispatch, shared serving metrics;
``continuous`` — ``ContinuousGateway``/``ContinuousScheduler``, flow-side
              continuous batching at anytime exit boundaries;
``decode``  — ``DecodeGateway``/``DecodeRequest``/``DecodeResponse``,
              decode-side continuous batching over fixed state slots;
``sharded`` — mesh placement for gateway batches (params via
              ``distributed.sharding``, batches split along the data axes);
``toy``     — protocol-complete toy sampler/engine for benchmarks + tests.
"""
from repro.serving.continuous import ContinuousGateway, ContinuousScheduler
from repro.serving.decode import DecodeGateway, DecodeRequest, DecodeResponse
from repro.serving.engine import (
    AnytimeFlowSampler,
    DecodeEngine,
    FlowSampler,
    greedy_demo,
    nearest_budget,
    nearest_latent_tokens,
)
from repro.serving.gateway import (
    BatchScheduler,
    Gateway,
    GatewayBase,
    GatewayStats,
    Request,
    RequestQueue,
    Response,
)
from repro.serving.zoo import SolverZoo, ZooStats

__all__ = ["AnytimeFlowSampler", "BatchScheduler", "ContinuousGateway",
           "ContinuousScheduler", "DecodeEngine", "DecodeGateway",
           "DecodeRequest", "DecodeResponse", "FlowSampler", "Gateway",
           "GatewayBase", "GatewayStats", "Request", "RequestQueue",
           "Response", "SolverZoo", "ZooStats", "greedy_demo",
           "nearest_budget", "nearest_latent_tokens"]
