"""repro.serving — TWO engines behind one gateway front-end, scaled out
by a fleet tier.

The serving stack batches both of the repo's engines through the same
queue/batcher machinery (``GatewayBase``: intake, serve thread, drain,
stats):

* FLOW — ``FlowSampler`` / ``AnytimeFlowSampler`` (the paper's product:
  m-forward BNS sampling, budget-routed multi-NFE serving from one
  artifact), fronted by ``Gateway`` (budget-coalescing padded flush
  batches) and ``ContinuousGateway`` (requests join in-flight anytime
  trajectories at exit boundaries).
* DECODE — ``DecodeEngine`` (autoregressive decode with KV-cache /
  recurrent state, jit'd multi-token ``greedy`` plus the slot-masked
  ``step_slots``/``prefill_slots`` API; ``page_size > 0`` swaps the dense
  per-slot cache for a shared paged pool + block tables, optionally through
  the Pallas paged-attention kernel; per-request ``SamplingParams`` add
  temperature/top-k/top-p beside greedy), fronted by ``DecodeGateway``
  (continuous batching over per-sequence state slots: finished sequences
  free their row AND their KV pages, queued sequences are admitted at the
  next engine step with chunked batched prefill, per-slot stop conditions,
  cancelled futures released at the next pump).

Five layers, bottom up — each consumes the one below and widens the
concurrency it can absorb:

1. **sampler** (``engine``) — one jit'd dispatch: a padded batch in,
   samples/tokens out, exactly m backbone forwards per BNS batch;
2. **gateway** (``gateway``) — one process: async intake queue, budget/
   shape coalescing into padded flush batches, mixed-budget shared-
   trajectory dispatch;
3. **continuous** (``continuous``) — one device's idle gaps: queued flow
   requests join IN-FLIGHT anytime trajectories at exit boundaries
   instead of waiting for the next flush;
4. **decode** (``decode``) — one engine's state slots: token-level
   continuous batching for the autoregressive engine, admit/retire per
   step;
5. **fleet** (``fleet``) — many hosts: ``FleetGateway`` federates per-host
   gateways behind one submit — the fleet-wide queue is SHARDED across
   the per-host queues, ``FleetRouter`` homes requests by budget/shape
   affinity (HRW hashing keeps assignments deterministic and jit caches
   hot), ``WorkStealer`` migrates queued work off overloaded shards, and
   hosts join/leave gracefully (bounded drain, no dropped futures).
   Routing never changes a sample: rows are independent and the fleet
   shares one uid namespace + base key, so every sample stays
   bit-identical to the single-gateway path.

Cutting across all five layers sits the **SLO plane** (``slo`` +
``stream``): attaching an ``SLOConfig`` to any gateway adds per-request
deadlines/priorities (``deadline_ms``/``priority`` on both request
types), fast-reject admission control (``AdmissionRejected``, modeled
from the registry's own dispatch-time histograms), queue shedding
(``DeadlineExceeded``), urgency-ordered planning, and — continuous tier
only — preemption of strictly-lower-priority slots at anytime exit
boundaries (the victim resumes from its saved carry, bit-identical).
``submit_stream`` yields per-exit-boundary partials (flow) or per-token
chunks (decode) and terminates with the exact settled response. With
``slo=None`` (default) every planner degenerates to the legacy FIFO
behavior byte-for-byte. See ``docs/ARCHITECTURE.md`` for the full
walkthrough and ``benchmarks/overload_bench.py`` for the
goodput-under-overload gate.

Cutting across all five layers sits the **observability** plane
(``repro.observability``): every tier emits into ONE ``MetricsRegistry``
schema owned by ``GatewayBase`` (each ``stats()`` dict is a projection
over a registry snapshot, and ``FleetGateway.stats()`` is the same
projection over the bucket-exact MERGE of the per-host registries), an
optional ``TraceRecorder`` captures per-request lifecycle spans
(submit -> route -> steal -> dispatch -> settle, JSONL-exportable, hop-
by-hop reconstructable for stolen requests), and ``serve.py`` exports
everything over ``--metrics-port`` (Prometheus + JSON) and
``--stats-interval`` (one shared line formatter for all modes).

Metric schema (name — type — labels — emitting tiers):

======================= ========= ============ =========================
``submitted``           counter   —            all gateways
``completed``           counter   —            all gateways
``failed``              counter   —            all gateways
``batches``             counter   —            gateway, decode
``mixed_batches``       counter   —            gateway
``forwards``            counter   —            gateway, continuous,
                                               decode
``real_rows``           counter   —            gateway, decode
``padded_rows``         counter   —            gateway, decode
``trajectories``        counter   —            continuous, decode
``legs``                counter   —            continuous
``joins``               counter   —            continuous, decode
``join_forwards``       counter   —            continuous
``slot_steps_active``   counter   —            continuous, decode
``slot_steps_total``    counter   —            continuous, decode
``tokens_out``          counter   —            decode
``cancelled``           counter   —            decode
``prefill_calls``       counter   —            decode
``prefill_tokens``      counter   —            decode
``stolen_in``           counter   —            any federated gateway
``stolen_out``          counter   —            any federated gateway
``rejected``            counter   —            all gateways (SLO)
``preemptions``         counter   —            continuous (SLO)
``deadline_misses``     counter   —            all gateways (SLO)
``goodput``             counter   —            all gateways (SLO)
``steals``              counter   —            fleet (stealer)
``steal_rounds``        counter   —            fleet (stealer)
``rerouted``            counter   —            fleet (host leave)
``dispatches``          counter   ``program``  all dispatching tiers
``zoo_hits`` etc.       counter   —            zoo (hits/loads/distills/
                                               misses/evictions/spills)
``queue_depth``         gauge     —            all gateways (lazy)
``inflight``            gauge     —            all gateways (lazy)
``jit_programs``        gauge     —            all dispatching tiers
``pages_in_use``        gauge     —            decode (``PageAllocator``)
``peak_pages``          gauge     —            decode (``PageAllocator``)
``page_pool_total``     gauge     —            decode (``PageAllocator``)
``wait_ms``             histogram —            all gateways (submit ->
                                               settle; count ==
                                               completed)
``host_assembly_ms``    histogram —            gateway
``device_dispatch_ms``  histogram —            gateway, continuous,
                                               decode
======================= ========= ============ =========================

Module map:

``engine``  — ``FlowSampler``, ``AnytimeFlowSampler``, ``DecodeEngine``
              (paged KV via ``page_size``/``paged_kernel``), plus
              ``SamplingParams``/``sample_tokens`` (temperature / top-k /
              top-p, Gumbel-max over sorted-logit cutoffs);
``zoo``     — ``SolverZoo``, the LRU SolverSpec -> SolverArtifact cache with
              directory scan, lazy distill-on-miss, preload and spill;
``gateway`` — ``GatewayBase``/``Gateway``/``BatchScheduler``: async request
              queue, budget-coalescing padded batches, mixed-budget shared-
              trajectory dispatch, shared serving metrics, fleet federation
              hooks (``federate``/``load``/``steal``/``inject``, bounded
              ``drain(timeout=)`` raising ``DrainTimeout``);
``continuous`` — ``ContinuousGateway``/``ContinuousScheduler``, flow-side
              continuous batching at anytime exit boundaries;
``decode``  — ``DecodeGateway``/``DecodeRequest``/``DecodeResponse`` and
              ``PageAllocator``: decode-side continuous batching over fixed
              state slots — chunked batched prefill, paged-KV page
              accounting (reserve at admission, free on finish, head-of-
              line blocking), per-request sampling routing;
``fleet``   — ``FleetGateway``/``FleetRouter``/``WorkStealer``: multi-host
              federation, sharded request queue, affinity routing, work
              stealing, graceful host join/leave (emulated-host CI via
              ``repro.distributed.emulate``);
``slo``     — ``SLOConfig``/``AdmissionRejected``/``DeadlineExceeded``/
              ``urgency_key``/``PausedCarry``: the pure SLO policy layer
              (deadlines, priorities, admission, shedding, preemption);
``stream``  — ``StreamSink``/``ResponseStream``/``StreamChunk``:
              incremental results riding the existing settle path;
``sharded`` — mesh placement for gateway batches (params via
              ``distributed.sharding``, batches split along the data axes);
``tiers``   — shape-tier ladder: pad near-shapes to configured rungs at
              submit so one slot pool serves heterogeneous multi-modal
              traffic, crop back to the native shape at settle;
``toy``     — protocol-complete toy sampler/engine for benchmarks + tests.
"""
from repro.serving.continuous import ContinuousGateway, ContinuousScheduler
from repro.serving.decode import (
    DecodeGateway,
    DecodeRequest,
    DecodeResponse,
    PageAllocator,
)
from repro.serving.engine import (
    AnytimeFlowSampler,
    DecodeEngine,
    FlowSampler,
    SamplingParams,
    greedy_demo,
    nearest_budget,
    nearest_latent_tokens,
    sample_tokens,
)
from repro.serving.fleet import FleetGateway, FleetRouter, WorkStealer
from repro.serving.gateway import (
    BatchScheduler,
    DrainTimeout,
    Gateway,
    GatewayBase,
    GatewayStats,
    HostLoad,
    Request,
    RequestQueue,
    Response,
)
from repro.serving.slo import (
    AdmissionRejected,
    DeadlineExceeded,
    PausedCarry,
    SLOConfig,
    urgency_key,
)
from repro.serving.stream import ResponseStream, StreamChunk, StreamSink
from repro.serving.tiers import ShapeLadder, TierOversize
from repro.serving.zoo import SolverZoo, ZooStats

__all__ = ["AdmissionRejected", "AnytimeFlowSampler", "BatchScheduler",
           "ContinuousGateway", "ContinuousScheduler", "DeadlineExceeded",
           "DecodeEngine", "DecodeGateway", "DecodeRequest",
           "DecodeResponse", "DrainTimeout", "FleetGateway", "FleetRouter",
           "FlowSampler", "Gateway", "GatewayBase", "GatewayStats",
           "HostLoad", "PageAllocator", "PausedCarry", "Request",
           "RequestQueue", "Response", "ResponseStream", "SLOConfig",
           "SamplingParams", "ShapeLadder", "SolverZoo", "StreamChunk",
           "StreamSink", "TierOversize", "WorkStealer", "ZooStats",
           "greedy_demo", "nearest_budget", "nearest_latent_tokens",
           "sample_tokens", "urgency_key"]
