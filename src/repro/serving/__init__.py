"""repro.serving — serving engines, the solver-zoo cache, and the gateway.

``engine``  — ``FlowSampler`` (one budget), ``AnytimeFlowSampler`` (budget-
              routed multi-NFE serving from one artifact), ``DecodeEngine``;
``zoo``     — ``SolverZoo``, the LRU SolverSpec -> SolverArtifact cache with
              directory scan, lazy distill-on-miss, preload and spill;
``gateway`` — ``Gateway``/``BatchScheduler``, the multi-user front-end:
              async request queue, budget-coalescing padded batches, mixed-
              budget shared-trajectory dispatch, serving metrics;
``sharded`` — mesh placement for gateway batches (params via
              ``distributed.sharding``, batches split along the data axes);
``continuous`` — ``ContinuousGateway``/``ContinuousScheduler``, continuous
              batching: requests join in-flight anytime trajectories at
              exit boundaries instead of waiting for the next flush.
"""
from repro.serving.continuous import ContinuousGateway, ContinuousScheduler
from repro.serving.engine import (
    AnytimeFlowSampler,
    DecodeEngine,
    FlowSampler,
    nearest_budget,
    nearest_latent_tokens,
)
from repro.serving.gateway import (
    BatchScheduler,
    Gateway,
    GatewayStats,
    Request,
    RequestQueue,
    Response,
)
from repro.serving.zoo import SolverZoo, ZooStats

__all__ = ["AnytimeFlowSampler", "BatchScheduler", "ContinuousGateway",
           "ContinuousScheduler", "DecodeEngine", "FlowSampler", "Gateway",
           "GatewayStats", "Request", "RequestQueue", "Response",
           "SolverZoo", "ZooStats", "nearest_budget",
           "nearest_latent_tokens"]
