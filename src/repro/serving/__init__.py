"""repro.serving — serving engines and the solver-zoo cache.

``engine``  — ``FlowSampler`` (one budget), ``AnytimeFlowSampler`` (budget-
              routed multi-NFE serving from one artifact), ``DecodeEngine``;
``zoo``     — ``SolverZoo``, the LRU SolverSpec -> SolverArtifact cache with
              directory scan and lazy distill-on-miss.
"""
from repro.serving.engine import (
    AnytimeFlowSampler,
    DecodeEngine,
    FlowSampler,
    nearest_latent_tokens,
)
from repro.serving.zoo import SolverZoo, ZooStats

__all__ = ["AnytimeFlowSampler", "DecodeEngine", "FlowSampler", "SolverZoo",
           "ZooStats", "nearest_latent_tokens"]
