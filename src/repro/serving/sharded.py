"""Sharded serving execution: gateway batches on the production mesh.

Params are placed once via ``distributed.sharding.param_specs`` (Megatron +
FSDP rules — the same table training uses), and each gateway batch is split
along the composed data axes before dispatch, so the samplers' existing jit
programs lower to GSPMD collectives with no sampler code changes. When the
padded bucket does not divide the data-axis size the batch is replicated
instead (correct, just not data-parallel) — bucket sizes are powers of two,
so sizing ``max_batch`` to the data axis keeps every bucket divisible.

No mesh -> nothing here runs and serving stays single-device jit (the
``Gateway(mesh=None)`` default).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _data_axes(mesh):
    from repro.launch.mesh import batch_axes

    axes = batch_axes(mesh)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return (axes if len(axes) > 1 else axes[0]), size


def shard_params(params, cfg, mesh):
    """Place a backbone param pytree on ``mesh`` per the serving/training
    sharding rules; returns the (now sharded) pytree."""
    from repro.distributed.sharding import param_specs

    specs = param_specs(params, cfg, mesh)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(params, shardings)


def shard_sampler(sampler, mesh):
    """Re-place a ``FlowSampler``/``AnytimeFlowSampler``'s params on the
    mesh, in place. Its jit'd programs recompile (once per budget/bucket)
    against the sharded layout on next call."""
    sampler.params = shard_params(sampler.params, sampler.cfg, mesh)
    return sampler


def place_decode_state(state, cfg, mesh):
    """Place a slot-batched decode state (dense ``KVCache``, ``PagedKVCache``,
    or recurrent state) on ``mesh`` per ``distributed.sharding.state_specs``
    — paged pools shard their KV heads on ``model`` while the block table
    and per-row positions stay replicated. The engine's write-masked step
    programs recompile once against the sharded layout."""
    from repro.distributed.sharding import state_specs

    batch = int(state.index.shape[0]) if state.index.ndim else 1
    specs = state_specs(state, cfg, mesh, batch)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(state, shardings)


def batch_placer(mesh):
    """A ``place(cond, x0) -> (cond, x0)`` callable sharding batch arrays
    along the data axes (leading dim), replicating when indivisible."""
    axes, size = _data_axes(mesh)

    def place_one(x):
        spec_b = axes if x.shape[0] % size == 0 else None
        sharding = NamedSharding(mesh, P(spec_b, *(None,) * (x.ndim - 1)))
        return jax.device_put(x, sharding)

    def place(cond, x0):
        x0 = place_one(x0)
        if cond is not None:
            cond = {k: place_one(v) if hasattr(v, "ndim") and v.ndim else v
                    for k, v in cond.items()}
        return cond, x0

    return place


def tier_placer(mesh, ladder):
    """``batch_placer`` specialised to a ``ShapeLadder``: a tier ladder
    makes the set of dispatch shapes finite and known up front — every
    batch is (bucket, rung, *tail) for a configured rung — so the
    ``NamedSharding`` for each shape is built once and cached, and the
    per-dispatch cost is a dict lookup instead of a spec construction.
    The cache admits only shapes whose position axis is a configured
    rung, so it is bounded by (buckets x rungs) regardless of traffic;
    off-ladder shapes (untiered ndim<2 samples sharing the gateway)
    place correctly but uncached, like ``batch_placer``."""
    axes, size = _data_axes(mesh)
    rungs = frozenset(ladder.rungs)
    cache: dict = {}

    def sharding_for(shape):
        spec_b = axes if shape[0] % size == 0 else None
        return NamedSharding(mesh, P(spec_b, *(None,) * (len(shape) - 1)))

    def place_one(x):
        shape = tuple(x.shape)
        if len(shape) >= 2 and shape[1] in rungs:
            s = cache.get(shape)
            if s is None:
                s = cache[shape] = sharding_for(shape)
            return jax.device_put(x, s)
        return jax.device_put(x, sharding_for(shape))

    def place(cond, x0):
        x0 = place_one(x0)
        if cond is not None:
            cond = {k: place_one(v) if hasattr(v, "ndim") and v.ndim else v
                    for k, v in cond.items()}
        return cond, x0

    return place


def carry_placer(mesh):
    """A ``place(carry) -> carry`` callable re-placing the continuous
    engine's slot-batched carry arrays after a join scatters new rows:
    ``x0``/``x`` along the data axes on the leading (slot) dim, ``U`` on its
    slot dim (axis 1), replicating when the slot count does not divide the
    data-axis size — size ``max_slots`` to the data axis to stay split."""
    axes, size = _data_axes(mesh)

    def place_axis(x, dim):
        spec = [None] * x.ndim
        if x.shape[dim] % size == 0:
            spec[dim] = axes
        return jax.device_put(x, NamedSharding(mesh, P(*spec)))

    def place(carry):
        return carry._replace(x0=place_axis(carry.x0, 0),
                              U=place_axis(carry.U, 1),
                              x=place_axis(carry.x, 0))

    return place


def serving_mesh(name: str):
    """CLI mesh selection: 'none' -> None (single-device jit), 'host' ->
    the 1x1 smoke mesh, 'production'/'multipod' -> ``launch.mesh`` shapes.
    Falls back to None with a warning when the host lacks the devices."""
    if name in (None, "none"):
        return None
    from repro.launch import mesh as mesh_mod

    try:
        if name == "host":
            return mesh_mod.make_host_mesh()
        if name == "production":
            return mesh_mod.make_production_mesh()
        if name == "multipod":
            return mesh_mod.make_production_mesh(multi_pod=True)
    except Exception as e:
        print(f"WARNING: cannot build {name!r} mesh ({e}); "
              "falling back to single-device jit")
        return None
    raise ValueError(f"unknown mesh {name!r}; "
                     "choose none|host|production|multipod")
