"""SLO policy: deadlines, priorities, admission control, shedding.

FIFO-with-aging collapses under overload: the queue grows without bound,
every request is eventually served LATE, and the forwards spent on hopeless
requests starve the feasible ones. This module holds the pure policy the
gateways consult when a ``SLOConfig`` is attached:

* **Deadlines.** ``Request.deadline_ms`` / ``DecodeRequest.deadline_ms``
  is a relative budget (ms from submit); the gateway stamps the absolute
  deadline on its own clock, so fake-clock benches measure SLO attainment
  deterministically. Settling on time ticks ``goodput``; settling late
  (or being shed) ticks ``deadline_misses``.
* **Admission control.** ``submit`` fast-rejects with ``AdmissionRejected``
  when the queue's MODELED service time cannot meet the deadline. The cost
  model is the registry's own observed dispatch-time histograms
  (``device_dispatch_ms`` + ``host_assembly_ms`` means — see
  ``GatewayBase._dispatch_cost_ms``), so it calibrates itself from live
  traffic: no configuration, and on the fake clock it sees simulated
  milliseconds, making the overload bench deterministic.
* **Shedding.** A queued entry whose deadline already passed is failed
  with ``DeadlineExceeded`` at the next pump instead of burning a slot —
  under overload the forwards saved go to requests that can still win.
* **Ordering.** ``urgency_key`` sorts higher priority first, then earlier
  deadline, then FIFO — entries with no deadline and priority 0 keep the
  exact legacy ``(t_submit, uid)`` order, so attaching an ``SLOConfig``
  never reorders plain traffic.
* **Preemption** (continuous tier): at an anytime EXIT BOUNDARY a
  strictly-lower-priority slot can be evicted for a queued urgent request.
  Eviction is free by construction — the victim's per-slot carry columns
  (x0, recorded velocities, state) are snapshotted to host and the request
  resumes later via ``AnytimeCarry``, bit-identical to an unpreempted run
  (the exit-boundary join invariant of ``core.anytime.anytime_extend``).

Everything here is a pure function of (entries, clock, config) — the unit
tests and ``benchmarks/overload_bench.py`` drive it with a fake clock.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


class AdmissionRejected(RuntimeError):
    """Fast reject at ``submit``: the queue's modeled service time cannot
    meet the request's deadline. Raised synchronously — the caller never
    gets a future — and counted under the ``rejected`` metric (NOT
    ``submitted``/``failed``: the request was never accepted)."""

    def __init__(self, message: str, *, estimated_ms: float = 0.0,
                 deadline_ms: float = 0.0, queue_depth: int = 0):
        super().__init__(message)
        self.estimated_ms = estimated_ms
        self.deadline_ms = deadline_ms
        self.queue_depth = queue_depth


class DeadlineExceeded(RuntimeError):
    """An ACCEPTED request was shed because its deadline passed while it
    was still queued. Surfaces through the future (counted under both
    ``failed`` and ``deadline_misses``)."""


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Switchboard for the SLO behaviours. ``slo=None`` on a gateway is
    exact legacy FIFO (deadline metrics are still recorded — the overload
    bench's baseline arm); ``slo=SLOConfig()`` turns everything on.

    ``slack_ms`` is a safety margin subtracted from every deadline before
    the admission/shedding comparison. ``default_cost_ms`` seeds the cost
    model before the first dispatch has been observed (0 = optimistic:
    accept everything until the histograms warm up).
    """

    admission: bool = True      # fast-reject at submit
    shedding: bool = True       # fail queued entries past their deadline
    preemption: bool = True     # evict low-priority continuous slots
    slack_ms: float = 0.0
    default_cost_ms: float = 0.0


def urgency_key(entry) -> tuple:
    """Sort key: higher priority first, earlier deadline first, then the
    legacy FIFO ``(t_submit, uid)`` — default entries (priority 0, no
    deadline) order exactly as before."""
    deadline = getattr(entry, "deadline", None)
    return (-getattr(entry, "priority", 0),
            deadline if deadline is not None else math.inf,
            entry.t_submit, entry.uid)


def is_urgent(entry) -> bool:
    """Queued entries that carry SLO pressure — what ``HostLoad.urgent``
    counts and the work stealer prefers to migrate."""
    return (getattr(entry, "priority", 0) > 0
            or getattr(entry, "deadline", None) is not None)


def hist_mean(hist_handle) -> Optional[float]:
    """Mean of a live ``Histogram`` handle (exact — count/sum are tracked
    outside the buckets); None before the first observation."""
    if hist_handle.count == 0:
        return None
    return hist_handle.sum / hist_handle.count


@dataclasses.dataclass(frozen=True)
class PausedCarry:
    """Host-side snapshot of one preempted slot, taken at an exit
    boundary: the victim's carry COLUMN (its x0 row, its recorded-velocity
    column ``U[:, slot]``, its current state row) plus the boundary it was
    paused at. Resuming reconstructs a mini ``AnytimeCarry`` at
    ``step=step`` from exactly these arrays, so the resumed trajectory is
    bit-identical to one that was never preempted."""

    step: int
    x0: object       # np.ndarray, the entry's own noise row
    U: object        # np.ndarray (n, *dim): recorded velocities, rows >= step zero
    x: object        # np.ndarray: state at ``step``
