"""Continuous batching: admit requests into in-flight anytime trajectories.

The flush-only gateway (``repro.serving.gateway``) exploits the anytime
solver's shared trajectory only at flush time: a request arriving one tick
after a flush waits a full ``max_wait_ms`` even though an in-flight
trajectory is passing an exit boundary it could join. This module turns the
solver's nested-grid structure into request-level continuous batching:

* An in-flight anytime dispatch is tracked as a SEQUENCE OF EXIT-BOUNDARY
  JOIN POINTS — the trajectory advances leg by leg between consecutive
  served budgets, returning control to the host at every boundary.
* At each boundary k the engine RELEASES the slots whose served budget is k
  (their early-exit output resolves the future immediately) and ADMITS
  queued requests with budget > k into the freed slots: a joiner's prefix
  ``0..k`` is computed from its OWN noise via the shared intermediate
  coefficients (the first k rows of the extracted ``ns_at_budget`` solver),
  then steps ``k..b`` ride the shared grid with the rest of the batch.
* Every served sample stays bit-identical to the direct sampler with the
  same noise — see the exit-boundary join invariant on
  ``core.anytime.anytime_extend`` — and a joined request at budget b adds at
  most b incremental backbone forwards (k for the prefix dispatch; the
  shared legs are already being paid for).

``ContinuousScheduler`` extends ``BatchScheduler`` with slot admission and
release planning (pure functions of pending + now — fake-clock testable);
``ContinuousGateway.pump`` interleaves trajectory legs, joins, and the
inherited flush planning, so requests that cannot join (budget at or below
the next boundary, or no free slot) still flush under the usual
max-batch/max-wait rules. ``stats()`` additionally reports join-rate and
slot-occupancy.

Shape tiers (``repro.serving.tiers``): with a ``ShapeLadder`` configured,
``shape_key`` holds the tier-padded shape, so trajectories are PER-TIER,
not per-exact-shape — a joiner of any native shape in the tier rides the
shared ``AnytimeCarry`` through its zero-padded position rows, and every
release/partial crops back to the entry's ``native_shape`` before the
caller sees it (bit-identical to the direct sampler at the native shape).

Samplers must speak the carry protocol on top of the budget protocol:
``carry_start(batch, x0)`` and ``carry_extend(batch, carry, stop)``
(``AnytimeFlowSampler`` jit-caches one program per (start, stop) leg).
With ``mesh=`` the carry arrays are re-placed on the serving mesh after
every join scatter (``sharded.carry_placer``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.observability import profile_span
from repro.serving.gateway import (
    BatchScheduler,
    Gateway,
    Response,
    _Entry,
    assemble_rows,
)
from repro.serving.slo import PausedCarry, is_urgent, urgency_key
from repro.serving.tiers import crop_row


class ContinuousScheduler(BatchScheduler):
    """Slot admission/release planning on top of flush planning.

    ``plan_start`` decides when pending requests open a new trajectory;
    ``plan_joins`` decides which requests are admitted into an in-flight one
    at an exit boundary. Both are pure functions of (pending, now, slot
    state) — the unit tests drive them with a fake clock and assert the
    exact slate. The inherited ``plan`` keeps serving whatever cannot ride
    a trajectory.
    """

    def __init__(self, max_slots: int = 8, boundaries: Sequence[int] = (),
                 max_batch: Optional[int] = None, max_wait_ms: float = 10.0,
                 policy: str = "auto", can_mix: bool = False,
                 top_budget: Optional[int] = None,
                 max_leg: Optional[int] = None,
                 join_cost_cap: float = 0.5, slo_aware: bool = False):
        super().__init__(max_batch=max_batch or max_slots,
                         max_wait_ms=max_wait_ms, policy=policy,
                         can_mix=can_mix, top_budget=top_budget,
                         slo_aware=slo_aware)
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        if max_leg is not None and max_leg < 1:
            raise ValueError("max_leg must be >= 1")
        if not 0.0 < join_cost_cap <= 1.0:
            raise ValueError("join_cost_cap must be in (0, 1]")
        self.max_slots = max_slots
        self.boundaries = tuple(sorted(boundaries))
        self.max_leg = max_leg
        self.join_cost_cap = join_cost_cap
        self._join_buckets = self._bucket_sizes(max_slots)

    def join_bucket(self, count: int) -> int:
        """Smallest padded size for a join-prefix dispatch — powers of two
        up to ``max_slots``, so each (boundary, bucket) prefix program is
        compiled exactly once (mirrors ``bucket`` for flush batches)."""
        for b in self._join_buckets:
            if b >= count:
                return b
        raise ValueError(f"count {count} exceeds max_slots {self.max_slots}")

    def next_boundary(self, step: int) -> Optional[int]:
        """The next stop strictly beyond ``step`` (None past the top): the
        first exit boundary, clipped to ``max_leg`` steps when set — a
        shorter leg is not a join point, but it hands control back to the
        host so interleaved flushes are not blocked behind a long leg."""
        for b in self.boundaries:
            if b > step:
                return min(b, step + self.max_leg) if self.max_leg else b
        return None

    def plan_start(self, pending: Sequence[_Entry], now: float,
                   force: bool = False) -> list[_Entry]:
        """The FIFO slate opening a new trajectory: same-shape entries, up
        to ``max_slots``, once the slots would fill or the oldest entry of
        that shape has aged out (the same full-or-aged rule
        ``BatchScheduler.plan`` applies to flushes).

        Shape groups are considered INDEPENDENTLY, oldest group first —
        gating the slate on the overall-oldest entry's shape let one unaged
        singleton park a full (or aged) slate of another shape forever
        (head-of-line blocking across shapes). Mixed-shape traffic now
        starts whichever shape group is ready; the passed-over group stays
        pending and opens the next trajectory.

        SLO mode additionally starts as soon as any URGENT entry (deadline
        or raised priority) is queued: ``plan_start`` only runs when no
        trajectory is in flight — the device is idle — and unlike a flush,
        an under-filled trajectory costs nothing extra (its free slots
        refill at every exit boundary), so holding urgent work for the
        full-or-aged rule would burn deadline budget for no batching win."""
        groups: dict[tuple, list[_Entry]] = {}
        order = urgency_key if self.slo_aware else (lambda e: e.uid)
        for e in sorted(pending, key=order):
            groups.setdefault(e.shape_key, []).append(e)
        for same in groups.values():     # insertion order = oldest-first
            aged = any(now - e.t_submit >= self.max_wait_s for e in same)
            if self.slo_aware and not aged:
                aged = any(is_urgent(e) for e in same)
            if force or aged or len(same) >= self.max_slots:
                return same[:self.max_slots]
        return []

    @staticmethod
    def join_cost(e: _Entry, boundary: int) -> int:
        """Prefix forwards admitting ``e`` at ``boundary`` costs: a fresh
        join recomputes 0..boundary; a PREEMPTED entry paused at step s <=
        boundary resumes its saved carry and only pays s..boundary."""
        p = getattr(e, "paused", None)
        if p is not None and p.step <= boundary:
            return boundary - p.step
        return boundary

    def plan_joins(self, pending: Sequence[_Entry], boundary: int,
                   free_slots: int, shape_key: tuple) -> list[_Entry]:
        """Entries admitted into the in-flight trajectory at ``boundary``:
        FIFO entries (urgency-ordered in SLO mode) of the trajectory's
        shape whose served budget lies STRICTLY beyond the boundary (their
        exit is still ahead on the shared grid) and whose prefix is worth
        paying — the join costs ``join_cost`` prefix forwards, so
        admission requires ``cost <= join_cost_cap * served`` (default:
        the prefix may be at most half the budget; very late joins burn
        forwards a future flush would amortize better; a resumed
        preempted entry's cost is only the saved-step gap). Capped by the
        freed slots; not age-gated — immediate admission is the latency
        win."""
        if free_slots <= 0:
            return []
        order = urgency_key if self.slo_aware else (lambda e: e.uid)
        ok = [e for e in sorted(pending, key=order)
              if e.shape_key == shape_key and e.served > boundary
              and self.join_cost(e, boundary)
              <= self.join_cost_cap * e.served]
        return ok[:free_slots]

    def plan_preemptions(self, pending: Sequence[_Entry], boundary: int,
                         active: Sequence[tuple], free_slots: int,
                         shape_key: tuple) -> list[tuple]:
        """Eviction pairs ``(slot_idx, victim, urgent)`` at an exit
        boundary: each still-queued urgent entry that could join (same
        conditions as ``plan_joins``) displaces one STRICTLY-lower-
        priority occupied slot — lowest-priority, youngest victim first.
        Empty when free slots remain (``plan_joins`` already used them) or
        nothing queued outranks a resident. Pure planning; eviction is
        free by construction at an exit boundary (the victim resumes via
        its saved carry, bit-identical — ``core.anytime``'s join
        invariant)."""
        if free_slots > 0 or not pending or not active:
            return []
        candidates = [e for e in sorted(pending, key=urgency_key)
                      if e.shape_key == shape_key and e.served > boundary
                      and self.join_cost(e, boundary)
                      <= self.join_cost_cap * e.served]
        victims = sorted(
            [(si, v) for si, v in active if v.served > boundary],
            key=lambda sv: (sv[1].priority, -sv[1].t_submit, -sv[1].uid))
        pairs = []
        for e in candidates:
            if not victims:
                break
            si, v = victims[0]
            if v.priority >= e.priority:
                break       # victims are sorted; nothing weaker remains
            victims.pop(0)
            pairs.append((si, v, e))
        return pairs


@dataclasses.dataclass
class _Trajectory:
    """One in-flight shared trajectory: the device carry plus per-slot host
    bookkeeping. ``entries[i] is None`` marks a free (padded) slot — its
    rows keep stale data, which is harmless because rows are independent
    through the backbone (the padded-batch contract)."""

    carry: object                     # sampler-level AnytimeCarry
    entries: list                     # Optional[_Entry] per slot
    shape_key: tuple
    tokens: Optional[np.ndarray]      # (slots, S) conditioning, or None

    def cond(self) -> Optional[dict]:
        if self.tokens is None:
            return None
        return {"tokens": jnp.asarray(self.tokens)}

    def active(self) -> list[tuple[int, _Entry]]:
        return [(i, e) for i, e in enumerate(self.entries) if e is not None]

    def free_slots(self) -> list[int]:
        return [i for i, e in enumerate(self.entries) if e is None]


class ContinuousGateway(Gateway):
    """Gateway with continuous batching over one anytime sampler.

    Same intake/lifecycle as ``Gateway``; ``pump`` becomes one engine tick:

    * no trajectory in flight — open one from the pending queue
      (``plan_start``), or
    * advance the trajectory one leg to the next exit boundary, release the
      slots exiting there, admit joiners into the freed slots
      (``plan_joins`` + prefix dispatch), and then
    * run the inherited flush planner over whatever remains pending, so
      non-joinable requests (budget at or below the next boundary, no free
      slot, other sample shape) never wait on the trajectory.

    ``drain`` additionally runs the in-flight trajectory to completion.
    """

    def __init__(self, sampler, *, max_slots: int = 8,
                 max_batch: Optional[int] = None, max_wait_ms: float = 10.0,
                 mixed_budget_policy: str = "auto", strict_nfe: bool = False,
                 mesh=None, clock=None, key=None,
                 max_leg: Optional[int] = None, join_cost_cap: float = 0.5,
                 metrics=None, recorder=None, slo=None, tiers=None):
        for method in ("carry_start", "carry_extend"):
            if not hasattr(sampler, method):
                raise TypeError(
                    "continuous batching needs a resumable anytime sampler "
                    f"(missing {method!r}); use AnytimeFlowSampler or serve "
                    "through the flush-only Gateway")
        kw = {} if clock is None else {"clock": clock}
        super().__init__(sampler, max_batch=max_batch or max_slots,
                         max_wait_ms=max_wait_ms,
                         mixed_budget_policy=mixed_budget_policy,
                         strict_nfe=strict_nfe, mesh=mesh, key=key,
                         metrics=metrics, recorder=recorder, slo=slo,
                         tiers=tiers, **kw)
        self.scheduler = ContinuousScheduler(
            max_slots=max_slots, boundaries=sampler.budgets,
            max_batch=max_batch or max_slots, max_wait_ms=max_wait_ms,
            policy=mixed_budget_policy,
            can_mix=self.scheduler.can_mix,
            top_budget=max(sampler.budgets),
            max_leg=max_leg, join_cost_cap=join_cost_cap,
            slo_aware=slo is not None)
        self._traj: Optional[_Trajectory] = None
        self._place_carry = None
        if mesh is not None:
            from repro.serving import sharded

            self._place_carry = sharded.carry_placer(mesh)

    # -- engine tick ---------------------------------------------------------

    def pump(self, force: bool = False) -> int:
        """One engine tick; returns how many dispatches ran (trajectory
        opens and legs count as one each, like flush batches)."""
        ran = 0
        with self._plan_lock:
            if self.slo is not None:
                self._shed_expired()
                self.scheduler.lead_ms = self._dispatch_cost_ms()
            if self._traj is not None:
                try:
                    self._advance_leg()
                except BaseException as exc:  # noqa: BLE001 — see below
                    # a failing leg must not strand the slots' futures or
                    # kill the serve thread (the trajectory twin of the
                    # flush-path guard in Gateway._run_batches)
                    self._fail_trajectory(exc)
                ran += 1
            if self._traj is None:
                # idle engine, or the trajectory just retired: a new slate
                # gets first claim on the pending queue — a trajectory costs
                # what a mixed flush costs but its slots refill at every
                # later boundary, so it must outrank the flush planner
                starters = self.scheduler.plan_start(
                    self.queue.snapshot(), self.clock(), force=force)
                if starters:
                    self._take(starters)
                    try:
                        self._start_trajectory(starters, self.clock())
                    except BaseException as exc:  # noqa: BLE001
                        self._fail_entries(starters, exc, count_all=True)
                        self._settle(len(starters))
                        self._traj = None
                    ran += 1
            # interleave flushes: whatever neither joined nor started still
            # obeys the flush-only rules (full buckets now, partials aged)
            batches = self.scheduler.plan(
                self.queue.snapshot(), self.clock(), force=force)
            self._take([e for b in batches for e in b.entries])
        return ran + self._run_batches(batches)

    def _estimate_wait_ms(self, entry) -> float:
        """Continuous-tier admission cost model: slots refill at every
        exit boundary, so the per-settled-request service time sits far
        below one whole dispatch (the flush model's unit) — joiners ride
        legs already paid for. The queue therefore drains at the OBSERVED
        device-time-per-settle rate, which the registry already tracks
        exactly (``device_dispatch_ms.sum`` over ``completed``). Before
        the first settle there is nothing to observe and the inherited
        flush batch model — seeded by ``slo.default_cost_ms`` — stands
        in."""
        with self._stats_lock:
            completed = self._m.completed.value
            device_ms = self._m.device_dispatch_ms.sum
            inflight = self._inflight
        if completed and device_ms > 0.0:
            # work ahead of us = queued entries plus the trajectory rows
            # already off the queue but not yet settled
            ahead = self.queue.depth() + inflight
            return device_ms / completed * (ahead + 1)
        return super()._estimate_wait_ms(entry)

    def _start_trajectory(self, starters: list, now: float) -> None:
        """Open a trajectory over ``starters`` (costs no forwards — the
        first leg runs on the next tick; waits end here, at admission)."""
        slots = self.scheduler.max_slots
        pad = slots - len(starters)
        x0_np, tokens = assemble_rows(starters, slots)
        for e in starters:
            e.t_admit, e.join_step = now, 0
        traj = _Trajectory(carry=None, entries=list(starters) + [None] * pad,
                           shape_key=starters[0].shape_key, tokens=tokens)
        with profile_span(f"continuous.start.k{slots}"):
            carry = self.sampler.carry_start(traj.cond(), jnp.asarray(x0_np))
        if self._place_carry is not None:
            carry = self._place_carry(carry)
        traj.carry = carry
        self._traj = traj
        with self._stats_lock:
            self._m.trajectories.inc()
            self._note_program(f"start/k{slots}")
        rec = self.recorder
        if rec:
            for e in starters:
                rec.event(e.uid, "dispatch", now, host=self._host,
                          kind="traj_start")

    def _advance_leg(self) -> None:
        """Advance to the next exit boundary, release exiting slots, admit
        joiners into the freed slots."""
        traj = self._traj
        step = traj.carry.step
        boundary = self.scheduler.next_boundary(step)
        assert boundary is not None, "trajectory ran past the top budget"
        active = traj.active()
        t0 = self.clock()   # gateway clock: fake-clock benches feed the
        #                     SLO cost model simulated dispatch times
        with profile_span(f"continuous.leg.{step}-{boundary}"):
            carry, exits = self.sampler.carry_extend(traj.cond(), traj.carry,
                                                     boundary)
        leg_ms = (self.clock() - t0) * 1e3
        traj.carry = carry
        # a max_leg-clipped stop is a control point, not an exit boundary:
        # nothing releases or joins there, but interleaved flushes can run
        is_exit = boundary in self.scheduler.boundaries
        released = [(si, e) for si, e in active
                    if is_exit and e.served == boundary]
        # streaming slots riding PAST this exit get the boundary's early-
        # exit latents as a partial (exactly the budget-`boundary` sample
        # for their noise — the anytime grid is nested)
        streaming = [(si, e) for si, e in active
                     if is_exit and e.sink is not None
                     and e.served > boundary]
        latents = (np.asarray(exits[boundary])
                   if (released or streaming) else None)
        with self._stats_lock:
            m = self._m
            m.legs.inc()
            m.forwards.inc(boundary - step)
            m.slot_steps_active.inc(len(active) * (boundary - step))
            m.slot_steps_total.inc(
                self.scheduler.max_slots * (boundary - step))
            m.device_dispatch_ms.observe(leg_ms)
            self._note_program(f"leg/{step}-{boundary}")
            if active and active[0][1].native_shape is not None:
                # per-tier occupancy, weighted by leg steps (the slot-
                # steps convention): native rows carried vs padded rows
                # paid for — slot padding AND tier padding in one ratio
                tier = traj.shape_key[1]
                steps = boundary - step
                self._note_tier(
                    tier,
                    steps * sum(e.native_shape[0] for _, e in active),
                    steps * self.scheduler.max_slots * tier[0])
        for si, e in streaming:
            e.sink.partial(crop_row(latents[si], e.native_shape),
                           boundary=boundary)
        for si, e in released:
            self._release(traj, si, e, crop_row(latents[si], e.native_shape),
                          boundary, len(active))
        if is_exit:
            joiners = self.scheduler.plan_joins(
                self.queue.snapshot(), boundary, len(traj.free_slots()),
                traj.shape_key)
            if joiners:
                self._take(joiners)
                try:
                    self._admit(traj, joiners, boundary)
                except BaseException as exc:  # noqa: BLE001
                    # joiners left the queue already; a failing prefix
                    # dispatch must reach their futures. The trajectory's
                    # own carry is untouched (assigned only after every
                    # scatter lands), so the in-flight slots roll on.
                    self._fail_entries(joiners, exc, count_all=True)
                    self._settle(len(joiners))
            if self.slo is not None and self.slo.preemption:
                self._preempt(traj, boundary)
        if not traj.active():
            self._traj = None

    def _release(self, traj: _Trajectory, si: int, e: _Entry, row,
                 boundary: int, batch_real: int) -> None:
        """Resolve one slot's future at its exit boundary and free the slot."""
        wait_ms = (e.t_admit - e.t_submit) * 1e3
        with self._stats_lock:
            # wait observed exactly where completed ticks, so the
            # histogram count == completed invariant holds tier-wide
            self._m.completed.inc()
            self._m.wait_ms.observe(wait_ms)
            self._note_deadline(e, self.clock())
            self._inflight -= 1      # taken at plan_start/plan_joins
        rec = self.recorder
        if rec:
            rec.event(e.uid, "settle", self.clock(), host=self._host,
                      status="completed", boundary=boundary, slot=si)
        response = Response(latents=row, meta={
            "requested_budget": e.requested,
            "served_budget": e.served,
            "nfe_batch": boundary,
            "batch_real": batch_real,
            "batch_padded": self.scheduler.max_slots,
            "mixed": False,
            "wait_ms": wait_ms,
            "continuous": True,
            "join_step": e.join_step,
            "slot": si,
        })
        if e.native_shape is not None:
            response.meta["tier_shape"] = e.shape_key[1]
            response.meta["native_shape"] = e.native_shape
        if e.trace and rec:
            response.trace = rec.trace(e.uid)
        try:
            e.future.set_result(response)
        except Exception:           # cancelled: the trajectory rolls on
            pass
        if e.sink is not None:
            e.sink.final(response)
        traj.entries[si] = None

    def _admit(self, traj: _Trajectory, joiners: list, boundary: int) -> None:
        """Join ``joiners`` at ``boundary``. Fresh joiners compute their
        prefix 0..boundary from their own noise on the shared intermediate
        coefficients (one padded mini-dispatch, ``boundary`` forwards);
        PREEMPTED joiners resume their saved carry from its paused step
        (``boundary - step`` forwards, zero when paused at this very
        boundary). Both land by scattering per-slot carry columns into the
        freed slots — bit-identical to never having left the trajectory
        (the exit-boundary join invariant) — then re-place on the mesh if
        sharded."""
        fresh = [e for e in joiners
                 if e.paused is None or e.paused.step > boundary]
        resumed = [e for e in joiners
                   if e.paused is not None and e.paused.step <= boundary]
        cols: dict[int, tuple] = {}   # uid -> (x0 row, U column, x row)
        programs: list[str] = []
        prefix_forwards = 0
        if fresh:
            k = len(fresh)
            bucket = self.scheduler.join_bucket(k)
            x0_np, t_np = assemble_rows(fresh, bucket)
            cond = None if t_np is None else {"tokens": jnp.asarray(t_np)}
            with profile_span(f"continuous.join.{boundary}/k{bucket}"):
                prefix = self.sampler.carry_start(cond, jnp.asarray(x0_np))
                prefix, _ = self.sampler.carry_extend(cond, prefix, boundary)
            prefix_forwards += boundary
            programs.append(f"join/{boundary}-k{bucket}")
            for i, e in enumerate(fresh):
                cols[e.uid] = (prefix.x0[i], prefix.U[:, i], prefix.x[i])
        by_step: dict[int, list] = {}
        for e in resumed:
            by_step.setdefault(e.paused.step, []).append(e)
        for s in sorted(by_step):
            group = by_step[s]
            k = len(group)
            bucket = self.scheduler.join_bucket(k)
            x0_np, u_np, x_np, t_np = self._stack_paused(group, bucket)
            rcarry = type(traj.carry)(
                x0=jnp.asarray(x0_np), U=jnp.asarray(u_np),
                x=jnp.asarray(x_np), step=s)
            if s < boundary:
                cond = (None if t_np is None
                        else {"tokens": jnp.asarray(t_np)})
                with profile_span(
                        f"continuous.resume.{s}-{boundary}/k{bucket}"):
                    rcarry, _ = self.sampler.carry_extend(cond, rcarry,
                                                          boundary)
                prefix_forwards += boundary - s
                programs.append(f"resume/{s}-{boundary}-k{bucket}")
            for i, e in enumerate(group):
                cols[e.uid] = (rcarry.x0[i], rcarry.U[:, i], rcarry.x[i])
        free = traj.free_slots()[:len(joiners)]
        idx = jnp.asarray(free)
        carry = traj.carry
        carry = carry._replace(
            x0=carry.x0.at[idx].set(
                jnp.stack([cols[e.uid][0] for e in joiners])),
            U=carry.U.at[:, idx].set(
                jnp.stack([cols[e.uid][1] for e in joiners], axis=1)),
            x=carry.x.at[idx].set(
                jnp.stack([cols[e.uid][2] for e in joiners])))
        if self._place_carry is not None:
            carry = self._place_carry(carry)
        traj.carry = carry
        now = self.clock()
        rec = self.recorder
        for si, e in zip(free, joiners):
            if e.paused is None:
                # a resumed entry keeps its FIRST admission: its wait
                # ended then, and join_step records where it entered
                e.t_admit, e.join_step = now, boundary
            e.paused = None
            if traj.tokens is not None:
                traj.tokens[si] = np.asarray(e.tokens)
            traj.entries[si] = e
            if rec:
                rec.event(e.uid, "join", now, host=self._host,
                          boundary=boundary, slot=si,
                          resumed=e in resumed)
        with self._stats_lock:
            m = self._m
            m.joins.inc(len(joiners))
            m.forwards.inc(prefix_forwards)
            m.join_forwards.inc(prefix_forwards)
            for program in programs:
                self._note_program(program)

    @staticmethod
    def _stack_paused(group: list, bucket: int):
        """Rebuild padded batch arrays from saved ``PausedCarry`` columns
        (the resume twin of ``assemble_rows``): stack each victim's x0
        row, recorded-velocity column, and state row, zero-padded to
        ``bucket`` — pad rows are independent through the backbone, so
        they never perturb a resumed sample."""
        pad = bucket - len(group)
        x0 = np.stack([np.asarray(e.paused.x0) for e in group])
        u = np.stack([np.asarray(e.paused.U) for e in group], axis=1)
        x = np.stack([np.asarray(e.paused.x) for e in group])
        if pad:
            x0 = np.concatenate(
                [x0, np.zeros((pad,) + x0.shape[1:], x0.dtype)])
            u = np.concatenate(
                [u, np.zeros((u.shape[0], pad) + u.shape[2:], u.dtype)],
                axis=1)
            x = np.concatenate(
                [x, np.zeros((pad,) + x.shape[1:], x.dtype)])
        tokens = None
        if group[0].tokens is not None:
            tokens = np.stack(
                [np.asarray(e.tokens) for e in group]
                + [np.zeros_like(np.asarray(group[0].tokens))] * pad)
        return x0, u, x, tokens

    def _preempt(self, traj: _Trajectory, boundary: int) -> None:
        """Evict strictly-lower-priority slots for queued urgent entries at
        an exit boundary (``plan_preemptions``), then admit the urgent
        entries into the freed slots. Each victim's carry column is
        snapshotted to host (``PausedCarry``) and the victim goes BACK to
        the queue; a later ``plan_joins`` resumes it for only the
        boundary-gap forwards, bit-identical to an unpreempted run."""
        pairs = self.scheduler.plan_preemptions(
            self.queue.snapshot(), boundary, traj.active(),
            len(traj.free_slots()), traj.shape_key)
        if not pairs:
            return
        carry = traj.carry
        rec = self.recorder
        now = self.clock()
        urgents = []
        for si, victim, urgent in pairs:
            victim.paused = PausedCarry(
                step=boundary,
                x0=np.asarray(carry.x0[si]),
                U=np.asarray(carry.U[:, si]),
                x=np.asarray(carry.x[si]))
            traj.entries[si] = None
            # back to the queue: still accepted (submitted already
            # counted), no longer in flight until it rejoins
            self.queue.push(victim)
            self._settle(1)
            urgents.append(urgent)
            if rec:
                rec.event(victim.uid, "preempt", now, host=self._host,
                          boundary=boundary, slot=si, by=urgent.uid)
        with self._stats_lock:
            self._m.preemptions.inc(len(pairs))
        self._take(urgents)
        try:
            self._admit(traj, urgents, boundary)
        except BaseException as exc:  # noqa: BLE001 — mirror plan_joins
            self._fail_entries(urgents, exc, count_all=True)
            self._settle(len(urgents))

    def _fail_trajectory(self, exc: BaseException) -> None:
        """Surface a failing leg into every occupied slot's future and
        retire the trajectory, keeping the engine (and its serve thread)
        alive — the trajectory twin of ``_run_batches``' per-batch guard."""
        traj, self._traj = self._traj, None
        if traj is not None:
            entries = [e for _, e in traj.active()]
            self._fail_entries(entries, exc, count_all=True)
            self._settle(len(entries))

    # -- lifecycle -----------------------------------------------------------

    def _drained(self) -> bool:
        """Drain additionally runs the in-flight trajectory to completion
        (its slots are in flight anyway — belt and braces)."""
        return super()._drained() and self._traj is None
