"""Reference toy sampler speaking the full serving protocol stack.

One implementation of the budget protocol (``budgets``, ``resolve_budget``,
``sample_from``, ``sample_all_from``) AND the carry protocol
(``carry_start``, ``carry_extend``) over the analytic two-moons velocity
field, shared by the serving benchmarks and the gateway/continuous test
suites so they all exercise the SAME sampler:

* ``jit=True`` (benchmark timing): per-budget programs compiled once and
  cached, like ``AnytimeFlowSampler``.
* ``jit=False`` (forward accounting / fake-clock simulation): everything
  runs eagerly through ``_u``, which calls the ``on_forward`` hook once per
  BATCH-LEVEL velocity evaluation — override it to count backbone forwards
  or to advance a simulated clock. The hook is not called on the jit path
  (compiled programs do not re-trace), so accounting users must keep
  ``jit=False``.

The anytime solver is ``init_anytime`` + per-leaf Gaussian jitter (seeded),
so two instances with the same (budgets, seed, jitter) are bit-identical —
the flush-vs-continuous comparisons rest on that.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ns_solver, schedulers, toy
from repro.core.anytime import (
    AnytimeCarry,
    anytime_carry,
    anytime_extend,
    anytime_sample,
    extract_ns,
    init_anytime,
)
from repro.serving.engine import nearest_budget

Array = jax.Array


class FakeClock:
    """Deterministic clock for gateway simulation: ``gateway.clock`` is any
    zero-arg callable, so tests and benchmarks advance time explicitly (or
    from an engine/sampler forward hook) instead of sleeping."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, seconds: float) -> None:
        self.t += seconds


class ToyAnytimeSampler:
    """Budget+carry-protocol sampler over the analytic toy field."""

    def __init__(self, budgets: Sequence[int] = (4, 8, 16), seed: int = 0,
                 jitter: float = 0.1, jit: bool = True):
        self.budgets = tuple(sorted(budgets))
        theta = init_anytime(None, self.budgets, "nested")
        leaves, treedef = jax.tree.flatten(theta)
        keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
        self.theta = jax.tree.unflatten(
            treedef, [l + jitter * jax.random.normal(k, l.shape)
                      for l, k in zip(leaves, keys)])
        sched = schedulers.fm_ot()
        self.field = toy.mixture_field(sched, toy.two_moons_means(),
                                       jnp.full((16,), 0.15), jnp.ones((16,)))
        self._jit = jit
        self._per_budget: dict[int, Callable] = {}
        self._all: Optional[Callable] = None

    def on_forward(self) -> None:
        """Called once per batch-level velocity evaluation (eager path
        only). Override to count forwards or advance a simulated clock."""

    def _u(self, t: Array, x: Array) -> Array:
        self.on_forward()
        return self.field.fn(t, x)

    # -- budget protocol -----------------------------------------------------

    def resolve_budget(self, m: int, strict: bool = False) -> int:
        return nearest_budget(self.budgets, m, strict)

    def sample_from(self, batch, x0: Array, budget: int) -> Array:
        if not self._jit:
            ns = extract_ns(self.theta, self.budgets, budget)
            return ns_solver.ns_sample(ns, self._u, x0, unroll=True)
        fn = self._per_budget.get(budget)
        if fn is None:
            ns = extract_ns(self.theta, self.budgets, budget)
            fn = self._per_budget[budget] = jax.jit(
                lambda x, ns=ns: ns_solver.ns_sample(ns, self.field.fn, x))
        return fn(x0)

    def sample_all_from(self, batch, x0: Array) -> dict[int, Array]:
        if not self._jit:
            return anytime_sample(self.theta, self.budgets, self._u, x0)
        if self._all is None:
            self._all = jax.jit(lambda x: anytime_sample(
                self.theta, self.budgets, self.field.fn, x))
        return self._all(x0)

    # -- carry protocol (continuous batching) --------------------------------

    def carry_start(self, batch, x0: Array) -> AnytimeCarry:
        return anytime_carry(self.theta, self.budgets, x0)

    def carry_extend(self, batch, carry: AnytimeCarry, stop: int):
        return anytime_extend(self.theta, self.budgets, self._u, carry, stop)


class CountingToySampler(ToyAnytimeSampler):
    """Eager variant metering batch-level backbone forwards — the NFE
    accounting the gateway tests assert against."""

    def __init__(self, budgets: Sequence[int] = (2, 4), seed: int = 0,
                 jitter: float = 0.1):
        super().__init__(budgets=budgets, seed=seed, jitter=jitter, jit=False)
        self.forwards = 0

    def on_forward(self) -> None:
        self.forwards += 1


class ToyDecodeEngine:
    """Slot-protocol toy engine for the decode gateway (``init_slot_state``,
    ``step_slots``, ``reset_slots`` — what ``DecodeGateway`` needs), shared
    by ``benchmarks/decode_bench.py`` and the decode-gateway tests.

    The "model" is a deterministic affine map over the vocabulary,
    ``next = (a * token + b + position) % vocab`` — row-independent like the
    real backbones, and position-dependent so positional bugs (a joiner
    inheriting a freed slot's stale index) change the emitted tokens. State
    is just the per-slot position vector; everything runs in numpy, so the
    ``on_step`` hook (fake clock / wall-step counting) fires exactly once
    per engine INVOCATION (decode step or prefill call) with zero compile
    noise — ``prefill_slots`` consumes a whole chunk of prompt tokens per
    row in ONE invocation, which is exactly the wall-step saving the decode
    benchmark measures. Greedy only (``supports_sampling = False``).

    ``page_size > 0`` makes the engine SPEAK the paged protocol (``paged``
    property, ``with_block_table`` no-op) without simulating page contents
    — the position-vector state is already O(1) per slot. The gateway's
    ``PageAllocator`` bookkeeping (reservation, head-of-line blocking,
    free-on-finish, peak tracking) then runs for real against the toy
    workload, which is what the decode benchmark's resident-memory metric
    measures.
    """

    supports_sampling = False

    def __init__(self, vocab: int = 97, a: int = 31, b: int = 7,
                 on_step: Optional[Callable[[], None]] = None,
                 page_size: int = 0):
        self.vocab, self.a, self.b = vocab, a, b
        self.on_step = on_step
        self.page_size = page_size
        self.steps = 0

    @property
    def paged(self) -> bool:
        return self.page_size > 0

    def init_slot_state(self, slots: int, cache_slots: int, dtype=None,
                        total_pages: Optional[int] = None):
        return np.zeros((slots,), np.int64)        # per-slot position

    def with_block_table(self, state, table):
        return state                               # nothing paged to route

    def _tick(self) -> None:
        self.steps += 1
        if self.on_step is not None:
            self.on_step()

    def step_slots(self, token, state, active):
        self._tick()
        token = np.asarray(token, np.int64)
        active = np.asarray(active)
        nxt = (self.a * token + self.b + state) % self.vocab
        return nxt.astype(np.int32), np.where(active, state + 1, state)

    def prefill_slots(self, tokens, lengths, state, mask):
        """Chunked prefill: one engine invocation advances each masked
        row's position by its (teacher-forced) token count — predictions
        during prefill are discarded, so only the position moves."""
        self._tick()
        lengths = np.asarray(lengths, np.int64)
        return np.where(np.asarray(mask), state + lengths, state)

    def reset_slots(self, state, free):
        return np.where(np.asarray(free), 0, state)

    def solo_tokens(self, prompt, max_tokens: int,
                    stop_token: Optional[int] = None) -> list[int]:
        """Reference: decode one sequence alone (the bit-identity oracle
        for slot-refill tests)."""
        out: list[int] = []
        pos, tok = 0, int(prompt[0])
        fed = 1
        while True:
            nxt = (self.a * tok + self.b + pos) % self.vocab
            pos += 1
            if fed < len(prompt):
                tok = int(prompt[fed])
                fed += 1
                continue
            if stop_token is not None and nxt == stop_token:
                return out
            out.append(int(nxt))
            if len(out) >= max_tokens:
                return out
            tok = int(nxt)
