"""Shape-tier ladder: one slot pool for heterogeneous multi-modal traffic.

Every scheduling layer above the sampler groups work by a ``shape_key``
tuple — flush buckets (``BatchScheduler.plan``), continuous trajectories
(``ContinuousScheduler.plan_start``/``plan_joins``), and fleet affinity
(``repro.serving.fleet.default_affinity``). With exact shapes as the key,
an audio clip of 15 latent positions and one of 16 can never share a
flush batch, a trajectory slot, or a jit program — heterogeneous traffic
fragments into per-shape puddles and the batching win evaporates.

A ``ShapeLadder`` fixes the key, not the schedulers: requests are padded
along their leading (sequence / resolution) axis up to the smallest
configured rung at SUBMIT time, so the ``shape_key`` every scheduler
already groups on IS the tier key, and one slot pool serves every shape
in a tier. The entry records its native shape; every settle path crops
the padded row back before it reaches the caller.

Bit-identity contract
---------------------
Tier padding extends the existing padded-batch contract from the BATCH
axis to the POSITION axis: pad positions are zeros, and positions must be
independent through the field for the crop to return exactly the direct
sampler's output at the native shape (the NS update itself is elementwise
— see ``core.ns_solver`` — so independence of the field is the only
requirement). That holds for per-position fields (the analytic toy field,
any pointwise score model); a backbone that mixes positions (full
attention without masking) would need a position mask to keep the
guarantee, which is why tiering is strictly OPT-IN (``tiers=None``
preserves today's exact-shape behaviour) and the invariant is asserted
against the direct-sampler oracle in ``tests/test_tiers.py`` and the
mixed-modality ``continuous_bench`` scenario.

Samples with no position axis (``ndim < 2``, e.g. the toy benches' bare
``(d,)`` points) are never padded: each such shape is its own exact tier.
Requests LONGER than the top rung are rejected at submit with
``TierOversize`` — silently serving them unpadded would fragment the pool
the ladder exists to unify.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


class TierOversize(ValueError):
    """The request's position axis exceeds the ladder's top rung. Raised
    at submit, before the request is ever queued or counted — the caller
    gets the configured rungs so the fix (raise the ladder, or shrink the
    request) is in the message."""

    def __init__(self, length: int, rungs: Sequence[int]):
        super().__init__(
            f"request has {length} positions but the tier ladder tops out "
            f"at {max(rungs)} (rungs={tuple(rungs)}); raise --tiers or "
            f"shorten the request")
        self.length = length
        self.rungs = tuple(rungs)


@dataclasses.dataclass(frozen=True)
class ShapeLadder:
    """Configured seq-length / resolution rungs, sorted ascending.

    ``rung(n)`` maps a native length to the smallest rung holding it;
    ``tier_shape(shape)`` maps a sample shape to its padded tier shape;
    ``request_key`` maps a request's (tokens, x0) shapes to the tier key
    the fleet router hashes (so near-shapes home to the same host).
    """

    rungs: tuple

    def __post_init__(self):
        rungs = tuple(sorted(set(int(r) for r in self.rungs)))
        if not rungs:
            raise ValueError("ShapeLadder needs at least one rung")
        if rungs[0] < 1:
            raise ValueError(f"rungs must be positive, got {rungs}")
        object.__setattr__(self, "rungs", rungs)

    @classmethod
    def parse(cls, text: str) -> "ShapeLadder":
        """Build from the CLI form ``"8,16,32"`` (``serve.py --tiers``)."""
        try:
            rungs = tuple(int(tok) for tok in text.split(",") if tok.strip())
        except ValueError:
            raise ValueError(
                f"--tiers expects comma-separated ints, got {text!r}")
        return cls(rungs)

    def rung(self, length: int) -> int:
        """Smallest rung >= ``length``; ``TierOversize`` past the top."""
        for r in self.rungs:
            if r >= length:
                return r
        raise TierOversize(length, self.rungs)

    def rung_for(self, shape: Sequence[int]) -> Optional[int]:
        """The rung for a sample shape, or None when the shape has no
        position axis (``ndim < 2``: its own exact tier, never padded)."""
        if len(shape) < 2:
            return None
        return self.rung(shape[0])

    def tier_shape(self, shape: Sequence[int]) -> tuple:
        """The padded shape a sample of ``shape`` is served at."""
        shape = tuple(shape)
        r = self.rung_for(shape)
        return shape if r is None else (r,) + shape[1:]

    def request_key(self, tok_shape: Optional[tuple],
                    x0_shape: Optional[tuple]) -> tuple:
        """Tier the (tokens, x0) shape pair of a not-yet-submitted request
        — the fleet affinity key. The rung comes from the x0 position axis
        when x0 is explicit, else from the token length (the gateway
        generates x0 as ``(len(tokens), latent_dim)``); both axes tier to
        the SAME rung so the key matches the submitted entry's padded
        ``shape_key``. Oversize falls back to the exact shapes — routing
        must not raise for a request submit() will reject anyway."""
        length = None
        if x0_shape is not None and len(x0_shape) >= 2:
            length = x0_shape[0]
        elif x0_shape is None and tok_shape:
            length = tok_shape[0]
        if length is None:
            return (tok_shape, x0_shape)
        try:
            r = self.rung(length)
        except TierOversize:
            return (tok_shape, x0_shape)
        tok = None if tok_shape is None else (r,) + tuple(tok_shape[1:])
        x0 = None if x0_shape is None else (r,) + tuple(x0_shape[1:])
        return (tok, x0)

    @staticmethod
    def label(shape: Sequence[int]) -> str:
        """Metric-label form of a tier shape (``(16, 2)`` -> ``"16x2"``)."""
        return "x".join(str(int(d)) for d in shape)


def pad_rows(arr, rung: int):
    """Zero-pad ``arr`` along its leading (position) axis up to ``rung``
    — the position-axis twin of ``assemble_rows``' batch padding, and the
    single definition of the tier pad contract (zero positions, cropped
    back at settle). Host numpy: padding happens once at submit, not per
    dispatch."""
    arr = np.asarray(arr)
    pad = rung - arr.shape[0]
    if pad <= 0:
        return arr
    return np.concatenate(
        [arr, np.zeros((pad,) + arr.shape[1:], arr.dtype)])


def crop_row(row, native_shape: Optional[tuple]):
    """Crop one settled row back to its native extent (no-op for untiered
    entries and exact-rung shapes). Every settle path — flush scatter,
    trajectory release, streaming partial — goes through this."""
    if native_shape is None or tuple(row.shape) == tuple(native_shape):
        return row
    return row[:native_shape[0]]
