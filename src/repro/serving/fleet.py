"""Fleet tier: multi-host gateway federation over a sharded request queue.

One gateway feeds one process; the ROADMAP north-star is millions of users.
Because a BNS solver artifact is tiny (<200 params), replicating the SOLVER
across hosts is free — the scaling problem is purely request distribution.
This module federates N per-host gateways (each a ``Gateway`` /
``ContinuousGateway`` / ``DecodeGateway`` — anything built on
``GatewayBase``) behind one ``submit(request) -> Future``:

* **Sharded request queue.** There is no central queue to contend on: each
  host gateway's own ``RequestQueue`` is one SHARD, and a submit routes
  straight to its home shard. The fleet-wide queue is the union of shards;
  entries carry fleet-unique uids (``GatewayBase.federate`` shares one
  counter) so they can migrate between shards without identity collisions.
* **Host-affinity routing.** ``FleetRouter`` deterministically assigns each
  request a home host by rendezvous (highest-random-weight) hashing of its
  AFFINITY KEY — (budget, sample shape) for flow (the TIER shape when the
  hosts run a ``ShapeLadder``), a max-tokens bucket for decode. Same-key
  requests congregate on one host, so that host's jit
  program cache for the (budget, bucket) pair stays hot and its batches
  coalesce denser; and because HRW is a pure function of (key, live host
  set, seed), the same trace on the same fleet yields the same assignments
  every run — CI asserts this.
* **Work stealing.** Affinity under a skewed mix overloads the hot keys'
  hosts while others idle. ``WorkStealer`` migrates QUEUED (never
  in-flight) entries from the deepest shards to idle hosts:
  ``GatewayBase.steal`` pops under the victim's plan lock (an entry still
  in the queue was, by that lock, never planned into a batch or
  trajectory), ``inject`` pushes into the thief. Migration moves only
  host-side bookkeeping — noise/latents are untouched, so a stolen
  request's sample is still bit-identical to the single-gateway path.
* **Graceful join/leave.** ``add_host`` registers a live host (HRW re-homes
  only the keys the new host wins — no global reshuffle); ``remove_host``
  stops routing to the leaver, migrates its whole queue shard to the
  survivors, then drains its in-flight work with a BOUNDED
  ``drain(timeout=)`` — no future is ever dropped, and a wedged engine
  raises ``DrainTimeout`` (with a stats snapshot) instead of wedging the
  fleet.

Bit-identity invariant: rows are independent through the backbone and the
anytime trajectory is exact, so WHERE a request is served (which host,
which batch, before/after a steal) never changes its sample — only x0
resolution could, and ``federate`` pins that to the fleet-wide submission
index exactly as a lone gateway numbers its own submits. The fleet is
therefore free to route and rebalance purely for latency/occupancy.

``stats()`` aggregates the shared ``GatewayStats`` counters across hosts
and adds the fleet view: per-host queue depths, occupancy, routed counts,
steal totals. Tested on emulated multi-device CPU (see
``repro.distributed.emulate``) every push.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import threading
import time
from concurrent.futures import Future
from typing import Callable, Mapping, Optional, Sequence, Union

import jax

from repro.observability import (
    NULL_RECORDER,
    MetricsRegistry,
    merge_snapshots,
)
from repro.serving.gateway import (
    GatewayBase,
    HostLoad,
    Request,
    stats_projection,
)
from repro.serving.stream import ResponseStream


def default_affinity(request, top_budget: Optional[int] = None,
                     tiers=None) -> tuple:
    """The routing key: requests sharing it share a home host (and thus a
    host-local jit program cache). Flow requests group by (budget, TIER
    shape) when the hosts run a ``ShapeLadder`` — raw shapes fragmented
    the fleet: two requests one position apart hashed to different homes
    and could never share a stolen batch, defeating the tier pool the
    hosts would have grouped them into. Without a ladder the key falls
    back to the exact (token shape, explicit-x0 shape). Decode requests
    group by power-of-two max-tokens bucket (the decode engine compiles
    one scan program per step count)."""
    if isinstance(request, Request):
        budget = request.budget if request.budget is not None else top_budget
        tok = None if request.tokens is None else tuple(request.tokens.shape)
        x0 = None if request.x0 is None else tuple(request.x0.shape)
        if tiers is not None:
            tok, x0 = tiers.request_key(tok, x0)
        return ("flow", budget, tok, x0)
    if hasattr(request, "prompt") and hasattr(request, "max_tokens"):
        bucket = 1
        while bucket < request.max_tokens:
            bucket *= 2
        return ("decode", bucket)
    raise TypeError(f"no affinity key for request type {type(request)!r}; "
                    "pass affinity= to FleetGateway")


def entry_affinity(entry) -> tuple:
    """Routing key recomputed from a QUEUED entry (used when a leaving
    host's shard is re-homed — the original request object is gone). May
    differ from the submit-time key (budgets are resolved by then), which
    only moves WHERE the entry lands, never what it samples. A tiered
    entry's ``shape_key`` already holds the padded tier shape, so this
    key is (budget, tier) without knowing the ladder."""
    if hasattr(entry, "shape_key"):                  # flow _Entry
        return ("flow", entry.requested, *entry.shape_key)
    if hasattr(entry, "prompt") and hasattr(entry, "max_tokens"):
        bucket = 1
        while bucket < entry.max_tokens:
            bucket *= 2
        return ("decode", bucket)
    raise TypeError(f"no affinity key for entry type {type(entry)!r}")


class FleetRouter:
    """Deterministic affinity routing via rendezvous (HRW) hashing.

    Each (key, host) pair gets a stable weight ``md5(seed|host|key)``;
    the key's home is the max-weight LIVE host. Properties the fleet
    leans on: pure function of (key, host set, seed) — same trace, same
    fleet, same assignments, every run and every process (md5, unlike
    ``hash()``, is unsalted); removing a host re-homes ONLY that host's
    keys; adding one re-homes only the keys it now wins. Keys are
    canonicalized via ``repr`` (tuples of ints/None/strings only).
    md5 and not crc32: CRC is linear over GF(2), so a seed change XORs
    every same-length weight by one constant and almost never flips the
    argmax — the seed would be dead.
    """

    def __init__(self, hosts: Sequence[str] = (), seed: int = 0):
        self.seed = seed
        self._hosts: list[str] = list(hosts)

    @property
    def hosts(self) -> tuple[str, ...]:
        return tuple(self._hosts)

    def add(self, name: str) -> None:
        if name in self._hosts:
            raise ValueError(f"host {name!r} already routed")
        self._hosts.append(name)

    def remove(self, name: str) -> None:
        self._hosts.remove(name)

    def weight(self, key: tuple, host: str) -> int:
        blob = f"{self.seed}|{host}|{key!r}".encode()
        return int.from_bytes(hashlib.md5(blob).digest()[:8], "big")

    def route(self, key: tuple) -> str:
        if not self._hosts:
            raise RuntimeError("fleet has no hosts to route to")
        return max(self._hosts, key=lambda h: (self.weight(key, h), h))


@dataclasses.dataclass
class WorkStealer:
    """Deterministic shard rebalancing policy (pure planning, no state).

    ``plan`` pairs each idle thief (empty-enough queue, nothing in flight)
    with the then-deepest victim shard and moves up to ``max_steal``
    entries — half the victim's queue, so one round neither empties the
    victim (its own device is about to flush a batch) nor floods the
    thief. A victim must hold at least ``min_queue`` queued entries:
    below that the home host's next flush serves them sooner than a
    migration plus a cold jit program would.

    Victims holding URGENT entries (``HostLoad.urgent`` — queued deadlines
    or raised priorities) are preferred over merely-deep shards, so SLO
    pressure migrates to idle hosts first; ``GatewayBase.steal`` pops in
    urgency order, so the moved entries are exactly the most urgent ones.
    With no urgent work anywhere the plan is identical to the legacy
    deepest-first policy.
    """

    min_queue: int = 2
    max_steal: int = 8
    idle_depth: int = 0

    def plan(self, loads: Mapping[str, HostLoad],
             thieves: Optional[Sequence[str]] = None
             ) -> list[tuple[str, str, int]]:
        """Moves ``(victim, thief, n)`` for one steal round — a pure
        function of the load snapshot (hosts visited in sorted order, so
        the round is deterministic). ``thieves`` overrides idleness
        detection (the fake-clock bench knows device busyness the load
        snapshot cannot see)."""
        if self.max_steal < 1:
            return []
        depth = {h: loads[h].queue_depth for h in loads}
        if thieves is None:
            thieves = [h for h in sorted(loads)
                       if loads[h].queue_depth <= self.idle_depth
                       and loads[h].inflight == 0]
        moves: list[tuple[str, str, int]] = []
        for thief in sorted(thieves):
            if thief not in depth:
                continue
            victims = [h for h in sorted(depth)
                       if h != thief and h not in thieves
                       and depth[h] >= max(self.min_queue, 1)]
            if not victims:
                break
            victim = max(victims, key=lambda h: (
                getattr(loads[h], "urgent", 0), depth[h], h))
            n = min(self.max_steal, (depth[victim] + 1) // 2)
            if n < 1:
                continue
            depth[victim] -= n
            moves.append((victim, thief, n))
        return moves


@dataclasses.dataclass
class _Host:
    """One federated host: its gateway (whose queue is this host's shard)
    plus fleet-side bookkeeping."""

    name: str
    gateway: GatewayBase
    routed: int = 0          # requests homed here by the router


class FleetGateway:
    """N per-host gateways behind one ``submit(request) -> Future``.

    ``hosts`` maps name -> gateway (or is a sequence, named ``h0..hN-1``).
    All hosts must serve the same replicated solver/engine — the router
    may send any request anywhere (stealing and leave-migration do).
    Registration calls ``GatewayBase.federate`` on each host, so build
    hosts fresh and submit only through the fleet.

    Manual mode (tests/benchmarks): ``pump()`` ticks every host once plus
    one steal round, on whatever fake clock the host gateways share.
    Threaded mode: ``start()`` runs each host's serve thread plus a fleet
    balancer thread running steal rounds. ``drain/stop/shutdown`` mirror
    ``GatewayBase``; ``drain(timeout=)`` bounds the whole fleet drain.
    """

    def __init__(self, hosts: Union[Mapping[str, GatewayBase],
                                    Sequence[GatewayBase]], *,
                 router: Optional[FleetRouter] = None,
                 stealer: Optional[WorkStealer] = None,
                 steal: bool = True,
                 affinity: Optional[Callable] = None,
                 key=None, seed: int = 0,
                 metrics: Optional[MetricsRegistry] = None, recorder=None):
        if not isinstance(hosts, Mapping):
            hosts = {f"h{i}": gw for i, gw in enumerate(hosts)}
        if not hosts:
            raise ValueError("a fleet needs at least one host")
        self.router = router if router is not None else FleetRouter(seed=seed)
        self.stealer = (stealer if stealer is not None
                        else WorkStealer() if steal else None)
        self._affinity = affinity
        self._base_key = key if key is not None else jax.random.PRNGKey(0)
        self._uids = itertools.count()   # ONE uid namespace across shards
        self._lock = threading.RLock()   # membership + routing + intake
        # fleet-level registry: only the counters that belong to the
        # FEDERATION itself (stealing/rerouting); everything else lives in
        # the per-host registries and is merged at snapshot time
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._stats_lock = self.metrics.lock
        self._m_steals = self.metrics.counter(
            "steals", "entries migrated by the work stealer")
        self._m_steal_rounds = self.metrics.counter(
            "steal_rounds", "rebalance rounds that moved >= 1 entry")
        self._m_rerouted = self.metrics.counter(
            "rerouted", "entries re-homed by a host leave")
        # ONE recorder fleet-wide: every host stamps events into it with
        # its host label, so a stolen request's hops interleave in order
        self.recorder = recorder if recorder is not None else NULL_RECORDER
        self._hosts: dict[str, _Host] = {}
        self._closed = False
        self._running = False
        self._poll_s = 0.001
        self._stop = threading.Event()
        self._balancer: Optional[threading.Thread] = None
        for name, gw in hosts.items():
            self.add_host(name, gw)

    # -- membership ----------------------------------------------------------

    def add_host(self, name: str, gateway: GatewayBase) -> None:
        """Join ``name`` to the fleet: share the uid namespace/base key,
        enter the routing table (HRW re-homes only the keys it wins), and
        start its serve thread if the fleet is running."""
        with self._lock:
            if self._closed:
                raise RuntimeError("fleet is draining; no new hosts")
            if name in self._hosts:
                raise ValueError(f"host {name!r} already in the fleet")
            gateway.federate(self._uids, self._base_key,
                             recorder=self.recorder if self.recorder
                             else None, host=name)
            self.router.add(name)
            self._hosts[name] = _Host(name=name, gateway=gateway)
            if self._running:
                gateway.start(self._poll_s)

    def remove_host(self, name: str,
                    timeout: Optional[float] = None) -> GatewayBase:
        """Graceful leave. Under the fleet lock: stop routing to ``name``
        and migrate its ENTIRE queue shard to the survivors (re-homed by
        entry affinity — deterministic, and HRW leaves the survivors' own
        keys untouched). Outside the lock: drain its in-flight work
        (bounded by ``timeout`` — raises ``DrainTimeout`` on a wedged
        engine, queued work already safe) and stop its thread. No future
        is dropped either way. Returns the detached gateway (closed; a
        rejoin needs a fresh one)."""
        with self._lock:
            if name not in self._hosts:
                raise KeyError(f"host {name!r} not in the fleet")
            if len(self._hosts) == 1:
                raise RuntimeError(
                    "cannot remove the last host; drain the fleet instead")
            host = self._hosts.pop(name)
            self.router.remove(name)
            moved = host.gateway.steal(None)         # the whole shard
            by_dest: dict[str, list] = {}
            for e in moved:
                by_dest.setdefault(self.router.route(entry_affinity(e)),
                                   []).append(e)
            for dest, es in by_dest.items():
                self._hosts[dest].gateway.inject(es)
        if moved:
            self._m_rerouted.inc(len(moved))
        host.gateway.drain(timeout=timeout)
        host.gateway.stop()
        return host.gateway

    @property
    def hosts(self) -> tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._hosts))

    # -- intake --------------------------------------------------------------

    def _key_of(self, request) -> tuple:
        if self._affinity is not None:
            return self._affinity(request)
        gw = next(iter(self._hosts.values())).gateway
        sampler = getattr(gw, "sampler", None)
        top = getattr(sampler, "budgets", (None,))[-1]
        # tier-aware routing: when the hosts pad to a shape ladder, hash
        # the TIER key so near-shapes home together (entry_affinity sees
        # the padded shape_key, so steal/re-home keys agree with this)
        return default_affinity(request, top_budget=top,
                                tiers=getattr(gw, "tiers", None))

    def home(self, request) -> str:
        """The deterministic home host for ``request`` (no submission)."""
        with self._lock:
            return self.router.route(self._key_of(request))

    def submit(self, request=None, **kw) -> Future:
        """Route one request to its home shard. Serialized under the fleet
        lock so fleet-wide submission order (= the shared uid order that
        seeds folded noise keys) is well defined."""
        if request is None:
            request = Request(**kw)
        with self._lock:
            if self._closed:
                raise RuntimeError("fleet is draining; no new requests")
            host = self._hosts[self.router.route(self._key_of(request))]
            future = host.gateway.submit(request)
            host.routed += 1
            rec = self.recorder
            if rec:
                # the home gateway stamped "submit"; the routing decision
                # is fleet-level, so it is stamped here (future.uid is set
                # by GatewayBase._enqueue before submit returns)
                rec.event(future.uid, "route", host.gateway.clock(),
                          host=host.name)
        return future

    def submit_stream(self, request=None, **kw) -> ResponseStream:
        """Streamed submit through the fleet: routes like ``submit`` and
        returns the home gateway's ``ResponseStream``. Work stealing never
        moves the sink (it rides the entry), so a stolen streamed request
        keeps emitting to the same stream from its new host."""
        if request is None:
            with self._lock:
                rtype = next(iter(self._hosts.values())).gateway._request_type
            request = rtype(**kw)
        request.stream = True
        future = self.submit(request)
        return ResponseStream(future, future.stream_sink)

    # -- stealing ------------------------------------------------------------

    def steal_round(self, thieves: Optional[Sequence[str]] = None) -> int:
        """One rebalancing round; returns entries moved. Load snapshots,
        the plan, and each migration are per-host atomic (victim plan
        lock), so rounds interleave safely with serve threads."""
        if self.stealer is None:
            return 0
        with self._lock:
            gateways = {n: h.gateway for n, h in self._hosts.items()}
        loads = {n: gw.load() for n, gw in gateways.items()}
        moved = 0
        for victim, thief, n in self.stealer.plan(loads, thieves=thieves):
            entries = gateways[victim].steal(n)
            if not entries:
                continue                  # victim flushed them first: fine
            try:
                gateways[thief].inject(entries)
            except RuntimeError:
                try:                      # thief began draining mid-round
                    gateways[victim].inject(entries)
                except RuntimeError as exc:
                    # both shards closed between plan and move: surface —
                    # an entry must never vanish with a live future
                    gateways[victim]._fail_entries(entries, exc,
                                                   count_all=True)
            else:
                moved += len(entries)
        if moved:
            with self._stats_lock:
                self._m_steals.inc(moved)
                self._m_steal_rounds.inc()
        return moved

    # -- manual engine tick (fake clock) -------------------------------------

    def pump(self, force: bool = False,
             hosts: Optional[Sequence[str]] = None) -> int:
        """Tick the named (default: all) hosts once, then one steal round;
        returns dispatches run plus entries migrated."""
        with self._lock:
            selected = [(n, self._hosts[n].gateway)
                        for n in (hosts if hosts is not None
                                  else sorted(self._hosts))
                        if n in self._hosts]
        ran = sum(gw.pump(force=force) for _, gw in selected)
        return ran + self.steal_round()

    # -- lifecycle -----------------------------------------------------------

    def start(self, poll_s: float = 0.001,
              balance_s: float = 0.002) -> None:
        """Start every host's serve thread plus the fleet balancer."""
        with self._lock:
            self._running = True
            self._poll_s = poll_s
            for h in self._hosts.values():
                h.gateway.start(poll_s)
        if self._balancer is None or not self._balancer.is_alive():
            self._stop.clear()

            def balance():
                while not self._stop.is_set():
                    self.steal_round()
                    time.sleep(balance_s)

            self._balancer = threading.Thread(target=balance,
                                              name="fleet-balance",
                                              daemon=True)
            self._balancer.start()

    def drain(self, timeout: Optional[float] = None) -> None:
        """Refuse new requests, then drain every shard. ``timeout`` bounds
        the WHOLE fleet drain (hosts share the remaining budget; a host
        hitting zero raises ``DrainTimeout`` — queued entries on later
        hosts are still safe in their shards, drain again to continue)."""
        with self._lock:
            self._closed = True
            hosts = [h.gateway for _, h in sorted(self._hosts.items())]
        deadline = (None if timeout is None
                    else time.monotonic() + max(timeout, 0.0))
        for gw in hosts:
            gw.drain(timeout=None if deadline is None
                     else max(deadline - time.monotonic(), 0.0))

    def stop(self) -> None:
        self._stop.set()
        if self._balancer is not None:
            self._balancer.join(timeout=10)
            self._balancer = None
        with self._lock:
            self._running = False
            hosts = list(self._hosts.values())
        for h in hosts:
            h.gateway.stop()

    def shutdown(self, timeout: Optional[float] = None) -> None:
        self.drain(timeout=timeout)
        self.stop()

    # -- metrics -------------------------------------------------------------

    def metrics_snapshot(self) -> dict:
        """The fleet-wide registry snapshot: the MERGE of every host's
        registry plus the fleet's own (steals/rerouting). Counters and
        gauges sum; wait histograms merge bucket-wise (exact), so the
        fleet p95 is computed from the combined distribution — not some
        average of per-host percentiles."""
        with self._lock:
            snaps = [h.gateway.metrics.snapshot()
                     for _, h in sorted(self._hosts.items())]
        snaps.append(self.metrics.snapshot())
        return merge_snapshots(snaps)

    def stats(self) -> dict:
        """Fleet-aggregated serving metrics plus the per-host view.

        The aggregate IS ``stats_projection`` over the merged per-host
        registry snapshots — identical code path to a single gateway, so
        occupancy / nfe_per_request / mean_wait come from summed
        numerators and denominators (a mean of ratios would weight empty
        hosts equally with busy ones) and the wait percentiles come from
        the merged histogram. ``queue_depths``/``routed`` expose the
        shard balance the stealer works against; ``per_host`` holds each
        host's full ``stats()``."""
        with self._lock:
            items = sorted(self._hosts.items())
            per_host = {n: dict(h.gateway.stats(), routed=h.routed)
                        for n, h in items}
            snaps = [h.gateway.metrics.snapshot() for _, h in items]
            clock = items[0][1].gateway.clock
            started = min(h.gateway._started for _, h in items)
        snaps.append(self.metrics.snapshot())
        merged = merge_snapshots(snaps)
        out = stats_projection(merged, clock() - started)
        out.update({
            "hosts": len(per_host),
            "queue_depths": {n: s["queue_depth"]
                             for n, s in per_host.items()},
            "routed": {n: s["routed"] for n, s in per_host.items()},
            "steals": int(merged.get("steals", 0)),
            "steal_rounds": int(merged.get("steal_rounds", 0)),
            "rerouted": int(merged.get("rerouted", 0)),
            "per_host": per_host,
        })
        return out
