"""Streaming handles: incremental results riding the existing settle path.

``GatewayBase.submit_stream`` returns a ``ResponseStream`` — an iterator
of ``StreamChunk``s fed by the serving tiers at their natural progress
points:

* FLOW (``ContinuousGateway``): one ``partial`` chunk per anytime EXIT
  BOUNDARY the request's trajectory crosses before its own exit — the
  early-exit latents at budget k are exactly what a budget-k request with
  the same noise would have received (the anytime grid is nested), so
  every partial is itself a valid sample at a smaller NFE.
* DECODE (``DecodeGateway``): one ``partial`` chunk per generated token,
  emitted the same tick the token lands in ``slot.emitted``.

The TERMINAL chunk carries the very ``Response``/``DecodeResponse`` the
request's ``Future`` resolves with — streaming adds emission points but
never forks the settle path, so a streamed request's final result is
bit-identical to the plain ``submit`` of the same request (asserted in
``tests/test_slo.py``). Failures surface as the original exception from
the iterator, mirroring ``Future.result()``.

The sink is a plain ``queue.Queue``: producers (serve threads, pumps)
never block, and a consumer iterating a stream whose gateway died waits
on ``timeout`` (default: forever, like ``Future.result()``).
"""
from __future__ import annotations

import dataclasses
import queue
from typing import Any, Optional

_PARTIAL, _FINAL, _ERROR = "partial", "final", "error"


@dataclasses.dataclass
class StreamChunk:
    """One streamed increment. ``kind`` is ``"partial"`` or ``"final"``;
    ``payload`` is a latents row at an exit boundary (flow) or one token
    id (decode) for partials, and the full ``Response``/``DecodeResponse``
    for the terminal chunk; ``meta`` records where the partial came from
    (flow: ``boundary``; decode: ``index``)."""

    kind: str
    payload: Any
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def final(self) -> bool:
        return self.kind == _FINAL


class StreamSink:
    """Producer side: the gateway pushes partials/final/error; never
    blocks. One sink per streamed entry, attached at submit."""

    __slots__ = ("_q",)

    def __init__(self) -> None:
        self._q: queue.Queue = queue.Queue()

    def partial(self, payload: Any, **meta: Any) -> None:
        self._q.put((_PARTIAL, payload, meta))

    def final(self, response: Any) -> None:
        self._q.put((_FINAL, response, None))

    def error(self, exc: BaseException) -> None:
        self._q.put((_ERROR, exc, None))


class ResponseStream:
    """Consumer side: iterate chunks until the terminal one (which carries
    the settled response); raises the settle exception like
    ``Future.result()`` would. ``result(timeout=)`` delegates to the
    underlying future for callers that only want the terminal value."""

    def __init__(self, future, sink: StreamSink,
                 timeout: Optional[float] = None):
        self.future = future
        self._sink = sink
        self._timeout = timeout
        self._done = False

    def __iter__(self):
        while not self._done:
            kind, payload, meta = self._sink._q.get(timeout=self._timeout)
            if kind == _ERROR:
                self._done = True
                raise payload
            if kind == _FINAL:
                self._done = True
                yield StreamChunk(_FINAL, payload)
                return
            yield StreamChunk(_PARTIAL, payload, meta or {})

    def chunks(self, timeout: Optional[float] = None) -> list[StreamChunk]:
        """Drain the whole stream (partials + terminal) into a list."""
        self._timeout = timeout if timeout is not None else self._timeout
        return list(self)

    def result(self, timeout: Optional[float] = None):
        return self.future.result(timeout)
