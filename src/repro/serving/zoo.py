"""SolverZoo — a budget-aware cache of solver artifacts for serving.

The zoo maps ``SolverSpec`` keys (the spec is a frozen, hashable dataclass —
the key IS the declarative solver description) to loaded ``SolverArtifact``s
with LRU eviction. A ``get`` resolves in order:

  1. memory hit — the loaded artifact, zero I/O, zero distillation;
  2. disk hit — a ``.msgpack`` artifact indexed by ``scan`` whose stored
     spec equals the requested one is loaded (no distillation);
  3. miss — the spec is distilled lazily via the zoo's ``distill_fn``
     (or ``SolverSpec.distill`` with the ``get`` call's field/pairs) and,
     when the zoo has a ``save_dir``, persisted for the next process.

``stats`` counts hits/misses/loads/distills/evictions/spills so serving can
assert the cache contract (a hit performs zero distillation) and dashboards
can watch the ratio. One anytime artifact covers every budget in its spec,
so multi-NFE serving needs exactly one entry.

Warm-start and spill (the serving-boot policy): ``preload(specs)`` resolves
the top-k specs before traffic arrives, and when the zoo has a ``save_dir``
an LRU eviction SPILLS the artifact to disk instead of dropping it, so the
next ``get`` is a load, never a re-distillation.
"""
from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict
from typing import Callable, Optional

from repro.observability import MetricsRegistry
from repro.solvers.artifact import FORMAT, SolverArtifact
from repro.solvers.spec import SolverSpec


@dataclasses.dataclass
class ZooStats:
    """Legacy counter bundle — a compatibility VIEW over the zoo's
    registry counters (``zoo_hits``/``zoo_misses``/...), not the store."""

    hits: int = 0          # served from memory
    loads: int = 0         # served from a scanned artifact file
    distills: int = 0      # distilled on miss
    misses: int = 0        # loads + distills
    evictions: int = 0     # LRU evictions past capacity
    spills: int = 0        # evicted artifacts saved to save_dir (not dropped)


class SolverZoo:
    """LRU cache of solver artifacts keyed by ``SolverSpec``."""

    def __init__(self, capacity: int = 8, *,
                 distill_fn: Optional[Callable[[SolverSpec], SolverArtifact]] = None,
                 scan_dirs=(), save_dir: Optional[str] = None,
                 metrics: Optional[MetricsRegistry] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.distill_fn = distill_fn
        self.save_dir = save_dir
        # counters live in the (possibly gateway-shared) registry so the
        # cache contract shows up in the same export as serving metrics
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m_hits = self.metrics.counter(
            "zoo_hits", "artifacts served from memory")
        self._m_loads = self.metrics.counter(
            "zoo_loads", "artifacts served from a scanned file")
        self._m_distills = self.metrics.counter(
            "zoo_distills", "artifacts distilled on miss")
        self._m_misses = self.metrics.counter(
            "zoo_misses", "gets not served from memory (loads + distills)")
        self._m_evictions = self.metrics.counter(
            "zoo_evictions", "LRU evictions past capacity")
        self._m_spills = self.metrics.counter(
            "zoo_spills", "evicted artifacts saved to save_dir, not dropped")
        self._cache: "OrderedDict[SolverSpec, SolverArtifact]" = OrderedDict()
        self._paths: dict[SolverSpec, str] = {}
        for d in scan_dirs:
            self.scan(d)

    @property
    def stats(self) -> ZooStats:
        """The legacy ``ZooStats`` view, built from the registry counters."""
        return ZooStats(hits=int(self._m_hits.value),
                        loads=int(self._m_loads.value),
                        distills=int(self._m_distills.value),
                        misses=int(self._m_misses.value),
                        evictions=int(self._m_evictions.value),
                        spills=int(self._m_spills.value))

    # -- disk index ---------------------------------------------------------

    def scan(self, directory: str) -> int:
        """Index saved ``.msgpack`` solver artifacts under ``directory``.

        Reads only each file's JSON meta (cheap); artifacts load lazily on
        ``get``. Non-artifact msgpack files are skipped. Returns how many
        artifacts were indexed.
        """
        from repro.checkpoint import checkpointer

        found = 0
        if not os.path.isdir(directory):
            return 0
        for name in sorted(os.listdir(directory)):
            if not name.endswith(".msgpack"):
                continue
            path = os.path.join(directory, name)
            try:
                meta = checkpointer.load_meta(path)
            except Exception:
                continue
            if not meta or meta.get("format") != FORMAT:
                continue
            self._paths[SolverSpec.from_dict(meta["spec"])] = path
            found += 1
        return found

    # -- cache --------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._cache)

    def __contains__(self, spec: SolverSpec) -> bool:
        return spec in self._cache

    def specs(self) -> list[SolverSpec]:
        """Cached specs, least- to most-recently used."""
        return list(self._cache)

    def put(self, artifact: SolverArtifact) -> SolverArtifact:
        """Insert (or refresh) an artifact under its own spec key.

        When the insert pushes the zoo past capacity, the LRU entry is
        evicted — and, if the zoo has a ``save_dir`` and the artifact is not
        already indexed on disk, SPILLED there first instead of being
        dropped, so a later ``get`` reloads it without re-distilling.
        """
        spec = artifact.spec
        # the inserted artifact shadows any disk copy of unknown freshness:
        # drop the index entry so a later eviction spills THIS artifact
        # instead of trusting a possibly-stale file (``get`` re-links the
        # path right after its own load/save, where file == artifact holds)
        self._paths.pop(spec, None)
        if spec in self._cache:
            self._cache.move_to_end(spec)
        self._cache[spec] = artifact
        while len(self._cache) > self.capacity:
            spec_e, art_e = self._cache.popitem(last=False)
            self._m_evictions.inc()
            if self.save_dir is not None and spec_e not in self._paths:
                path = os.path.join(self.save_dir, self._filename(spec_e))
                art_e.save(path)
                self._paths[spec_e] = path
                self._m_spills.inc()
        return artifact

    def preload(self, specs, *, field=None, train_pairs=None, val_pairs=None,
                train_cfg=None, log=None) -> list[SolverArtifact]:
        """Warm-start: resolve the top-k specs (by expected traffic, caller-
        ordered) at boot so the first real request never pays a load/distill.

        Specs beyond ``capacity`` would immediately evict one another, so
        only the first ``capacity`` are resolved (with a log note). Returns
        the loaded artifacts in request order.
        """
        specs = list(specs)
        if len(specs) > self.capacity:
            if log:
                log(f"zoo: preloading only the first {self.capacity} of "
                    f"{len(specs)} specs (capacity)")
            specs = specs[:self.capacity]
        return [self.get(s, field=field, train_pairs=train_pairs,
                         val_pairs=val_pairs, train_cfg=train_cfg, log=log)
                for s in specs]

    def get(self, spec: SolverSpec, *, field=None, train_pairs=None,
            val_pairs=None, train_cfg=None, log=None) -> SolverArtifact:
        """The artifact for ``spec`` — cached, loaded from disk, or distilled.

        A memory or disk hit performs zero distillation; only a true miss
        trains, via ``distill_fn`` when the zoo has one, else
        ``spec.distill(field, train_pairs, val_pairs, train_cfg)``.
        """
        art = self._cache.get(spec)
        if art is not None:
            self._m_hits.inc()
            self._cache.move_to_end(spec)
            return art
        self._m_misses.inc()
        path = self._paths.get(spec)
        if path is not None and os.path.exists(path):
            art = SolverArtifact.load(path)
            if art.spec == spec:
                self._m_loads.inc()
                if log:
                    log(f"zoo: loaded {spec.mode}/{spec.name} from {path}")
                art = self.put(art)
                self._paths[spec] = path       # file == artifact, re-link
                return art
            # file changed since it was indexed — never serve the wrong solver
            del self._paths[spec]
        art = self._distill(spec, field, train_pairs, val_pairs, train_cfg,
                            log)
        art = self.put(art)
        if self.save_dir is not None:
            path = os.path.join(self.save_dir, self._filename(spec))
            art.save(path)
            self._paths[spec] = path
            if log:
                log(f"zoo: saved {path}")
        return art

    @staticmethod
    def _filename(spec: SolverSpec) -> str:
        """Readable prefix + full-spec digest: specs differing only in e.g.
        cfg_scale or sigma0 must never collide on disk."""
        import hashlib
        import json

        digest = hashlib.md5(
            json.dumps(spec.to_dict(), sort_keys=True).encode()).hexdigest()
        return f"{spec.mode}_{spec.name}_nfe{spec.nfe}_{digest[:10]}.msgpack"

    def _distill(self, spec, field, train_pairs, val_pairs, train_cfg,
                 log) -> SolverArtifact:
        if self.distill_fn is not None:
            self._m_distills.inc()
            art = self.distill_fn(spec)
        elif field is not None:
            self._m_distills.inc()
            art = spec.distill(field, train_pairs, val_pairs, train_cfg,
                               log=log).artifact()
        else:
            raise KeyError(
                f"{spec} not cached and the zoo cannot distill it (no "
                "distill_fn; pass field/train_pairs/val_pairs to get)")
        if art.spec != spec:
            raise ValueError(f"distill_fn returned artifact for {art.spec}, "
                             f"requested {spec}")
        return art
