"""Decode-side gateway: continuous batching over ``DecodeEngine``.

The flow gateways batch the paper's BNS sampler; this module batches the
serving stack's SECOND engine — autoregressive decode with KV-cache /
recurrent state. Callers ``submit`` a ``DecodeRequest(prompt, max_tokens)``
and get a ``Future[DecodeResponse]``; the gateway multiplexes every accepted
sequence onto the rows of ONE fixed-slot batched decode state
(``DecodeEngine.init_slot_state``), so each engine step costs one backbone
forward for the whole slot batch regardless of how many sequences ride it.

Continuous slot refill
----------------------
* Each sequence owns a STATE SLOT: one row of the batched KV/recurrent
  state, at its own decode position (the per-row ``index`` vector — the
  decode twin of PR 4's trajectory slots, with per-slot write masks instead
  of exit boundaries).
* A sequence finishing (``max_tokens`` reached or ``stop_token`` emitted)
  resolves its future immediately and FREES its slot; queued sequences are
  admitted into freed slots at the very next engine step — the batch never
  drains to empty before refilling (run-to-completion batching does, and
  pays ``max(lengths)`` wall-steps per wave; see ``refill=False`` and
  ``benchmarks/decode_bench.py``).
* Admission resets the slot's state row to zeros (``reset_slots``) and
  feeds the prompt token by token (teacher-forced prefill), then greedy
  decode continues from the prompt's last token. Rows are independent
  through the backbone and each row carries its own position, so a
  sequence admitted into a freed slot produces tokens BIT-IDENTICAL to
  decoding it alone (MoE: in the no-capacity-drop regime, as for batched
  decode generally).

Stop conditions are per slot: ``max_tokens`` caps generation (finish_reason
``"length"``), ``stop_token`` ends it early (``"stop"``; the stop token is
not included in the returned tokens).

Stats ride the shared ``GatewayStats``: ``forwards`` counts engine steps
(one backbone forward each), ``tokens_out``/``tokens_per_s`` the generated
tokens, ``slot_occupancy`` the active-slot share of every step taken;
``trajectories`` counts engine-batch lifetimes (idle -> busy -> idle) and
``joins`` the sequences admitted while other slots were mid-flight — the
continuous-refill events.

``GatewayBase`` supplies intake, the serve-thread lifecycle, drain (waits on
in-flight sequences, not just queue depth), and the ``stats()`` snapshot.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro.serving.gateway import GatewayBase


@dataclasses.dataclass
class DecodeRequest:
    """One user's decode request: prompt tokens (at least one; fed
    teacher-forced), a generation cap, and an optional stop token."""

    prompt: Union[Sequence[int], np.ndarray]
    max_tokens: int = 16
    stop_token: Optional[int] = None


@dataclasses.dataclass
class DecodeResponse:
    """Generated tokens plus serving metadata.

    ``meta`` records: finish_reason ("length" | "stop"), prompt_len,
    new_tokens, steps (engine steps this sequence was resident for =
    backbone forwards it shared), slot, join_step (engine step at
    admission; > 0 means the sequence joined an in-flight batch), wait_ms
    (queue time — waits end at admission).
    """

    tokens: np.ndarray
    meta: dict


@dataclasses.dataclass
class _DecodeEntry:
    uid: int
    prompt: np.ndarray
    max_tokens: int
    stop_token: Optional[int]
    t_submit: float
    future: Future
    t_admit: Optional[float] = None
    join_step: int = 0          # engine step at admission (0 = opened batch)


@dataclasses.dataclass
class _Slot:
    """Host bookkeeping for one occupied state row: the sequence it serves,
    how much of its prompt has been fed, and what it has generated."""

    entry: _DecodeEntry
    pos: int = 1                # prompt tokens already fed
    emitted: list = dataclasses.field(default_factory=list)


class DecodeGateway(GatewayBase):
    """Continuous-batching front-end over one ``DecodeEngine``.

    ``submit(DecodeRequest) -> Future[DecodeResponse]``; ``pump()`` is one
    engine tick: admit queued sequences into free slots, then run one
    write-masked decode step over the slot batch (``engine.step_slots``)
    and advance each active sequence (prefill feed, greedy continue, or
    finish). ``start()``/``drain()``/``shutdown()`` come from
    ``GatewayBase``; the unit tests and ``benchmarks/decode_bench.py``
    drive ``pump`` directly with a fake clock.

    ``refill=False`` degrades admission to run-to-completion batching (new
    sequences wait until EVERY slot is free) — the baseline the decode
    benchmark gates continuous refill against.

    The engine only needs the slot protocol (``init_slot_state``,
    ``step_slots``, ``reset_slots``) — ``DecodeEngine`` for real backbones,
    ``repro.serving.toy.ToyDecodeEngine`` for deterministic simulation.
    """

    def __init__(self, engine, *, max_slots: int = 8, cache_slots: int = 128,
                 dtype=None, refill: bool = True,
                 clock: Callable[[], float] = time.monotonic):
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        if getattr(getattr(engine, "cfg", None), "family", None) == "encdec":
            # encdec decode cross-attends per-sequence ENCODER MEMORY the
            # slot protocol has no hook to supply (init_slot_state zero-
            # fills it) — serving would silently produce garbage tokens
            raise TypeError(
                "DecodeGateway cannot serve encoder-decoder engines: the "
                "slot state has no per-request encoder memory; decode "
                "encdec batches through DecodeEngine.greedy with a "
                "prefilled state instead")
        super().__init__(clock=clock)
        self.engine = engine
        self.max_slots = max_slots
        self.refill = refill
        # non-windowed KV-cache families clamp writes past the cache's last
        # physical slot (silently degraded tokens) — reject over-length
        # requests at submit instead (None = unbounded: ring buffer,
        # recurrent state, toy engines)
        self._capacity = (cache_slots
                          if getattr(engine, "seq_capacity_bounded", False)
                          else None)
        state_kw = {} if dtype is None else {"dtype": dtype}
        self._state = engine.init_slot_state(max_slots, cache_slots,
                                             **state_kw)
        self._slots: list[Optional[_Slot]] = [None] * max_slots
        self._feed = np.zeros((max_slots,), np.int32)   # next token per slot
        self._steps = 0                                  # engine steps run

    # -- intake ---------------------------------------------------------------

    def submit(self, request: Optional[DecodeRequest] = None, **kw) -> Future:
        """Enqueue one sequence; returns a Future[DecodeResponse]."""
        if request is None:
            request = DecodeRequest(**kw)
        prompt = np.asarray(request.prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("prompt needs at least one token")
        if request.max_tokens < 1:
            raise ValueError("max_tokens must be >= 1")
        # worst-case positions used (length-finish): (P-1) prefill steps +
        # max_tokens generation steps write positions 0..P+T-2
        if (self._capacity is not None
                and prompt.size + request.max_tokens - 1 > self._capacity):
            raise ValueError(
                f"prompt ({prompt.size}) + max_tokens "
                f"({request.max_tokens}) exceeds the decode cache capacity "
                f"({self._capacity} slots); raise cache_slots or lower "
                "max_tokens")
        entry = _DecodeEntry(uid=next(self._uid), prompt=prompt,
                             max_tokens=int(request.max_tokens),
                             stop_token=request.stop_token,
                             t_submit=self.clock(), future=Future())
        return self._enqueue(entry)

    # -- engine tick ----------------------------------------------------------

    def pump(self, force: bool = False) -> int:
        """One engine tick: admit into free slots, one masked decode step."""
        with self._plan_lock:
            self._admit()
            active = np.array([s is not None for s in self._slots])
            if not active.any():
                return 0
            try:
                nxt, state = self.engine.step_slots(self._feed.copy(),
                                                    self._state, active)
            except BaseException as exc:  # noqa: BLE001 — see _fail_slots
                self._fail_slots(exc)
                return 1
            self._state = state
            nxt = np.asarray(nxt)
            self._steps += 1
            with self._stats_lock:
                s = self.stats_raw
                s.forwards += 1          # one backbone forward per step
                s.batches += 1
                s.real_rows += int(active.sum())
                s.padded_rows += self.max_slots
                s.slot_steps_active += int(active.sum())
                s.slot_steps_total += self.max_slots
            for i, slot in enumerate(self._slots):
                if slot is not None:
                    self._advance_slot(i, slot, int(nxt[i]))
            return 1

    def _admit(self) -> None:
        """Admit queued sequences (FIFO) into free slots: reset each freed
        row to the zero state and feed the sequence's first prompt token on
        the next step. Admission is immediate — the latency win — unless
        ``refill=False`` holds new sequences until the whole batch drains."""
        free = [i for i, s in enumerate(self._slots) if s is None]
        busy = self.max_slots - len(free)
        if not free or (not self.refill and busy):
            return
        pending = sorted(self.queue.snapshot(),
                         key=lambda e: e.uid)[:len(free)]
        if not pending:
            return
        self._take(pending)
        assigned = list(zip(free, pending))
        mask = np.zeros((self.max_slots,), bool)
        for i, _ in assigned:
            mask[i] = True
        self._state = self.engine.reset_slots(self._state, mask)
        now = self.clock()
        for i, e in assigned:
            e.t_admit, e.join_step = now, self._steps
            self._slots[i] = _Slot(entry=e)
            self._feed[i] = e.prompt[0]
        with self._stats_lock:
            s = self.stats_raw
            if busy:
                s.joins += len(assigned)   # continuous refill mid-flight
            else:
                s.trajectories += 1        # opened a fresh engine batch

    def _advance_slot(self, si: int, slot: _Slot, tok: int) -> None:
        """Advance one active sequence given the model's prediction ``tok``
        for the token its row was just fed."""
        e = slot.entry
        if slot.pos < len(e.prompt):
            # prefill: the prediction is discarded, the next prompt token
            # is fed teacher-forced
            self._feed[si] = e.prompt[slot.pos]
            slot.pos += 1
            return
        if e.stop_token is not None and tok == e.stop_token:
            self._finish(si, slot, "stop")
            return
        slot.emitted.append(tok)
        if len(slot.emitted) >= e.max_tokens:
            self._finish(si, slot, "length")
            return
        self._feed[si] = tok

    def _finish(self, si: int, slot: _Slot, reason: str) -> None:
        """Resolve one sequence's future and free its slot — the next
        ``_admit`` can scatter a fresh sequence into the row."""
        e = slot.entry
        wait_ms = (e.t_admit - e.t_submit) * 1e3
        with self._stats_lock:
            s = self.stats_raw
            s.completed += 1
            s.tokens_out += len(slot.emitted)
            s.sum_wait_ms += wait_ms
            s.max_wait_ms = max(s.max_wait_ms, wait_ms)
            self._inflight -= 1        # taken at admission
        response = DecodeResponse(
            tokens=np.asarray(slot.emitted, np.int32),
            meta={
                "finish_reason": reason,
                "prompt_len": int(len(e.prompt)),
                "new_tokens": len(slot.emitted),
                "steps": self._steps - e.join_step,
                "slot": si,
                "join_step": e.join_step,
                "wait_ms": wait_ms,
            })
        try:
            e.future.set_result(response)
        except Exception:              # cancelled: the batch rolls on
            pass
        self._slots[si] = None

    def _fail_slots(self, exc: BaseException) -> None:
        """Surface a failing engine step into every resident sequence's
        future and free all slots, keeping the serve thread alive (the
        decode twin of ``ContinuousGateway._fail_trajectory``). Freed rows
        hold stale state; admission resets them before reuse."""
        entries = [s.entry for s in self._slots if s is not None]
        self._fail_entries(entries, exc, count_all=True)
        self._settle(len(entries))
        self._slots = [None] * self.max_slots
