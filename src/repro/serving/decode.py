"""Decode-side gateway: continuous batching over ``DecodeEngine``.

The flow gateways batch the paper's BNS sampler; this module batches the
serving stack's SECOND engine — autoregressive decode with KV-cache /
recurrent state. Callers ``submit`` a ``DecodeRequest(prompt, max_tokens)``
and get a ``Future[DecodeResponse]``; the gateway multiplexes every accepted
sequence onto the rows of ONE fixed-slot batched decode state
(``DecodeEngine.init_slot_state``), so each engine step costs one backbone
forward for the whole slot batch regardless of how many sequences ride it.

Continuous slot refill
----------------------
* Each sequence owns a STATE SLOT: one row of the batched KV/recurrent
  state, at its own decode position (the per-row ``index`` vector — the
  decode twin of PR 4's trajectory slots, with per-slot write masks instead
  of exit boundaries).
* A sequence finishing (``max_tokens`` reached or ``stop_token`` emitted)
  resolves its future immediately and FREES its slot; queued sequences are
  admitted into freed slots at the very next engine step — the batch never
  drains to empty before refilling (run-to-completion batching does, and
  pays ``max(lengths)`` wall-steps per wave; see ``refill=False`` and
  ``benchmarks/decode_bench.py``).
* Rows are independent through the backbone and each row carries its own
  position, so a sequence admitted into a freed slot produces tokens
  BIT-IDENTICAL to decoding it alone (MoE: in the no-capacity-drop regime,
  as for batched decode generally).

Chunked batched prefill
-----------------------
Prompts are fed through ``engine.prefill_slots``: every pump tick runs at
most ONE prefill call covering up to ``prefill_chunk`` prompt tokens for
ALL prefilling rows at once, then one decode step over the rows that are
past their prompt. A 100-token prompt therefore costs ~``100/chunk`` engine
invocations instead of 100 decode-step ticks, and sequences mid-generation
keep emitting every tick while long prompts stream in beside them. Chunk
widths are bucketed to powers of two so a serving session compiles at most
``log2(prefill_chunk)`` prefill programs. ``prefill_chunk=0`` restores the
legacy token-by-token teacher-forced feed (the decode benchmark's
comparison baseline). The prefill scan body is the same ``decode_apply``
as ``step_slots``, so generated tokens are bit-identical either way.

Paged KV cache
--------------
A paged engine (``DecodeEngine(page_size=N)``) swaps the dense
``(slots, cache_slots, ...)`` cache rows for a shared page pool plus a
per-row block table (``PagedKVCache``). The gateway owns the
``PageAllocator``: admission reserves ``ceil((P + max_tokens - 1) /
page_size)`` pages up front (FIFO head-of-line blocking when the pool runs
short — a sequence never starts unless it can finish), finish/cancel/fail
returns them, and every free immediately resets the row so its stale block
table points back at the reserved trash page 0 before the freed pages can
be reallocated. Resident KV memory therefore tracks ACTUAL sequence
lengths, not ``max_slots * cache_slots`` worst case — the pool can be
sized to the expected load (``total_pages``) and admission degrades to
queueing, never to corruption.

Sampling
--------
``DecodeRequest.sampling`` (a ``SamplingParams``) switches a sequence from
greedy to temperature / top-k / top-p sampling. Randomness is keyed per
SEQUENCE as ``fold_in(base_key, uid)`` and per STEP by folding in the
emitted-token count, so a request's tokens depend only on (base key, uid,
step): reproducible across restarts, batch compositions, and fleet
re-routing (``GatewayBase.federate`` shares the base key fleet-wide).
Mixed batches cost one program — greedy rows ride the sampled step at
temperature 0, which is an exact argmax.

Stop conditions are per slot: ``max_tokens`` caps generation (finish_reason
``"length"``), ``stop_token`` ends it early (``"stop"``; the stop token is
not included in the returned tokens). A CANCELLED future releases its slot
(and pages) at the next pump instead of decoding to completion — cancelled
sequences count under ``cancelled``, never ``completed``/``tokens_out``.

Stats ride the shared ``GatewayStats``: ``forwards`` counts engine
invocations (prefill calls + decode steps — the wall-step unit),
``prefill_calls``/``prefill_tokens`` the chunked-prefill share,
``tokens_out``/``tokens_per_s`` the generated tokens (settled futures
only), ``slot_occupancy`` the active-slot share of every step taken;
``trajectories`` counts engine-batch lifetimes (idle -> busy -> idle) and
``joins`` the sequences admitted while other slots were mid-flight — the
continuous-refill events. Paged gateways add ``pages_in_use`` /
``peak_pages`` / ``page_size`` to the ``stats()`` snapshot.

``GatewayBase`` supplies intake, the serve-thread lifecycle, drain (waits on
in-flight sequences, not just queue depth), and the ``stats()`` snapshot.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from typing import Any, Callable, Optional, Sequence, Union

import numpy as np

from repro.observability import profile_span
from repro.serving.gateway import GatewayBase
from repro.serving.slo import urgency_key
from repro.serving.stream import StreamSink


@dataclasses.dataclass
class DecodeRequest:
    """One user's decode request: prompt tokens (at least one; fed
    teacher-forced), a generation cap, an optional stop token, and optional
    ``SamplingParams`` (None = greedy)."""

    prompt: Union[Sequence[int], np.ndarray]
    max_tokens: int = 16
    stop_token: Optional[int] = None
    sampling: Optional[Any] = None      # repro.serving.engine.SamplingParams
    # opt-in: attach the recorded lifecycle trace to the DecodeResponse
    trace: bool = False
    # SLO: relative deadline (ms from submit; None = best-effort) and
    # priority (higher first under an SLOConfig; 0 = default)
    deadline_ms: Optional[float] = None
    priority: int = 0
    # per-token streaming (use submit_stream, which sets this)
    stream: bool = False


@dataclasses.dataclass
class DecodeResponse:
    """Generated tokens plus serving metadata.

    ``meta`` records: finish_reason ("length" | "stop"), prompt_len,
    new_tokens, steps (engine steps this sequence was resident for =
    backbone forwards it shared), slot, join_step (engine step at
    admission; > 0 means the sequence joined an in-flight batch), wait_ms
    (queue time — waits end at admission).
    """

    tokens: np.ndarray
    meta: dict
    trace: Optional[list] = None    # recorded lifecycle (opt-in)


class PageAllocator:
    """Host-side free list over the shared KV page pool.

    Page 0 is RESERVED as the trash page: freed/inactive rows' block tables
    point at it, so their in-flight writes inside the one compiled step
    program land harmlessly instead of corrupting reallocated pages. The
    allocator hands out pages 1..total-1; ``peak`` tracks the high-water
    mark (the benchmark's resident-memory gauge)."""

    def __init__(self, total_pages: int):
        if total_pages < 2:
            raise ValueError("total_pages must be >= 2 (page 0 is the "
                             "reserved trash page)")
        self.total = total_pages
        self._free = list(range(total_pages - 1, 0, -1))  # pop() -> page 1 first
        self.peak = 0

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return (self.total - 1) - len(self._free)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: need {n}, have {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        self.peak = max(self.peak, self.in_use)
        return pages

    def free(self, pages: Sequence[int]) -> None:
        self._free.extend(pages)

    def bind(self, registry) -> None:
        """Register lazy gauges into the owning gateway's metrics
        registry — page accounting already lives here, so the registry
        reads it at snapshot time instead of double-booking each
        alloc/free."""
        registry.gauge("pages_in_use",
                       "KV pages allocated out of the shared pool") \
            .set_fn(lambda: self.in_use)
        registry.gauge("peak_pages",
                       "high-water KV pages in use").set_fn(lambda: self.peak)
        registry.gauge("page_pool_total",
                       "allocatable pages (total minus trash page 0)") \
            .set_fn(lambda: self.total - 1)


@dataclasses.dataclass
class _DecodeEntry:
    uid: int
    prompt: np.ndarray
    max_tokens: int
    stop_token: Optional[int]
    t_submit: float
    future: Future
    sampling: Optional[Any] = None
    t_admit: Optional[float] = None
    join_step: int = 0          # engine step at admission (0 = opened batch)
    trace: bool = False         # attach the recorded lifecycle on finish
    deadline: Optional[float] = None    # absolute, on the gateway clock
    priority: int = 0
    sink: Optional[Any] = None          # StreamSink when streaming


@dataclasses.dataclass
class _Slot:
    """Host bookkeeping for one occupied state row: the sequence it serves,
    how much of its prompt has been fed, what it has generated, and (paged)
    which pool pages it owns."""

    entry: _DecodeEntry
    pos: int = 1                # prompt tokens already fed (incl. pending feed)
    emitted: list = dataclasses.field(default_factory=list)
    pages: list = dataclasses.field(default_factory=list)

    @property
    def prefilling(self) -> bool:
        return self.pos < len(self.entry.prompt)


class DecodeGateway(GatewayBase):
    """Continuous-batching front-end over one ``DecodeEngine``.

    ``submit(DecodeRequest) -> Future[DecodeResponse]``; ``pump()`` is one
    engine tick: admit queued sequences into free slots, release cancelled
    ones, run at most one chunked-prefill call over the rows still
    consuming their prompts, then one write-masked decode step over the
    rows past them (``engine.step_slots``) and advance each active
    sequence. ``start()``/``drain()``/``shutdown()`` come from
    ``GatewayBase``; the unit tests and ``benchmarks/decode_bench.py``
    drive ``pump`` directly with a fake clock.

    ``refill=False`` degrades admission to run-to-completion batching (new
    sequences wait until EVERY slot is free) — the baseline the decode
    benchmark gates continuous refill against. ``prefill_chunk=0`` degrades
    prefill to the legacy token-by-token teacher-forced feed.

    The engine only needs the slot protocol (``init_slot_state``,
    ``step_slots``, ``reset_slots``, plus ``prefill_slots`` when
    ``prefill_chunk > 0`` and ``with_block_table`` when paged) —
    ``DecodeEngine`` for real backbones, ``repro.serving.toy.
    ToyDecodeEngine`` for deterministic simulation.
    """

    _request_type = DecodeRequest       # submit_stream builds these

    def __init__(self, engine, *, max_slots: int = 8, cache_slots: int = 128,
                 dtype=None, refill: bool = True, prefill_chunk: int = 64,
                 total_pages: Optional[int] = None, key=None, mesh=None,
                 clock: Callable[[], float] = time.monotonic,
                 metrics=None, recorder=None, slo=None):
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        if prefill_chunk < 0:
            raise ValueError("prefill_chunk must be >= 0 (0 = token-by-token)")
        if getattr(getattr(engine, "cfg", None), "family", None) == "encdec":
            # encdec decode cross-attends per-sequence ENCODER MEMORY the
            # slot protocol has no hook to supply (init_slot_state zero-
            # fills it) — serving would silently produce garbage tokens
            raise TypeError(
                "DecodeGateway cannot serve encoder-decoder engines: the "
                "slot state has no per-request encoder memory; decode "
                "encdec batches through DecodeEngine.greedy with a "
                "prefilled state instead")
        super().__init__(clock=clock, metrics=metrics, recorder=recorder,
                         slo=slo)
        self.engine = engine
        self.max_slots = max_slots
        self.refill = refill
        self.prefill_chunk = prefill_chunk
        # non-windowed KV-cache families clamp writes past the cache's last
        # physical slot (silently degraded tokens) — reject over-length
        # requests at submit instead (None = unbounded: ring buffer,
        # recurrent state, toy engines)
        self._capacity = (cache_slots
                          if getattr(engine, "seq_capacity_bounded", False)
                          else None)
        self._paged = bool(getattr(engine, "paged", False))
        self._alloc: Optional[PageAllocator] = None
        state_kw: dict[str, Any] = {} if dtype is None else {"dtype": dtype}
        if self._paged:
            ps = engine.page_size
            if cache_slots % ps:
                raise ValueError(
                    f"cache_slots ({cache_slots}) must be a multiple of "
                    f"page_size ({ps})")
            blocks = cache_slots // ps
            pages = (1 + max_slots * blocks) if total_pages is None \
                else total_pages
            self._alloc = PageAllocator(pages)
            self._alloc.bind(self.metrics)
            self._table = np.zeros((max_slots, blocks), np.int32)
            state_kw["total_pages"] = pages
        self._state = engine.init_slot_state(max_slots, cache_slots,
                                             **state_kw)
        if mesh is not None:
            from repro.serving import sharded

            engine.params = sharded.shard_params(engine.params, engine.cfg,
                                                 mesh)
            self._state = sharded.place_decode_state(self._state, engine.cfg,
                                                     mesh)
        self._slots: list[Optional[_Slot]] = [None] * max_slots
        self._feed = np.zeros((max_slots,), np.int32)   # next token per slot
        self._steps = 0                                  # engine steps run
        # per-slot sampling buffers (temperature 0 = greedy row)
        self._samp_keys = np.zeros((max_slots, 2), np.uint32)
        self._temps = np.zeros((max_slots,), np.float32)
        self._top_ks = np.zeros((max_slots,), np.int32)
        self._top_ps = np.ones((max_slots,), np.float32)
        self._sampling_resident = 0
        if key is not None:
            self._base_key = key
        elif getattr(engine, "supports_sampling", False):
            import jax

            self._base_key = jax.random.PRNGKey(0)
        else:
            self._base_key = None

    # -- intake ---------------------------------------------------------------

    def submit(self, request: Optional[DecodeRequest] = None, **kw) -> Future:
        """Enqueue one sequence; returns a Future[DecodeResponse]."""
        if request is None:
            request = DecodeRequest(**kw)
        prompt = np.asarray(request.prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("prompt needs at least one token")
        if request.max_tokens < 1:
            raise ValueError("max_tokens must be >= 1")
        # worst-case positions used (length-finish): (P-1) prefill steps +
        # max_tokens generation steps write positions 0..P+T-2
        if (self._capacity is not None
                and prompt.size + request.max_tokens - 1 > self._capacity):
            raise ValueError(
                f"prompt ({prompt.size}) + max_tokens "
                f"({request.max_tokens}) exceeds the decode cache capacity "
                f"({self._capacity} slots); raise cache_slots or lower "
                "max_tokens")
        sampling = request.sampling
        if sampling is not None and sampling.temperature > 0 \
                and not getattr(self.engine, "supports_sampling", False):
            raise ValueError(
                "engine does not support sampling (greedy only); omit "
                "DecodeRequest.sampling or use temperature=0")
        t_submit = self.clock()
        entry = _DecodeEntry(uid=next(self._uid), prompt=prompt,
                             max_tokens=int(request.max_tokens),
                             stop_token=request.stop_token,
                             sampling=sampling,
                             t_submit=t_submit, future=Future(),
                             trace=request.trace,
                             deadline=(None if request.deadline_ms is None
                                       else t_submit
                                       + request.deadline_ms / 1e3),
                             priority=int(request.priority),
                             sink=StreamSink() if request.stream else None)
        self._check_admission(entry)
        return self._enqueue(entry)

    # -- engine tick ----------------------------------------------------------

    def pump(self, force: bool = False) -> int:
        """One engine tick: release cancelled sequences, admit into free
        slots, one chunked-prefill call (if any row is consuming its
        prompt), one masked decode step (if any row is past it)."""
        with self._plan_lock:
            self._sweep_cancelled()
            if self.slo is not None:
                self._shed_expired()
            self._admit()
            did = 0
            if self.prefill_chunk:
                did = self._pump_prefill()
                if did and not any(s is not None and not s.prefilling
                                   for s in self._slots):
                    return 1        # every occupied row is still prefilling
            if self.prefill_chunk:
                active = np.array([s is not None and not s.prefilling
                                   for s in self._slots])
            else:
                active = np.array([s is not None for s in self._slots])
            if not active.any():
                return did
            sampling = self._slot_sampling() if self._sampling_resident else None
            t0 = self.clock()   # gateway clock: fake-clock benches feed the
            #                     SLO cost model simulated dispatch times
            try:
                with profile_span(f"decode.step.k{self.max_slots}"):
                    if sampling is None:
                        nxt, state = self.engine.step_slots(
                            self._feed.copy(), self._state, active)
                    else:
                        nxt, state = self.engine.step_slots(
                            self._feed.copy(), self._state, active,
                            sampling=sampling)
            except BaseException as exc:  # noqa: BLE001 — see _fail_slots
                self._fail_slots(exc)
                return 1
            step_ms = (self.clock() - t0) * 1e3
            self._state = state
            nxt = np.asarray(nxt)
            self._steps += 1
            with self._stats_lock:
                m = self._m
                m.forwards.inc()         # one backbone forward per step
                m.batches.inc()
                m.real_rows.inc(int(active.sum()))
                m.padded_rows.inc(self.max_slots)
                m.slot_steps_active.inc(int(active.sum()))
                m.slot_steps_total.inc(self.max_slots)
                m.device_dispatch_ms.observe(step_ms)
                self._note_program(f"step/k{self.max_slots}")
            for i, slot in enumerate(self._slots):
                if slot is not None and active[i]:
                    self._advance_slot(i, slot, int(nxt[i]))
            return 1

    def _slot_sampling(self):
        """Assemble the per-slot ``SlotSampling`` arrays. Copies — the jit
        call holds the buffers asynchronously and zero-copy aliases numpy
        on CPU, so handing over the live (mutated between pumps) arrays
        would race the dispatch."""
        from repro.serving.engine import SlotSampling

        counts = np.array([len(s.emitted) if s is not None else 0
                           for s in self._slots], np.int32)
        return SlotSampling(keys=self._samp_keys.copy(), counts=counts,
                            temps=self._temps.copy(),
                            top_ks=self._top_ks.copy(),
                            top_ps=self._top_ps.copy())

    def _pages_needed(self, entry: _DecodeEntry) -> int:
        ps = self.engine.page_size
        return -(-(len(entry.prompt) + entry.max_tokens - 1) // ps)

    def _sweep_cancelled(self) -> None:
        """Release slots whose futures the client cancelled — without this
        a cancelled sequence keeps decoding (and holding its row + pages)
        until max_tokens, starving the queue: the slot-leak fix."""
        rec = self.recorder
        for i, slot in enumerate(self._slots):
            if slot is not None and slot.entry.future.cancelled():
                self._release_slot(i, slot)
                with self._stats_lock:
                    self._m.cancelled.inc()
                    self._inflight -= 1       # taken at admission
                if rec:
                    rec.event(slot.entry.uid, "settle", self.clock(),
                              host=self._host, status="cancelled")

    def _admit(self) -> None:
        """Admit queued sequences (FIFO) into free slots: reset each freed
        row to the zero state, reserve pages (paged), and stage the prompt
        (first token fed next step, or chunked prefill from position 0).
        Admission is immediate — the latency win — unless ``refill=False``
        holds new sequences until the whole batch drains. A paged admission
        that cannot reserve its worst-case pages BLOCKS the queue head
        (FIFO) until finishes free pages, rather than skipping ahead."""
        free = [i for i, s in enumerate(self._slots) if s is None]
        busy = self.max_slots - len(free)
        if not free or (not self.refill and busy):
            return
        order = urgency_key if self.slo is not None else (lambda e: e.uid)
        pending = sorted(self.queue.snapshot(), key=order)
        dropped = [e for e in pending if e.future.cancelled()]
        if dropped:
            self._take(dropped)
            self._m.cancelled.inc(len(dropped))
            self._settle(len(dropped))
            pending = [e for e in pending if not e.future.cancelled()]
        admitted = []
        reserve = self._alloc.available if self._alloc is not None else 0
        for e in pending:
            if len(admitted) == len(free):
                break
            if self._alloc is not None:
                need = self._pages_needed(e)
                if need > reserve:
                    break               # head-of-line: keep FIFO order
                reserve -= need
            admitted.append(e)
        if not admitted:
            return
        self._take(admitted)
        assigned = list(zip(free, admitted))
        mask = np.zeros((self.max_slots,), bool)
        for i, _ in assigned:
            mask[i] = True
        self._state = self.engine.reset_slots(self._state, mask)
        now = self.clock()
        for i, e in assigned:
            e.t_admit, e.join_step = now, self._steps
            slot = _Slot(entry=e)
            if self._alloc is not None:
                slot.pages = self._alloc.alloc(self._pages_needed(e))
                self._table[i, :] = 0
                self._table[i, :len(slot.pages)] = slot.pages
            if self.prefill_chunk and len(e.prompt) > 1:
                slot.pos = 0            # chunked prefill feeds the prompt
            else:
                slot.pos = 1
                self._feed[i] = e.prompt[0]
            sp = e.sampling
            if sp is not None and sp.temperature > 0:
                import jax

                self._samp_keys[i] = np.asarray(
                    jax.random.fold_in(self._base_key, e.uid))
                self._temps[i] = sp.temperature
                self._top_ks[i] = sp.top_k
                self._top_ps[i] = sp.top_p
                self._sampling_resident += 1
            else:
                self._samp_keys[i] = 0
                self._temps[i], self._top_ks[i], self._top_ps[i] = 0, 0, 1.0
            self._slots[i] = slot
        if self._alloc is not None:
            self._state = self.engine.with_block_table(self._state,
                                                       self._table.copy())
        with self._stats_lock:
            m = self._m
            if busy:
                m.joins.inc(len(assigned))  # continuous refill mid-flight
            else:
                m.trajectories.inc()        # opened a fresh engine batch
        rec = self.recorder
        if rec:
            for i, e in assigned:
                rec.event(e.uid, "dispatch", now, host=self._host,
                          kind="admit", slot=i, join_step=e.join_step)

    def _pump_prefill(self) -> int:
        """One chunked-prefill engine call covering every row still
        consuming its prompt: row i is fed up to ``prefill_chunk`` of its
        remaining prompt tokens (all but the last — the decode step feeds
        that and emits the first token). Chunk widths are bucketed to
        powers of two to bound compile count."""
        need = [(i, s) for i, s in enumerate(self._slots)
                if s is not None and s.pos < len(s.entry.prompt) - 1]
        if not need:
            return 0
        longest = max(len(s.entry.prompt) - 1 - s.pos for _, s in need)
        width = 1
        while width < min(longest, self.prefill_chunk):
            width *= 2
        tokens = np.zeros((self.max_slots, width), np.int32)
        lengths = np.zeros((self.max_slots,), np.int32)
        mask = np.zeros((self.max_slots,), bool)
        for i, s in need:
            p = s.entry.prompt
            take = min(width, len(p) - 1 - s.pos)
            tokens[i, :take] = p[s.pos:s.pos + take]
            lengths[i] = take
            mask[i] = True
        t0 = self.clock()
        try:
            with profile_span(f"decode.prefill.w{width}"):
                self._state = self.engine.prefill_slots(tokens, lengths,
                                                        self._state, mask)
        except BaseException as exc:  # noqa: BLE001 — see _fail_slots
            self._fail_slots(exc)
            return 1
        prefill_ms = (self.clock() - t0) * 1e3
        with self._stats_lock:
            m = self._m
            m.forwards.inc()             # one engine invocation
            m.prefill_calls.inc()
            m.prefill_tokens.inc(int(lengths.sum()))
            m.device_dispatch_ms.observe(prefill_ms)
            self._note_program(f"prefill/w{width}")
        rec = self.recorder
        now = self.clock() if rec else 0.0
        for i, sl in need:
            sl.pos += int(lengths[i])
            p = sl.entry.prompt
            if sl.pos == len(p) - 1:     # prompt consumed: decode next tick
                self._feed[i] = p[-1]
                sl.pos = len(p)
                if rec:
                    rec.event(sl.entry.uid, "prefill", now, host=self._host,
                              prompt_len=int(len(p)))
        return 1

    def _advance_slot(self, si: int, slot: _Slot, tok: int) -> None:
        """Advance one active sequence given the model's prediction ``tok``
        for the token its row was just fed."""
        e = slot.entry
        if slot.pos < len(e.prompt):
            # legacy (prefill_chunk=0) path: the prediction is discarded,
            # the next prompt token is fed teacher-forced
            self._feed[si] = e.prompt[slot.pos]
            slot.pos += 1
            return
        if e.stop_token is not None and tok == e.stop_token:
            self._finish(si, slot, "stop")
            return
        slot.emitted.append(tok)
        if e.sink is not None:
            e.sink.partial(tok, index=len(slot.emitted) - 1)
        if len(slot.emitted) >= e.max_tokens:
            self._finish(si, slot, "length")
            return
        self._feed[si] = tok

    def _release_slot(self, si: int, slot: _Slot) -> None:
        """Free one slot's row (and pages). Paged rows are reset
        IMMEDIATELY: their stale block table would otherwise route the
        freed row's in-flight writes into pages the allocator may hand to
        the next admission — the reset points it back at trash page 0."""
        if self._alloc is not None:
            self._alloc.free(slot.pages)
            self._table[si, :] = 0
            mask = np.zeros((self.max_slots,), bool)
            mask[si] = True
            self._state = self.engine.reset_slots(self._state, mask)
        if self._temps[si] > 0:
            self._sampling_resident -= 1
        self._samp_keys[si] = 0
        self._temps[si], self._top_ks[si], self._top_ps[si] = 0, 0, 1.0
        self._slots[si] = None

    def _finish(self, si: int, slot: _Slot, reason: str) -> None:
        """Resolve one sequence's future and free its slot — the next
        ``_admit`` can scatter a fresh sequence into the row. Stats count
        the sequence only if its future actually SETTLED: a future
        cancelled in the same tick must not inflate ``tokens_out`` or the
        wait aggregates (the stats-skew fix)."""
        e = slot.entry
        rec = self.recorder
        if rec:
            rec.event(e.uid, "settle", self.clock(), host=self._host,
                      status="completed", finish_reason=reason, slot=si)
        response = DecodeResponse(
            tokens=np.asarray(slot.emitted, np.int32),
            meta={
                "finish_reason": reason,
                "prompt_len": int(len(e.prompt)),
                "new_tokens": len(slot.emitted),
                "steps": self._steps - e.join_step,
                "slot": si,
                "join_step": e.join_step,
                "wait_ms": (e.t_admit - e.t_submit) * 1e3,
            })
        if e.trace and rec:
            response.trace = rec.trace(e.uid)
        try:
            e.future.set_result(response)
            settled = True
        except Exception:              # cancelled: the batch rolls on
            settled = False
        if e.sink is not None:
            e.sink.final(response)
        wait_ms = (e.t_admit - e.t_submit) * 1e3
        with self._stats_lock:
            m = self._m
            if settled:
                m.completed.inc()
                m.tokens_out.inc(len(slot.emitted))
                m.wait_ms.observe(wait_ms)
                self._note_deadline(e, self.clock())
            else:
                m.cancelled.inc()
            self._inflight -= 1        # taken at admission
        self._release_slot(si, slot)

    def _fail_slots(self, exc: BaseException) -> None:
        """Surface a failing engine call into every resident sequence's
        future and free all slots, keeping the serve thread alive (the
        decode twin of ``ContinuousGateway._fail_trajectory``). Freed rows
        hold stale state; admission resets them before reuse (and, paged,
        pushes a fresh block table)."""
        entries = [s.entry for s in self._slots if s is not None]
        self._fail_entries(entries, exc, count_all=True)
        self._settle(len(entries))
        if self._alloc is not None:
            for s in self._slots:
                if s is not None and s.pages:
                    self._alloc.free(s.pages)
            self._table[:] = 0
        self._samp_keys[:] = 0
        self._temps[:], self._top_ks[:], self._top_ps[:] = 0, 0, 1.0
        self._sampling_resident = 0
        self._slots = [None] * self.max_slots

    # -- SLO cost model -------------------------------------------------------

    def _estimate_wait_ms(self, entry: _DecodeEntry) -> float:
        """Modeled completion time for a decode request: every engine tick
        costs one observed dispatch (``_dispatch_cost_ms``), the request
        itself needs ~``prompt + max_tokens`` ticks once resident, and each
        full wave of queued sequences ahead of it costs an average
        sequence length of ticks before a slot frees up."""
        cost = self._dispatch_cost_ms()
        with self._stats_lock:
            done = self._m.completed.value
            toks = self._m.tokens_out.value
        avg_len = (toks / done) if done else float(entry.max_tokens)
        waves = self.queue.depth() // self.max_slots
        own = len(entry.prompt) + entry.max_tokens
        return cost * (own + waves * avg_len)

    # -- metrics --------------------------------------------------------------

    def stats(self) -> dict[str, Any]:
        out = super().stats()
        if self._alloc is not None:
            ps = self.engine.page_size
            out["page_size"] = ps
            out["pages_in_use"] = self._alloc.in_use
            out["peak_pages"] = self._alloc.peak
            # high-water resident KV positions per slot — the paged-memory
            # win: bounded by actual sequence lengths, not cache_slots
            out["peak_kv_per_slot"] = self._alloc.peak * ps / self.max_slots
        return out
