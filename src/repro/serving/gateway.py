"""Serving gateway: async request queue, budget-coalescing batcher, and
sharded execution for BNS samplers.

The distilled solver makes each request cost exactly m backbone forwards;
this module makes that speed survive concurrency. Callers ``submit`` single-
sample ``Request``s and get ``concurrent.futures.Future``s; the gateway
coalesces pending requests into padded fixed-size batches and runs them on a
``FlowSampler`` / ``AnytimeFlowSampler`` (or anything speaking the budget
protocol: ``budgets``, ``resolve_budget``, ``sample_from``, and optionally
``sample_all_from``).

Batching contract
-----------------
* Requests are grouped by (resolved NFE budget, sample shape). A group
  reaching ``max_batch`` flushes immediately; partial groups flush once the
  oldest pending request has waited ``max_wait_ms`` (a flush tick drains all
  partial groups, so one aged request never strands its neighbours).
* Batches are padded to a fixed BUCKET size (powers of two up to
  ``max_batch``, plus ``max_batch`` itself), so the jit program for each
  (budget, bucket) pair is compiled exactly once and every later batch reuses
  it. Pad rows are zeros; rows are independent through the backbone, so each
  served sample is bit-identical to calling ``sampler.sample_from`` directly
  with the same x0 — padding never perturbs real samples.
* A per-budget batch at budget m costs exactly m backbone forwards,
  regardless of how many requests were coalesced into it — that is the whole
  point of batching a bespoke solver.

Mixed-budget policy
-------------------
When a flush tick leaves partial groups at several budgets, dispatching each
group separately costs ``sum(distinct budgets)`` backbone forwards, while the
anytime shared trajectory (``sample_all_from``) serves every budget from ONE
dispatch at ``max(sampler.budgets)`` forwards. ``mixed_budget_policy``:

    "never"  — always per-budget batches (keeps the bit-identical-to-
               ``sample_from`` guarantee for every sample);
    "auto"   — merge iff the shared trajectory is strictly cheaper, i.e.
               ``max(sampler.budgets) < sum(distinct pending budgets)``;
    "always" — merge any multi-budget flush.

Merged samples are bit-identical to ``sampler.sample_all_from`` for the same
x0 (the shared trajectory is itself exact — see ``core.anytime``); each
response's metadata records ``mixed=True`` plus the requested/served budget
pair, so budget drift is never silent.

Sharded execution: pass ``mesh=`` (see ``repro.serving.sharded``) to shard
the backbone params via ``distributed.sharding.param_specs`` and split
batches along the data axes; with no mesh the gateway falls back to the
samplers' single-device jit unchanged.

Continuous batching: ``repro.serving.continuous.ContinuousGateway`` extends
this gateway so queued requests are admitted into IN-FLIGHT anytime
trajectories at exit boundaries instead of waiting for the next flush; its
scheduler adds slot admission/release planning on top of ``BatchScheduler``
and its pump interleaves joins with these flushes.

``GatewayBase`` holds everything sampler-agnostic (intake, serve thread,
drain with in-flight accounting, locked stats snapshot) — it also fronts
the DECODE engine via ``repro.serving.decode.DecodeGateway``, so both of
the repo's engines serve through one queue/lifecycle/stats stack.

Fleet federation (``repro.serving.fleet``): a ``FleetGateway`` treats each
per-host gateway's queue as one SHARD of a fleet-wide request queue. The
hooks it rides live here on ``GatewayBase``: ``load()`` (a point-in-time
queue-depth/in-flight snapshot the work stealer balances on), ``steal()`` /
``inject()`` (atomically migrate QUEUED — never in-flight — entries between
shards), ``federate()`` (share one uid namespace and base PRNG key across
hosts so migrated entries keep their identity and folded noise keys match
the single-gateway path bit-for-bit), and ``drain(timeout=)`` (bounded
drain for graceful host leave — raises ``DrainTimeout`` with a stats
snapshot instead of hanging on a wedged engine).

Observability (``repro.observability``): ``GatewayBase`` owns a
``MetricsRegistry`` holding ONE shared metric schema (``METRIC_SCHEMA``)
that every tier — ``Gateway``/``ContinuousGateway``/``DecodeGateway``/
``FleetGateway``, plus ``SolverZoo`` and ``PageAllocator`` — emits into.
``stats()`` is now a compatibility projection of a registry snapshot
(``stats_projection``), wait times land in a mergeable log-bucket
histogram (p50/p95/p99 for free), and an optional ``TraceRecorder``
stamps per-request lifecycle events (submit -> route/steal ->
dispatch -> settle) that ``Response.trace`` opts into. With no recorder
the hot path does one attribute read and one falsy test — nothing else.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.observability import MetricsRegistry, NULL_RECORDER, profile_span
from repro.serving.slo import (
    AdmissionRejected,
    DeadlineExceeded,
    SLOConfig,
    hist_mean,
    is_urgent,
    urgency_key,
)
from repro.serving.stream import ResponseStream, StreamSink
from repro.serving.tiers import ShapeLadder, crop_row, pad_rows

Array = jax.Array

POLICIES = ("never", "auto", "always")


class DrainTimeout(RuntimeError):
    """``drain(timeout=...)`` expired with work still unresolved. Carries
    the ``stats()`` projection taken at expiry, the full registry
    ``snapshot`` (queue-depth / in-flight gauges included), and the
    ``spans`` of every traced request that never settled — so a hung
    drain is diagnosable: a fleet host-leave logs WHAT was stuck and
    moves on instead of hanging the whole fleet behind one wedged
    engine."""

    def __init__(self, message: str, stats: dict,
                 snapshot: Optional[dict] = None,
                 spans: Optional[dict] = None):
        super().__init__(message)
        self.stats = stats
        self.snapshot = snapshot if snapshot is not None else {}
        self.spans = spans if spans is not None else {}


@dataclasses.dataclass(frozen=True)
class HostLoad:
    """Point-in-time load snapshot of one gateway (= one fleet queue
    shard): entries still queued and entries taken but unresolved. The
    work stealer balances on these — only ``queue_depth`` is stealable.
    ``urgent`` counts queued entries carrying SLO pressure (priority > 0
    or a deadline); the stealer prefers victims holding urgent work."""

    queue_depth: int
    inflight: int
    urgent: int = 0

    @property
    def total(self) -> int:
        return self.queue_depth + self.inflight


@dataclasses.dataclass
class Request:
    """One user's sample request: conditioning tokens (S,), an NFE budget
    (None = the sampler's top budget), and either explicit noise ``x0``
    (bit-reproducibility) or a PRNG ``key`` (the gateway folds in a unique
    id when both are None)."""

    tokens: Optional[Array] = None
    budget: Optional[int] = None
    x0: Optional[Array] = None
    key: Optional[Array] = None
    # opt-in: resolve the Response with its recorded lifecycle trace
    # attached (requires the gateway to have a TraceRecorder)
    trace: bool = False
    # SLO (repro.serving.slo): latency budget relative to submit (None =
    # best-effort) and scheduling priority (higher = more urgent; plain
    # requests at 0 keep exact FIFO order)
    deadline_ms: Optional[float] = None
    priority: int = 0
    # streaming (repro.serving.stream): emit per-exit-boundary partials;
    # set by submit_stream, which returns the ResponseStream
    stream: bool = False


@dataclasses.dataclass
class Response:
    """One sample plus its serving metadata.

    ``latents`` is the sample's row, materialized on host (the gateway does
    one device->host transfer per BATCH and scatters rows in numpy — per-row
    device slicing costs an eager op per request and erases the batching
    win at small budgets).

    ``meta`` records: requested_budget, served_budget (budget drift is data,
    not just a warning), nfe_batch (backbone forwards the carrying batch
    spent), batch_real / batch_padded (occupancy), mixed (shared-trajectory
    dispatch), wait_ms (queue time).

    ``trace`` is the request's recorded lifecycle (list of event dicts)
    when ``Request.trace`` was set and the gateway has a recorder.
    """

    latents: Array
    meta: dict
    trace: Optional[list] = None


@dataclasses.dataclass
class _Entry:
    uid: int
    tokens: Optional[Array]
    x0: Array
    requested: int
    served: int
    shape_key: tuple
    t_submit: float
    future: Future
    # continuous batching (repro.serving.continuous): when this entry was
    # admitted into a trajectory (wait ends here, not at exit) and at which
    # exit boundary it joined (0 = opened the trajectory)
    t_admit: Optional[float] = None
    join_step: int = 0
    trace: bool = False   # attach the recorded lifecycle to the Response
    # SLO scheduling: ABSOLUTE deadline on the gateway clock (None =
    # best-effort) and priority (higher = more urgent)
    deadline: Optional[float] = None
    priority: int = 0
    # streaming sink (repro.serving.stream.StreamSink), or None
    sink: Optional[Any] = None
    # preemption (continuous tier): host snapshot of this entry's carry
    # column, taken when its slot was evicted at an exit boundary
    # (repro.serving.slo.PausedCarry); resume restores it bit-identically
    paused: Optional[Any] = None
    # shape tiering (repro.serving.tiers): the x0 shape BEFORE tier
    # padding (None = untiered); ``shape_key``/``x0``/``tokens`` hold the
    # padded tier forms, and every settle path crops back to this
    native_shape: Optional[tuple] = None
    # SLO calibration: the admission cost model's wait estimate stamped
    # at submit; |estimate - actual| lands in ``cost_est_error_ms``
    est_wait_ms: Optional[float] = None


class RequestQueue:
    """Thread-safe FIFO of pending entries with a depth gauge."""

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: list[_Entry] = []

    def push(self, entry: _Entry) -> None:
        with self._lock:
            self._entries.append(entry)

    def depth(self) -> int:
        with self._lock:
            return len(self._entries)

    def remove(self, taken: set) -> None:
        """Drop exactly the batched entries (by uid). Entries pushed while
        the scheduler was planning are untouched — never lost."""
        with self._lock:
            self._entries = [e for e in self._entries if e.uid not in taken]

    def snapshot(self) -> list[_Entry]:
        with self._lock:
            return list(self._entries)


def assemble_rows(entries: Sequence["_Entry"], bucket: int):
    """Host-side padded-batch assembly, shared by flush execution,
    trajectory starts, and join-prefix dispatches: stack each entry's x0
    (and tokens) and zero-pad to ``bucket`` rows — ONE device transfer per
    dispatch, and the single definition of the pad contract (zero rows,
    independent through the backbone, so padding never perturbs a real
    sample). Returns host numpy arrays ``(x0, tokens-or-None)``."""
    import numpy as np

    pad = bucket - len(entries)
    x0 = np.stack([np.asarray(e.x0) for e in entries])
    if pad:
        x0 = np.concatenate(
            [x0, np.zeros((pad,) + x0.shape[1:], x0.dtype)])
    tokens = None
    if entries[0].tokens is not None:
        tokens = np.stack([np.asarray(e.tokens) for e in entries]
                          + [np.zeros_like(np.asarray(entries[0].tokens))]
                          * pad)
    return x0, tokens


@dataclasses.dataclass
class Batch:
    """A planned dispatch: FIFO entries, the served budget (None when the
    batch rides the shared anytime trajectory), and the padded bucket."""

    entries: list
    budget: Optional[int]
    bucket: int
    mixed: bool = False


class BatchScheduler:
    """Deterministic batch planning (pure function of pending + now).

    ``plan`` never touches wall-clock or device state, so tests drive it
    with a fake clock and assert the exact batch layout.
    """

    def __init__(self, max_batch: int = 8, max_wait_ms: float = 10.0,
                 policy: str = "auto", can_mix: bool = False,
                 top_budget: Optional[int] = None, slo_aware: bool = False):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if policy not in POLICIES:
            raise ValueError(f"mixed_budget_policy {policy!r} not in {POLICIES}")
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self.policy = policy
        self.can_mix = can_mix
        self.top_budget = top_budget
        # SLO mode: order entries by urgency_key instead of FIFO, and add
        # deadline pressure to the flush trigger. ``lead_ms`` is the
        # gateway's current one-dispatch cost estimate (refreshed each
        # pump from the registry histograms): a partial group flushes
        # early when waiting one more tick would miss a member's deadline
        self.slo_aware = slo_aware
        self.lead_ms = 0.0
        self._buckets = self._bucket_sizes(max_batch)

    @staticmethod
    def _bucket_sizes(max_batch: int) -> tuple[int, ...]:
        sizes = {max_batch}
        b = 1
        while b < max_batch:
            sizes.add(b)
            b *= 2
        return tuple(sorted(sizes))

    def bucket(self, count: int) -> int:
        """Smallest padded size holding ``count`` — one jit program per
        (budget, bucket), not one per observed batch size."""
        for b in self._buckets:
            if b >= count:
                return b
        raise ValueError(f"count {count} exceeds max_batch {self.max_batch}")

    def _use_mixed(self, budgets: Sequence[int], total: int) -> bool:
        """Cost model in backbone forwards per flush: per-budget dispatch
        costs sum(distinct budgets) — leftover groups are below max_batch,
        one dispatch each — while merging dispatches ceil(total / max_batch)
        chunks of the shared trajectory, each running to the sampler's TOP
        budget (``sample_all``). Merge only when that is strictly cheaper."""
        if not self.can_mix or len(budgets) < 2 or self.policy == "never":
            return False
        if self.policy == "always":
            return True
        if self.top_budget is None:
            return False
        chunks = -(-total // self.max_batch)
        return chunks * self.top_budget < sum(budgets)

    def plan(self, pending: Sequence[_Entry], now: float,
             force: bool = False) -> list[Batch]:
        """The batches ready to dispatch; unbatched entries stay pending
        (the caller removes exactly the batched entries from its queue)."""
        batches: list[Batch] = []
        groups: dict[tuple, list[_Entry]] = {}
        if self.slo_aware:
            pending = sorted(pending, key=urgency_key)
        for e in pending:
            groups.setdefault((e.shape_key, e.served), []).append(e)

        leftovers: dict[tuple, list[_Entry]] = {}
        for (shape, served), es in groups.items():
            while len(es) >= self.max_batch:
                head, es = es[:self.max_batch], es[self.max_batch:]
                batches.append(Batch(head, served, self.bucket(len(head))))
            if es:
                leftovers[(shape, served)] = es

        aged = any(now - e.t_submit >= self.max_wait_s
                   for es in leftovers.values() for e in es)
        if self.slo_aware and not aged:
            # deadline pressure: flush partials when waiting one more
            # dispatch would push a member past its deadline
            lead_s = self.lead_ms / 1e3
            aged = any(e.deadline is not None and now + lead_s >= e.deadline
                       for es in leftovers.values() for e in es)
        if not (force or aged):
            return batches

        by_shape: dict[tuple, dict[int, list[_Entry]]] = {}
        for (shape, served), es in leftovers.items():
            by_shape.setdefault(shape, {})[served] = es
        for shape in sorted(by_shape, key=repr):
            per_budget = by_shape[shape]
            total = sum(len(es) for es in per_budget.values())
            if self._use_mixed(sorted(per_budget), total):
                merged = sorted((e for es in per_budget.values() for e in es),
                                key=lambda e: e.uid)
                for i in range(0, len(merged), self.max_batch):
                    chunk = merged[i:i + self.max_batch]
                    served_set = {e.served for e in chunk}
                    if len(served_set) > 1:
                        batches.append(Batch(chunk, None,
                                             self.bucket(len(chunk)),
                                             mixed=True))
                    else:
                        batches.append(Batch(chunk, chunk[0].served,
                                             self.bucket(len(chunk))))
            else:
                for served in sorted(per_budget):
                    es = per_budget[served]
                    batches.append(Batch(es, served, self.bucket(len(es))))
        if self.slo_aware and len(batches) > 1:
            # most urgent batch dispatches first (batches run serially
            # within one pump; an urgent batch behind a long one misses)
            batches.sort(key=lambda b: min(urgency_key(e)
                                           for e in b.entries))
        return batches


@dataclasses.dataclass
class GatewayStats:
    """Legacy counter bundle, kept as a compatibility VIEW: the registry
    (``GatewayBase.metrics``) is the single source of truth and
    ``GatewayBase.stats_raw`` reconstructs this dataclass from it."""

    submitted: int = 0
    completed: int = 0
    failed: int = 0
    batches: int = 0
    mixed_batches: int = 0
    forwards: int = 0          # backbone forwards spent (batch-level NFE sum)
    real_rows: int = 0
    padded_rows: int = 0
    sum_wait_ms: float = 0.0
    max_wait_ms: float = 0.0
    started: float = 0.0
    # continuous batching (zero under the flush-only gateway):
    trajectories: int = 0      # anytime trajectories opened
    legs: int = 0              # boundary-to-boundary trajectory dispatches
    joins: int = 0             # requests admitted into in-flight trajectories
    join_forwards: int = 0     # forwards spent computing join prefixes
    slot_steps_active: int = 0  # occupied slot-steps across trajectory legs
    slot_steps_total: int = 0   # max_slots * steps across trajectory legs
    # decode serving (zero under the flow gateways):
    tokens_out: int = 0        # generated tokens delivered to clients
    cancelled: int = 0         # sequences dropped on a cancelled future
    prefill_calls: int = 0     # chunked-prefill engine invocations
    prefill_tokens: int = 0    # prompt tokens consumed by chunked prefill
    # fleet federation (zero outside a FleetGateway):
    stolen_in: int = 0         # queued entries migrated INTO this shard
    stolen_out: int = 0        # queued entries migrated OUT of this shard
    # SLO scheduling (zero without an SLOConfig / deadlines):
    rejected: int = 0          # fast-rejected by admission control
    preemptions: int = 0       # slots evicted at exit boundaries
    deadline_misses: int = 0   # deadline requests settled late or shed
    goodput: int = 0           # deadline requests completed on time


# The ONE shared metric schema every serving tier emits into. Counter
# names deliberately match the ``GatewayStats`` field names so the
# legacy view is a field-for-field read; the gauges/histograms are the
# telemetry the flat counters could not express. ``SolverZoo`` (zoo_*)
# and ``PageAllocator`` (pages_*/peak_pages) register their names into
# the same registry when bound to a gateway.
METRIC_SCHEMA: tuple = (
    ("submitted", "counter", "requests accepted by submit()"),
    ("completed", "counter", "requests resolved with a result"),
    ("failed", "counter", "requests resolved with an exception"),
    ("batches", "counter", "padded batches dispatched"),
    ("mixed_batches", "counter", "shared-trajectory mixed-budget batches"),
    ("forwards", "counter", "backbone forwards spent (batch-level NFE)"),
    ("real_rows", "counter", "real rows across dispatched batches"),
    ("padded_rows", "counter", "padded rows across dispatched batches"),
    ("trajectories", "counter", "anytime trajectories opened"),
    ("legs", "counter", "boundary-to-boundary trajectory dispatches"),
    ("joins", "counter", "requests admitted into in-flight work"),
    ("join_forwards", "counter", "forwards spent computing join prefixes"),
    ("slot_steps_active", "counter", "occupied slot-steps across legs"),
    ("slot_steps_total", "counter", "available slot-steps across legs"),
    ("tokens_out", "counter", "generated tokens delivered to clients"),
    ("cancelled", "counter", "sequences dropped on a cancelled future"),
    ("prefill_calls", "counter", "chunked-prefill engine invocations"),
    ("prefill_tokens", "counter", "prompt tokens consumed by prefill"),
    ("stolen_in", "counter", "queued entries migrated INTO this shard"),
    ("stolen_out", "counter", "queued entries migrated OUT of this shard"),
    ("rejected", "counter", "requests fast-rejected by admission control"),
    ("preemptions", "counter",
     "slots evicted at exit boundaries for urgent work"),
    ("deadline_misses", "counter",
     "deadline-carrying requests settled late or shed in queue"),
    ("goodput", "counter",
     "deadline-carrying requests completed before their deadline"),
    ("queue_depth", "gauge", "entries waiting in the intake queue"),
    ("inflight", "gauge", "entries taken off the queue, unresolved"),
    ("jit_programs", "gauge", "distinct jit programs dispatched "
                              "(a climb in steady state = retracing)"),
    ("tier_occupancy", "gauge",
     "native/padded position-row share of dispatched work, per shape "
     "tier (labelled tier=<shape>; the unlabelled base stays 0 — "
     "populated only when a ShapeLadder is configured)"),
    ("wait_ms", "histogram", "queue wait per settled request (ms)"),
    ("cost_est_error_ms", "histogram",
     "admission cost model calibration: |estimated - actual| settle "
     "time per deadline-carrying settled request (ms)"),
    ("host_assembly_ms", "histogram",
     "host-side batch assembly + transfer per dispatch (ms)"),
    ("device_dispatch_ms", "histogram",
     "device dispatch wall time per batch/leg (ms)"),
)


class GatewayMetrics:
    """Cached handles into one registry for the shared schema — one
    attribute read per emission on the hot path, no name lookups."""

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        for name, kind, help_ in METRIC_SCHEMA:
            if kind == "counter":
                m = registry.counter(name, help_)
            elif kind == "gauge":
                m = registry.gauge(name, help_)
            else:
                m = registry.histogram(name, help_)
            setattr(self, name, m)


def stats_projection(snap: dict, raw_elapsed: float) -> dict:
    """The legacy flat ``stats()`` dict, derived from a registry
    snapshot. Every tier — including the fleet-wide MERGE of per-host
    snapshots — reports through this one function, so keys and derived
    ratios cannot diverge across the five gateways again."""
    elapsed = max(raw_elapsed, 1e-9)

    def n(key):
        return snap.get(key, 0) or 0

    w = snap.get("wait_ms") or {}
    ce = snap.get("cost_est_error_ms") or {}
    completed = int(n("completed"))
    tokens_out = int(n("tokens_out"))
    slot_total = n("slot_steps_total")
    return {
        "queue_depth": int(n("queue_depth")),
        "inflight": int(n("inflight")),
        "submitted": int(n("submitted")),
        "completed": completed,
        "failed": int(n("failed")),
        "batches": int(n("batches")),
        "mixed_batches": int(n("mixed_batches")),
        "forwards": int(n("forwards")),
        "nfe_per_request": n("forwards") / max(completed, 1),
        "occupancy": n("real_rows") / max(n("padded_rows"), 1),
        "mean_wait_ms": w.get("sum", 0.0) / max(completed, 1),
        "max_wait_ms": w.get("max", 0.0),
        "wait_p50_ms": w.get("p50", 0.0),
        "wait_p95_ms": w.get("p95", 0.0),
        "wait_p99_ms": w.get("p99", 0.0),
        "throughput_rps": completed / elapsed,
        "jit_programs": int(n("jit_programs")),
        # continuous batching (all zero under the flush-only gateway)
        "trajectories": int(n("trajectories")),
        "legs": int(n("legs")),
        "joins": int(n("joins")),
        "join_rate": n("joins") / max(completed, 1),
        "slot_occupancy": (n("slot_steps_active") / slot_total
                           if slot_total else 0.0),
        # decode serving (zero under the flow gateways)
        "tokens_out": tokens_out,
        # a zero-elapsed snapshot (frozen fake clock, or stats() in the
        # same instant as construction) must read 0, not tokens/1e-9
        "tokens_per_s": (tokens_out / elapsed if raw_elapsed > 0 else 0.0),
        "cancelled": int(n("cancelled")),
        "prefill_calls": int(n("prefill_calls")),
        "prefill_tokens": int(n("prefill_tokens")),
        # fleet federation (zero outside a FleetGateway)
        "stolen_in": int(n("stolen_in")),
        "stolen_out": int(n("stolen_out")),
        # SLO scheduling (zero without deadlines). hit rate is measured
        # over OFFERED deadline requests: on-time completions / (on-time
        # + late-or-shed + fast-rejected) — a gateway cannot improve it
        # by rejecting everything
        "rejected": int(n("rejected")),
        "preemptions": int(n("preemptions")),
        "deadline_misses": int(n("deadline_misses")),
        "goodput": int(n("goodput")),
        "deadline_hit_rate": (
            n("goodput")
            / max(n("goodput") + n("deadline_misses") + n("rejected"), 1)),
        # admission cost-model calibration (zero without deadline traffic):
        # how far the wait estimate stamped at submit landed from the
        # actual settle time, over every deadline request that settled
        "cost_est_samples": int(ce.get("count", 0)),
        "cost_est_error_mean_ms": (ce.get("sum", 0.0)
                                   / max(ce.get("count", 0), 1)),
        "cost_est_error_p95_ms": ce.get("p95", 0.0),
    }


class GatewayBase:
    """Shared request-queue front-end: thread-safe intake, the serve-thread
    lifecycle, drain, in-flight accounting, and aggregate ``stats()`` — the
    machinery common to the flow gateways (``Gateway``/``ContinuousGateway``)
    and the decode gateway (``repro.serving.decode.DecodeGateway``).

    Subclasses implement ``submit`` (build an entry, hand it to
    ``_enqueue``) and ``pump`` (plan one tick: pull planned entries off the
    queue with ``_take``, and ``_settle`` them once their futures resolve
    or fail).
    """

    #: request dataclass ``submit_stream`` builds from kwargs (overridden
    #: by DecodeGateway)
    _request_type = Request

    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 metrics: Optional[MetricsRegistry] = None,
                 recorder=None, slo: Optional[SLOConfig] = None):
        self.clock = clock
        self.slo = slo
        self.queue = RequestQueue()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._m = GatewayMetrics(self.metrics)
        self.recorder = recorder if recorder is not None else NULL_RECORDER
        self._host = ""    # fleet host label stamped into trace events
        self._started = clock()
        self._uid = itertools.count()
        self._plan_lock = threading.Lock()
        self._intake_lock = threading.Lock()   # closed-check + push atomic
        # the registry RLock IS the stats lock: a block of handle updates
        # is one atomic multi-metric transaction, and snapshot() sees a
        # consistent cut (drain + serve thread both execute; '+=' on the
        # handles is not atomic without it)
        self._stats_lock = self.metrics.lock
        self._inflight = 0   # entries off the queue, futures still unresolved
        self._programs: set = set()   # distinct jit programs dispatched
        # lazy gauges: queue depth / in-flight already live on the
        # gateway; the registry reads them at snapshot time instead of
        # double-booking every transition
        self._m.queue_depth.set_fn(self.queue.depth)
        self._m.inflight.set_fn(lambda: self._inflight)
        self._closed = False
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    @property
    def stats_raw(self) -> GatewayStats:
        """Compatibility view: the legacy counter dataclass reconstructed
        from the registry under its lock (one consistent cut)."""
        m = self._m
        with self._stats_lock:
            return GatewayStats(
                submitted=m.submitted.value,
                completed=m.completed.value,
                failed=m.failed.value,
                batches=m.batches.value,
                mixed_batches=m.mixed_batches.value,
                forwards=m.forwards.value,
                real_rows=m.real_rows.value,
                padded_rows=m.padded_rows.value,
                sum_wait_ms=m.wait_ms.sum,
                max_wait_ms=m.wait_ms.max,
                started=self._started,
                trajectories=m.trajectories.value,
                legs=m.legs.value,
                joins=m.joins.value,
                join_forwards=m.join_forwards.value,
                slot_steps_active=m.slot_steps_active.value,
                slot_steps_total=m.slot_steps_total.value,
                tokens_out=m.tokens_out.value,
                cancelled=m.cancelled.value,
                prefill_calls=m.prefill_calls.value,
                prefill_tokens=m.prefill_tokens.value,
                stolen_in=m.stolen_in.value,
                stolen_out=m.stolen_out.value,
                rejected=m.rejected.value,
                preemptions=m.preemptions.value,
                deadline_misses=m.deadline_misses.value,
                goodput=m.goodput.value,
            )

    def _note_program(self, program: str) -> None:
        """Per-dispatch program accounting (caller holds ``_stats_lock``):
        one labelled ``dispatches`` tick, and the ``jit_programs`` gauge
        tracks the distinct (budget, bucket) programs seen — the count
        plateaus once every program is compiled, so a climb in steady
        state is the retrace/recompile signal."""
        if program not in self._programs:
            self._programs.add(program)
            self._m.jit_programs.set(len(self._programs))
        self.metrics.counter("dispatches",
                             "dispatches per compiled jit program",
                             labels={"program": program}).inc()

    def _note_tier(self, tier_shape: tuple, real: int, padded: int) -> None:
        """Per-tier occupancy accounting (caller holds ``_stats_lock``):
        labelled native/padded position-row counters per shape tier, and
        the labelled ``tier_occupancy`` gauge as their running ratio —
        1.0 means every padded position carried a native row; the gap is
        what tier padding (plus batch padding) costs this tier."""
        label = ShapeLadder.label(tier_shape)
        reg = self.metrics
        r = reg.counter("tier_real_rows",
                        "native position-rows dispatched, per shape tier",
                        labels={"tier": label})
        p = reg.counter("tier_padded_rows",
                        "padded position-rows dispatched, per shape tier",
                        labels={"tier": label})
        r.inc(real)
        p.inc(padded)
        reg.gauge(
            "tier_occupancy",
            "native/padded position-row share of dispatched work, per "
            "shape tier",
            labels={"tier": label}).set(r.value / max(p.value, 1))

    # -- intake ---------------------------------------------------------------

    def _enqueue(self, entry) -> Future:
        """Push one entry; the closed check and the push are one atomic step
        wrt ``drain()`` — once drain flips ``_closed`` (under this lock), no
        entry can slip in after its final flush and strand an unresolved
        future. The submitted counter moves under ``_stats_lock`` like every
        other counter, and BEFORE the push, so no ``stats()`` snapshot can
        show ``completed > submitted``."""
        with self._intake_lock:
            if self._closed:
                raise RuntimeError("gateway is draining; no new requests")
            self._m.submitted.inc()
            # the Future carries the uid so callers holding only the
            # future (FleetGateway.submit, trace consumers) can stamp /
            # look up events without the private entry
            entry.future.uid = entry.uid
            sink = getattr(entry, "sink", None)
            if sink is not None:
                # submit_stream reads the sink back off the future (the
                # entry is private; the future crosses the fleet tier)
                entry.future.stream_sink = sink
            self.queue.push(entry)
        rec = self.recorder
        if rec:
            rec.event(entry.uid, "submit", entry.t_submit, host=self._host)
        return entry.future

    # -- in-flight accounting -------------------------------------------------

    def _take(self, entries: Sequence) -> None:
        """Remove planned entries from the queue and mark them IN FLIGHT.
        ``drain()`` waits on this count, not just queue depth: entries a
        concurrent serve-thread pump has removed and is still executing are
        invisible to the queue, and the old depth-only loop could return
        with their futures unresolved.

        The increment happens BEFORE the queue removal (and ``_drained``
        reads depth before in-flight): an entry is therefore visible to at
        least one of the two checks at every instant of the hand-off —
        counting it twice momentarily is safe, missing it is the race."""
        with self._stats_lock:
            self._inflight += len(entries)
        self.queue.remove({e.uid for e in entries})

    def _settle(self, n: int) -> None:
        """Mark ``n`` taken entries resolved (result or exception set)."""
        with self._stats_lock:
            self._inflight -= n

    def _fail_entries(self, entries: Sequence, exc: BaseException,
                      count_all: bool = False) -> None:
        """Surface ``exc`` into every still-unresolved future. A future the
        client already cancelled rejects ``set_exception``; that must not
        keep the failure from reaching its batch-mates."""
        failed = 0
        rec = self.recorder
        now = self.clock()
        for e in entries:
            try:
                e.future.set_exception(exc)
                failed += 1
            except Exception:       # cancelled/raced future: nothing to do
                failed += int(count_all)
            sink = getattr(e, "sink", None)
            if sink is not None:
                sink.error(exc)     # unblock a consumer iterating the stream
            if rec:
                rec.event(e.uid, "settle", now, host=self._host,
                          status="failed")
        if failed:
            self._m.failed.inc(failed)

    # -- SLO scheduling (repro.serving.slo) -----------------------------------

    def _dispatch_cost_ms(self) -> float:
        """Observed mean cost of one dispatch (assembly + device), read
        from the registry's own histograms — the admission cost model
        calibrates itself from live traffic. Before the first dispatch it
        falls back to ``slo.default_cost_ms`` (0 = optimistic accept)."""
        with self._stats_lock:
            dispatch = hist_mean(self._m.device_dispatch_ms)
            assembly = hist_mean(self._m.host_assembly_ms)
        if dispatch is None:
            return self.slo.default_cost_ms if self.slo else 0.0
        return dispatch + (assembly or 0.0)

    def _estimate_wait_ms(self, entry) -> float:
        """Modeled time until ``entry`` would settle, given the current
        queue. Subclasses refine with their batching shape; the base
        estimate is one dispatch per queued entry ahead plus our own."""
        return self._dispatch_cost_ms() * (self.queue.depth() + 1)

    def _check_admission(self, entry) -> None:
        """Fast reject: raise ``AdmissionRejected`` when the modeled
        service time cannot meet the entry's deadline. Called by submit
        BEFORE ``_enqueue`` — a rejected request is never counted as
        submitted and its caller gets the exception, not a future."""
        slo = self.slo
        if slo is None or not slo.admission or entry.deadline is None:
            return
        est = self._estimate_wait_ms(entry)
        # stamp the estimate for calibration: at settle, |estimate -
        # actual| lands in cost_est_error_ms (a rejected entry never
        # settles, so the stamp is inert on the reject path)
        entry.est_wait_ms = est
        budget = (entry.deadline - self.clock()) * 1e3 - slo.slack_ms
        if est > budget:
            depth = self.queue.depth()
            with self._stats_lock:
                self._m.rejected.inc()
            rec = self.recorder
            if rec:
                rec.event(entry.uid, "reject", self.clock(), host=self._host,
                          estimated_ms=est, queue_depth=depth)
            raise AdmissionRejected(
                f"deadline infeasible: modeled service {est:.1f}ms exceeds "
                f"the remaining budget {budget:.1f}ms "
                f"(queue_depth={depth})",
                estimated_ms=est, deadline_ms=budget, queue_depth=depth)

    def _shed_expired(self) -> None:
        """Fail queued entries whose deadline already passed (caller holds
        ``_plan_lock``). Their forwards go to requests that can still
        win; each shed entry counts under ``failed`` AND
        ``deadline_misses``."""
        slo = self.slo
        if slo is None or not slo.shedding:
            return
        now = self.clock()
        expired = [e for e in self.queue.snapshot()
                   if e.deadline is not None
                   and (now - e.deadline) * 1e3 > -slo.slack_ms]
        if not expired:
            return
        self._take(expired)
        with self._stats_lock:
            self._m.deadline_misses.inc(len(expired))
        self._fail_entries(
            expired,
            DeadlineExceeded(f"deadline passed while queued "
                             f"({len(expired)} shed at t={now:.3f})"),
            count_all=True)
        self._settle(len(expired))

    def _note_deadline(self, entry, settle_t: float) -> None:
        """Goodput accounting at settle (caller holds ``_stats_lock``):
        a deadline request completing on time ticks ``goodput``, late
        ticks ``deadline_misses``. No-deadline requests tick neither."""
        if entry.deadline is None:
            return
        if settle_t <= entry.deadline:
            self._m.goodput.inc()
        else:
            self._m.deadline_misses.inc()
        est = getattr(entry, "est_wait_ms", None)
        if est is not None:
            actual = (settle_t - entry.t_submit) * 1e3
            self._m.cost_est_error_ms.observe(abs(actual - est))

    # -- streaming (repro.serving.stream) -------------------------------------

    def submit_stream(self, request=None, **kw) -> ResponseStream:
        """Submit with streaming: returns a ``ResponseStream`` yielding
        per-exit-boundary partials (flow) or per-token chunks (decode),
        terminated by the same response the future resolves with."""
        if request is None:
            request = self._request_type(**kw)
        request.stream = True
        future = self.submit(request)
        return ResponseStream(future, future.stream_sink)

    # -- fleet federation hooks (repro.serving.fleet) ------------------------

    def federate(self, uid_counter, base_key: Optional[Array] = None, *,
                 recorder=None, host: Optional[str] = None) -> None:
        """Adopt a fleet-shared uid namespace (and base PRNG key).

        Entries migrated between shard queues are identified by uid alone
        (``RequestQueue.remove``/``_take``); per-host counters would
        collide, so every host in a fleet draws from ONE counter. Sharing
        the base key keeps the no-x0/no-key noise path bit-identical to a
        single gateway: the folded key depends on the fleet-wide submission
        index, which the shared counter makes exactly the index a lone
        gateway would have used. Call before any traffic is submitted.

        ``recorder``/``host`` wire fleet-wide tracing: every host stamps
        events into the fleet's ONE recorder, labelled with its host
        name, so a stolen request's hops interleave in one ring."""
        self._uid = uid_counter
        if base_key is not None and hasattr(self, "_base_key"):
            self._base_key = base_key
        if recorder is not None:
            self.recorder = recorder
        if host is not None:
            self._host = host

    def load(self) -> HostLoad:
        """Load snapshot for fleet routing/stealing decisions."""
        with self._stats_lock:
            inflight = self._inflight
        pending = self.queue.snapshot()
        return HostLoad(queue_depth=len(pending), inflight=inflight,
                        urgent=sum(1 for e in pending if is_urgent(e)))

    def steal(self, max_n: Optional[int] = None) -> list:
        """Atomically pop up to ``max_n`` QUEUED entries (most urgent
        first — for plain entries the urgency key degenerates to the old
        oldest-first order; ``None`` = all). Runs under ``_plan_lock``,
        the same lock every pump plans under, so a stolen entry was never
        planned into a batch or trajectory — in-flight work is
        structurally unstealable. The entries' futures stay live; the
        thief resolves them."""
        with self._plan_lock:
            pending = sorted(self.queue.snapshot(), key=urgency_key)
            taken = pending if max_n is None else pending[:max_n]
            self.queue.remove({e.uid for e in taken})
        if taken:
            self._m.stolen_out.inc(len(taken))
            rec = self.recorder
            if rec:
                now = self.clock()
                for e in taken:
                    rec.event(e.uid, "steal", now, host=self._host)
        return taken

    def inject(self, entries: Sequence) -> None:
        """Accept entries stolen from another shard into this queue. The
        closed check mirrors ``_enqueue`` (an entry injected after drain's
        final flush would strand its future) but ``submitted`` does NOT
        move — the home shard already counted the request; fleet totals
        stay one-count-per-request."""
        with self._intake_lock:
            if self._closed:
                raise RuntimeError(
                    "gateway is draining; cannot accept migrated entries")
            self._m.stolen_in.inc(len(entries))
            for e in entries:
                self.queue.push(e)
        rec = self.recorder
        if rec:
            now = self.clock()
            for e in entries:
                rec.event(e.uid, "inject", now, host=self._host)

    # -- scheduling -----------------------------------------------------------

    def pump(self, force: bool = False) -> int:
        raise NotImplementedError

    # -- lifecycle ------------------------------------------------------------

    def serve_forever(self, poll_s: float = 0.001) -> None:
        """Pump until ``stop``; sleeps ``poll_s`` when there is no work."""
        while not self._stop.is_set():
            if self.pump() == 0:
                time.sleep(poll_s)

    def start(self, poll_s: float = 0.001) -> threading.Thread:
        if self._thread is not None and self._thread.is_alive():
            return self._thread
        self._stop.clear()
        self._thread = threading.Thread(
            target=self.serve_forever, kwargs={"poll_s": poll_s},
            name="gateway-serve", daemon=True)
        self._thread.start()
        return self._thread

    def _drained(self) -> bool:
        # depth FIRST, in-flight second — the mirror of _take's ordering.
        # If depth reads 0 because a concurrent _take just removed the
        # entry, its in-flight increment already happened, so the second
        # read catches it (unless it also settled, i.e. resolved — drained).
        if self.queue.depth():
            return False
        with self._stats_lock:
            return self._inflight == 0

    def drain(self, timeout: Optional[float] = None) -> None:
        """Graceful drain: refuse new requests, then pump until every
        accepted request has RESOLVED — queue empty AND nothing in flight
        (a batch a concurrent serve-thread pump is still executing counts;
        spinning on queue depth alone returned early on exactly that).

        ``timeout`` (wall seconds, measured on ``time.monotonic`` — the
        gateway clock may be fake and frozen) bounds the wait: a wedged
        engine raises ``DrainTimeout`` carrying the stats snapshot instead
        of hanging forever — fleet host-leave needs the bound. The gateway
        STAYS closed after the raise; call ``drain`` again to keep waiting,
        or inspect ``exc.stats`` to see what is stuck."""
        with self._intake_lock:
            self._closed = True        # no submit can pass the check now
        deadline = (None if timeout is None
                    else time.monotonic() + max(timeout, 0.0))
        while not self._drained():
            if deadline is not None and time.monotonic() >= deadline:
                registry = self.metrics.snapshot()
                snap = stats_projection(registry,
                                        self.clock() - self._started)
                rec = self.recorder
                raise DrainTimeout(
                    f"drain timed out after {timeout:g}s: "
                    f"queue_depth={snap['queue_depth']} "
                    f"inflight={snap['inflight']} "
                    f"completed={snap['completed']}/{snap['submitted']}",
                    snap, snapshot=registry,
                    spans=rec.open_spans() if rec else {})
            if self.pump(force=True) == 0:
                time.sleep(5e-4)       # a concurrent pump holds the work

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None

    def shutdown(self, timeout: Optional[float] = None) -> None:
        self.drain(timeout=timeout)
        self.stop()

    # -- metrics --------------------------------------------------------------

    def stats(self) -> dict[str, Any]:
        """Aggregate serving metrics as one flat dict: the compatibility
        projection of a registry snapshot (the snapshot is one consistent
        cut under the registry lock, so derived ratios are internally
        consistent — completed never exceeds submitted, the wait
        histogram count equals completed)."""
        return stats_projection(self.metrics.snapshot(),
                                self.clock() - self._started)

    def metrics_snapshot(self) -> dict:
        """Raw registry snapshot — the export surface (Prometheus/JSON).
        ``FleetGateway`` overrides this with the merge of its hosts'
        snapshots; everything below it reports its own registry."""
        return self.metrics.snapshot()


class Gateway(GatewayBase):
    """Multi-user front-end over one budget-routing sampler.

    ``submit(request) -> Future[Response]``; ``pump()`` plans and executes
    ready batches (the unit tests drive it with a fake clock); ``start()`` /
    ``serve_forever()`` run the pump loop on a thread; ``drain()`` stops
    accepting and flushes everything; ``shutdown()`` = drain + stop.

    ``from_zoo`` acquires the solver artifact through a ``SolverZoo`` so a
    gateway boot is a cache hit/load, never an accidental re-distillation.
    """

    def __init__(self, sampler, *, max_batch: int = 8,
                 max_wait_ms: float = 10.0,
                 mixed_budget_policy: str = "auto", strict_nfe: bool = False,
                 mesh=None, clock: Callable[[], float] = time.monotonic,
                 key: Optional[Array] = None,
                 metrics: Optional[MetricsRegistry] = None, recorder=None,
                 slo: Optional[SLOConfig] = None,
                 tiers: Optional[ShapeLadder] = None):
        super().__init__(clock=clock, metrics=metrics, recorder=recorder,
                         slo=slo)
        self.sampler = sampler
        # shape-tier ladder (repro.serving.tiers): when set, submit pads
        # each request's position axis to its tier rung, so shape_key —
        # the grouping key of every scheduler layer — IS the tier key and
        # near-shapes share flush buckets / trajectory slots / programs.
        # None keeps the exact-shape behaviour (per-position independence
        # of the field is the tiering precondition; see tiers.py)
        self.tiers = tiers
        can_mix = (hasattr(sampler, "sample_all_from")
                   and len(sampler.budgets) > 1)
        self.scheduler = BatchScheduler(
            max_batch=max_batch, max_wait_ms=max_wait_ms,
            policy=mixed_budget_policy, can_mix=can_mix,
            top_budget=max(sampler.budgets), slo_aware=slo is not None)
        self.strict_nfe = strict_nfe
        self._base_key = key if key is not None else jax.random.PRNGKey(0)
        self._place = None
        if mesh is not None:
            from repro.serving import sharded

            sharded.shard_sampler(self.sampler, mesh)
            self._place = (sharded.tier_placer(mesh, tiers)
                           if tiers is not None
                           else sharded.batch_placer(mesh))

    @classmethod
    def from_zoo(cls, zoo, spec, *, params: dict, cfg, sched,
                 update_fn: Optional[Callable] = None, log=None,
                 **gateway_kw) -> "Gateway":
        """Boot a gateway from a zoo-resolved artifact (hit/load/distill)."""
        from repro.serving.engine import AnytimeFlowSampler, FlowSampler

        artifact = zoo.get(spec, log=log)
        if artifact.kind == "anytime":
            sampler = AnytimeFlowSampler.from_artifact(
                artifact, params=params, cfg=cfg, sched=sched,
                update_fn=update_fn)
        else:
            sampler = FlowSampler.from_artifact(
                artifact, params=params, cfg=cfg, sched=sched,
                update_fn=update_fn)
        return cls(sampler, **gateway_kw)

    # -- intake -------------------------------------------------------------

    def submit(self, request: Optional[Request] = None, **kw) -> Future:
        """Enqueue one request; returns a Future resolving to ``Response``.

        The budget is resolved to a served one NOW (strict mode raises here,
        before the request ever queues); the (requested, served) pair rides
        in the response metadata either way.
        """
        if request is None:
            request = Request(**kw)
        requested = (request.budget if request.budget is not None
                     else self.sampler.budgets[-1])
        served = self.sampler.resolve_budget(requested,
                                             strict=self.strict_nfe)
        uid = next(self._uid)
        x0 = request.x0
        if x0 is None:
            if request.tokens is None:
                raise ValueError("request needs tokens and/or explicit x0")
            key = (request.key if request.key is not None
                   else jax.random.fold_in(self._base_key, uid))
            x0 = jax.random.normal(
                key, (request.tokens.shape[0], self.sampler.cfg.latent_dim))
        # tiering: noise is generated at the NATIVE shape above (the fold-in
        # key path stays bit-identical to an untiered gateway), THEN the
        # position axis pads to the tier rung. shape_key is computed from
        # the padded forms, so every scheduler groups on the tier for free;
        # settle paths crop back to native_shape. Oversize raises here —
        # before the request is queued or counted (TierOversize).
        tokens = request.tokens
        native_shape = None
        if self.tiers is not None:
            rung = self.tiers.rung_for(x0.shape)
            if rung is not None:
                native_shape = tuple(x0.shape)
                if rung != native_shape[0]:
                    x0 = pad_rows(x0, rung)
                if tokens is not None and tokens.shape[0] < rung:
                    tokens = pad_rows(tokens, rung)
        shape_key = (None if tokens is None
                     else tuple(tokens.shape), tuple(x0.shape))
        t_submit = self.clock()
        entry = _Entry(uid=uid, tokens=tokens, x0=x0,
                       requested=requested, served=served,
                       shape_key=shape_key, t_submit=t_submit,
                       native_shape=native_shape,
                       future=Future(), trace=request.trace,
                       deadline=(None if request.deadline_ms is None
                                 else t_submit + request.deadline_ms / 1e3),
                       priority=request.priority,
                       sink=StreamSink() if request.stream else None)
        self._check_admission(entry)
        return self._enqueue(entry)

    # -- scheduling / execution --------------------------------------------

    def pump(self, force: bool = False) -> int:
        """Plan ready batches and execute them; returns how many ran."""
        with self._plan_lock:
            if self.slo is not None:
                self._shed_expired()
                self.scheduler.lead_ms = self._dispatch_cost_ms()
            batches = self.scheduler.plan(
                self.queue.snapshot(), self.clock(), force=force)
            # take exactly the batched entries — a submit landing after
            # the snapshot stays queued for the next pump, never dropped
            self._take([e for b in batches for e in b.entries])
        return self._run_batches(batches)

    def _estimate_wait_ms(self, entry) -> float:
        """Flush-gateway cost model: queued entries dispatch in batches of
        up to ``max_batch``, so the wait is (whole batches ahead of us,
        plus our own) times the observed per-dispatch cost."""
        batches_ahead = self.queue.depth() // self.scheduler.max_batch + 1
        return self._dispatch_cost_ms() * batches_ahead

    def _run_batches(self, batches: Sequence[Batch]) -> int:
        """Execute planned batches; an exception escaping one batch (e.g. a
        cancelled future rejecting its result mid-scatter) is surfaced into
        that batch's unresolved futures and the NEXT batch still runs —
        entries were already removed from the queue, so anything less
        strands their futures forever (the old mid-drain failure mode)."""
        for batch in batches:
            try:
                self._execute(batch)
            except BaseException as exc:  # noqa: BLE001 — must not strand
                self._fail_entries(batch.entries, exc)
            finally:
                self._settle(len(batch.entries))
        return len(batches)

    def _execute(self, batch: Batch) -> None:
        import numpy as np

        es = batch.entries
        dispatched = self.clock()   # wait_ms is QUEUE time, ending here —
        #                             not device/compile time
        program = (f"b{'mix' if batch.mixed else batch.budget}"
                   f"/k{batch.bucket}")
        try:
            # assemble on host: ONE device transfer per batch, not one eager
            # stack/slice op per request (those dominate at small budgets).
            # Timing runs on the GATEWAY clock (production: time.monotonic,
            # same resolution as perf_counter) so fake-clock benches feed
            # the SLO cost model simulated, deterministic dispatch times
            t0 = self.clock()
            x0_np, t_np = assemble_rows(es, batch.bucket)
            x0 = jnp.asarray(x0_np)
            cond = None if t_np is None else {"tokens": jnp.asarray(t_np)}
            if self._place is not None:
                cond, x0 = self._place(cond, x0)
            t1 = self.clock()
            with profile_span(f"gateway.dispatch.{program}"):
                if batch.mixed:
                    outs = self.sampler.sample_all_from(cond, x0)
                    nfe = max(self.sampler.budgets)
                    host = {m: np.asarray(outs[m])
                            for m in {e.served for e in es}}
                    rows = [host[e.served][i] for i, e in enumerate(es)]
                else:
                    lat = np.asarray(
                        self.sampler.sample_from(cond, x0, batch.budget))
                    nfe = batch.budget
                    rows = [lat[i] for i in range(len(es))]
            t2 = self.clock()
        except Exception as exc:
            self._fail_entries(es, exc, count_all=True)
            return
        settle_t = self.clock()
        with self._stats_lock:
            m = self._m
            m.batches.inc()
            if batch.mixed:
                m.mixed_batches.inc()
            m.forwards.inc(nfe)
            m.real_rows.inc(len(es))
            m.padded_rows.inc(batch.bucket)
            m.host_assembly_ms.observe((t1 - t0) * 1e3)
            m.device_dispatch_ms.observe((t2 - t1) * 1e3)
            self._note_program(program)
            if es[0].native_shape is not None:
                tier = es[0].shape_key[1]
                self._note_tier(
                    tier, sum(e.native_shape[0] for e in es),
                    batch.bucket * tier[0])
            for e in es:
                m.wait_ms.observe((dispatched - e.t_submit) * 1e3)
                m.completed.inc()
                self._note_deadline(e, settle_t)
        rec = self.recorder
        for e, row in zip(es, rows):
            row = crop_row(row, e.native_shape)
            wait_ms = (dispatched - e.t_submit) * 1e3
            if rec:
                rec.event(e.uid, "dispatch", dispatched, host=self._host,
                          program=program)
                rec.event(e.uid, "settle", dispatched, host=self._host,
                          status="completed")
            response = Response(latents=row, meta={
                "requested_budget": e.requested,
                "served_budget": e.served,
                "nfe_batch": nfe,
                "batch_real": len(es),
                "batch_padded": batch.bucket,
                "mixed": batch.mixed,
                "wait_ms": wait_ms,
            })
            if e.native_shape is not None:
                response.meta["tier_shape"] = e.shape_key[1]
                response.meta["native_shape"] = e.native_shape
            if e.trace and rec:
                response.trace = rec.trace(e.uid)
            try:
                e.future.set_result(response)
            except Exception:   # cancelled mid-batch: batch-mates still land
                pass
            if e.sink is not None:
                e.sink.final(response)
