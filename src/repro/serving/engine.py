"""Serving engines.

``FlowSampler`` — the paper's product: BNS-accelerated batched sampling of a
flow model (any backbone in the zoo). A thin jit'd session over Algorithm 1:
construct it from a serialized ``SolverArtifact`` (``from_artifact``) or any
NS solver, and each request batch costs exactly ``n`` backbone forwards.

``DecodeEngine`` — batched autoregressive decode with KV cache / recurrent
state (the ``serve_step`` the decode dry-run shapes lower).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import ns_solver
from repro.core.ns_solver import NSParams
from repro.core.schedulers import Scheduler
from repro.models import model as M

Array = jax.Array


@dataclasses.dataclass
class FlowSampler:
    params: dict
    cfg: ModelConfig
    sched: Scheduler
    solver: NSParams
    cfg_scale: float = 0.0

    def __post_init__(self):
        def _sample(params, solver, batch, x0):
            field = M.velocity_field(params, self.cfg, self.sched, batch,
                                     cfg_scale=self.cfg_scale)
            return ns_solver.ns_sample(solver, field.fn, x0)

        self._sample = jax.jit(_sample)

    @classmethod
    def from_artifact(cls, artifact, *, params: dict, cfg: ModelConfig,
                      sched: Scheduler) -> "FlowSampler":
        """Serving session from a loaded ``repro.solvers.SolverArtifact``.

        The artifact carries the solver parameters and the CFG scale it was
        distilled under; the backbone (params/cfg/sched) is supplied by the
        launcher.
        """
        return cls(params=params, cfg=cfg, sched=sched,
                   solver=artifact.ns_params,
                   cfg_scale=artifact.spec.cfg_scale)

    def sample(self, batch: dict, key: Array) -> Array:
        """Generate latent sequences conditioned on ``batch`` tokens.

        The latent length equals the conditioning token length — the backbone
        adds conditioning embeddings position-wise, so they cannot differ.
        """
        B, S = batch["tokens"].shape
        x0 = jax.random.normal(key, (B, S, self.cfg.latent_dim))
        return self._sample(self.params, self.solver, batch, x0)

    def nearest_tokens(self, latents: Array) -> Array:
        """Decode sampled latents to tokens by nearest latent embedding."""
        table = self.params["flow"]["latent_embed"].astype(jnp.float32)
        d2 = (jnp.sum(latents.astype(jnp.float32) ** 2, -1, keepdims=True)
              - 2.0 * latents.astype(jnp.float32) @ table.T
              + jnp.sum(table**2, -1))
        return jnp.argmin(d2, axis=-1)


@dataclasses.dataclass
class DecodeEngine:
    params: dict
    cfg: ModelConfig
    window: int = 0

    def __post_init__(self):
        def _step(params, token, state):
            return M.decode_apply(params, self.cfg, token, state,
                                  window=self.window)

        self._step = jax.jit(_step)

    def init_state(self, batch: int, slots: int, dtype=jnp.float32):
        return M.init_decode_state(self.cfg, batch, slots, dtype)

    def greedy(self, prompt: Array, state, num_steps: int) -> tuple[Array, object]:
        """prompt: (B,) last prompt token. Returns (B, num_steps) tokens."""
        outs = []
        token = prompt
        for _ in range(num_steps):
            logits, state = self._step(self.params, token, state)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            outs.append(token)
        return jnp.stack(outs, axis=1), state
