"""Serving engines.

``FlowSampler`` — the paper's product: BNS-accelerated batched sampling of a
flow model (any backbone in the zoo). A thin jit'd session over Algorithm 1:
construct it from a serialized ``SolverArtifact`` (``from_artifact``) or any
NS solver, and each request batch costs exactly ``n`` backbone forwards.

``AnytimeFlowSampler`` — multi-NFE anytime serving from ONE artifact.

Budget-routing contract: the sampler owns the anytime solver's served
``budgets``; a request asks for an NFE budget and is routed as follows.

  * ``sample(batch, key, budget=m)`` with ``m`` in ``budgets`` runs the
    extracted m-step early-exit solver (``core.anytime.extract_ns``) — a
    batch of requests at budget m costs exactly m backbone forwards, and the
    jit'd program for each budget is compiled once and cached.
  * ``m`` not in ``budgets``: ``resolve_budget(m)`` picks the nearest served
    budget (ties to the smaller, i.e. cheaper); ``strict=True`` raises
    instead. Callers that must not silently change NFE (``launch/serve.py
    --strict-nfe``) pass strict.
  * ``sample_all(batch, key)`` runs the one shared trajectory to the top
    budget and emits every early exit — max(budgets) forwards total for all
    budgets at once (mixed-budget batches, evaluation).

``DecodeEngine`` — batched autoregressive decode with KV cache / recurrent
state (the ``serve_step`` the decode dry-run shapes lower).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import anytime as anytime_mod
from repro.core import ns_solver
from repro.core.ns_solver import NSParams
from repro.core.schedulers import Scheduler
from repro.models import model as M

Array = jax.Array


def nearest_budget(budgets, m: int, strict: bool = False) -> int:
    """THE budget-routing rule, shared by every serving surface: an exact
    match passes through; otherwise the nearest served budget (ties break
    to the smaller — fewer backbone forwards), or ``ValueError`` under
    ``strict``."""
    if m in budgets:
        return m
    if strict:
        raise ValueError(f"budget {m} not served; have {tuple(budgets)}")
    return min(budgets, key=lambda b: (abs(b - m), b))


def nearest_latent_tokens(params: dict, latents: Array) -> Array:
    """Decode sampled latents to tokens by nearest latent embedding."""
    table = params["flow"]["latent_embed"].astype(jnp.float32)
    d2 = (jnp.sum(latents.astype(jnp.float32) ** 2, -1, keepdims=True)
          - 2.0 * latents.astype(jnp.float32) @ table.T
          + jnp.sum(table**2, -1))
    return jnp.argmin(d2, axis=-1)


@dataclasses.dataclass
class FlowSampler:
    params: dict
    cfg: ModelConfig
    sched: Scheduler
    solver: NSParams
    cfg_scale: float = 0.0
    update_fn: Optional[Callable] = None   # e.g. kernels.ns_update make_update_fn

    def __post_init__(self):
        def _sample(params, solver, batch, x0):
            field = M.velocity_field(params, self.cfg, self.sched, batch,
                                     cfg_scale=self.cfg_scale)
            return ns_solver.ns_sample(solver, field.fn, x0,
                                       update_fn=self.update_fn)

        self._sample = jax.jit(_sample)

    @classmethod
    def from_artifact(cls, artifact, *, params: dict, cfg: ModelConfig,
                      sched: Scheduler, budget: Optional[int] = None,
                      update_fn: Optional[Callable] = None) -> "FlowSampler":
        """Serving session from a loaded ``repro.solvers.SolverArtifact``.

        The artifact carries the solver parameters and the CFG scale it was
        distilled under; the backbone (params/cfg/sched) is supplied by the
        launcher. ``budget`` selects one early exit of an anytime artifact
        (required there — use ``AnytimeFlowSampler`` to serve them all).
        """
        if budget is None and artifact.kind == "anytime":
            raise TypeError(
                "anytime artifacts serve several budgets; pass budget=m for "
                "a fixed-NFE session or use AnytimeFlowSampler.from_artifact")
        solver = (artifact.ns_params if budget is None
                  else artifact.ns_at_budget(budget))
        return cls(params=params, cfg=cfg, sched=sched, solver=solver,
                   cfg_scale=artifact.spec.cfg_scale, update_fn=update_fn)

    # -- budget protocol (shared with AnytimeFlowSampler, used by the
    #    gateway): a fixed-NFE session serves exactly one budget. -----------

    @property
    def budgets(self) -> tuple[int, ...]:
        return (self.solver.n,)

    def resolve_budget(self, m: int, strict: bool = False) -> int:
        """One served budget: exact match or (with ``strict``) rejection."""
        if m != self.solver.n and strict:
            raise ValueError(f"budget {m} not served; have {self.budgets}")
        return self.solver.n

    def sample_from(self, batch: Optional[dict], x0: Array,
                    budget: Optional[int] = None) -> Array:
        """Integrate given noise ``x0`` (this session's one budget)."""
        if budget is not None and budget != self.solver.n:
            raise ValueError(f"budget {budget} not served; have {self.budgets}")
        return self._sample(self.params, self.solver, batch, x0)

    def sample(self, batch: dict, key: Array) -> Array:
        """Generate latent sequences conditioned on ``batch`` tokens.

        The latent length equals the conditioning token length — the backbone
        adds conditioning embeddings position-wise, so they cannot differ.
        """
        B, S = batch["tokens"].shape
        x0 = jax.random.normal(key, (B, S, self.cfg.latent_dim))
        return self._sample(self.params, self.solver, batch, x0)

    def nearest_tokens(self, latents: Array) -> Array:
        """Decode sampled latents to tokens by nearest latent embedding."""
        return nearest_latent_tokens(self.params, latents)


@dataclasses.dataclass
class AnytimeFlowSampler:
    """Budget-aware serving session over ONE anytime solver artifact.

    See the module docstring for the budget-routing contract. Per-budget
    jit'd programs are compiled lazily and cached, so a running server pays
    one compile per distinct budget, then exactly m forwards per request.
    """

    params: dict
    cfg: ModelConfig
    sched: Scheduler
    anytime: anytime_mod.AnytimeParams
    budgets: tuple[int, ...]
    cfg_scale: float = 0.0
    update_fn: Optional[Callable] = None   # e.g. kernels.ns_update make_update_fn

    def __post_init__(self):
        self.budgets = tuple(sorted(self.budgets))
        self._per_budget: dict[int, Callable] = {}
        self._all: Optional[Callable] = None
        self._extends: dict[tuple[int, int], Callable] = {}

    @classmethod
    def from_artifact(cls, artifact, *, params: dict, cfg: ModelConfig,
                      sched: Scheduler,
                      update_fn: Optional[Callable] = None
                      ) -> "AnytimeFlowSampler":
        """Serving session from a loaded anytime ``SolverArtifact``."""
        if artifact.kind != "anytime":
            raise TypeError(f"{artifact.kind!r} artifacts serve one budget; "
                            "use FlowSampler.from_artifact")
        return cls(params=params, cfg=cfg, sched=sched,
                   anytime=artifact.params, budgets=artifact.budgets,
                   cfg_scale=artifact.spec.cfg_scale, update_fn=update_fn)

    def _field(self, batch: dict):
        return M.velocity_field(self.params, self.cfg, self.sched, batch,
                                cfg_scale=self.cfg_scale)

    def resolve_budget(self, m: int, strict: bool = False) -> int:
        """Route a requested NFE to a served budget (nearest; ties cheaper)."""
        return nearest_budget(self.budgets, m, strict)

    def ns_at_budget(self, m: int) -> NSParams:
        return anytime_mod.extract_ns(self.anytime, self.budgets, m)

    def sample_from(self, batch: dict, x0: Array, budget: int) -> Array:
        """Integrate given noise ``x0`` at exactly ``budget`` NFE."""
        fn = self._per_budget.get(budget)
        if fn is None:
            ns = self.ns_at_budget(budget)   # raises on unserved budgets

            def _sample(params, batch, x0, ns=ns):
                field = M.velocity_field(params, self.cfg, self.sched, batch,
                                         cfg_scale=self.cfg_scale)
                return ns_solver.ns_sample(ns, field.fn, x0,
                                           update_fn=self.update_fn)

            fn = self._per_budget[budget] = jax.jit(_sample)
        return fn(self.params, batch, x0)

    def sample(self, batch: dict, key: Array, budget: int,
               strict: bool = False) -> Array:
        """Generate latents for ``batch`` at the requested NFE budget."""
        budget = self.resolve_budget(budget, strict=strict)
        B, S = batch["tokens"].shape
        x0 = jax.random.normal(key, (B, S, self.cfg.latent_dim))
        return self.sample_from(batch, x0, budget)

    def sample_all_from(self, batch: dict, x0: Array) -> dict[int, Array]:
        """One shared trajectory from ``x0``; every budget's output, at
        max(budgets) total forwards."""
        if self._all is None:
            def _sample(params, batch, x0):
                field = M.velocity_field(params, self.cfg, self.sched, batch,
                                         cfg_scale=self.cfg_scale)
                return anytime_mod.anytime_sample(self.anytime, self.budgets,
                                                  field.fn, x0,
                                                  update_fn=self.update_fn)

            self._all = jax.jit(_sample)
        return self._all(self.params, batch, x0)

    def sample_all(self, batch: dict, key: Array) -> dict[int, Array]:
        B, S = batch["tokens"].shape
        x0 = jax.random.normal(key, (B, S, self.cfg.latent_dim))
        return self.sample_all_from(batch, x0)

    # -- carry protocol (continuous batching, repro.serving.continuous) ------

    def carry_start(self, batch: Optional[dict],
                    x0: Array) -> anytime_mod.AnytimeCarry:
        """A fresh shared-trajectory carry over ``x0`` (no forwards spent)."""
        return anytime_mod.anytime_carry(self.anytime, self.budgets, x0)

    def carry_extend(self, batch: Optional[dict],
                     carry: anytime_mod.AnytimeCarry, stop: int
                     ) -> tuple[anytime_mod.AnytimeCarry, dict[int, Array]]:
        """Advance the shared trajectory to ``stop`` evals; returns the new
        carry plus the early-exit outputs crossed on the way.

        Costs exactly ``stop - carry.step`` backbone forwards for the whole
        slot batch. One jit program per (start, stop) leg — the boundary
        pairs a trajectory can traverse are few and fixed, so a running
        server compiles each leg once (mirroring the per-budget programs).
        """
        key = (carry.step, stop)
        fn = self._extends.get(key)
        if fn is None:
            start, step_stop = key

            def _extend(params, batch, x0, U, x):
                field = M.velocity_field(params, self.cfg, self.sched, batch,
                                         cfg_scale=self.cfg_scale)
                c = anytime_mod.AnytimeCarry(x0=x0, U=U, x=x, step=start)
                out, exits = anytime_mod.anytime_extend(
                    self.anytime, self.budgets, field.fn, c, step_stop,
                    update_fn=self.update_fn)
                return out.U, out.x, exits

            fn = self._extends[key] = jax.jit(_extend)
        U, x, exits = fn(self.params, batch, carry.x0, carry.U, carry.x)
        return anytime_mod.AnytimeCarry(x0=carry.x0, U=U, x=x,
                                        step=stop), exits

    def nearest_tokens(self, latents: Array) -> Array:
        """Decode sampled latents to tokens by nearest latent embedding."""
        return nearest_latent_tokens(self.params, latents)


@dataclasses.dataclass
class DecodeEngine:
    params: dict
    cfg: ModelConfig
    window: int = 0

    def __post_init__(self):
        def _step(params, token, state):
            return M.decode_apply(params, self.cfg, token, state,
                                  window=self.window)

        self._step = jax.jit(_step)

    def init_state(self, batch: int, slots: int, dtype=jnp.float32):
        return M.init_decode_state(self.cfg, batch, slots, dtype)

    def greedy(self, prompt: Array, state, num_steps: int) -> tuple[Array, object]:
        """prompt: (B,) last prompt token. Returns (B, num_steps) tokens."""
        outs = []
        token = prompt
        for _ in range(num_steps):
            logits, state = self._step(self.params, token, state)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            outs.append(token)
        return jnp.stack(outs, axis=1), state
