"""Serving engines.

``FlowSampler`` — the paper's product: BNS-accelerated batched sampling of a
flow model (any backbone in the zoo). A thin jit'd session over Algorithm 1:
construct it from a serialized ``SolverArtifact`` (``from_artifact``) or any
NS solver, and each request batch costs exactly ``n`` backbone forwards.

``AnytimeFlowSampler`` — multi-NFE anytime serving from ONE artifact.

Budget-routing contract: the sampler owns the anytime solver's served
``budgets``; a request asks for an NFE budget and is routed as follows.

  * ``sample(batch, key, budget=m)`` with ``m`` in ``budgets`` runs the
    extracted m-step early-exit solver (``core.anytime.extract_ns``) — a
    batch of requests at budget m costs exactly m backbone forwards, and the
    jit'd program for each budget is compiled once and cached.
  * ``m`` not in ``budgets``: ``resolve_budget(m)`` picks the nearest served
    budget (ties to the smaller, i.e. cheaper); ``strict=True`` raises
    instead. Callers that must not silently change NFE (``launch/serve.py
    --strict-nfe``) pass strict.
  * ``sample_all(batch, key)`` runs the one shared trajectory to the top
    budget and emits every early exit — max(budgets) forwards total for all
    budgets at once (mixed-budget batches, evaluation).

``DecodeEngine`` — batched autoregressive decode with KV cache / recurrent
state (the ``serve_step`` the decode dry-run shapes lower). ``greedy`` is a
jit'd ``lax.scan`` multi-token program; the slot API (``init_slot_state`` /
``step_slots`` / ``reset_slots`` / ``prefill_slots``) serves independent
sequences from the rows of one fixed-slot batched state — the substrate of
the decode-side continuous-batching gateway
(``repro.serving.decode.DecodeGateway``). ``page_size > 0`` switches the
KV-cache families to a PAGED state (``PagedKVCache``: shared page pool +
per-row block table, vLLM-style), and ``SamplingParams`` /
``sample_tokens`` add temperature / top-k / top-p sampling beside greedy.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import anytime as anytime_mod
from repro.core import ns_solver
from repro.core.ns_solver import NSParams
from repro.core.schedulers import Scheduler
from repro.models import model as M

Array = jax.Array


def nearest_budget(budgets, m: int, strict: bool = False) -> int:
    """THE budget-routing rule, shared by every serving surface: an exact
    match passes through; otherwise the nearest served budget (ties break
    to the smaller — fewer backbone forwards), or ``ValueError`` under
    ``strict``."""
    if m in budgets:
        return m
    if strict:
        raise ValueError(f"budget {m} not served; have {tuple(budgets)}")
    return min(budgets, key=lambda b: (abs(b - m), b))


def nearest_latent_tokens(params: dict, latents: Array) -> Array:
    """Decode sampled latents to tokens by nearest latent embedding."""
    table = params["flow"]["latent_embed"].astype(jnp.float32)
    d2 = (jnp.sum(latents.astype(jnp.float32) ** 2, -1, keepdims=True)
          - 2.0 * latents.astype(jnp.float32) @ table.T
          + jnp.sum(table**2, -1))
    return jnp.argmin(d2, axis=-1)


@dataclasses.dataclass
class FlowSampler:
    params: dict
    cfg: ModelConfig
    sched: Scheduler
    solver: NSParams
    cfg_scale: float = 0.0
    update_fn: Optional[Callable] = None   # e.g. kernels.ns_update make_update_fn

    def __post_init__(self):
        def _sample(params, solver, batch, x0):
            field = M.velocity_field(params, self.cfg, self.sched, batch,
                                     cfg_scale=self.cfg_scale)
            return ns_solver.ns_sample(solver, field.fn, x0,
                                       update_fn=self.update_fn)

        self._sample = jax.jit(_sample)

    @classmethod
    def from_artifact(cls, artifact, *, params: dict, cfg: ModelConfig,
                      sched: Scheduler, budget: Optional[int] = None,
                      update_fn: Optional[Callable] = None) -> "FlowSampler":
        """Serving session from a loaded ``repro.solvers.SolverArtifact``.

        The artifact carries the solver parameters and the CFG scale it was
        distilled under; the backbone (params/cfg/sched) is supplied by the
        launcher. ``budget`` selects one early exit of an anytime artifact
        (required there — use ``AnytimeFlowSampler`` to serve them all).
        """
        if budget is None and artifact.kind == "anytime":
            raise TypeError(
                "anytime artifacts serve several budgets; pass budget=m for "
                "a fixed-NFE session or use AnytimeFlowSampler.from_artifact")
        solver = (artifact.ns_params if budget is None
                  else artifact.ns_at_budget(budget))
        return cls(params=params, cfg=cfg, sched=sched, solver=solver,
                   cfg_scale=artifact.spec.cfg_scale, update_fn=update_fn)

    # -- budget protocol (shared with AnytimeFlowSampler, used by the
    #    gateway): a fixed-NFE session serves exactly one budget. -----------

    @property
    def budgets(self) -> tuple[int, ...]:
        return (self.solver.n,)

    def resolve_budget(self, m: int, strict: bool = False) -> int:
        """One served budget: exact match or (with ``strict``) rejection."""
        if m != self.solver.n and strict:
            raise ValueError(f"budget {m} not served; have {self.budgets}")
        return self.solver.n

    def sample_from(self, batch: Optional[dict], x0: Array,
                    budget: Optional[int] = None) -> Array:
        """Integrate given noise ``x0`` (this session's one budget)."""
        if budget is not None and budget != self.solver.n:
            raise ValueError(f"budget {budget} not served; have {self.budgets}")
        return self._sample(self.params, self.solver, batch, x0)

    def sample(self, batch: dict, key: Array) -> Array:
        """Generate latent sequences conditioned on ``batch`` tokens.

        The latent length equals the conditioning token length — the backbone
        adds conditioning embeddings position-wise, so they cannot differ.
        """
        B, S = batch["tokens"].shape
        x0 = jax.random.normal(key, (B, S, self.cfg.latent_dim))
        return self._sample(self.params, self.solver, batch, x0)

    def nearest_tokens(self, latents: Array) -> Array:
        """Decode sampled latents to tokens by nearest latent embedding."""
        return nearest_latent_tokens(self.params, latents)


@dataclasses.dataclass
class AnytimeFlowSampler:
    """Budget-aware serving session over ONE anytime solver artifact.

    See the module docstring for the budget-routing contract. Per-budget
    jit'd programs are compiled lazily and cached, so a running server pays
    one compile per distinct budget, then exactly m forwards per request.
    """

    params: dict
    cfg: ModelConfig
    sched: Scheduler
    anytime: anytime_mod.AnytimeParams
    budgets: tuple[int, ...]
    cfg_scale: float = 0.0
    update_fn: Optional[Callable] = None   # e.g. kernels.ns_update make_update_fn

    def __post_init__(self):
        self.budgets = tuple(sorted(self.budgets))
        self._per_budget: dict[int, Callable] = {}
        self._all: Optional[Callable] = None
        self._extends: dict[tuple[int, int], Callable] = {}

    @classmethod
    def from_artifact(cls, artifact, *, params: dict, cfg: ModelConfig,
                      sched: Scheduler,
                      update_fn: Optional[Callable] = None
                      ) -> "AnytimeFlowSampler":
        """Serving session from a loaded anytime ``SolverArtifact``."""
        if artifact.kind != "anytime":
            raise TypeError(f"{artifact.kind!r} artifacts serve one budget; "
                            "use FlowSampler.from_artifact")
        return cls(params=params, cfg=cfg, sched=sched,
                   anytime=artifact.params, budgets=artifact.budgets,
                   cfg_scale=artifact.spec.cfg_scale, update_fn=update_fn)

    def _field(self, batch: dict):
        return M.velocity_field(self.params, self.cfg, self.sched, batch,
                                cfg_scale=self.cfg_scale)

    def resolve_budget(self, m: int, strict: bool = False) -> int:
        """Route a requested NFE to a served budget (nearest; ties cheaper)."""
        return nearest_budget(self.budgets, m, strict)

    def ns_at_budget(self, m: int) -> NSParams:
        return anytime_mod.extract_ns(self.anytime, self.budgets, m)

    def sample_from(self, batch: dict, x0: Array, budget: int) -> Array:
        """Integrate given noise ``x0`` at exactly ``budget`` NFE."""
        fn = self._per_budget.get(budget)
        if fn is None:
            ns = self.ns_at_budget(budget)   # raises on unserved budgets

            def _sample(params, batch, x0, ns=ns):
                field = M.velocity_field(params, self.cfg, self.sched, batch,
                                         cfg_scale=self.cfg_scale)
                return ns_solver.ns_sample(ns, field.fn, x0,
                                           update_fn=self.update_fn)

            fn = self._per_budget[budget] = jax.jit(_sample)
        return fn(self.params, batch, x0)

    def sample(self, batch: dict, key: Array, budget: int,
               strict: bool = False) -> Array:
        """Generate latents for ``batch`` at the requested NFE budget."""
        budget = self.resolve_budget(budget, strict=strict)
        B, S = batch["tokens"].shape
        x0 = jax.random.normal(key, (B, S, self.cfg.latent_dim))
        return self.sample_from(batch, x0, budget)

    def sample_all_from(self, batch: dict, x0: Array) -> dict[int, Array]:
        """One shared trajectory from ``x0``; every budget's output, at
        max(budgets) total forwards."""
        if self._all is None:
            def _sample(params, batch, x0):
                field = M.velocity_field(params, self.cfg, self.sched, batch,
                                         cfg_scale=self.cfg_scale)
                return anytime_mod.anytime_sample(self.anytime, self.budgets,
                                                  field.fn, x0,
                                                  update_fn=self.update_fn)

            self._all = jax.jit(_sample)
        return self._all(self.params, batch, x0)

    def sample_all(self, batch: dict, key: Array) -> dict[int, Array]:
        B, S = batch["tokens"].shape
        x0 = jax.random.normal(key, (B, S, self.cfg.latent_dim))
        return self.sample_all_from(batch, x0)

    # -- carry protocol (continuous batching, repro.serving.continuous) ------

    def carry_start(self, batch: Optional[dict],
                    x0: Array) -> anytime_mod.AnytimeCarry:
        """A fresh shared-trajectory carry over ``x0`` (no forwards spent)."""
        return anytime_mod.anytime_carry(self.anytime, self.budgets, x0)

    def carry_extend(self, batch: Optional[dict],
                     carry: anytime_mod.AnytimeCarry, stop: int
                     ) -> tuple[anytime_mod.AnytimeCarry, dict[int, Array]]:
        """Advance the shared trajectory to ``stop`` evals; returns the new
        carry plus the early-exit outputs crossed on the way.

        Costs exactly ``stop - carry.step`` backbone forwards for the whole
        slot batch. One jit program per (start, stop) leg — the boundary
        pairs a trajectory can traverse are few and fixed, so a running
        server compiles each leg once (mirroring the per-budget programs).

        The returned exits dict is also the STREAMING surface: row i of
        ``exits[k]`` is exactly the sample a budget-k request with slot
        i's noise would have received (the anytime grid is nested), so
        ``ContinuousGateway`` forwards it to streaming clients as a valid
        intermediate sample at zero extra forwards — and because the
        carry's per-row columns fully determine the remaining trajectory,
        the same property makes exit boundaries free preemption points
        (``serving.slo.PausedCarry``).
        """
        key = (carry.step, stop)
        fn = self._extends.get(key)
        if fn is None:
            start, step_stop = key

            def _extend(params, batch, x0, U, x):
                field = M.velocity_field(params, self.cfg, self.sched, batch,
                                         cfg_scale=self.cfg_scale)
                c = anytime_mod.AnytimeCarry(x0=x0, U=U, x=x, step=start)
                out, exits = anytime_mod.anytime_extend(
                    self.anytime, self.budgets, field.fn, c, step_stop,
                    update_fn=self.update_fn)
                return out.U, out.x, exits

            fn = self._extends[key] = jax.jit(_extend)
        U, x, exits = fn(self.params, batch, carry.x0, carry.U, carry.x)
        return anytime_mod.AnytimeCarry(x0=carry.x0, U=U, x=x,
                                        step=stop), exits

    def nearest_tokens(self, latents: Array) -> Array:
        """Decode sampled latents to tokens by nearest latent embedding."""
        return nearest_latent_tokens(self.params, latents)


# ---------------------------------------------------------------------------
# Sampling (temperature / top-k / top-p beside greedy)
# ---------------------------------------------------------------------------

_NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs. ``temperature == 0`` is exact greedy
    (argmax); ``top_k == 0`` and ``top_p == 1.0`` disable those filters.
    Determinism contract: given the gateway's base key, a request's tokens
    depend only on (base key, request uid, step) — reproducible across
    restarts and fleet re-routing."""

    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


class SlotSampling(NamedTuple):
    """Batched per-slot sampling state fed to the sampled step program.
    ``keys`` are per-SEQUENCE keys (base key folded with the request uid);
    ``counts`` is each row's emitted-token count, folded in per step so every
    position draws fresh randomness without host-side key churn."""

    keys: Array      # (slots, 2) uint32 per-sequence PRNG keys
    counts: Array    # (slots,) int32 tokens emitted so far
    temps: Array     # (slots,) f32 temperature (0 = greedy)
    top_ks: Array    # (slots,) int32 top-k cutoff (0 = off)
    top_ps: Array    # (slots,) f32 top-p cutoff (1.0 = off)


def sample_tokens(logits: Array, keys: Array, temps: Array, top_ks: Array,
                  top_ps: Array) -> Array:
    """Vectorised per-row sampling: temperature scale, top-k and top-p
    truncation, Gumbel-max draw; rows with ``temps == 0`` take the exact
    argmax. All filters run on the descending-sorted logits so the k-th
    largest value and the nucleus boundary are O(V log V) with no scatters.
    """
    V = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    scaled = lf / jnp.maximum(temps, 1e-6)[:, None]
    sorted_desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    # top-k threshold: the k-th largest scaled logit (k == 0 -> keep all)
    k = jnp.clip(jnp.where(top_ks > 0, top_ks, V), 1, V)
    kth = jnp.take_along_axis(sorted_desc, (k - 1)[:, None], axis=-1)
    # top-p threshold over the sorted distribution; the exclusive cumsum
    # guarantees the top-1 token always survives
    probs = jax.nn.softmax(sorted_desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    in_nucleus = (cum - probs) < top_ps[:, None]
    pth = jnp.min(jnp.where(in_nucleus, sorted_desc, jnp.inf), axis=-1,
                  keepdims=True)
    cutoff = jnp.maximum(kth, pth)
    masked = jnp.where(scaled >= cutoff, scaled, _NEG_INF)
    gumbel = jax.vmap(lambda key: jax.random.gumbel(key, (V,)))(keys)
    sampled = jnp.argmax(masked + gumbel, axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy)


@dataclasses.dataclass
class DecodeEngine:
    """Batched autoregressive decode with KV cache / recurrent state.

    Two serving surfaces:

    * ``greedy(prompt, state, num_steps)`` — run-to-completion batched
      decode: one jit'd ``lax.scan`` program per ``num_steps``, compiled
      once and cached (the old host-side per-token Python loop paid a
      device dispatch round-trip per token).
    * slot serving — ``init_slot_state`` builds a fixed-slot batched state
      whose rows are INDEPENDENT sequences at their own decode positions
      (per-row ``index`` vector); ``step_slots`` advances only the rows
      picked by the active mask (write-masked state update) and
      ``reset_slots`` re-zeroes freed rows for the next admission. Rows
      are independent through the backbone, so a slot's tokens are
      bit-identical to decoding its sequence alone (MoE: in the
      no-capacity-drop regime, as for batched decode generally). This is
      the substrate of ``repro.serving.decode.DecodeGateway``.

    ``page_size > 0`` pages the slot state for the KV-cache families: the
    cache becomes a shared ``(L, num_pages, page_size, KV, hd)`` pool plus a
    per-row block table (``PagedKVCache``). Page ownership replaces row
    masking for the pool leaves — a masked-off row's in-flight write lands in
    its own pages (overwritten before the row is next read) or in the
    reserved trash page 0 (freed rows), so ``step_slots`` takes the new pool
    unconditionally and ``reset_slots`` never zeroes it. The ``ssm`` family
    accepts ``page_size`` as a no-op (its recurrent state is already O(1)
    per slot); hybrid/encdec reject it.
    """

    params: dict
    cfg: ModelConfig
    window: int = 0
    page_size: int = 0        # > 0: paged KV cache (KV families; ssm no-op)
    paged_kernel: bool = False  # paged attention via the Pallas kernel

    #: gateways probe this before routing sampled requests (toy engines
    #: and older engines are greedy-only).
    supports_sampling = True

    def __post_init__(self):
        if self.page_size:
            if self.window:
                raise ValueError(
                    "paged KV cache is incompatible with sliding-window "
                    "decode (the ring buffer already bounds resident KV)")
            if self.cfg.family not in M.PAGED_FAMILIES + ("ssm",):
                raise TypeError(
                    f"page_size set but family {self.cfg.family!r} has no "
                    f"pageable KV state (pageable: {M.PAGED_FAMILIES}; "
                    "ssm accepted as a no-op)")

        def _step(params, token, state):
            return M.decode_apply(params, self.cfg, token, state,
                                  window=self.window,
                                  paged_kernel=self.paged_kernel)

        self._step = jax.jit(_step)
        self._greedy_fns: dict[int, Callable] = {}
        self._prefill_fns: dict[int, Callable] = {}

        axes = M.decode_state_batch_axes(self.cfg, paged=self.paged)

        def _mask_rows(mask, new, old):
            """Per-leaf row select: ``mask`` picks rows (along each leaf's
            batch axis) that take ``new``; other rows keep ``old``. Leaves
            whose axis reads ``-1`` (the shared page pool) take ``new``
            unconditionally — isolation there is by page ownership, not by
            row masking (see class docstring)."""

            def keep(ax, n, o):
                if ax == -1:
                    return n
                shape = [1] * n.ndim
                shape[ax] = mask.shape[0]
                return jnp.where(mask.reshape(shape), n, o)

            return jax.tree.map(keep, axes, new, old)

        self._mask_rows_fn = _mask_rows

        def _step_slots(params, token, state, active):
            logits, new = M.decode_apply(params, self.cfg, token, state,
                                         window=self.window,
                                         paged_kernel=self.paged_kernel)
            state = _mask_rows(active, new, state)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), state

        self._step_slots = jax.jit(_step_slots)

        def _step_slots_sampled(params, token, state, active, keys, counts,
                                temps, top_ks, top_ps):
            logits, new = M.decode_apply(params, self.cfg, token, state,
                                         window=self.window,
                                         paged_kernel=self.paged_kernel)
            state = _mask_rows(active, new, state)
            step_keys = jax.vmap(jax.random.fold_in)(keys, counts)
            toks = sample_tokens(logits, step_keys, temps, top_ks, top_ps)
            return toks, state

        self._step_slots_sampled = jax.jit(_step_slots_sampled)

        def _reset_slots(state, free):
            """Zero the rows where ``free`` is True — except the shared page
            pool (axis ``-1``), which other rows' live pages make
            untouchable; freed rows are isolated by their zeroed block
            table (trash page 0) instead."""

            def keep(ax, o):
                if ax == -1:
                    return o
                shape = [1] * o.ndim
                shape[ax] = free.shape[0]
                return jnp.where(free.reshape(shape), jnp.zeros_like(o), o)

            return jax.tree.map(keep, axes, state)

        self._reset_slots = jax.jit(_reset_slots)

    def init_state(self, batch: int, slots: int, dtype=jnp.float32):
        return M.init_decode_state(self.cfg, batch, slots, dtype)

    @property
    def paged(self) -> bool:
        """True when slot state is a ``PagedKVCache`` (page_size set AND the
        family has pageable KV; ssm keeps its dense recurrent state)."""
        return self.page_size > 0 and self.cfg.family in M.PAGED_FAMILIES

    @property
    def seq_capacity_bounded(self) -> bool:
        """True when decode positions must fit the cache's physical slots:
        the non-windowed KV-cache families silently clamp writes to the
        last slot past capacity (degraded tokens, no error). Sliding-window
        ring buffers and pure recurrent state decode unbounded lengths."""
        return self.window == 0 and self.cfg.family != "ssm"

    def step(self, token: Array, state):
        """One batched decode step: token (B,) -> (logits (B, V), state)."""
        return self._step(self.params, token, state)

    def greedy(self, prompt: Array, state, num_steps: int) -> tuple[Array, object]:
        """prompt: (B,) last prompt token. Returns (B, num_steps) tokens.

        The whole multi-token loop is ONE jit'd ``lax.scan`` program per
        ``num_steps`` (cached), so a serving session pays one compile and
        then zero host round-trips inside the decode loop.
        """
        fn = self._greedy_fns.get(num_steps)
        if fn is None:
            def _greedy(params, token, state):
                def body(carry, _):
                    token, state = carry
                    logits, state = M.decode_apply(params, self.cfg, token,
                                                   state, window=self.window)
                    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                    return (token, state), token

                (_, state), toks = jax.lax.scan(body, (token, state), None,
                                                length=num_steps)
                return jnp.swapaxes(toks, 0, 1), state

            fn = self._greedy_fns[num_steps] = jax.jit(_greedy)
        return fn(self.params, prompt, state)

    # -- slot serving (decode-side continuous batching) ----------------------

    def init_slot_state(self, slots: int, cache_slots: int,
                        dtype=jnp.float32,
                        total_pages: Optional[int] = None):
        """Fixed-slot batched decode state with PER-ROW positions: row i
        serves an independent sequence; ``index`` is a (slots,) vector so
        sequences admitted at different times sit at different positions.

        Paged engines return a ``PagedKVCache`` instead: a shared pool of
        ``total_pages`` pages (default: page 0 as trash + every slot at full
        ``cache_slots`` residency — shrink it to overcommit) and an all-zero
        block table awaiting the gateway's allocator. ``cache_slots`` must be
        a multiple of ``page_size`` (it fixes the block-table width, and the
        dense-gather fallback is bit-identical to the dense cache only when
        the gathered length matches)."""
        if self.paged:
            ps = self.page_size
            if cache_slots % ps:
                raise ValueError(
                    f"cache_slots ({cache_slots}) must be a multiple of "
                    f"page_size ({ps})")
            blocks = cache_slots // ps
            pages = (1 + slots * blocks) if total_pages is None else total_pages
            if pages < 2:
                raise ValueError("total_pages must be >= 2 (page 0 is the "
                                 "reserved trash page)")
            return M.init_paged_decode_state(self.cfg, slots, pages, ps,
                                             blocks, dtype)
        state = M.init_decode_state(self.cfg, slots, cache_slots, dtype)
        return state._replace(index=jnp.zeros((slots,), jnp.int32))

    def step_slots(self, token: Array, state, active: Array,
                   sampling: Optional[SlotSampling] = None):
        """One write-masked decode step over the slot batch.

        ``token`` (slots,) feeds each row; rows where ``active`` is False
        still flow through the backbone (fixed batch shape — one compiled
        program regardless of occupancy) but their state rows and positions
        are left untouched. Returns (next token (slots,), state): greedy
        argmax, or per-row ``SlotSampling`` draws when ``sampling`` is given
        (rows with temperature 0 stay exact greedy, so mixed batches cost
        one program)."""
        if sampling is None:
            return self._step_slots(self.params, token, state, active)
        return self._step_slots_sampled(self.params, token, state, active,
                                        *sampling)

    def prefill_slots(self, tokens: Array, lengths: Array, state, mask: Array):
        """Batched chunked prefill: feed ``tokens`` (slots, C) teacher-forced
        into the rows where ``mask`` is True, row i consuming its first
        ``lengths[i]`` columns (the rest are padding). One jit'd scan program
        per chunk width C, shared by every prompt; logits are discarded. The
        scan body is the same ``decode_apply`` as ``step_slots``, so prefill
        state is bit-identical to feeding the prompt token-by-token."""
        C = int(tokens.shape[1])
        fn = self._prefill_fns.get(C)
        if fn is None:
            def _prefill(params, tokens, lengths, state, mask):
                def body(state, t):
                    tok = jnp.take(tokens, t, axis=1)
                    act = mask & (t < lengths)
                    _, new = M.decode_apply(params, self.cfg, tok, state,
                                            window=self.window,
                                            paged_kernel=self.paged_kernel)
                    return self._mask_rows_fn(act, new, state), None

                state, _ = jax.lax.scan(body, state, jnp.arange(C))
                return state

            fn = self._prefill_fns[C] = jax.jit(_prefill)
        return fn(self.params, tokens, lengths, state, mask)

    def reset_slots(self, state, free: Array):
        """Scatter a fresh zero state into the rows where ``free`` is True
        (``init_decode_state`` is all-zeros), readying them for admission
        of a new sequence at position 0. Paged: zeroes the freed rows'
        block-table entries (-> trash page 0) and positions but leaves the
        shared pool alone."""
        return self._reset_slots(state, free)

    def with_block_table(self, state, table) -> object:
        """Swap in the gateway allocator's host-side block table (paged
        engines only). ``table`` is (slots, blocks_per_slot) page ids."""
        return state._replace(block_table=jnp.asarray(table, jnp.int32))


def greedy_demo(engine: DecodeEngine, batch: int, steps: int,
                cache_slots: int, prompt: Optional[Array] = None
                ) -> tuple[Array, float]:
    """Shared solo-decode demo loop (``launch/serve.py --mode decode`` and
    ``examples/serve_decode.py`` previously each had their own copy): fresh
    state, ``steps`` greedy tokens, returns (tokens, ms_per_token)."""
    state = engine.init_state(batch, cache_slots)
    if prompt is None:
        prompt = jnp.zeros((batch,), jnp.int32)
    t0 = time.time()
    tokens, _ = engine.greedy(prompt, state, steps)
    jax.block_until_ready(tokens)
    dt_ms = (time.time() - t0) / steps * 1e3
    return tokens, dt_ms
