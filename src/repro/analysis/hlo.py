"""Trip-count-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE — for layer
scans this under-reports FLOPs/bytes by the layer count. This analyzer parses
``compiled.as_text()``, builds the call graph, multiplies every computation's
cost by the product of enclosing ``known_trip_count``s, and returns per-device
totals:

  flops        — matmul FLOPs (dot ops: 2 * prod(out) * prod(contract dims);
                 the MFU convention — elementwise flops are ignored)
  bytes        — approximate HBM traffic: sum of operand+output bytes over
                 materializing ops (fusions count their boundary tensors only,
                 which is exactly the fused traffic)
  collectives  — output bytes + op counts per collective kind

Approximations are documented in EXPERIMENTS.md §Roofline; exactness is not
required — the roofline needs the right order of magnitude and the right
*ratios* between candidate optimizations.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "s16": 2, "u16": 2, "pred": 1, "s64": 8,
                "u64": 8, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")


def _parse_op_line(line: str):
    """Split an HLO op line into (name, type_str, kind, rest) or None.

    Tuple types may contain `/*index=N*/` comments and layout braces, so the
    type is scanned with balanced parentheses rather than a regex.
    """
    m = _OP_HEAD_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    if i < n and line[i] == "(":
        depth = 0
        j = i
        while j < n:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    j += 1
                    break
            j += 1
        type_str = line[i:j]
    else:
        j = i
        while j < n and not line[j].isspace():
            j += 1
        type_str = line[i:j]
    km = re.match(r"\s+([\w\-$]+)\(", line[j:])
    if not km:
        return None
    kind = km.group(1)
    rest = line[j + km.end():]
    return name, type_str, kind, rest
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(
    r"(?:body|condition|calls|to_apply)=%?([\w.\-]+)"
    r"|branch_computations=\{([^}]*)\}")

# Ops whose operands/outputs we count as memory traffic. TPU-fusion-optimistic
# model: top-level elementwise ops (add/multiply/convert/...) are EXCLUDED —
# the TPU backend fuses them into neighbors, while the CPU backend we compile
# with leaves them top-level and inserts bf16->f32 convert copies a TPU would
# never emit. What remains: matmul operand/result traffic, fusion boundaries,
# slice/update traffic (KV caches), reductions, and collectives.
_TRAFFIC_KINDS = {
    "fusion", "dot", "copy", "dynamic-slice", "dynamic-update-slice",
    "slice", "concatenate", "reduce", "reduce-window", "pad",
    "gather", "scatter", "sort", "reverse",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, ()
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    type_str: str
    rest: str        # operand list + attrs


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list
    shapes: dict     # value name -> type string


def parse_module(text: str) -> dict:
    comps: dict[str, _Computation] = {}
    cur = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and ("{" in line):
            cur = _Computation(name=mc.group(1), ops=[], shapes={})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        parsed = _parse_op_line(line)
        if parsed:
            name, type_str, kind, rest = parsed
            op = _Op(name=name, kind=kind, type_str=type_str, rest=rest)
            cur.ops.append(op)
            cur.shapes[op.name] = op.type_str
    return comps


def _dot_flops(op: _Op, shapes: dict) -> float:
    _, out_dims = _shape_dims(op.type_str)
    out_n = 1
    for d in out_dims:
        out_n *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contract = 1
    if m:
        # first operand name (older HLO prints `f32[...] %ref`, newer `%ref`
        # — search, don't anchor)
        ops_m = re.search(r"%([\w.\-]+)", op.rest)
        lhs_dims = ()
        if ops_m and ops_m.group(1) in shapes:
            _, lhs_dims = _shape_dims(shapes[ops_m.group(1)])
        for idx in m.group(1).split(","):
            if idx and lhs_dims and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_n * contract


def _operand_bytes(op: _Op, shapes: dict) -> int:
    # operands = %refs before the closing paren of the operand list
    depth, i, end = 1, 0, len(op.rest)
    while i < end and depth > 0:
        if op.rest[i] == "(":
            depth += 1
        elif op.rest[i] == ")":
            depth -= 1
        i += 1
    operand_str = op.rest[:i]
    total = 0
    for ref in re.findall(r"%([\w.\-]+)", operand_str):
        if ref in shapes:
            total += _shape_bytes(shapes[ref])
    return total


def analyze(text: str) -> dict:
    comps = parse_module(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: the computation with the most ops
        entry = max(comps, key=lambda c: len(comps[c].ops))

    memo: dict[str, dict] = {}

    def comp_cost(name: str) -> dict:
        if name in memo:
            return memo[name]
        memo[name] = {"flops": 0.0, "bytes": 0.0,
                      "collectives": defaultdict(float)}  # break cycles
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        out = {"flops": 0.0, "bytes": 0.0, "collectives": defaultdict(float)}
        for op in comp.ops:
            if op.kind == "dot":
                out["flops"] += _dot_flops(op, comp.shapes)
            if op.kind in _TRAFFIC_KINDS:
                if op.kind in ("dynamic-slice", "slice", "gather"):
                    # reads only the slice, not the whole operand
                    out["bytes"] += 2 * _shape_bytes(op.type_str)
                elif op.kind == "dynamic-update-slice":
                    # in-place read-modify-write of the update region
                    ops_m = re.findall(r"%([\w.\-]+)", op.rest)
                    upd = next((comp.shapes[o] for o in ops_m[1:2]
                                if o in comp.shapes), op.type_str)
                    out["bytes"] += 2 * _shape_bytes(upd)
                else:
                    out["bytes"] += _shape_bytes(op.type_str) + \
                        _operand_bytes(op, comp.shapes)
            if op.kind in _COLLECTIVES:
                out["collectives"][op.kind] += _shape_bytes(op.type_str)
                out["collectives"][op.kind + "_count"] += 1
            # called computations: while bodies run trip-count times and
            # propagate full costs; fusions/to_apply propagate FLOPs only
            # (their boundary traffic is already counted at the fusion op).
            trip = 1
            if op.kind == "while":
                mt = _TRIP_RE.search(op.rest)
                trip = int(mt.group(1)) if mt else 1
            fused_call = op.kind in ("fusion", "reduce", "reduce-window",
                                     "scatter", "sort", "map")
            for m in _CALLED_RE.finditer(op.rest):
                names = [m.group(1)] if m.group(1) else \
                    re.findall(r"%([\w.\-]+)", m.group(2) or "")
                for cn in names:
                    if cn not in comps:
                        continue
                    sub = comp_cost(cn)
                    out["flops"] += trip * sub["flops"]
                    if not fused_call:
                        out["bytes"] += trip * sub["bytes"]
                    for k, v in sub["collectives"].items():
                        out["collectives"][k] += trip * v
        memo[name] = out
        return out

    res = comp_cost(entry)
    return {"flops": res["flops"], "bytes": res["bytes"],
            "collectives": dict(res["collectives"])}
