"""Mixture-of-Experts transformer (qwen3-moe family: 128 experts, top-8)
[hf:Qwen/Qwen3-30B-A3B].

Two dispatch strategies, selectable via ``MoEConfig.router_impl``:

  * ``scatter`` (default for big configs): capacity-based token routing via
    scatter-add into an (E, C, d) expert buffer and gather-combine. Memory is
    O(T k d) — no (T, E, C) one-hot tensor — and under pjit with experts
    sharded on the ``model`` axis the resharding of the (E, C, d) buffer is
    the expert-parallel all-to-all.
  * ``onehot`` (reference): the classic GShard/Switch einsum formulation;
    numerically transparent, used as the oracle in tests.

Both drop tokens over capacity C = ceil(group/E * k * capacity_factor) —
the scatter path groups per sequence (GShard groups), the onehot reference
per global batch — like the production systems this mirrors (GShard,
Switch, MaxText "dropping").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (
    KVCache,
    PagedKVCache,
    attention_forward,
    decode_attention,
    decode_attention_paged,
    init_attention,
)
from repro.models.layers import dense_init, rms_norm, stack_layer_params
from repro.models.transformer import cast_params, init_flow_head

Array = jax.Array


def init_moe_mlp(key: Array, cfg: ModelConfig) -> dict:
    m = cfg.moe
    k0, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": dense_init(k0, cfg.d_model, m.num_experts),
        "w_gate": jax.random.normal(k1, (m.num_experts, cfg.d_model, m.d_expert)) * cfg.d_model**-0.5,
        "w_up": jax.random.normal(k2, (m.num_experts, cfg.d_model, m.d_expert)) * cfg.d_model**-0.5,
        "w_down": jax.random.normal(k3, (m.num_experts, m.d_expert, cfg.d_model)) * m.d_expert**-0.5,
    }


def _routing(p: dict, x2d: Array, cfg: ModelConfig):
    """x2d: (T, d) -> (gates (T,k), expert_idx (T,k), aux_loss)."""
    m = cfg.moe
    logits = (x2d.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gates, idx = jax.lax.top_k(probs, m.top_k)                # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss.
    density = jnp.mean(jax.nn.one_hot(idx[:, 0], m.num_experts), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(density * density_proxy)
    return gates.astype(x2d.dtype), idx, aux


def _capacity(T: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    return max(int(T * m.top_k * m.capacity_factor / m.num_experts), m.top_k)


def moe_mlp_scatter(p: dict, x: Array, cfg: ModelConfig):
    """(B, S, d) -> (B, S, d), aux. Scatter/gather dispatch with
    PER-SEQUENCE groups (GShard-style).

    Capacity and position-in-expert are computed within each sequence, so
    the routing cumsum has no cross-batch-shard dependency — with the batch
    dim sharded, dispatch stays collective-free and the only expert-parallel
    communication is the canonical (B-shard -> E-shard) all-to-all of the
    (B, E, C, d) buffers. (The earlier global-cumsum variant all-gathered
    (T_global*k, E) routing tensors: ~1.2 TB wire per step on qwen3-30b
    train — see EXPERIMENTS.md §Perf.)"""
    m = cfg.moe
    B, S, d = x.shape
    C = _capacity(S, cfg)                                          # per group
    gates, idx, aux = _routing(p, x.reshape(B * S, d), cfg)
    gates = gates.reshape(B, S, m.top_k)
    idx = idx.reshape(B, S, m.top_k)

    # position of each (token, k) inside its expert, within this sequence
    onehot = jax.nn.one_hot(idx, m.num_experts, dtype=jnp.int32)   # (B,S,k,E)
    flat = onehot.reshape(B, S * m.top_k, m.num_experts)
    pos = jnp.cumsum(flat, axis=1) - flat                          # (B,S*k,E)
    pos_in_e = jnp.sum(pos * flat, axis=-1).reshape(B, S, m.top_k)
    keep = pos_in_e < C
    dest = jnp.where(keep, idx * C + pos_in_e, m.num_experts * C)  # drop slot

    # NOTE: constraining this zeros buffer to batch sharding was tried and
    # REFUTED (collective 79.6 -> 434.6 s): it fights the expert-parallel
    # resharding GSPMD wants for the (B, E, C, d) -> expert-sharded einsums.
    # See EXPERIMENTS.md §Perf (MoE follow-up).
    buf = jnp.zeros((B, m.num_experts * C + 1, d), x.dtype)
    src = jnp.repeat(x[:, :, None, :], m.top_k, axis=2) \
        .reshape(B, S * m.top_k, d)
    rows = jnp.arange(B)[:, None]
    buf = buf.at[rows, dest.reshape(B, S * m.top_k)].add(src)      # scatter-add
    expert_in = buf[:, :-1].reshape(B, m.num_experts, C, d)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", expert_in, p["w_gate"])) \
        * jnp.einsum("becd,edf->becf", expert_in, p["w_up"])
    expert_out = jnp.einsum("becf,efd->becd", h, p["w_down"])

    flat_out = jnp.concatenate(
        [expert_out.reshape(B, m.num_experts * C, d),
         jnp.zeros((B, 1, d), x.dtype)], axis=1)
    picked = flat_out[rows[:, :, None], dest]                      # (B,S,k,d)
    out = jnp.sum(picked * (gates * keep)[..., None], axis=2)
    return out, aux


def moe_mlp_onehot(p: dict, x: Array, cfg: ModelConfig):
    """Reference GShard-style einsum dispatch (small shapes only)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    C = _capacity(T, cfg)
    x2d = x.reshape(T, d)
    gates, idx, aux = _routing(p, x2d, cfg)

    onehot = jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32)  # (T, k, E)
    pos = jnp.cumsum(onehot.reshape(T * m.top_k, -1), axis=0).reshape(
        T, m.top_k, m.num_experts) * onehot - 1.0
    keep = (pos < C) & (pos >= 0)
    cap_onehot = jax.nn.one_hot(pos.astype(jnp.int32), C) * keep[..., None]
    dispatch = jnp.einsum("tke,tkec->tec", onehot, cap_onehot)      # (T, E, C)
    combine = jnp.einsum("tk,tke,tkec->tec", gates.astype(jnp.float32), onehot,
                         cap_onehot)

    expert_in = jnp.einsum("tec,td->ecd", dispatch.astype(x.dtype), x2d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out = jnp.einsum("tec,ecd->td", combine.astype(x.dtype), expert_out)
    return out.reshape(B, S, d), aux


def moe_mlp(p: dict, x: Array, cfg: ModelConfig):
    impl = cfg.moe.router_impl
    return (moe_mlp_scatter if impl == "scatter" else moe_mlp_onehot)(p, x, cfg)


# ---------------------------------------------------------------------------
# Full MoE model (attention blocks shared with the dense family)
# ---------------------------------------------------------------------------


def _layer_init(key: Array, cfg: ModelConfig) -> dict:
    hd = cfg.resolved_head_dim
    k_attn, k_moe = jax.random.split(key)
    return {
        "attn": init_attention(k_attn, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               hd, cfg.qk_norm),
        "moe": init_moe_mlp(k_moe, cfg),
        "norm1": jnp.ones((cfg.d_model,), jnp.float32),
        "norm2": jnp.ones((cfg.d_model,), jnp.float32),
    }


def init_moe_params(key: Array, cfg: ModelConfig, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 3)
    params = {
        "embed": dense_init(keys[-3], cfg.vocab, cfg.d_model, scale=1.0),
        "layers": stack_layer_params([_layer_init(keys[i], cfg)
                                      for i in range(cfg.n_layers)]),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": dense_init(keys[-2], cfg.d_model, cfg.vocab),
        "flow": init_flow_head(keys[-1], cfg),
    }
    return cast_params(params, dtype)


def moe_hidden(params: dict, cfg: ModelConfig, h: Array, positions: Array,
               *, causal: bool = True, window: int = 0,
               remat: bool = False) -> tuple[Array, Array]:
    hd = cfg.resolved_head_dim
    attn_kw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
                   rope_theta=cfg.rope_theta, causal=causal, window=window,
                   norm_eps=cfg.norm_eps)

    def body(carry, layer_p):
        h, aux = carry
        h = h + attention_forward(layer_p["attn"],
                                  rms_norm(h, layer_p["norm1"], cfg.norm_eps),
                                  positions, **attn_kw)
        mlp_out, a = moe_mlp(layer_p["moe"], rms_norm(h, layer_p["norm2"],
                                                      cfg.norm_eps), cfg)
        return (h + mlp_out, aux + a), None

    if remat:
        body = jax.checkpoint(body)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                               params["layers"])
    return rms_norm(h, params["final_norm"], cfg.norm_eps), aux / cfg.n_layers


def lm_forward(params: dict, cfg: ModelConfig, tokens: Array,
               positions=None, *, window: int = 0, last_only: bool = False):
    h = params["embed"][tokens]
    if positions is None:
        positions = jnp.arange(h.shape[1])
    h, aux = moe_hidden(params, cfg, h, positions, causal=True, window=window)
    if last_only:
        h = h[:, -1:, :]
    return h @ params["lm_head"], aux


def decode_step(params: dict, cfg: ModelConfig, token: Array, caches,
                *, window: int = 0, paged_kernel: bool = False):
    h = params["embed"][token][:, None, :]
    hd = cfg.resolved_head_dim
    paged = isinstance(caches, PagedKVCache)
    if paged:
        pos = jnp.broadcast_to(caches.index, (h.shape[0],)).astype(jnp.int32)
        attn_kw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
                       rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps,
                       kernel=paged_kernel)
    else:
        attn_kw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
                       rope_theta=cfg.rope_theta, window=window,
                       norm_eps=cfg.norm_eps)

    def body(carry, xs):
        h = carry
        layer_p, k_c, v_c = xs
        hn = rms_norm(h, layer_p["norm1"], cfg.norm_eps)
        if paged:
            attn_out, k_c, v_c = decode_attention_paged(
                layer_p["attn"], hn, k_c, v_c, caches.block_table, pos,
                **attn_kw)
        else:
            cache = KVCache(k=k_c, v=v_c, index=caches.index)
            attn_out, cache = decode_attention(layer_p["attn"], hn, cache,
                                               **attn_kw)
            k_c, v_c = cache.k, cache.v
        h = h + attn_out
        mlp_out, _ = moe_mlp(layer_p["moe"],
                             rms_norm(h, layer_p["norm2"], cfg.norm_eps), cfg)
        return h + mlp_out, (k_c, v_c)

    kv_in = (caches.k_pages, caches.v_pages) if paged else (caches.k, caches.v)
    h, (ks, vs) = jax.lax.scan(body, h, (params["layers"],) + kv_in)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)[:, 0, :]
    logits = h @ params["lm_head"]
    if paged:
        return logits, PagedKVCache(k_pages=ks, v_pages=vs,
                                    block_table=caches.block_table,
                                    index=pos + 1)
    return logits, KVCache(k=ks, v=vs, index=caches.index + 1)
