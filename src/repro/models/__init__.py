from repro.models import (
    attention,
    layers,
    linear_scan,
    mamba2,
    model,
    moe,
    rwkv6,
    transformer,
    vlm,
    whisper,
)

__all__ = ["attention", "layers", "linear_scan", "mamba2", "model", "moe",
           "rwkv6", "transformer", "vlm", "whisper"]
