"""Mamba2 (SSD) blocks and the Zamba2 hybrid [arXiv:2411.15242].

Zamba2-2.7b: a backbone of Mamba2 blocks with ONE weight-shared GQA attention
block applied every ``hybrid_attn_every`` layers (the paper's
'shared attention' — parameters are reused at every application site, but
each site keeps its own KV cache).

Mamba2 block: in_proj -> (z, xBC, dt); depthwise causal conv over xBC; SSD
recurrence via the shared chunked GLA primitive (decay = dt * -exp(A_log) per
head, state (d_state, head_dim)); D skip; gated RMSNorm; out_proj.

Simplification vs. reference (DESIGN.md): single B/C group (ngroups=1).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import KVCache, attention_forward, decode_attention, init_attention
from repro.models.layers import dense_init, rms_norm, stack_layer_params, swiglu
from repro.models.linear_scan import gla_chunked, gla_step
from repro.models.transformer import cast_params, init_flow_head

Array = jax.Array


class HybridState(NamedTuple):
    conv: Array      # (L, B, d_conv-1, conv_dim) conv tail buffer
    ssm: Array       # (L, B, nheads, d_state, head_dim)
    kv: Array        # (sites, B, slots, n_kv, hd) shared-attn K cache
    vv: Array        # same for V
    index: Array


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.d_state
    return d_inner, n_heads, conv_dim


def init_mamba_block(key: Array, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 3)
    d_in_proj = 2 * d_inner + 2 * s.d_state + n_heads
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, d_in_proj),
        "conv_w": 0.1 * jax.random.normal(ks[1], (s.d_conv, conv_dim)),
        "A_log": jnp.zeros((n_heads,), jnp.float32),          # A = -1
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.full((n_heads,), -2.0, jnp.float32),   # softplus ~ 0.12
        "gate_norm": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[2], d_inner, cfg.d_model),
        "norm": jnp.ones((cfg.d_model,), jnp.float32),
    }


def _ssd(cfg: ModelConfig, x: Array, B_: Array, C_: Array, dt: Array,
         p: dict, s0=None, chunk=None):
    """x: (B,L,d_inner); B_,C_: (B,L,d_state); dt: (B,L,nheads)."""
    s = cfg.ssm
    d_inner, n_heads, _ = _dims(cfg)
    Bsz, L, _ = x.shape
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    ld = dt * (-jnp.exp(p["A_log"]))                          # (B,L,nh)
    xh = x.reshape(Bsz, L, n_heads, s.head_dim)
    v = xh * dt[..., None].astype(xh.dtype)
    k = jnp.broadcast_to(B_[:, :, None, :], (Bsz, L, n_heads, s.d_state))
    q = jnp.broadcast_to(C_[:, :, None, :], (Bsz, L, n_heads, s.d_state))
    # scalar decay per (head, step): trailing dim 1 triggers the (c, c)
    # decay-matrix specialization in gla_chunked (SSD structure)
    o, S = gla_chunked(q, k, v, ld[..., None], s0, inclusive=True,
                       chunk=chunk or s.chunk)
    y = o + p["D"][:, None].astype(o.dtype) * xh
    return y.reshape(Bsz, L, d_inner), S


def mamba_block_seq(p: dict, cfg: ModelConfig, h: Array, chunk=None) -> Array:
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    hn = rms_norm(h, p["norm"], cfg.norm_eps)
    zxbcdt = hn @ p["in_proj"]
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    # depthwise causal conv, width d_conv
    pad = jnp.zeros(xBC.shape[:1] + (s.d_conv - 1,) + xBC.shape[2:], xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)
    conv = sum(xp[:, i:i + xBC.shape[1]] * p["conv_w"][i].astype(xBC.dtype)
               for i in range(s.d_conv))
    xBC = jax.nn.silu(conv)
    x, B_, C_ = jnp.split(xBC, [d_inner, d_inner + s.d_state], axis=-1)
    y, _ = _ssd(cfg, x, B_, C_, dt, p, chunk=chunk)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return h + y @ p["out_proj"]


def mamba_block_step(p: dict, cfg: ModelConfig, h: Array, conv_state: Array,
                     S: Array) -> tuple[Array, Array, Array]:
    """h: (B, d); conv_state: (B, d_conv-1, conv_dim); S: (B,nh,ds,hd)."""
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    hn = rms_norm(h, p["norm"], cfg.norm_eps)
    zxbcdt = hn @ p["in_proj"]
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    window = jnp.concatenate([conv_state.astype(xBC.dtype), xBC[:, None]], axis=1)
    conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(xBC.dtype))
    xBC_c = jax.nn.silu(conv)
    x, B_, C_ = jnp.split(xBC_c, [d_inner, d_inner + s.d_state], axis=-1)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    ld = dtf * (-jnp.exp(p["A_log"]))
    xh = x.reshape(-1, n_heads, s.head_dim)
    v = xh * dtf[..., None].astype(xh.dtype)
    k = jnp.broadcast_to(B_[:, None, :], xh.shape[:2] + (s.d_state,))
    q = jnp.broadcast_to(C_[:, None, :], xh.shape[:2] + (s.d_state,))
    ldk = jnp.broadcast_to(ld[..., None], xh.shape[:2] + (s.d_state,))
    o, S = gla_step(q, k, v, ldk, S, inclusive=True)
    y = (o + p["D"][:, None].astype(o.dtype) * xh).reshape(-1, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return h + y @ p["out_proj"], window[:, 1:], S


def init_shared_attn(key: Array, cfg: ModelConfig) -> dict:
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd),
        "mlp": {
            "w_gate": dense_init(k2, cfg.d_model, cfg.d_ff),
            "w_up": dense_init(k3, cfg.d_model, cfg.d_ff),
            "w_down": dense_init(k4, cfg.d_ff, cfg.d_model),
        },
        "norm1": jnp.ones((cfg.d_model,), jnp.float32),
        "norm2": jnp.ones((cfg.d_model,), jnp.float32),
    }


def init_hybrid_params(key: Array, cfg: ModelConfig, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    assert cfg.n_layers % cfg.hybrid_attn_every == 0
    keys = jax.random.split(key, cfg.n_layers + 4)
    params = {
        "embed": dense_init(keys[-4], cfg.vocab, cfg.d_model, scale=1.0),
        "layers": stack_layer_params([init_mamba_block(keys[i], cfg)
                                      for i in range(cfg.n_layers)]),
        "shared_attn": init_shared_attn(keys[-3], cfg),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": dense_init(keys[-2], cfg.d_model, cfg.vocab),
        "flow": init_flow_head(keys[-1], cfg),
    }
    return cast_params(params, dtype)


def _shared_attn_seq(p: dict, cfg: ModelConfig, h: Array, positions: Array,
                     window: int = 0) -> Array:
    hd = cfg.resolved_head_dim
    h = h + attention_forward(p["attn"], rms_norm(h, p["norm1"], cfg.norm_eps),
                              positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                              head_dim=hd, rope_theta=cfg.rope_theta, causal=True,
                              window=window, norm_eps=cfg.norm_eps)
    return h + swiglu(rms_norm(h, p["norm2"], cfg.norm_eps), **p["mlp"])


def hybrid_hidden(params: dict, cfg: ModelConfig, h: Array, positions=None,
                  *, window: int = 0, remat: bool = False) -> Array:
    """Scan over superblocks: [shared attention] + k mamba layers."""
    k = cfg.hybrid_attn_every
    n_super = cfg.n_layers // k
    if positions is None:
        positions = jnp.arange(h.shape[1])
    grouped = jax.tree.map(
        lambda x: x.reshape((n_super, k) + x.shape[1:]), params["layers"])

    def super_body(h, layer_group):
        h = _shared_attn_seq(params["shared_attn"], cfg, h, positions, window)

        def inner(h, lp):
            return mamba_block_seq(lp, cfg, h), None

        h, _ = jax.lax.scan(inner, h, layer_group)
        return h, None

    if remat:
        super_body = jax.checkpoint(super_body)
    h, _ = jax.lax.scan(super_body, h, grouped)
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def lm_forward(params: dict, cfg: ModelConfig, tokens: Array, positions=None,
               *, window: int = 0, last_only: bool = False) -> Array:
    h = hybrid_hidden(params, cfg, params["embed"][tokens], positions, window=window)
    if last_only:
        h = h[:, -1:, :]
    return h @ params["lm_head"]


def init_state(cfg: ModelConfig, batch: int, slots: int,
               dtype=jnp.bfloat16) -> HybridState:
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    sites = cfg.n_layers // cfg.hybrid_attn_every
    hd = cfg.resolved_head_dim
    return HybridState(
        conv=jnp.zeros((cfg.n_layers, batch, s.d_conv - 1, conv_dim), dtype),
        ssm=jnp.zeros((cfg.n_layers, batch, n_heads, s.d_state, s.head_dim),
                      jnp.float32),
        kv=jnp.zeros((sites, batch, slots, cfg.n_kv_heads, hd), dtype),
        vv=jnp.zeros((sites, batch, slots, cfg.n_kv_heads, hd), dtype),
        index=jnp.zeros((), jnp.int32),
    )


def decode_step(params: dict, cfg: ModelConfig, token: Array,
                state: HybridState, *, window: int = 0
                ) -> tuple[Array, HybridState]:
    h = params["embed"][token]                                # (B, d)
    k = cfg.hybrid_attn_every
    n_super = cfg.n_layers // k
    hd = cfg.resolved_head_dim
    grouped = jax.tree.map(
        lambda x: x.reshape((n_super, k) + x.shape[1:]), params["layers"])
    conv_g = state.conv.reshape((n_super, k) + state.conv.shape[1:])
    ssm_g = state.ssm.reshape((n_super, k) + state.ssm.shape[1:])
    sp = params["shared_attn"]

    def super_body(h, xs):
        layer_group, conv_s, ssm_s, k_c, v_c = xs
        cache = KVCache(k=k_c, v=v_c, index=state.index)
        hn = rms_norm(h[:, None], sp["norm1"], cfg.norm_eps)
        attn_out, cache = decode_attention(
            sp["attn"], hn, cache, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=hd, rope_theta=cfg.rope_theta, window=window,
            norm_eps=cfg.norm_eps)
        h = h + attn_out[:, 0]
        h = h + swiglu(rms_norm(h, sp["norm2"], cfg.norm_eps), **sp["mlp"])

        def inner(h, xs_in):
            lp, cs, ss = xs_in
            h, cs, ss = mamba_block_step(lp, cfg, h, cs, ss)
            return h, (cs, ss)

        h, (conv_new, ssm_new) = jax.lax.scan(inner, h, (layer_group, conv_s, ssm_s))
        return h, (conv_new, ssm_new, cache.k, cache.v)

    h, (conv_n, ssm_n, kn, vn) = jax.lax.scan(
        super_body, h, (grouped, conv_g, ssm_g, state.kv, state.vv))
    logits = rms_norm(h, params["final_norm"], cfg.norm_eps) @ params["lm_head"]
    new_state = HybridState(
        conv=conv_n.reshape(state.conv.shape), ssm=ssm_n.reshape(state.ssm.shape),
        kv=kn, vv=vn, index=state.index + 1)
    return logits, new_state
