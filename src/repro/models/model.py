"""Unified model API across the architecture pool.

Every family exposes the same four entry points, dispatched on
``cfg.family``:

  init_params(key, cfg)                      -> params pytree
  lm_apply(params, cfg, batch)               -> logits        (train/prefill)
  init_decode_state(cfg, batch, slots, ...)  -> state pytree  (KV cache / RNN state)
  decode_apply(params, cfg, token, state)    -> (logits, state)

plus the paper's substrate:

  velocity(params, cfg, t, x, cond)          -> u_t(x) over latent sequences
  cfm_loss(params, cfg, batch, rng, sched)   -> Conditional Flow Matching loss
                                                (paper eq. 56)

``batch`` is a dict: {"tokens": (B,S) int32} plus "frames" (audio) or
"patches" (vlm) stub-frontend embeddings per the assignment.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.parametrization import VelocityField
from repro.core.schedulers import Scheduler
from repro.models import mamba2, moe, rwkv6, transformer, vlm, whisper
from repro.models.transformer import latent_targets

Array = jax.Array

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


def init_params(key: Array, cfg: ModelConfig, dtype=None) -> dict:
    return {
        "dense": transformer.init_dense_params,
        "moe": moe.init_moe_params,
        "ssm": rwkv6.init_rwkv_params,
        "hybrid": mamba2.init_hybrid_params,
        "encdec": whisper.init_encdec_params,
        "vlm": vlm.init_vlm_params,
    }[cfg.family](key, cfg, dtype)


def lm_apply(params: dict, cfg: ModelConfig, batch: dict, *,
             window: int = 0, last_only: bool = False) -> Array:
    """Training/prefill logits. ``last_only`` slices the final position
    BEFORE the vocab projection — serving prefill only needs the next-token
    logits, and projecting all 32k positions into a (B, S, V) f32 tensor
    dominates prefill HBM traffic (§Perf iteration)."""
    tokens = batch["tokens"]
    if cfg.family == "dense":
        out = transformer.lm_forward(params, cfg, tokens, window=window,
                                     last_only=last_only)
    elif cfg.family == "moe":
        out, _aux = moe.lm_forward(params, cfg, tokens, window=window,
                                   last_only=last_only)
    elif cfg.family == "ssm":
        out = rwkv6.lm_forward(params, cfg, tokens, last_only=last_only)
    elif cfg.family == "hybrid":
        out = mamba2.lm_forward(params, cfg, tokens, window=window,
                                last_only=last_only)
    elif cfg.family == "encdec":
        out = whisper.lm_forward(params, cfg, tokens, batch["frames"],
                                 last_only=last_only)
    elif cfg.family == "vlm":
        out = vlm.lm_forward(params, cfg, tokens, batch["patches"],
                             window=window, last_only=last_only)
    else:
        raise KeyError(cfg.family)
    return out


def init_decode_state(cfg: ModelConfig, batch: int, slots: int,
                      dtype=jnp.bfloat16, num_frames: int = 1500):
    if cfg.family in ("dense",):
        return transformer.init_caches(cfg, batch, slots, dtype)
    if cfg.family == "moe":
        return transformer.init_caches(cfg, batch, slots, dtype)
    if cfg.family == "ssm":
        return rwkv6.init_state(cfg, batch)
    if cfg.family == "hybrid":
        return mamba2.init_state(cfg, batch, slots, dtype)
    if cfg.family == "encdec":
        return whisper.init_state(cfg, batch, slots, num_frames, dtype)
    if cfg.family == "vlm":
        return vlm.init_state(cfg, batch, slots, dtype)
    raise KeyError(cfg.family)


PAGED_FAMILIES = ("dense", "moe", "vlm")   # KV-cache families that can page


def init_paged_decode_state(cfg: ModelConfig, batch: int, num_pages: int,
                            page_size: int, blocks_per_slot: int,
                            dtype=jnp.bfloat16):
    """Paged decode state (``PagedKVCache``) for the KV-cache families.
    Recurrent/hybrid/encdec state has no pageable KV axis — the SSM family's
    state is already O(1) per slot, and hybrid/encdec are rejected upstream
    (``DecodeEngine``)."""
    if cfg.family not in PAGED_FAMILIES:
        raise TypeError(f"paged KV cache not supported for {cfg.family!r} "
                        f"(pageable families: {PAGED_FAMILIES})")
    return transformer.init_paged_caches(cfg, batch, num_pages, page_size,
                                         blocks_per_slot, dtype)


def decode_state_batch_axes(cfg: ModelConfig, paged: bool = False):
    """Pytree (matching ``init_decode_state``'s structure) of the BATCH axis
    per state leaf — the axis indexed by sequence slot. Slot serving
    (``DecodeEngine.step_slots``) uses this to write-mask, gather, and reset
    individual sequences' state rows without knowing each family's layout.
    ``index`` reads as axis 0 of the per-row ``(B,)`` vector form (scalar
    index states cannot be slot-masked — positions must be per row).

    ``paged=True``: the page POOL leaves have no per-row axis and read as
    ``-1`` — they cannot be row-masked; isolation comes from exclusive
    page ownership plus the reserved trash page (see ``PagedKVCache``), so
    masked steps take the new pool unconditionally and resets leave it
    untouched.
    """
    from repro.models.attention import KVCache, PagedKVCache
    from repro.models.mamba2 import HybridState
    from repro.models.rwkv6 import RWKVState
    from repro.models.whisper import EncDecState

    if paged and cfg.family in PAGED_FAMILIES:
        return PagedKVCache(k_pages=-1, v_pages=-1, block_table=0, index=0)
    if cfg.family in ("dense", "moe", "vlm"):
        return KVCache(k=1, v=1, index=0)
    if cfg.family == "ssm":
        return RWKVState(shift_tm=1, shift_cm=1, wkv=1, index=0)
    if cfg.family == "hybrid":
        return HybridState(conv=1, ssm=1, kv=1, vv=1, index=0)
    if cfg.family == "encdec":
        return EncDecState(k=1, v=1, memory=0, index=0)
    raise KeyError(cfg.family)


def decode_apply(params: dict, cfg: ModelConfig, token: Array, state, *,
                 window: int = 0, paged_kernel: bool = False):
    if cfg.family == "dense":
        return transformer.decode_step(params, cfg, token, state,
                                       window=window,
                                       paged_kernel=paged_kernel)
    if cfg.family == "moe":
        return moe.decode_step(params, cfg, token, state, window=window,
                               paged_kernel=paged_kernel)
    if cfg.family == "ssm":
        return rwkv6.decode_step(params, cfg, token, state)
    if cfg.family == "hybrid":
        return mamba2.decode_step(params, cfg, token, state, window=window)
    if cfg.family == "encdec":
        return whisper.decode_step(params, cfg, token, state)
    if cfg.family == "vlm":
        return vlm.decode_step(params, cfg, token, state, window=window,
                               paged_kernel=paged_kernel)
    raise KeyError(cfg.family)


# ---------------------------------------------------------------------------
# Flow mode: the backbone as velocity field u_t(x) — the paper's substrate
# ---------------------------------------------------------------------------


def _hidden_fn(cfg: ModelConfig, batch: Optional[dict], remat: bool = False):
    """Family-specific hidden-state function for the flow head."""
    if cfg.family == "dense":
        return lambda p, c, h, pos: transformer.dense_hidden(p, c, h, pos,
                                                             remat=remat)
    if cfg.family == "vlm":
        def fn(p, c, h, pos):
            # condition on the (stub) vision patches as a sequence prefix
            if batch is not None and "patches" in batch:
                pre = vlm.project_patches(p, batch["patches"]).astype(h.dtype)
                m = pre.shape[1]
                h = jnp.concatenate([pre, h], axis=1)
                out = transformer.dense_hidden(
                    p, c, h, jnp.arange(h.shape[1]), remat=remat)
                return out[:, m:]
            return transformer.dense_hidden(p, c, h, pos, remat=remat)
        return fn
    if cfg.family == "moe":
        return lambda p, c, h, pos: moe.moe_hidden(p, c, h, pos, remat=remat)[0]
    if cfg.family == "ssm":
        return lambda p, c, h, pos: rwkv6.rwkv_hidden(p, c, h, remat=remat)
    if cfg.family == "hybrid":
        return lambda p, c, h, pos: mamba2.hybrid_hidden(p, c, h, pos,
                                                         remat=remat)
    if cfg.family == "encdec":
        def fn(p, c, h, pos):
            memory = whisper.encode(p, c, batch["frames"], remat=remat)
            return whisper.decoder_hidden(p, c, h, memory, pos, remat=remat)
        return fn
    raise KeyError(cfg.family)


def velocity(params: dict, cfg: ModelConfig, t: Array, x: Array,
             batch: Optional[dict] = None, *, remat: bool = False) -> Array:
    """u_t(x): x (B, S, latent_dim) -> velocity. ``batch`` provides the
    conditioning (tokens / frames / patches); None = unconditional (CFG)."""
    cond = batch.get("tokens") if batch else None
    return transformer.flow_velocity(params, cfg, t, x, cond,
                                     hidden_fn=_hidden_fn(cfg, batch, remat))


def velocity_field(params: dict, cfg: ModelConfig, sched: Scheduler,
                   batch: Optional[dict] = None, *, cfg_scale: float = 0.0
                   ) -> VelocityField:
    """Wrap the model for the BNS sampler, with classifier-free guidance."""

    def u(t, x):
        uc = velocity(params, cfg, t, x, batch)
        if cfg_scale == 0.0:
            return uc
        uu = velocity(params, cfg, t, x, None)
        return (1.0 + cfg_scale) * uc - cfg_scale * uu

    return VelocityField(fn=u, scheduler=sched)


def cfm_loss(params: dict, cfg: ModelConfig, batch: dict, rng: Array,
             sched: Scheduler, *, p_uncond: float = 0.1,
             remat: bool = False) -> Array:
    """Conditional Flow Matching loss (paper eq. 56) over latent sequences.

    x1 = latent embedding of the data tokens; x_t = sigma_t x0 + alpha_t x1;
    target velocity = sigma'_t x0 + alpha'_t x1.
    """
    from repro.distributed import context

    tokens = batch["tokens"]
    B, S = tokens.shape
    k_t, k_x0, k_drop = jax.random.split(rng, 3)
    x1 = latent_targets(params, tokens).astype(jnp.float32)
    # RNG-generated tensors default to replicated under GSPMD — pin the batch
    # sharding here or it poisons every downstream activation (§Perf iter 3).
    b = context.batch_axis()
    x0 = jax.random.normal(k_x0, x1.shape, jnp.float32)
    x0 = context.constrain(x0, b, None, None)
    t = jax.random.uniform(k_t, (B,))
    t = context.constrain(t, b)
    tb = t[:, None, None]
    a, s = sched.alpha(tb), sched.sigma(tb)
    da, ds = sched.dalpha(tb), sched.dsigma(tb)
    x_t = s * x0 + a * x1
    target = ds * x0 + da * x1
    # CFG training: drop conditioning with prob p_uncond (paper's P-Uncond)
    drop = jax.random.bernoulli(k_drop, p_uncond, (B,))
    cond_tokens = jnp.where(drop[:, None], jnp.zeros_like(tokens), tokens)
    v = velocity(params, cfg, t, x_t.astype(jnp.float32),
                 {**batch, "tokens": cond_tokens}, remat=remat)
    return jnp.mean((v.astype(jnp.float32) - target) ** 2)
