"""Chunked gated-linear-recurrence primitive (shared by RWKV6 and Mamba2).

Recurrence (per batch, head):
    S_t = diag(exp(ld_t)) S_{t-1} + k_t v_t^T          S in (dk, dv)
    o_t = q_t^T S_t                  ("inclusive", Mamba2/SSD convention)
    o_t = q_t^T S_{t-1}              ("exclusive", RWKV wkv convention)

The chunked form processes blocks of ``chunk`` tokens with matmuls (MXU
friendly) and carries the (dk, dv) state across chunks. All decay factors are
differences of within-chunk cumulative log-decays with non-positive exponents
— numerically bounded by 1, no overflow for arbitrarily strong decay.

``gla_recurrent`` is the step-by-step oracle used in tests and decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def gla_recurrent(q: Array, k: Array, v: Array, ld: Array,
                  s0: Array | None = None, *, inclusive: bool = True
                  ) -> tuple[Array, Array]:
    """Oracle: scan over time. Shapes q,k,ld: (B,L,H,dk); v: (B,L,H,dv)."""
    B, L, H, dk = q.shape
    dv = v.shape[-1]
    S = jnp.zeros((B, H, dk, dv), jnp.float32) if s0 is None else s0.astype(jnp.float32)

    def step(S, xs):
        q_t, k_t, v_t, ld_t = xs
        decay = jnp.exp(ld_t.astype(jnp.float32))[..., None]          # (B,H,dk,1)
        kv = k_t[..., None].astype(jnp.float32) * v_t[..., None, :]   # (B,H,dk,dv)
        S_new = decay * S + kv
        S_read = S_new if inclusive else S
        o = jnp.einsum("bhd,bhdv->bhv", q_t.astype(jnp.float32), S_read)
        return S_new, o

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, ld))
    S, o = jax.lax.scan(step, S, xs)
    return jnp.moveaxis(o, 0, 1).astype(v.dtype), S


def gla_step(q: Array, k: Array, v: Array, ld: Array, S: Array,
             *, inclusive: bool = True) -> tuple[Array, Array]:
    """Single decode step. q,k,ld: (B,H,dk); v: (B,H,dv); S: (B,H,dk,dv)."""
    decay = jnp.exp(ld.astype(jnp.float32))[..., None]
    kv = k[..., None].astype(jnp.float32) * v[..., None, :]
    S_new = decay * S + kv
    S_read = S_new if inclusive else S
    o = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), S_read)
    return o.astype(v.dtype), S_new


def gla_chunked(q: Array, k: Array, v: Array, ld: Array,
                s0: Array | None = None, *, inclusive: bool = True,
                chunk: int = 64) -> tuple[Array, Array]:
    """Chunked-parallel form. Shapes as ``gla_recurrent``; arbitrary L (padded
    internally to a chunk multiple — pad steps have k=v=0, decay=1, so the
    carried state and real outputs are unaffected)."""
    B, L, H, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, L)
    pad = (-L) % chunk
    if pad:
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v, ld = map(zpad, (q, k, v, ld))
    L_pad = L + pad
    n_chunks = L_pad // chunk

    def to_chunks(a):
        return a.reshape(B, n_chunks, chunk, H, a.shape[-1]).transpose(1, 0, 3, 2, 4)

    qc, kc, vc, ldc = map(to_chunks, (q, k, v, ld))      # (N, B, H, c, dx)
    S_init = jnp.zeros((B, H, dk, dv), jnp.float32) if s0 is None \
        else s0.astype(jnp.float32)
    # the zero-init carry is otherwise unsharded, which makes GSPMD
    # replicate the batch dim through the whole chunk scan (§Perf iter 3)
    from repro.distributed import context
    S_init = context.constrain(S_init, context.batch_axis(), "?", "?", "?")

    tri = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :]) if inclusive \
        else (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])

    # Scalar-decay specialization (Mamba2/SSD: one decay per head per step,
    # ld trailing dim == 1): the intra-chunk decay matrix is (c, c) instead
    # of (c, c, dk) — 64x less HBM traffic for d_state=64 (§Perf iteration).
    scalar_decay = ld.shape[-1] == 1

    def body(S, xs):
        q_, k_, v_, ld_ = (a.astype(jnp.float32) for a in xs)  # (B,H,c,dx)
        cum = jnp.cumsum(ld_, axis=2)                          # (B,H,c,dk|1)
        # decay exponent endpoint: t for inclusive, t-1 for exclusive
        cum_q = cum if inclusive else cum - ld_
        # cross-chunk: tokens before this chunk, decayed through cum_q
        o_cross = jnp.einsum("bhtd,bhdv->bhtv", q_ * jnp.exp(cum_q), S)
        # intra-chunk: bounded decay differences (<= 0 under the mask)
        if scalar_decay:
            dd = cum_q[:, :, :, None, 0] - cum[:, :, None, :, 0]  # (B,H,t,s)
            scores = jnp.einsum("bhtd,bhsd->bhts", q_, k_) * \
                jnp.exp(jnp.minimum(dd, 0.0))
        else:
            dd = cum_q[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,H,t,s,dk)
            scores = jnp.einsum("bhtd,bhsd,bhtsd->bhts", q_, k_,
                                jnp.exp(jnp.minimum(dd, 0.0)))
        scores = jnp.where(tri, scores, 0.0)
        o_intra = jnp.einsum("bhts,bhsv->bhtv", scores, v_)
        # state update: S' = diag(e^{cum_end}) S + sum_s (k_s e^{cum_end-cum_s}) v_s
        cum_end = cum[:, :, -1:, :]
        k_scaled = k_ * jnp.exp(cum_end - cum)
        S_new = jnp.exp(jnp.broadcast_to(cum_end[:, :, 0, :],
                                         S.shape[:-1]))[..., None] * S + \
            jnp.einsum("bhsd,bhsv->bhdv", k_scaled, v_)
        return S_new, (o_cross + o_intra)

    S_final, o = jax.lax.scan(body, S_init, (qc, kc, vc, ldc))
    o = o.transpose(1, 0, 3, 2, 4).reshape(B, L_pad, H, dv)[:, :L]
    return o.astype(v.dtype), S_final
