"""InternVL2-26b backbone: InternLM2-style dense LM consuming stubbed vision
patch embeddings [arXiv:2404.16821].

Per the assignment, the InternViT encoder is a STUB — ``input_specs()``
provides patch embeddings (B, n_patches, vit_dim); only the trainable MLP
projector (vit_dim -> d_model) and the language model are implemented. Patch
tokens are prepended to the text sequence (cross-modal token interleave),
giving the LM a multimodal prefix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import KVCache
from repro.models.layers import dense_init
from repro.models import transformer

Array = jax.Array


def init_vlm_params(key: Array, cfg: ModelConfig, dtype=None) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    params = transformer.init_dense_params(k1, cfg, dtype)
    vit_dim = cfg.frontend.embed_dim
    params["projector"] = {
        "w1": dense_init(k2, vit_dim, cfg.d_model).astype(dtype or jnp.dtype(cfg.dtype)),
        "w2": dense_init(k3, cfg.d_model, cfg.d_model).astype(dtype or jnp.dtype(cfg.dtype)),
    }
    return params


def project_patches(params: dict, patches: Array) -> Array:
    p = params["projector"]
    return jax.nn.gelu(patches.astype(p["w1"].dtype) @ p["w1"]) @ p["w2"]


def lm_forward(params: dict, cfg: ModelConfig, tokens: Array, patches: Array,
               positions=None, *, window: int = 0,
               last_only: bool = False) -> Array:
    """Multimodal prefill: logits over [patch tokens; text tokens]."""
    embeds = project_patches(params, patches)
    return transformer.lm_forward(params, cfg, tokens, positions,
                                  window=window, extra_embeds=embeds,
                                  last_only=last_only)


def init_state(cfg: ModelConfig, batch: int, slots: int,
               dtype=jnp.bfloat16) -> KVCache:
    return transformer.init_caches(cfg, batch, slots, dtype)


def decode_step(params: dict, cfg: ModelConfig, token: Array, caches,
                *, window: int = 0, paged_kernel: bool = False):
    """Text decode after the multimodal prefix is already in the cache."""
    return transformer.decode_step(params, cfg, token, caches, window=window,
                                   paged_kernel=paged_kernel)
