"""Grouped-query attention with RoPE, masks, and KV-cache decode paths.

Layouts keep KV heads grouped — q is reshaped to (B, L, KV, G, hd) with
G = H / KV — so GQA never materializes repeated K/V (HBM matters: decode is
memory-bound on the cache). Sliding-window decode uses a ring buffer of
``window`` physical slots, which is what makes ``long_500k`` sub-quadratic
for the dense architectures.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rms_norm

Array = jax.Array
NEG_INF = -1e30


class KVCache(NamedTuple):
    k: Array       # (B, slots, KV, hd)
    v: Array       # (B, slots, KV, hd)
    index: Array   # int32 tokens already decoded: scalar, or (B,) per-row
    #                (slot serving — each batch row at its own position)

    @property
    def slots(self) -> int:
        return self.k.shape[1]


class PagedKVCache(NamedTuple):
    """vLLM-style paged KV cache: K/V live in a SHARED pool of fixed-size
    pages and each sequence row owns a block-table row mapping its logical
    block index to a physical page id, so resident cache memory per slot is
    the pages the sequence actually uses, not ``max_seq_len`` dense rows.

    Page 0 is RESERVED as the trash page: freed/inactive rows' block-table
    entries point at it, so the write a masked-out row still computes inside
    the one compiled ``step_slots`` program lands in a page nobody attends
    over (the pool has no per-row axis, so it cannot be write-masked the way
    the dense cache's rows are — see ``DecodeEngine._mask_rows``). The page
    allocator (``repro.serving.decode.PageAllocator``) never hands page 0
    out.
    """

    k_pages: Array      # (L, num_pages, page_size, KV, hd) shared pool
    v_pages: Array      # (L, num_pages, page_size, KV, hd)
    block_table: Array  # (B, blocks_per_slot) int32 physical page ids
    index: Array        # (B,) int32 tokens already decoded per row

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[-3]

    @property
    def capacity(self) -> int:
        """Logical positions addressable per row (block-table width x page
        size) — the paged analogue of ``KVCache.slots``."""
        return self.block_table.shape[1] * self.page_size


def init_kv_cache(batch: int, slots: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, slots, n_kv, head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   index=jnp.zeros((), jnp.int32))


def init_attention(key: Array, d_model: int, n_heads: int, n_kv: int,
                   head_dim: int, qk_norm: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((head_dim,), jnp.float32)
    return p


def _grouped_attend(q: Array, k: Array, v: Array, mask: Optional[Array]) -> Array:
    """q: (B, Lq, KV, G, hd); k, v: (B, Lk, KV, hd); mask: (B?, Lq, Lk) bool."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q * scale, k).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out


def _split_heads(x: Array, n: int, hd: int) -> Array:
    return x.reshape(x.shape[:-1] + (n, hd))


def _chunked_attend(q: Array, k: Array, v: Array, positions: Array,
                    causal: bool, window: int, qc: int) -> Array:
    """Blockwise online attention over query chunks (flash-style in XLA):
    bounds score-tensor residency to (B, KV, G, qc, Lk) and never
    materializes the (L, L) mask — per-block masks come from iota compares
    and fuse into the score computation."""
    B, L, KV, G, hd = q.shape
    nq = L // qc
    qb = jnp.moveaxis(q.reshape(B, nq, qc, KV, G, hd), 1, 0)

    def body(_, xs):
        i, qi = xs                                     # qi: (B, qc, KV, G, hd)
        mask = None
        if causal:
            pos_q = jax.lax.dynamic_slice_in_dim(positions, i * qc, qc)
            rel = pos_q[:, None] - positions[None, :]
            mask = rel >= 0
            if window:
                mask = mask & (rel < window)
            mask = jnp.broadcast_to(mask, (B, qc, positions.shape[0]))
        return None, _grouped_attend(qi, k, v, mask)

    _, outs = jax.lax.scan(body, None, (jnp.arange(nq) , qb))
    return jnp.moveaxis(outs, 0, 1).reshape(B, L, KV, G, hd)


def attention_forward(
    p: dict,
    x: Array,                    # (B, L, d)
    positions: Array,            # (L,) absolute positions
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float,
    causal: bool = True,
    window: int = 0,
    norm_eps: float = 1e-5,
) -> Array:
    """Full-sequence attention (training / prefill).

    Under an installed sharding context (repro.distributed.context) this
    optionally runs sequence-parallel (query positions sharded on ``model``
    — required when head counts don't divide the tensor axis) and/or
    q-chunked online softmax (long prefill memory).
    """
    from repro.distributed import context

    B, L, _ = x.shape
    G = n_heads // n_kv
    q = _split_heads(x @ p["wq"], n_heads, head_dim)
    k = _split_heads(x @ p["wk"], n_kv, head_dim)
    v = _split_heads(x @ p["wv"], n_kv, head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], norm_eps)
        k = rms_norm(k, p["k_norm"], norm_eps)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    q = q.reshape(B, L, n_kv, G, head_dim)

    seq_par = context.seq_parallel_attn_enabled()
    if seq_par:
        b = context.batch_axis()
        q = context.constrain(q, b, "model", None, None, None)
        k = context.constrain(k, b, None, None, None)
        v = context.constrain(v, b, None, None, None)

    if (context.flash_attention_enabled() and causal and not window
            and L % 256 == 0):
        # interpret-mode Pallas flash attention: lowers to a blocked while
        # loop over VMEM-sized tiles — models the TPU kernel's HBM traffic
        # (no S x S materialization) in the dry-run HLO.
        from repro.kernels.flash_attention.flash_attention import flash_attention
        qh = q.reshape(B, L, n_heads, head_dim).transpose(0, 2, 1, 3)
        out = flash_attention(qh, k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True,
                              bq=256, bk=256, interpret=True)
        out = out.transpose(0, 2, 1, 3).reshape(B, L, n_kv, G, head_dim)
    elif (qc := context.q_chunk()) and L > qc and L % qc == 0:
        out = _chunked_attend(q, k, v, positions, causal, window, qc)
    else:
        mask = None
        if causal:
            rel = positions[:, None] - positions[None, :]      # (L, L)
            mask = rel >= 0
            if window:
                mask = mask & (rel < window)
            mask = jnp.broadcast_to(mask, (B, L, L))
            if seq_par:
                mask = context.constrain(mask, context.batch_axis(), "model",
                                         None)
        out = _grouped_attend(q, k, v, mask)
    out = out.reshape(B, L, n_heads * head_dim)
    if seq_par:
        # keep query positions sharded through the output projection — the
        # backward of the attention einsums then stays L-sharded (moving the
        # shard to the head dim here made XLA replicate the S x S scores in
        # the gradient computation: §Perf iteration 2).
        out = context.constrain(out, context.batch_axis(), "model", None)
        o = out @ p["wo"]
        return context.constrain(o, context.batch_axis(), None, None)
    return out @ p["wo"]


def decode_attention(
    p: dict,
    x: Array,                    # (B, 1, d) — the new token
    cache: KVCache,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float,
    window: int = 0,
    norm_eps: float = 1e-5,
) -> tuple[Array, KVCache]:
    """One-token decode over a KV cache (ring buffer when window > 0).

    ``cache.index`` is either a scalar (the whole batch sits at one decode
    position — the classic engine) or a per-row ``(B,)`` vector (slot
    serving: each batch row is an independent sequence at its own position,
    see ``DecodeEngine.step_slots``). RoPE, the cache write slot, and the
    validity mask are all computed per row, so rows never share position
    state and each row's decode is bit-identical to decoding it alone.
    """
    B, Lq, _ = x.shape
    assert Lq == 1
    G = n_heads // n_kv
    pos = jnp.broadcast_to(cache.index, (B,)).astype(jnp.int32)  # per-row position
    q = _split_heads(x @ p["wq"], n_heads, head_dim)
    k_new = _split_heads(x @ p["wk"], n_kv, head_dim)
    v_new = _split_heads(x @ p["wv"], n_kv, head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], norm_eps)
        k_new = rms_norm(k_new, p["k_norm"], norm_eps)
    posb = pos[:, None]                                         # (B, 1)
    q = apply_rope(q, posb, rope_theta)
    k_new = apply_rope(k_new, posb, rope_theta)

    slot = pos % cache.slots if window else jnp.minimum(pos, cache.slots - 1)
    rows = jnp.arange(B)
    k = cache.k.at[rows, slot].set(k_new[:, 0].astype(cache.k.dtype))
    v = cache.v.at[rows, slot].set(v_new[:, 0].astype(cache.v.dtype))

    # validity of each physical slot, per row
    slot_ids = jnp.arange(cache.slots)
    if window:
        valid = slot_ids[None, :] < jnp.minimum(pos + 1, cache.slots)[:, None]
    else:
        valid = slot_ids[None, :] <= pos[:, None]
    mask = valid[:, None, :]                                    # (B, 1, slots)

    q = q.reshape(B, 1, n_kv, G, head_dim)
    out = _grouped_attend(q, k.astype(q.dtype), v.astype(q.dtype), mask)
    out = out.reshape(B, 1, n_heads * head_dim) @ p["wo"]
    return out, KVCache(k=k, v=v, index=pos + 1)


def decode_attention_paged(
    p: dict,
    x: Array,                    # (B, 1, d) — the new token
    k_pages: Array,              # (num_pages, page_size, KV, hd) one layer
    v_pages: Array,
    block_table: Array,          # (B, nb) int32 page ids
    pos: Array,                  # (B,) int32 decode position per row
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float,
    norm_eps: float = 1e-5,
    kernel: bool = False,
) -> tuple[Array, Array, Array]:
    """One-token decode over one layer's slice of a paged KV cache.

    The new K/V land at page ``block_table[b, pos // page_size]`` offset
    ``pos % page_size``; attention then runs over the row's own pages only.
    ``kernel=False`` is the dense-gather fallback — it reassembles the
    row-major (B, nb*ps, KV, hd) layout and reuses ``_grouped_attend``, so
    with ``nb * page_size == cache_slots`` its output is BIT-IDENTICAL to
    ``decode_attention`` over the dense cache (same shapes, same ops; masked
    positions are NEG_INF in both paths, so pool garbage never leaks).
    ``kernel=True`` routes through the Pallas paged-attention kernel
    (``kernels.flash_attention.paged_attention``), which DMAs pages via a
    scalar-prefetched block table instead of gathering a dense copy.

    Returns (out, k_pages, v_pages); the caller advances ``index``.
    """
    B, Lq, _ = x.shape
    assert Lq == 1
    G = n_heads // n_kv
    ps = k_pages.shape[1]
    nb = block_table.shape[1]
    q = _split_heads(x @ p["wq"], n_heads, head_dim)
    k_new = _split_heads(x @ p["wk"], n_kv, head_dim)
    v_new = _split_heads(x @ p["wv"], n_kv, head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], norm_eps)
        k_new = rms_norm(k_new, p["k_norm"], norm_eps)
    posb = pos[:, None]                                         # (B, 1)
    q = apply_rope(q, posb, rope_theta)
    k_new = apply_rope(k_new, posb, rope_theta)

    # write the new K/V into each row's own page (clamped like the dense
    # non-windowed path; the gateway rejects over-capacity requests).
    # Inactive rows' block tables point at the reserved trash page 0.
    posw = jnp.minimum(pos, nb * ps - 1)
    rows = jnp.arange(B)
    page = block_table[rows, posw // ps]                        # (B,)
    off = posw % ps
    k_pages = k_pages.at[page, off].set(k_new[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[page, off].set(v_new[:, 0].astype(v_pages.dtype))

    qg = q.reshape(B, n_kv, G, head_dim)
    if kernel:
        from repro.kernels.flash_attention.ops import paged_attend

        out = paged_attend(qg, k_pages, v_pages, block_table, pos + 1)
        out = out.reshape(B, 1, n_heads * head_dim).astype(x.dtype)
    else:
        # dense-gather fallback: row b's logical positions, page-major
        k = k_pages[block_table].reshape(B, nb * ps, n_kv, head_dim)
        v = v_pages[block_table].reshape(B, nb * ps, n_kv, head_dim)
        valid = jnp.arange(nb * ps)[None, :] <= pos[:, None]
        out = _grouped_attend(qg[:, None], k.astype(q.dtype),
                              v.astype(q.dtype), valid[:, None, :])
        out = out.reshape(B, 1, n_heads * head_dim)
    return out @ p["wo"], k_pages, v_pages


def cross_attention_forward(
    p: dict,
    x: Array,                    # (B, L, d) decoder states
    memory: Array,               # (B, M, d_mem) encoder states (pre-projected keys ok)
    *,
    n_heads: int,
    head_dim: int,
) -> Array:
    """Encoder-decoder cross attention (no mask, no RoPE) — whisper decoder."""
    B, L, _ = x.shape
    q = _split_heads(x @ p["wq"], n_heads, head_dim)
    k = _split_heads(memory @ p["wk"], n_heads, head_dim)
    v = _split_heads(memory @ p["wv"], n_heads, head_dim)
    q = q.reshape(B, L, n_heads, 1, head_dim)
    out = _grouped_attend(q, k, v, None)
    return out.reshape(B, L, n_heads * head_dim) @ p["wo"]
