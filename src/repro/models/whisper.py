"""Whisper-medium encoder-decoder backbone [arXiv:2212.04356].

Per the assignment, the mel-spectrogram + conv feature extractor frontend is
a STUB: ``input_specs()`` provides precomputed frame embeddings
(B, num_frames, d_model). The transformer itself — 24 encoder layers
(bidirectional) + 24 decoder layers (causal self-attn + cross-attn) — is
implemented fully.

Deviations noted in DESIGN.md: RoPE instead of learned absolute positions;
pre-norm RMSNorm instead of LayerNorm (consistent with the rest of the zoo).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (
    KVCache,
    attention_forward,
    cross_attention_forward,
    decode_attention,
    init_attention,
)
from repro.models.layers import dense_init, rms_norm, stack_layer_params
from repro.models.transformer import cast_params, init_flow_head

Array = jax.Array


class EncDecState(NamedTuple):
    k: Array         # (L, B, slots, KV, hd) decoder self-attn keys
    v: Array
    memory: Array    # (B, M, d) encoded audio (computed once at prefill)
    index: Array


def _mlp_init(key, d, ff):
    k1, k2 = jax.random.split(key)
    return {"w1": dense_init(k1, d, ff), "w2": dense_init(k2, ff, d)}


def _mlp(p, x):
    return jax.nn.gelu(x @ p["w1"]) @ p["w2"]


def _enc_layer_init(key: Array, cfg: ModelConfig) -> dict:
    hd = cfg.resolved_head_dim
    k1, k2 = jax.random.split(key)
    return {
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd),
        "mlp": _mlp_init(k2, cfg.d_model, cfg.d_ff),
        "norm1": jnp.ones((cfg.d_model,), jnp.float32),
        "norm2": jnp.ones((cfg.d_model,), jnp.float32),
    }


def _dec_layer_init(key: Array, cfg: ModelConfig) -> dict:
    hd = cfg.resolved_head_dim
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd),
        "cross_attn": init_attention(k2, cfg.d_model, cfg.n_heads, cfg.n_heads, hd),
        "mlp": _mlp_init(k3, cfg.d_model, cfg.d_ff),
        "norm1": jnp.ones((cfg.d_model,), jnp.float32),
        "norm2": jnp.ones((cfg.d_model,), jnp.float32),
        "norm3": jnp.ones((cfg.d_model,), jnp.float32),
    }


def init_encdec_params(key: Array, cfg: ModelConfig, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    n_enc = cfg.n_encoder_layers or cfg.n_layers
    keys = jax.random.split(key, n_enc + cfg.n_layers + 2)
    params = {
        "embed": dense_init(keys[-2], cfg.vocab, cfg.d_model, scale=1.0),
        "enc_layers": stack_layer_params([_enc_layer_init(keys[i], cfg)
                                          for i in range(n_enc)]),
        "dec_layers": stack_layer_params(
            [_dec_layer_init(keys[n_enc + i], cfg) for i in range(cfg.n_layers)]),
        "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "flow": init_flow_head(keys[-1], cfg),
    }
    return cast_params(params, dtype)  # lm head tied to embed (whisper ties)


def encode(params: dict, cfg: ModelConfig, frames: Array,
           remat: bool = False) -> Array:
    """frames: (B, M, d_model) stub frontend embeddings -> encoder memory."""
    hd = cfg.resolved_head_dim
    positions = jnp.arange(frames.shape[1])

    def body(h, p):
        h = h + attention_forward(p["attn"], rms_norm(h, p["norm1"], cfg.norm_eps),
                                  positions, n_heads=cfg.n_heads,
                                  n_kv=cfg.n_kv_heads, head_dim=hd,
                                  rope_theta=cfg.rope_theta, causal=False)
        h = h + _mlp(p["mlp"], rms_norm(h, p["norm2"], cfg.norm_eps))
        return h, None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, frames, params["enc_layers"])
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def decoder_hidden(params: dict, cfg: ModelConfig, h: Array, memory: Array,
                   positions: Optional[Array] = None, *, causal: bool = True,
                   remat: bool = False) -> Array:
    hd = cfg.resolved_head_dim
    if positions is None:
        positions = jnp.arange(h.shape[1])

    def body(h, p):
        h = h + attention_forward(p["self_attn"],
                                  rms_norm(h, p["norm1"], cfg.norm_eps), positions,
                                  n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                                  head_dim=hd, rope_theta=cfg.rope_theta,
                                  causal=causal)
        h = h + cross_attention_forward(p["cross_attn"],
                                        rms_norm(h, p["norm2"], cfg.norm_eps),
                                        memory, n_heads=cfg.n_heads, head_dim=hd)
        h = h + _mlp(p["mlp"], rms_norm(h, p["norm3"], cfg.norm_eps))
        return h, None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["dec_layers"])
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def lm_forward(params: dict, cfg: ModelConfig, tokens: Array,
               frames: Array, positions=None, last_only: bool = False,
               **_) -> Array:
    memory = encode(params, cfg, frames)
    h = decoder_hidden(params, cfg, params["embed"][tokens], memory, positions)
    if last_only:
        h = h[:, -1:, :]
    return h @ params["embed"].T


def init_state(cfg: ModelConfig, batch: int, slots: int, num_frames: int,
               dtype=jnp.bfloat16) -> EncDecState:
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, slots, cfg.n_kv_heads, hd)
    return EncDecState(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        memory=jnp.zeros((batch, num_frames, cfg.d_model), dtype),
        index=jnp.zeros((), jnp.int32),
    )


def decode_step(params: dict, cfg: ModelConfig, token: Array,
                state: EncDecState, **_) -> tuple[Array, EncDecState]:
    hd = cfg.resolved_head_dim
    h = params["embed"][token][:, None, :]

    def body(h, xs):
        p, k_c, v_c = xs
        cache = KVCache(k=k_c, v=v_c, index=state.index)
        attn_out, cache = decode_attention(
            p["self_attn"], rms_norm(h, p["norm1"], cfg.norm_eps), cache,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
            rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps)
        h = h + attn_out
        h = h + cross_attention_forward(p["cross_attn"],
                                        rms_norm(h, p["norm2"], cfg.norm_eps),
                                        state.memory, n_heads=cfg.n_heads,
                                        head_dim=hd)
        h = h + _mlp(p["mlp"], rms_norm(h, p["norm3"], cfg.norm_eps))
        return h, (cache.k, cache.v)

    h, (ks, vs) = jax.lax.scan(body, h, (params["dec_layers"], state.k, state.v))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)[:, 0]
    logits = h @ params["embed"].T
    return logits, EncDecState(k=ks, v=vs, memory=state.memory,
                               index=state.index + 1)
