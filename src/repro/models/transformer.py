"""Dense decoder-only transformer family (llama-style GQA).

Covers yi-6b / yi-34b [arXiv:2403.04652], phi3-medium-14b [arXiv:2404.14219],
command-r-35b (parallel attn+FFN block, no biases)
[hf:CohereForAI/c4ai-command-r-v01], and the InternLM2-style LM of
internvl2-26b [arXiv:2404.16821].

Layer params are stacked on a leading axis and the forward pass is a
``lax.scan`` over layers — one compiled block body regardless of depth, which
keeps dry-run HLO size flat across the 32-94 layer pool.

Two heads:
  * LM head      — ``lm_forward`` / ``decode_step`` (serving substrate);
  * velocity head — ``flow_velocity`` (the paper's flow-matching substrate).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (
    KVCache,
    PagedKVCache,
    attention_forward,
    decode_attention,
    decode_attention_paged,
    init_attention,
    init_kv_cache,
)
from repro.models.layers import (
    dense_init,
    rms_norm,
    stack_layer_params,
    swiglu,
    timestep_embedding,
)

Array = jax.Array


def _layer_init(key: Array, cfg: ModelConfig) -> dict:
    hd = cfg.resolved_head_dim
    k_attn, k1, k2, k3 = jax.random.split(key, 4)
    p = {
        "attn": init_attention(k_attn, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               hd, cfg.qk_norm),
        "mlp": {
            "w_gate": dense_init(k1, cfg.d_model, cfg.d_ff),
            "w_up": dense_init(k2, cfg.d_model, cfg.d_ff),
            "w_down": dense_init(k3, cfg.d_ff, cfg.d_model),
        },
        "norm1": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.parallel_block:
        p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
    return p


def init_flow_head(key: Array, cfg: ModelConfig) -> dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "latent_embed": dense_init(k1, cfg.vocab, cfg.latent_dim, scale=1.0),
        "proj_in": dense_init(k2, cfg.latent_dim, cfg.d_model),
        "proj_out": dense_init(k3, cfg.d_model, cfg.latent_dim),
        "time_w1": dense_init(k4, cfg.d_model, cfg.d_model),
        "time_w2": dense_init(k5, cfg.d_model, cfg.d_model),
    }


def init_dense_params(key: Array, cfg: ModelConfig, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 3)
    layers = stack_layer_params([_layer_init(keys[i], cfg)
                                 for i in range(cfg.n_layers)])
    params = {
        "embed": dense_init(keys[-3], cfg.vocab, cfg.d_model, scale=1.0),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "flow": init_flow_head(keys[-1], cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[-2], cfg.d_model, cfg.vocab)
    return cast_params(params, dtype)


def cast_params(params, dtype):
    """Cast matmul weights; keep norm scales (1-D) in fp32."""
    return jax.tree.map(
        lambda x: x if x.ndim == 1 else x.astype(dtype), params)


def _block(p: dict, cfg: ModelConfig, h: Array, positions: Array,
           causal: bool, window: int) -> Array:
    hd = cfg.resolved_head_dim
    attn_kw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
                   rope_theta=cfg.rope_theta, causal=causal, window=window,
                   norm_eps=cfg.norm_eps)
    if cfg.parallel_block:
        hn = rms_norm(h, p["norm1"], cfg.norm_eps)
        return h + attention_forward(p["attn"], hn, positions, **attn_kw) \
                 + swiglu(hn, **p["mlp"])
    h = h + attention_forward(p["attn"], rms_norm(h, p["norm1"], cfg.norm_eps),
                              positions, **attn_kw)
    h = h + swiglu(rms_norm(h, p["norm2"], cfg.norm_eps), **p["mlp"])
    return h


def dense_hidden(params: dict, cfg: ModelConfig, h: Array, positions: Array,
                 *, causal: bool = True, window: int = 0,
                 remat: bool = False) -> Array:
    def body(h, layer_p):
        return _block(layer_p, cfg, h, positions, causal, window), None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["layers"])
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def lm_forward(params: dict, cfg: ModelConfig, tokens: Array,
               positions: Optional[Array] = None, *, window: int = 0,
               extra_embeds: Optional[Array] = None,
               last_only: bool = False) -> Array:
    """Training / prefill: logits for every position. ``extra_embeds`` is the
    VLM/audio path: stub embeddings prepended to the token embeddings."""
    h = params["embed"][tokens]
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=1)
    L = h.shape[1]
    if positions is None:
        positions = jnp.arange(L)
    h = dense_hidden(params, cfg, h, positions, causal=True, window=window)
    if last_only:
        h = h[:, -1:, :]
    if cfg.tie_embeddings:
        return h @ params["embed"].T
    return h @ params["lm_head"]


def init_caches(cfg: ModelConfig, batch: int, slots: int, dtype=jnp.bfloat16) -> KVCache:
    hd = cfg.resolved_head_dim
    one = init_kv_cache(batch, slots, cfg.n_kv_heads, hd, dtype)
    return KVCache(
        k=jnp.zeros((cfg.n_layers,) + one.k.shape, dtype),
        v=jnp.zeros((cfg.n_layers,) + one.v.shape, dtype),
        index=jnp.zeros((), jnp.int32),
    )


def init_paged_caches(cfg: ModelConfig, batch: int, num_pages: int,
                      page_size: int, blocks_per_slot: int,
                      dtype=jnp.bfloat16) -> PagedKVCache:
    """Paged decode state: a shared (L, num_pages, page_size, KV, hd) pool
    plus a zeroed per-row block table — all rows start on the reserved
    trash page 0 (see ``PagedKVCache``) until the gateway's page allocator
    assigns them real pages at admission."""
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, num_pages, page_size, cfg.n_kv_heads, hd)
    return PagedKVCache(
        k_pages=jnp.zeros(shape, dtype),
        v_pages=jnp.zeros(shape, dtype),
        block_table=jnp.zeros((batch, blocks_per_slot), jnp.int32),
        index=jnp.zeros((batch,), jnp.int32),
    )


def decode_step(params: dict, cfg: ModelConfig, token: Array, caches,
                *, window: int = 0,
                paged_kernel: bool = False):
    """One-token decode: token (B,) int32 -> (logits (B, V), new caches).

    ``caches`` is a dense ``KVCache`` or a ``PagedKVCache``; the layer scan
    carries each layer's cache slice either way (dense rows vs page-pool
    slices + the shared block table)."""
    h = params["embed"][token][:, None, :]                     # (B, 1, d)
    hd = cfg.resolved_head_dim
    paged = isinstance(caches, PagedKVCache)
    if paged:
        pos = jnp.broadcast_to(caches.index, (h.shape[0],)).astype(jnp.int32)
        attn_kw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
                       rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps,
                       kernel=paged_kernel)
    else:
        attn_kw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
                       rope_theta=cfg.rope_theta, window=window,
                       norm_eps=cfg.norm_eps)

    def body(carry, xs):
        h = carry
        layer_p, k_c, v_c = xs
        hn = rms_norm(h, layer_p["norm1"], cfg.norm_eps)
        if paged:
            attn_out, k_c, v_c = decode_attention_paged(
                layer_p["attn"], hn, k_c, v_c, caches.block_table, pos,
                **attn_kw)
        else:
            cache = KVCache(k=k_c, v=v_c, index=caches.index)
            attn_out, cache = decode_attention(layer_p["attn"], hn, cache,
                                               **attn_kw)
            k_c, v_c = cache.k, cache.v
        if cfg.parallel_block:
            h = h + attn_out + swiglu(hn, **layer_p["mlp"])
        else:
            h = h + attn_out
            h = h + swiglu(rms_norm(h, layer_p["norm2"], cfg.norm_eps),
                           **layer_p["mlp"])
        return h, (k_c, v_c)

    kv_in = (caches.k_pages, caches.v_pages) if paged else (caches.k, caches.v)
    h, (ks, vs) = jax.lax.scan(body, h, (params["layers"],) + kv_in)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)[:, 0, :]
    logits = h @ (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    if paged:
        return logits, PagedKVCache(k_pages=ks, v_pages=vs,
                                    block_table=caches.block_table,
                                    index=pos + 1)
    return logits, KVCache(k=ks, v=vs, index=caches.index + 1)


# ---------------------------------------------------------------------------
# Flow mode — the backbone as a velocity field u_t(x) (paper substrate)
# ---------------------------------------------------------------------------


def flow_velocity(params: dict, cfg: ModelConfig, t: Array, x: Array,
                  cond_tokens: Optional[Array], *,
                  hidden_fn=None, remat: bool = False) -> Array:
    """u_t(x): x (B, S, latent_dim) noisy latents -> velocity, same shape.

    Conditioning: token embeddings added to the input projection (class/text
    conditioning analog); ``cond_tokens=None`` is the unconditional branch
    (CFG). ``hidden_fn`` lets non-dense families reuse this head."""
    f = params["flow"]
    h = x.astype(f["proj_in"].dtype) @ f["proj_in"]
    if cond_tokens is not None:
        h = h + params["embed"][cond_tokens]
    temb = timestep_embedding(t, cfg.d_model).astype(h.dtype)
    temb = jax.nn.silu(temb @ f["time_w1"]) @ f["time_w2"]
    h = h + temb[:, None, :] if temb.ndim == 2 else h + temb[None, None, :]
    positions = jnp.arange(x.shape[1])
    if hidden_fn is None:
        h = dense_hidden(params, cfg, h, positions, causal=True, remat=remat)
    else:
        h = hidden_fn(params, cfg, h, positions)
    return (h @ f["proj_out"]).astype(x.dtype)


def latent_targets(params: dict, tokens: Array) -> Array:
    """x1 = latent embedding of the data tokens (flow-matching target)."""
    return params["flow"]["latent_embed"][tokens]
