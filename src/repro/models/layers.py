"""Shared neural building blocks (pure-JAX, pytree params).

Parameter trees are plain nested dicts of jnp arrays; every initializer takes
an explicit PRNG key. Layer stacks store params with a leading layer axis so
the forward pass can ``lax.scan`` over layers (keeps HLO small for the
dry-run of 40-90 layer architectures).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def dense_init(key: Array, d_in: int, d_out: int, scale: float | None = None) -> Array:
    scale = scale if scale is not None else d_in ** -0.5
    return scale * jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)


def rms_norm(x: Array, weight: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight).astype(dtype)


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def rope_frequencies(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def timestep_embedding(t: Array, dim: int, max_period: float = 10_000.0) -> Array:
    """Sinusoidal flow-time embedding; t scalar or (batch,)."""
    t = jnp.atleast_1d(jnp.asarray(t, jnp.float32))
    half = dim // 2
    freqs = jnp.exp(-jnp.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t[..., None] * freqs * 1000.0
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def stack_layer_params(params_list):
    """Stack per-layer param dicts along a new leading axis for lax.scan."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)
