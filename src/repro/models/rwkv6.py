"""RWKV6 "Finch" — attention-free RNN with data-dependent decay
[arXiv:2404.05892].

Per-layer: a time-mix block (the wkv6 linear recurrence with per-channel
data-dependent decay w_t produced by a LoRA on the token-shifted input, plus
the 'bonus' u term) and a channel-mix block (squared-ReLU FFN with receptance
gating). Token shift is the RWKV 1-step convolution.

Simplifications vs. the reference (noted in DESIGN.md): the five DDLerp
token-shift mixes use static per-channel mu (the decay LoRA — the paper's
defining feature — is kept); GroupNorm on wkv output is per-head RMSNorm.

State for decode: (shift_tm, shift_cm, wkv state) — no KV cache, O(1) memory
in sequence length, which is why long_500k runs natively on this arch.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm, stack_layer_params
from repro.models.linear_scan import gla_chunked, gla_step
from repro.models.transformer import cast_params, init_flow_head

Array = jax.Array

HEAD_DIM = 64
LORA_DIM = 64


class RWKVState(NamedTuple):
    shift_tm: Array   # (layers, B, d) last token's input to time-mix
    shift_cm: Array   # (layers, B, d) last token's input to channel-mix
    wkv: Array        # (layers, B, H, dk, dv) recurrence state
    index: Array      # scalar int32


def _layer_init(key: Array, cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 12)
    H = d // HEAD_DIM
    return {
        "tm": {
            "mu": 0.5 * jnp.ones((5, d), jnp.float32),   # r,k,v,w,g mixes
            "w0": -6.0 + jnp.zeros((d,), jnp.float32),    # decay bias (slow)
            "w_lora_a": dense_init(ks[0], d, LORA_DIM, scale=0.01),
            "w_lora_b": dense_init(ks[1], LORA_DIM, d, scale=0.01),
            "u": jnp.zeros((H, HEAD_DIM), jnp.float32),   # bonus
            "wr": dense_init(ks[2], d, d),
            "wk": dense_init(ks[3], d, d),
            "wv": dense_init(ks[4], d, d),
            "wg": dense_init(ks[5], d, d),
            "wo": dense_init(ks[6], d, d),
            "ln_x": jnp.ones((H, HEAD_DIM), jnp.float32),
        },
        "cm": {
            "mu": 0.5 * jnp.ones((2, d), jnp.float32),   # k,r mixes
            "wk": dense_init(ks[7], d, ff),
            "wv": dense_init(ks[8], ff, d),
            "wr": dense_init(ks[9], d, d),
        },
        "norm1": jnp.ones((d,), jnp.float32),
        "norm2": jnp.ones((d,), jnp.float32),
    }


def init_rwkv_params(key: Array, cfg: ModelConfig, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 3)
    params = {
        "embed": dense_init(keys[-3], cfg.vocab, cfg.d_model, scale=1.0),
        "layers": stack_layer_params([_layer_init(keys[i], cfg)
                                      for i in range(cfg.n_layers)]),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": dense_init(keys[-2], cfg.d_model, cfg.vocab),
        "flow": init_flow_head(keys[-1], cfg),
    }
    return cast_params(params, dtype)


def _decay(p: dict, m_w: Array) -> Array:
    """Data-dependent log-decay: ld = -exp(w0 + lora(m_w)), <= 0."""
    lora = jnp.tanh(m_w @ p["w_lora_a"]) @ p["w_lora_b"]
    return -jnp.exp(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32))


def _time_mix_seq(p: dict, x: Array, shift_in: Array, chunk: int
                  ) -> tuple[Array, Array, Array]:
    """x: (B, L, d). Returns (out, last_x, final wkv state)."""
    B, L, d = x.shape
    H = d // HEAD_DIM
    x_prev = jnp.concatenate([shift_in[:, None, :], x[:, :-1, :]], axis=1)
    xx = x_prev - x
    m = x[None] + xx[None] * p["mu"][:, None, None, :]        # (5, B, L, d)
    m_r, m_k, m_v, m_w, m_g = m
    r = (m_r @ p["wr"]).reshape(B, L, H, HEAD_DIM)
    k = (m_k @ p["wk"]).reshape(B, L, H, HEAD_DIM)
    v = (m_v @ p["wv"]).reshape(B, L, H, HEAD_DIM)
    g = jax.nn.silu(m_g @ p["wg"])
    ld = _decay(p, m_w).reshape(B, L, H, HEAD_DIM)

    o_hist, S = gla_chunked(r, k, v, ld, inclusive=False, chunk=chunk)
    bonus = jnp.sum(r * p["u"] * k, axis=-1, keepdims=True) * v
    o = o_hist + bonus.astype(o_hist.dtype)
    o = rms_norm(o, p["ln_x"]).reshape(B, L, d)
    return (o * g) @ p["wo"], x[:, -1, :], S


def _time_mix_step(p: dict, x: Array, shift_in: Array, S: Array
                   ) -> tuple[Array, Array, Array]:
    """x: (B, d) single token."""
    B, d = x.shape
    H = d // HEAD_DIM
    xx = shift_in - x
    m = x[None] + xx[None] * p["mu"][:, None, :]
    m_r, m_k, m_v, m_w, m_g = m
    r = (m_r @ p["wr"]).reshape(B, H, HEAD_DIM)
    k = (m_k @ p["wk"]).reshape(B, H, HEAD_DIM)
    v = (m_v @ p["wv"]).reshape(B, H, HEAD_DIM)
    g = jax.nn.silu(m_g @ p["wg"])
    ld = _decay(p, m_w).reshape(B, H, HEAD_DIM)
    o_hist, S = gla_step(r, k, v, ld, S, inclusive=False)
    bonus = jnp.sum(r * p["u"] * k, axis=-1, keepdims=True) * v
    o = rms_norm(o_hist + bonus.astype(o_hist.dtype), p["ln_x"]).reshape(B, d)
    return (o * g) @ p["wo"], x, S


def _channel_mix(p: dict, x: Array, x_prev: Array) -> Array:
    """Works for (B, L, d) with shifted x_prev, or (B, d) single step."""
    xx = x_prev - x
    m_k = x + xx * p["mu"][0]
    m_r = x + xx * p["mu"][1]
    k = jnp.square(jax.nn.relu(m_k @ p["wk"]))
    return jax.nn.sigmoid(m_r @ p["wr"]) * (k @ p["wv"])


def rwkv_hidden(params: dict, cfg: ModelConfig, h: Array, positions=None,
                *, chunk: int = 0, remat: bool = False) -> Array:
    """Full-sequence forward (training / prefill / flow)."""
    chunk = chunk or (cfg.ssm.chunk if cfg.ssm else 64)
    B, L, d = h.shape

    def body(h, layer_p):
        zero = jnp.zeros((B, d), h.dtype)
        tm_out, _, _ = _time_mix_seq(layer_p["tm"],
                                     rms_norm(h, layer_p["norm1"], cfg.norm_eps),
                                     zero, chunk)
        h = h + tm_out
        hn = rms_norm(h, layer_p["norm2"], cfg.norm_eps)
        hn_prev = jnp.concatenate([zero[:, None], hn[:, :-1]], axis=1)
        h = h + _channel_mix(layer_p["cm"], hn, hn_prev)
        return h, None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["layers"])
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def lm_forward(params: dict, cfg: ModelConfig, tokens: Array, positions=None,
               last_only: bool = False, **_) -> Array:
    h = rwkv_hidden(params, cfg, params["embed"][tokens])
    if last_only:
        h = h[:, -1:, :]
    return h @ params["lm_head"]


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> RWKVState:
    H = cfg.d_model // HEAD_DIM
    L = cfg.n_layers
    return RWKVState(
        shift_tm=jnp.zeros((L, batch, cfg.d_model), dtype),
        shift_cm=jnp.zeros((L, batch, cfg.d_model), dtype),
        wkv=jnp.zeros((L, batch, H, HEAD_DIM, HEAD_DIM), jnp.float32),
        index=jnp.zeros((), jnp.int32),
    )


def decode_step(params: dict, cfg: ModelConfig, token: Array, state: RWKVState,
                **_) -> tuple[Array, RWKVState]:
    h = params["embed"][token]                                # (B, d)

    def body(h, xs):
        layer_p, sh_tm, sh_cm, S = xs
        hn = rms_norm(h, layer_p["norm1"], cfg.norm_eps)
        tm_out, sh_tm, S = _time_mix_step(layer_p["tm"], hn, sh_tm.astype(hn.dtype), S)
        h = h + tm_out
        hn2 = rms_norm(h, layer_p["norm2"], cfg.norm_eps)
        h = h + _channel_mix(layer_p["cm"], hn2, sh_cm.astype(hn2.dtype))
        return h, (sh_tm, hn2, S)

    h, (sh_tm, sh_cm, wkv) = jax.lax.scan(
        body, h, (params["layers"], state.shift_tm, state.shift_cm, state.wkv))
    logits = rms_norm(h, params["final_norm"], cfg.norm_eps) @ params["lm_head"]
    return logits, RWKVState(shift_tm=sh_tm.astype(state.shift_tm.dtype),
                             shift_cm=sh_cm.astype(state.shift_cm.dtype),
                             wkv=wkv, index=state.index + 1)
