"""SolverArtifact — the <200-parameter solver as a serializable product.

The paper's solver is trained once and served everywhere; this is its wire
format: the ``SolverSpec``, the trained parameter pytree, the validation
PSNR it earned, and free-form provenance (arch, scheduler, git rev, ...).
Storage goes through ``repro.checkpoint.checkpointer`` (msgpack leaves +
JSON meta), so an artifact is a single ``.msgpack`` file that round-trips
bit-exactly — ``launch/serve.py`` loads one instead of re-distilling.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp

from repro.checkpoint import checkpointer
from repro.core import anytime as anytime_mod
from repro.core import bst_solver, ns_solver
from repro.core.ns_solver import NSParams
from repro.core.parametrization import VelocityField
from repro.solvers.pipeline import Sampler
from repro.solvers.spec import SolverSpec, ns_at_budget, reduce_to_ns

FORMAT = "bns-solver-artifact"
FORMAT_VERSION = 1

_KINDS = {
    NSParams: "ns",
    ns_solver.BNSParams: "bns",
    bst_solver.BSTParams: "bst",
    anytime_mod.AnytimeParams: "anytime",
}


def _param_template(kind: str, spec: SolverSpec):
    """Zero pytree with the shapes ``spec`` implies, for checkpoint restore."""
    n = spec.nfe
    if kind == "ns":
        return NSParams(times=jnp.zeros((n,)), a=jnp.zeros((n,)),
                        b=jnp.zeros((n, n)))
    if kind == "bns":
        return ns_solver.BNSParams(time_logits=jnp.zeros((n,)),
                                   a=jnp.zeros((n,)), b=jnp.zeros((n, n)))
    if kind == "bst":
        k = bst_solver.knot_positions(n, spec.name).shape[0]
        return bst_solver.BSTParams(time_logits=jnp.zeros((k - 1,)),
                                    log_s=jnp.zeros((k,)),
                                    log_dt=jnp.zeros((k,)),
                                    ds=jnp.zeros((k,)))
    if kind == "anytime":
        m = len(spec.budgets) - 1
        return anytime_mod.AnytimeParams(time_raw=jnp.zeros((n,)),
                                         a=jnp.zeros((n,)),
                                         b=jnp.zeros((n, n)),
                                         exit_a=jnp.zeros((m,)),
                                         exit_b=jnp.zeros((m, n)))
    raise ValueError(f"unknown artifact param kind {kind!r}")


@dataclasses.dataclass
class SolverArtifact:
    """spec + trained params + val PSNR + provenance, in one file."""

    spec: SolverSpec
    params: Any
    val_psnr: float
    provenance: dict = dataclasses.field(default_factory=dict)

    @property
    def kind(self) -> str:
        try:
            return _KINDS[type(self.params)]
        except KeyError:
            raise TypeError(
                f"unsupported artifact params {type(self.params).__name__}")

    @property
    def ns_params(self) -> NSParams:
        """Canonical NS parameters for Algorithm-1 serving."""
        return reduce_to_ns(self.params)

    @property
    def budgets(self) -> tuple[int, ...]:
        """NFE budgets this artifact serves (a single one unless anytime)."""
        return self.spec.budgets or (self.spec.nfe,)

    def ns_at_budget(self, m: int) -> NSParams:
        """The m-step NS solver served at budget ``m``.

        Anytime artifacts extract the bona-fide m-step early-exit solver;
        single-budget artifacts require ``m`` to be their one NFE.
        """
        return ns_at_budget(self.params, self.budgets, m)

    def nearest_budget(self, m: int) -> int:
        """The served budget closest to ``m`` (ties break to the smaller —
        fewer backbone forwards)."""
        return min(self.budgets, key=lambda b: (abs(b - m), b))

    def sampler(self, field: VelocityField, update_fn=None,
                budget: Optional[int] = None) -> Sampler:
        """Thin jit'd session sampling the artifact's solver on ``field``.

        ``budget`` selects the early exit of an anytime artifact (defaults
        to the top budget); single-budget artifacts ignore it only when it
        matches their NFE.
        """
        if budget is None and self.kind == "anytime":
            budget = self.budgets[-1]
        ns = self.ns_params if budget is None else self.ns_at_budget(budget)
        return Sampler(ns, field, update_fn=update_fn)

    def save(self, path: str) -> None:
        meta = {"format": FORMAT, "version": FORMAT_VERSION,
                "kind": self.kind, "spec": self.spec.to_dict(),
                "val_psnr": float(self.val_psnr),
                "provenance": self.provenance}
        checkpointer.save(path, self.params, meta=meta)

    @classmethod
    def load(cls, path: str) -> "SolverArtifact":
        meta = checkpointer.load_meta(path)
        if meta is None or meta.get("format") != FORMAT:
            raise ValueError(f"{path} is not a solver artifact")
        spec = SolverSpec.from_dict(meta["spec"])
        template = _param_template(meta["kind"], spec)
        params = checkpointer.restore(path, template)
        return cls(spec=spec, params=params,
                   val_psnr=float(meta["val_psnr"]),
                   provenance=dict(meta.get("provenance", {})))


def save_artifact(path: str, trained, provenance: Optional[dict] = None) -> "SolverArtifact":
    """Convenience: wrap a ``TrainedSolver`` and write it in one call."""
    art = trained.artifact(provenance)
    art.save(path)
    return art
