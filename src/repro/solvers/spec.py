"""SolverSpec — one declarative description of a solver, four ways to run it.

A spec names a registered solver plus the sampling configuration (NFE, grid
override, sigma0 preconditioning, CFG scale) and a ``mode``:

    baseline — the named solver as-is (no training);
    bns      — Bespoke Non-Stationary training (Algorithm 2), initialized
               from the named solver;
    bst      — Bespoke Scale-Time training (prior-work baseline), base =
               the named solver (euler | midpoint);
    anytime  — one shared solver serving every budget in ``budgets``.

``build(field)`` returns exact NS parameters; ``distill(field, ...)`` runs
the matching trainer and returns a ``TrainedSolver`` that converts to a
serializable ``SolverArtifact``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.core import ns_solver
from repro.core.bns import BNSTrainConfig, train_bns, train_bst
from repro.core.ns_solver import NSParams
from repro.core.parametrization import VelocityField
from repro.solvers import registry
from repro.solvers.pipeline import Sampler, evaluate_psnr

MODES = ("baseline", "bns", "bst", "anytime")


def reduce_to_ns(params) -> NSParams:
    """Canonical NS parameters of a trained/stored solver, if it has them."""
    if isinstance(params, NSParams):
        return params
    if isinstance(params, ns_solver.BNSParams):
        return ns_solver.materialize(params)
    from repro.core import anytime as anytime_mod

    if isinstance(params, anytime_mod.AnytimeParams):
        raise TypeError(
            "AnytimeParams serve several budgets and do not reduce to a "
            "single NSParams; pick one with ns_at_budget(params, budgets, m) "
            "(or SolverArtifact.ns_at_budget / AnytimeFlowSampler for "
            "serving)")
    raise TypeError(f"{type(params).__name__} solvers do not reduce to a "
                    "single NSParams")


def ns_at_budget(params, budgets, m: int) -> NSParams:
    """The m-step NS solver a trained/stored solver serves at budget ``m``.

    Anytime solvers extract the bona-fide m-step early-exit solver; every
    other kind reduces to its single NSParams, which must already have
    ``m`` steps.
    """
    from repro.core import anytime as anytime_mod

    if isinstance(params, anytime_mod.AnytimeParams):
        return anytime_mod.extract_ns(params, budgets, m)
    ns = reduce_to_ns(params)
    if ns.n != m:
        raise ValueError(f"solver has {ns.n} steps, not {m}; only anytime "
                         "solvers serve multiple budgets")
    return ns


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """Declarative solver description; the unit the artifact format stores."""

    name: str = "midpoint"
    nfe: int = 8
    grid: Optional[tuple[float, ...]] = None  # override the default time grid
    sigma0: float = 1.0
    cfg_scale: float = 0.0
    mode: str = "baseline"
    budgets: Optional[tuple[int, ...]] = None  # anytime mode only

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")
        if self.mode == "anytime":
            if not self.budgets:
                raise ValueError("anytime mode needs budgets, e.g. (4, 8, 16)")
            object.__setattr__(self, "budgets", tuple(sorted(self.budgets)))
            if self.nfe != self.budgets[-1]:
                object.__setattr__(self, "nfe", self.budgets[-1])
        if self.grid is not None:
            object.__setattr__(self, "grid", tuple(float(g) for g in self.grid))

    @property
    def info(self) -> registry.SolverInfo:
        return registry.get_solver(self.name)

    def build(self, field: VelocityField) -> NSParams:
        """Exact NS parameters of the (baseline / init) solver for ``field``."""
        import numpy as np

        grid = None if self.grid is None else np.asarray(self.grid)
        return registry.build_ns(self.name, self.nfe, field,
                                 sigma0=self.sigma0, grid=grid)

    def sampler(self, field: VelocityField, update_fn=None) -> Sampler:
        """Jit'd baseline sampling session (no training)."""
        return Sampler(self.build(field), field, update_fn=update_fn)

    def train_config(self, base: Optional[BNSTrainConfig] = None) -> BNSTrainConfig:
        """A BNSTrainConfig with this spec's nfe/init/sigma0 pinned in."""
        base = base or BNSTrainConfig()
        return dataclasses.replace(base, nfe=self.nfe, init_solver=self.name,
                                   sigma0=self.sigma0)

    def distill(
        self,
        field: VelocityField,
        train_pairs,
        val_pairs,
        train_cfg: Optional[BNSTrainConfig] = None,
        *,
        log=None,
    ) -> "TrainedSolver":
        """Run the mode's trainer; unifies train_bns / train_bst / anytime."""
        cfg = self.train_config(train_cfg)
        if self.mode == "baseline":
            params = self.build(field)
            vp = evaluate_psnr(params, field, val_pairs, cfg.max_val)
            return TrainedSolver(spec=self, params=params, val_psnr=vp,
                                 history=[], wall_seconds=0.0,
                                 num_parameters=params.num_parameters())
        if self.mode == "bns":
            res = train_bns(field, train_pairs, val_pairs, cfg, log=log)
        elif self.mode == "bst":
            if self.name not in ("euler", "midpoint"):
                raise ValueError("bst mode needs base euler or midpoint")
            res = train_bst(field, train_pairs, val_pairs, cfg,
                            base=self.name, log=log)
        else:  # anytime — imported lazily (core.anytime imports this package)
            from repro.core.anytime import train_anytime

            res = train_anytime(field, list(self.budgets), train_pairs,
                                val_pairs, cfg, log=log)
        return TrainedSolver(spec=self, params=res.params,
                             val_psnr=res.val_psnr, history=res.history,
                             wall_seconds=res.wall_seconds,
                             num_parameters=res.num_parameters)

    def to_dict(self) -> dict:
        return {"name": self.name, "nfe": self.nfe,
                "grid": list(self.grid) if self.grid is not None else None,
                "sigma0": self.sigma0, "cfg_scale": self.cfg_scale,
                "mode": self.mode,
                "budgets": list(self.budgets) if self.budgets else None}

    @classmethod
    def from_dict(cls, d: dict) -> "SolverSpec":
        return cls(name=d["name"], nfe=int(d["nfe"]),
                   grid=tuple(d["grid"]) if d.get("grid") else None,
                   sigma0=float(d.get("sigma0", 1.0)),
                   cfg_scale=float(d.get("cfg_scale", 0.0)),
                   mode=d.get("mode", "baseline"),
                   budgets=tuple(d["budgets"]) if d.get("budgets") else None)


@dataclasses.dataclass
class TrainedSolver:
    """Output of ``SolverSpec.distill``: spec + trained parameters + score."""

    spec: SolverSpec
    params: Any          # NSParams | BNSParams | BSTParams | AnytimeParams
    val_psnr: float
    history: list
    wall_seconds: float
    num_parameters: int

    @property
    def ns_params(self) -> NSParams:
        """Canonical NS parameters, ready for Algorithm-1 serving."""
        return reduce_to_ns(self.params)

    @property
    def budgets(self) -> tuple[int, ...]:
        """NFE budgets this solver serves (a single one unless anytime)."""
        return self.spec.budgets or (self.spec.nfe,)

    def ns_at_budget(self, m: int) -> NSParams:
        """The m-step NS solver served at budget ``m`` (anytime early exit)."""
        return ns_at_budget(self.params, self.budgets, m)

    def sampler(self, field: VelocityField, update_fn=None) -> Sampler:
        return Sampler(self.ns_params, field, update_fn=update_fn)

    def artifact(self, provenance: Optional[dict] = None) -> "SolverArtifact":
        from repro.solvers.artifact import SolverArtifact

        return SolverArtifact(spec=self.spec, params=self.params,
                              val_psnr=self.val_psnr,
                              provenance=dict(provenance or {}))
