"""repro.solvers — unified solver API (registry / spec / artifact / pipeline).

The solver lifecycle in three lines:

    spec = SolverSpec("midpoint", nfe=8, mode="bns")
    art = spec.distill(field, train_pairs, val_pairs, cfg).artifact()
    art.save("solver.msgpack")   # serve: SolverArtifact.load(...).sampler(field)

``registry``  — ``@register_solver`` + capability-filtered ``list_solvers()``;
``spec``      — ``SolverSpec.build/distill`` unifying baseline/BNS/BST/anytime;
``artifact``  — serializable solver product (spec + params + PSNR + provenance);
``pipeline``  — ``Sampler``, the thin jit'd Algorithm-1 session.
"""
from repro.solvers.artifact import SolverArtifact, save_artifact
from repro.solvers.pipeline import Sampler, evaluate_psnr
from repro.solvers.registry import (
    SolverInfo,
    build_ns,
    get_solver,
    list_solvers,
    register_solver,
    solver_names,
)
from repro.solvers.spec import (
    MODES,
    SolverSpec,
    TrainedSolver,
    ns_at_budget,
    reduce_to_ns,
)

__all__ = [
    "MODES", "Sampler", "SolverArtifact", "SolverInfo", "SolverSpec",
    "TrainedSolver", "build_ns", "evaluate_psnr", "get_solver",
    "list_solvers", "ns_at_budget", "reduce_to_ns", "register_solver",
    "save_artifact", "solver_names",
]
