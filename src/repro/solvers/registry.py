"""Solver registry — every named solver as a self-describing entry.

Replaces the ``_GENERIC``/``_EXP`` string sets and the if/elif ladder that
used to live in ``repro.core.bns.solver_to_ns``. Each entry records its
capabilities (family, sigma0-preconditioning support, scheduler dependence,
default grid family) next to a ``build`` function producing the solver's
exact NS parameters (Theorem 3.2), so call sites enumerate solvers by
capability instead of hardcoding name lists.

    @register_solver("euler", family="generic", supports_sigma0=True)
    def _build_euler(nfe, field, *, sigma0=1.0, grid=None): ...

    build_ns("euler", 8, field)            # == old solver_to_ns("euler", ...)
    list_solvers(family="generic")         # capability-filtered enumeration
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

# Submodule imports (not `from repro.core import ...`) keep this module safe
# to import while `repro.core.__init__` is still initializing.
import repro.core.solvers as generic
import repro.core.st_solvers as st_solvers
import repro.core.st_transform as st_transform
from repro.core.exponential import exp_grid, exponential_program
from repro.core.ns_solver import NSParams
from repro.core.parametrization import VelocityField
from repro.core.taxonomy import to_ns

# build(nfe, field, *, sigma0=1.0, grid=None) -> NSParams
BuildFn = Callable[..., NSParams]


@dataclasses.dataclass(frozen=True)
class SolverInfo:
    """A registered solver and its capabilities."""

    name: str
    family: str                 # "generic" | "exponential" | "scale-time"
    build: BuildFn
    supports_sigma0: bool = False   # accepts a sigma0-preconditioned init
    needs_scheduler: bool = False   # grid/coefficients depend on the scheduler
    grid_family: str = "uniform"    # "uniform" | "lambda" (log-SNR)
    evals_per_interval: int = 1
    baseline: bool = False          # include in benchmark baseline sweeps

    def default_grid(self, nfe: int, field: VelocityField):
        if self.grid_family == "lambda":
            return exp_grid(field.scheduler, nfe)
        return generic.grid_for_nfe(
            self.name if self.family == "generic" else "heun", nfe)

    def valid_nfe(self, nfe: int) -> bool:
        return nfe % self.evals_per_interval == 0


_REGISTRY: dict[str, SolverInfo] = {}


def register_solver(
    name: str,
    *,
    family: str,
    supports_sigma0: bool = False,
    needs_scheduler: bool = False,
    grid_family: str = "uniform",
    evals_per_interval: int = 1,
    baseline: bool = False,
) -> Callable[[BuildFn], BuildFn]:
    """Decorator registering ``build(nfe, field, *, sigma0, grid)`` under ``name``."""

    def deco(build: BuildFn) -> BuildFn:
        if name in _REGISTRY:
            raise ValueError(f"solver {name!r} already registered")
        _REGISTRY[name] = SolverInfo(
            name=name, family=family, build=build,
            supports_sigma0=supports_sigma0, needs_scheduler=needs_scheduler,
            grid_family=grid_family, evals_per_interval=evals_per_interval,
            baseline=baseline)
        return build

    return deco


def get_solver(name: str) -> SolverInfo:
    if name not in _REGISTRY:
        raise KeyError(f"unknown solver {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_solvers(
    *,
    family: Optional[str] = None,
    baseline: Optional[bool] = None,
    supports_sigma0: Optional[bool] = None,
) -> list[SolverInfo]:
    """Registered solvers (registration order), filtered by capability."""
    out = []
    for info in _REGISTRY.values():
        if family is not None and info.family != family:
            continue
        if baseline is not None and info.baseline != baseline:
            continue
        if supports_sigma0 is not None and info.supports_sigma0 != supports_sigma0:
            continue
        out.append(info)
    return out


def solver_names(**filters) -> list[str]:
    return [info.name for info in list_solvers(**filters)]


def build_ns(
    name: str,
    nfe: int,
    field: VelocityField,
    *,
    sigma0: float = 1.0,
    grid=None,
) -> NSParams:
    """Build the named solver's exact NS parameters for ``field``.

    The returned parameters sample the ORIGINAL field via Algorithm 1 — any
    sigma0-preconditioning ST transform is absorbed into the coefficients.
    """
    info = get_solver(name)
    if sigma0 != 1.0 and not info.supports_sigma0:
        raise ValueError(
            f"{name!r} does not support sigma0 preconditioning "
            "(precondition exponential solvers via their own scheduler)")
    return info.build(nfe, field, sigma0=sigma0, grid=grid)


# ---------------------------------------------------------------------------
# Built-in solvers
# ---------------------------------------------------------------------------


def _generic_build(name: str) -> BuildFn:
    def build(nfe: int, field: VelocityField, *, sigma0: float = 1.0,
              grid=None) -> NSParams:
        grid = generic.grid_for_nfe(name, nfe) if grid is None else grid
        prog = generic.solver_program(name)
        if sigma0 != 1.0:
            target = st_transform.scaled_sigma(field.scheduler, sigma0)
            st = st_transform.scheduler_change_st(field.scheduler, target)
            return to_ns(st_solvers.st_program(prog, st), grid)
        return to_ns(prog, grid)

    build.__name__ = f"build_{name}"
    return build


for _name in ("euler", "midpoint", "heun", "rk4", "ab2", "ab4"):
    register_solver(
        _name, family="generic", supports_sigma0=True,
        evals_per_interval=generic.evals_per_interval(_name),
        baseline=_name in ("euler", "midpoint"),
    )(_generic_build(_name))
del _name


def _exponential_build(name: str) -> BuildFn:
    # sigma0 support is enforced centrally by build_ns (supports_sigma0=False)
    def build(nfe: int, field: VelocityField, *, sigma0: float = 1.0,
              grid=None) -> NSParams:
        if grid is None:
            grid = exp_grid(field.scheduler, nfe)
        return to_ns(exponential_program(name), grid, field.scheduler)

    build.__name__ = f"build_{name}"
    return build


for _name in ("ddim", "dpm2m"):
    register_solver(
        _name, family="exponential", needs_scheduler=True,
        grid_family="lambda", baseline=True,
    )(_exponential_build(_name))
del _name


@register_solver("edm_heun", family="scale-time", needs_scheduler=True,
                 evals_per_interval=2)
def _build_edm_heun(nfe: int, field: VelocityField, *, sigma0: float = 1.0,
                    grid=None) -> NSParams:
    grid = generic.grid_for_nfe("heun", nfe) if grid is None else grid
    prog = st_solvers.edm_program(generic.heun_program, field.scheduler)
    return to_ns(prog, grid)
