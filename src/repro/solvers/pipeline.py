"""Sampling pipeline — thin jit'd sessions around Algorithm 1.

``Sampler`` binds NS parameters to a velocity field behind one ``jax.jit``
boundary; it is the object serving constructs from a ``SolverArtifact``
(see ``repro.serving.engine.FlowSampler``) and the helper benchmarks use to
score solvers without re-spelling the ``ns_sample``-then-``psnr`` dance.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import ns_solver
from repro.core.bns import psnr
from repro.core.ns_solver import NSParams
from repro.core.parametrization import VelocityField

Array = jax.Array


class Sampler:
    """A jit'd sampling session: ``sampler(x0) -> x1`` at exactly n NFE.

    ``update_fn`` may override the weighted-sum update (e.g. the Pallas
    ``ns_update`` kernel); it is closed over, so it stays static under jit.
    """

    def __init__(self, params: NSParams, field: VelocityField,
                 update_fn: Optional[Callable] = None):
        self.params = params
        self.field = field
        self._sample = jax.jit(
            lambda p, x0: ns_solver.ns_sample(p, field.fn, x0,
                                              update_fn=update_fn))

    @property
    def nfe(self) -> int:
        return self.params.n

    def __call__(self, x0: Array) -> Array:
        return self._sample(self.params, x0)

    def psnr(self, pairs: tuple[Array, Array], max_val: float = 1.0) -> float:
        """Mean PSNR of this sampler against (x0, x1) reference pairs."""
        x0, x1 = pairs
        return float(jnp.mean(psnr(self(x0), x1, max_val)))


def evaluate_psnr(params: NSParams, field: VelocityField,
                  pairs: tuple[Array, Array], max_val: float = 1.0) -> float:
    """One-shot: build a session for ``params`` and score it on ``pairs``."""
    return Sampler(params, field).psnr(pairs, max_val)
