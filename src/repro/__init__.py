"""repro — Bespoke Non-Stationary Solvers (Shaul et al., ICML 2024) as a
production multi-pod JAX framework. See README.md and DESIGN.md."""

__version__ = "1.0.0"
