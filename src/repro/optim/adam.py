"""Pure-JAX Adam/AdamW over arbitrary pytrees (Kingma & Ba 2017).

The paper optimizes BNS solvers with Adam; the model trainer uses AdamW.
State is a pytree-of-pytrees so it shards exactly like the params under pjit.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = object


class AdamState(NamedTuple):
    step: Array
    mu: PyTree
    nu: PyTree


def adam_init(params: PyTree, dtype=jnp.float32) -> AdamState:
    zeros = lambda p: jnp.zeros_like(p, dtype=dtype)
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adam_update(
    grads: PyTree,
    state: AdamState,
    params: PyTree,
    lr: Array | float,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip_norm: float | None = None,
) -> tuple[PyTree, AdamState]:
    """One AdamW step; returns (new_params, new_state)."""
    step = state.step + 1
    if grad_clip_norm is not None:
        leaves = jax.tree.leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
        scale = jnp.minimum(1.0, grad_clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype), state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)),
                      state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            update = update + weight_decay * p.astype(update.dtype)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamState(step=step, mu=mu, nu=nu)


def adamw(lr_fn: Callable[[Array], Array], **kwargs):
    """Closure-style API: returns (init_fn, update_fn) with a LR schedule."""

    def init(params):
        return adam_init(params)

    def update(grads, state, params):
        lr = lr_fn(state.step)
        return adam_update(grads, state, params, lr, **kwargs)

    return init, update
