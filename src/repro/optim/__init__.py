from repro.optim.adam import AdamState, adam_init, adam_update, adamw
from repro.optim.schedule import (
    constant_schedule,
    cosine_annealing,
    poly_decay,
    warmup_cosine,
)

__all__ = [
    "AdamState", "adam_init", "adam_update", "adamw",
    "constant_schedule", "cosine_annealing", "poly_decay", "warmup_cosine",
]
