"""Learning-rate schedules used in the paper's training recipes.

Class-conditional BNS: lr 5e-4 with polynomial decay; T2I/audio BNS: lr 1e-4
with cosine annealing; backbone pretraining: constant or poly-decay + warmup.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def poly_decay(lr: float, total_steps: int, power: float = 1.0, end_lr: float = 0.0):
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return (lr - end_lr) * (1.0 - frac) ** power + end_lr

    return fn


def cosine_annealing(lr: float, total_steps: int, end_lr: float = 0.0):
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return end_lr + 0.5 * (lr - end_lr) * (1.0 + jnp.cos(jnp.pi * frac))

    return fn


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int, end_lr: float = 0.0):
    cos = cosine_annealing(lr, max(total_steps - warmup_steps, 1), end_lr)

    def fn(step):
        step_f = step.astype(jnp.float32)
        warm = lr * step_f / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))

    return fn
