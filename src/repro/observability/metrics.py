"""Dependency-free metrics registry: Counter / Gauge / Histogram.

One ``MetricsRegistry`` per serving tier (``GatewayBase`` owns one;
``FleetGateway`` merges its hosts' snapshots).  Design constraints, in
order:

* **Deterministic.**  The fake-clock benches gate histogram bucket
  counts and interpolated percentiles against committed baselines, so
  every operation is exact integer/float arithmetic — no sampling, no
  reservoir, no decay.
* **Mergeable.**  ``snapshot()`` returns plain dicts of numbers (and
  histogram dicts) that ``merge_snapshots`` can sum across hosts —
  the fleet-wide p95 is computed from the SUMMED buckets, which is
  exact for bucketed histograms (unlike merging percentiles).
* **Cheap under one lock.**  The registry exposes its ``RLock`` so a
  gateway can alias its stats lock to it: a block of handle updates is
  then one atomic multi-metric transaction, and ``snapshot()`` sees a
  consistent cut (counters monotone, histogram count == settled).

Histograms use fixed log-spaced bucket bounds (``DEFAULT_MS_BOUNDS``:
quarter-millisecond lower edge, sqrt(2) growth) so two registries that
never exchanged state still merge exactly, and percentile error is
bounded by one bucket width — the property ``continuous_bench`` gates.
"""
from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Callable, Dict, List, Optional, Sequence, Tuple

# 0.25ms .. ~181s in sqrt(2) steps: 40 bounds + overflow bucket. Wide
# enough for fake-clock waits (ms) and real dispatch legs (s) alike.
DEFAULT_MS_BOUNDS: Tuple[float, ...] = tuple(
    0.25 * 2.0 ** (i / 2.0) for i in range(40))


def _label_key(name: str, labels: Optional[dict]) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone counter. ``inc`` only; never decremented or reset."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Point-in-time value: ``set``/``add``, or a ``set_fn`` callback
    evaluated lazily at snapshot time (used for queue depth / in-flight
    counts that already live on the gateway — no double bookkeeping)."""

    __slots__ = ("value", "fn")

    def __init__(self) -> None:
        self.value = 0.0
        self.fn: Optional[Callable[[], float]] = None

    def set(self, v: float) -> None:
        self.value = v

    def add(self, d: float) -> None:
        self.value += d

    def set_fn(self, fn: Callable[[], float]) -> None:
        self.fn = fn

    def read(self) -> float:
        return float(self.fn()) if self.fn is not None else self.value


class Histogram:
    """Fixed-bound log-bucket histogram.

    ``buckets[i]`` counts observations ``<= bounds[i]`` (exclusive of
    lower buckets); ``buckets[-1]`` is the overflow bucket. Tracks
    ``count``/``sum``/``max`` exactly, so means and maxima are not
    subject to bucketing error — only percentiles are, and those are
    bounded by one bucket width.
    """

    __slots__ = ("bounds", "buckets", "count", "sum", "max")

    def __init__(self, bounds: Sequence[float] = DEFAULT_MS_BOUNDS) -> None:
        self.bounds = tuple(float(b) for b in bounds)
        self.buckets = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.buckets[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if v > self.max:
            self.max = v

    def percentile(self, q: float) -> float:
        return percentile_from_buckets(self.bounds, self.buckets, q,
                                       vmax=self.max)


def percentile_from_buckets(bounds: Sequence[float], buckets: Sequence[int],
                            q: float, vmax: Optional[float] = None) -> float:
    """Interpolated percentile from bucket counts.

    Finds the bucket containing the ``q``-th rank and interpolates
    linearly inside it; the overflow bucket reports ``vmax`` (the exact
    tracked maximum) when available, else the top bound. In-bucket
    interpolation can overshoot the true maximum when the rank lands in
    the max's own bucket, so the result is clamped to ``vmax``.
    """
    total = sum(buckets)
    if total == 0:
        return 0.0
    rank = (q / 100.0) * total
    cum = 0
    for i, c in enumerate(buckets):
        if c and cum + c >= rank:
            if i >= len(bounds):          # overflow bucket
                return float(vmax if vmax is not None else bounds[-1])
            lo = bounds[i - 1] if i > 0 else 0.0
            hi = bounds[i]
            frac = (rank - cum) / c
            val = lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            return min(val, vmax) if vmax is not None else val
        cum += c
    return float(vmax if vmax is not None else bounds[-1])


def bucket_bounds_at(bounds: Sequence[float], buckets: Sequence[int],
                     q: float) -> Tuple[float, float]:
    """(lo, hi) edges of the bucket containing the ``q``-th rank —
    the "one bucket width" the percentile claim is measured against."""
    total = sum(buckets)
    if total == 0:
        return (0.0, 0.0)
    rank = (q / 100.0) * total
    cum = 0
    for i, c in enumerate(buckets):
        if c and cum + c >= rank:
            if i >= len(bounds):
                return (bounds[-1], float("inf"))
            return (bounds[i - 1] if i > 0 else 0.0, bounds[i])
        cum += c
    return (bounds[-1], float("inf"))


class MetricsRegistry:
    """Named metrics behind one re-entrant lock.

    Handles are get-or-create by ``(name, labels)`` and type-checked;
    ``snapshot()`` is a consistent cut of every metric plus a ``_meta``
    map (name -> type/help) that drives the Prometheus exposition.
    """

    def __init__(self) -> None:
        self.lock = threading.RLock()
        self._metrics: Dict[str, object] = {}
        self._meta: Dict[str, Dict[str, str]] = {}

    def _get(self, name: str, help: str, labels: Optional[dict],
             kind: str, factory):
        key = _label_key(name, labels)
        with self.lock:
            m = self._metrics.get(key)
            if m is None:
                m = factory()
                self._metrics[key] = m
                self._meta.setdefault(name, {"type": kind, "help": help})
            elif self._meta.get(name, {}).get("type") != kind:
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{self._meta[name]['type']}, not {kind}")
            return m

    def counter(self, name: str, help: str = "",
                labels: Optional[dict] = None) -> Counter:
        return self._get(name, help, labels, "counter", Counter)

    def gauge(self, name: str, help: str = "",
              labels: Optional[dict] = None) -> Gauge:
        return self._get(name, help, labels, "gauge", Gauge)

    def histogram(self, name: str, help: str = "",
                  labels: Optional[dict] = None,
                  bounds: Sequence[float] = DEFAULT_MS_BOUNDS) -> Histogram:
        return self._get(name, help, labels, "histogram",
                         lambda: Histogram(bounds))

    def snapshot(self) -> dict:
        """Consistent cut: ``{name_or_labelled_key: number | hist-dict}``
        plus ``"_meta"``. Histogram dicts carry bounds + buckets (for
        merging and CI gating) and pre-interpolated p50/p95/p99."""
        out: dict = {}
        with self.lock:
            for key, m in self._metrics.items():
                if isinstance(m, Counter):
                    out[key] = m.value
                elif isinstance(m, Gauge):
                    out[key] = m.read()
                else:
                    out[key] = {
                        "count": m.count,
                        "sum": m.sum,
                        "max": m.max,
                        "bounds": list(m.bounds),
                        "buckets": list(m.buckets),
                        "p50": m.percentile(50.0),
                        "p95": m.percentile(95.0),
                        "p99": m.percentile(99.0),
                    }
            out["_meta"] = {n: dict(v) for n, v in self._meta.items()}
        return out


def merge_snapshots(snaps: Sequence[dict]) -> dict:
    """Merge per-host snapshots: counters/gauges sum, histograms sum
    bucket-wise (exact — bounds must match) with percentiles recomputed
    from the merged buckets. This is how ``FleetGateway`` reports: the
    fleet registry IS the merge of its hosts' registries."""
    out: dict = {"_meta": {}}
    for snap in snaps:
        for key, v in snap.items():
            if key == "_meta":
                out["_meta"].update(v)
                continue
            if isinstance(v, dict):      # histogram
                cur = out.get(key)
                if cur is None:
                    out[key] = {k: (list(x) if isinstance(x, list) else x)
                                for k, x in v.items()}
                    continue
                if list(cur["bounds"]) != list(v["bounds"]):
                    raise ValueError(f"histogram {key!r}: bounds differ, "
                                     f"cannot merge exactly")
                cur["count"] += v["count"]
                cur["sum"] += v["sum"]
                cur["max"] = max(cur["max"], v["max"])
                cur["buckets"] = [a + b for a, b in
                                  zip(cur["buckets"], v["buckets"])]
            else:
                out[key] = out.get(key, 0) + v
    for key, v in out.items():
        if key != "_meta" and isinstance(v, dict):
            for q in (50.0, 95.0, 99.0):
                v[f"p{int(q)}"] = percentile_from_buckets(
                    v["bounds"], v["buckets"], q, vmax=v["max"])
    return out


def _prom_escape(s: str) -> str:
    return s.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def to_prometheus(snapshot: dict, prefix: str = "repro") -> str:
    """Prometheus text exposition (v0.0.4) of a ``snapshot()`` dict."""
    meta = snapshot.get("_meta", {})
    lines: List[str] = []
    seen_header = set()

    def header(name: str, kind: str) -> None:
        if name in seen_header:
            return
        seen_header.add(name)
        info = meta.get(name, {})
        if info.get("help"):
            lines.append(f"# HELP {prefix}_{name} "
                         f"{_prom_escape(info['help'])}")
        lines.append(f"# TYPE {prefix}_{name} {kind}")

    for key in sorted(k for k in snapshot if k != "_meta"):
        v = snapshot[key]
        brace = key.find("{")
        name = key if brace < 0 else key[:brace]
        labels = "" if brace < 0 else key[brace:]
        kind = meta.get(name, {}).get("type", "untyped")
        header(name, kind)
        if isinstance(v, dict):
            inner = labels[1:-1] if labels else ""
            sep = "," if inner else ""
            cum = 0
            for bound, c in zip(v["bounds"], v["buckets"]):
                cum += c
                lines.append(f'{prefix}_{name}_bucket{{{inner}{sep}'
                             f'le="{bound:g}"}} {cum}')
            lines.append(f'{prefix}_{name}_bucket{{{inner}{sep}'
                         f'le="+Inf"}} {v["count"]}')
            lines.append(f"{prefix}_{name}_sum{labels} {v['sum']:g}")
            lines.append(f"{prefix}_{name}_count{labels} {v['count']}")
        else:
            lines.append(f"{prefix}_{name}{labels} {v:g}")
    return "\n".join(lines) + "\n"
