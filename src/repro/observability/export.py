"""Export surfaces: one stats-line formatter, a periodic printer, and
an HTTP endpoint serving Prometheus text + JSON snapshots.

``format_stats_line`` is THE formatter — serve.py's four per-mode stats
print blocks (gateway / continuous / decode / fleet) are all this one
function; the tier-specific segments switch on keys the compatibility
projection only emits for the tiers that have them (``trajectories``,
``tokens_out``, ``page_size``, ``hosts``).
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional

from repro.observability.metrics import to_prometheus


def format_stats_line(s: dict, prefix: str = "stats") -> str:
    """One line for any tier's ``stats()`` dict."""
    g = s.get
    parts = [
        f"{prefix}: done={g('completed', 0)}/{g('submitted', 0)}"
        f" q={g('queue_depth', 0)}"
        f" batches={g('batches', 0)}"
        f" mixed={g('mixed_batches', 0)}"
        f" forwards={g('forwards', 0)}"
        f" nfe/req={g('nfe_per_request', 0.0):.2f}"
        f" occ={g('occupancy', 0.0):.2f}"
        f" wait p50/p95/max="
        f"{g('wait_p50_ms', 0.0):.1f}/{g('wait_p95_ms', 0.0):.1f}"
        f"/{g('max_wait_ms', 0.0):.1f}ms"
        f" rps={g('throughput_rps', 0.0):.1f}"
    ]
    if g("trajectories", 0) and not g("tokens_out", 0):
        # the decode segment below already carries slot_occ/joins
        parts.append(
            f"traj={s['trajectories']} legs={g('legs', 0)}"
            f" joins={g('joins', 0)} join_rate={g('join_rate', 0.0):.2f}"
            f" slot_occ={g('slot_occupancy', 0.0):.2f}")
    if g("tokens_out", 0):
        parts.append(
            f"tokens={s['tokens_out']} tok/s={g('tokens_per_s', 0.0):.1f}"
            f" slot_occ={g('slot_occupancy', 0.0):.2f}"
            f" joins={g('joins', 0)} prefill={g('prefill_calls', 0)}"
            f" cancelled={g('cancelled', 0)}")
    if "page_size" in s:
        parts.append(
            f"paged page_size={s['page_size']}"
            f" pages={g('pages_in_use', 0)}/{g('peak_pages', 0)} peak"
            f" kv/slot={g('peak_kv_per_slot', 0.0):.1f}")
    if "hosts" in s:
        routed = s.get("routed", {})
        routed_txt = " ".join(f"{h}={n}" for h, n in sorted(routed.items()))
        parts.append(
            f"fleet hosts={s['hosts']} steals={g('steals', 0)}"
            f" rounds={g('steal_rounds', 0)} rerouted={g('rerouted', 0)}"
            + (f" routed: {routed_txt}" if routed_txt else ""))
    return " | ".join(parts)


class StatsPrinter:
    """Daemon thread printing ``line_fn()`` every ``interval_s``.

    ``serve.py --stats-interval N`` wires this around the traffic loop
    for every mode; it never prints concurrently with ``stop()``'s
    final flush.
    """

    def __init__(self, line_fn: Callable[[], str], interval_s: float,
                 log: Callable[[str], None] = print) -> None:
        self.line_fn = line_fn
        self.interval_s = max(float(interval_s), 1e-3)
        self.log = log
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "StatsPrinter":
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="stats-printer")
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.log(self.line_fn())
            except Exception as exc:           # keep serving regardless
                self.log(f"stats-printer error: {exc!r}")

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None


class MetricsServer:
    """Minimal stdlib HTTP endpoint: ``/metrics`` (Prometheus text
    exposition) and ``/metrics.json`` (raw snapshot). ``port=0`` binds
    an ephemeral port (``.port`` has the real one) — used by tests."""

    def __init__(self, snapshot_fn: Callable[[], dict], port: int = 0,
                 host: str = "127.0.0.1") -> None:
        self.snapshot_fn = snapshot_fn

        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self) -> None:
                try:
                    snap = outer.snapshot_fn()
                    if self.path.startswith("/metrics.json"):
                        body = json.dumps(snap, indent=2).encode()
                        ctype = "application/json"
                    elif self.path.startswith("/metrics"):
                        body = to_prometheus(snap).encode()
                        ctype = "text/plain; version=0.0.4"
                    else:
                        self.send_error(404)
                        return
                except Exception as exc:
                    self.send_error(500, repr(exc))
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a) -> None:   # no per-scrape stderr spam
                pass

        self._server = ThreadingHTTPServer((host, port), Handler)
        self.port = self._server.server_address[1]
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "MetricsServer":
        self._thread = threading.Thread(
            target=self._server.serve_forever, daemon=True,
            name="metrics-server")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
