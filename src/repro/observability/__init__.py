"""repro.observability — serving telemetry: metrics, tracing, export.

Dependency-free (stdlib only; jax is touched lazily and optionally).
Three pieces, consumed by every serving tier:

* ``metrics`` — ``Counter``/``Gauge``/``Histogram`` behind a
  ``MetricsRegistry``; deterministic fixed-log-bucket histograms with
  interpolated p50/p95/p99, exact cross-host merging
  (``merge_snapshots``), Prometheus text exposition
  (``to_prometheus``).
* ``trace`` — ``TraceRecorder`` bounded ring of per-request lifecycle
  events (submit -> route -> steal -> dispatch -> settle) with JSONL
  export; ``NULL_RECORDER`` is the allocation-free disabled path.
* ``export`` — ``format_stats_line`` (the ONE stats-line formatter all
  serve.py modes share), ``StatsPrinter`` (periodic line), and
  ``MetricsServer`` (``/metrics`` + ``/metrics.json`` over stdlib
  http.server).

``profile_span(name)`` wraps device-dispatch legs in a
``jax.profiler.TraceAnnotation`` when jax is importable (so gateway
dispatches show up named in a profiler trace) and degrades to a
null context otherwise — the registry itself never imports jax.
"""
from __future__ import annotations

import contextlib

from repro.observability.export import (
    MetricsServer,
    StatsPrinter,
    format_stats_line,
)
from repro.observability.metrics import (
    DEFAULT_MS_BOUNDS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    bucket_bounds_at,
    merge_snapshots,
    percentile_from_buckets,
    to_prometheus,
)
from repro.observability.trace import (
    NULL_RECORDER,
    NullRecorder,
    TraceRecorder,
    read_jsonl,
)

_PROFILE_FACTORY = None


def profile_span(name: str):
    """Context manager naming a dispatch leg in a jax profiler trace;
    a null context when jax (or its profiler) is unavailable."""
    global _PROFILE_FACTORY
    if _PROFILE_FACTORY is None:
        try:
            from jax.profiler import TraceAnnotation
            _PROFILE_FACTORY = TraceAnnotation
        except Exception:
            _PROFILE_FACTORY = lambda _name: contextlib.nullcontext()
    return _PROFILE_FACTORY(name)


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_MS_BOUNDS", "merge_snapshots",
           "percentile_from_buckets", "bucket_bounds_at", "to_prometheus",
           "TraceRecorder", "NullRecorder", "NULL_RECORDER", "read_jsonl",
           "MetricsServer", "StatsPrinter", "format_stats_line",
           "profile_span"]
