"""Per-request tracing: a bounded ring of lifecycle events.

Every gateway tier stamps events against the request uid (uids are
fleet-global after ``federate``, so one recorder shared across hosts
reconstructs a stolen request hop-by-hop: submit -> route -> steal ->
inject -> dispatch -> settle).

The disabled path is ``NULL_RECORDER``: falsy, every method a no-op.
Hot paths are written as::

    rec = self.recorder
    if rec:
        rec.event(uid, "dispatch", t, host=self._host)

so with tracing off the cost is one attribute read and one truth test —
no argument tuples, no dict building, zero allocations.
"""
from __future__ import annotations

import collections
import json
import threading
from typing import Dict, List, Optional


class NullRecorder:
    """Disabled recorder: falsy, allocation-free no-ops."""

    enabled = False

    def __bool__(self) -> bool:
        return False

    def event(self, uid, name, t, host="", **data) -> None:
        pass

    def trace(self, uid) -> list:
        return []

    def events(self) -> list:
        return []

    def open_spans(self) -> dict:
        return {}

    def export_jsonl(self, path) -> int:
        return 0


NULL_RECORDER = NullRecorder()


class TraceRecorder:
    """Bounded ring buffer of ``(t, uid, host, event, data)`` tuples.

    ``capacity`` bounds memory: the oldest events fall off first, so a
    long-running server keeps the most recent requests reconstructable
    without ever growing. All methods are thread-safe; ``event`` is a
    single locked deque append on the hot path.
    """

    enabled = True

    def __init__(self, capacity: int = 4096) -> None:
        self.capacity = capacity
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self._lock = threading.Lock()

    def __bool__(self) -> bool:
        return True

    def event(self, uid: int, name: str, t: float, host: str = "",
              **data) -> None:
        with self._lock:
            self._ring.append((t, uid, host, name, data or None))

    @staticmethod
    def _as_dict(ev) -> dict:
        t, uid, host, name, data = ev
        d = {"t": t, "uid": uid, "host": host, "event": name}
        if data:
            d.update(data)
        return d

    def events(self) -> List[dict]:
        """All retained events, oldest first."""
        with self._lock:
            ring = list(self._ring)
        return [self._as_dict(ev) for ev in ring]

    def trace(self, uid: int) -> List[dict]:
        """The retained lifecycle of one request, oldest first."""
        with self._lock:
            ring = list(self._ring)
        return [self._as_dict(ev) for ev in ring if ev[1] == uid]

    def open_spans(self) -> Dict[int, List[dict]]:
        """Events of requests that have not settled — what a hung drain
        was still waiting on (attached to ``DrainTimeout``)."""
        by_uid: Dict[int, List[dict]] = {}
        settled = set()
        for d in self.events():
            by_uid.setdefault(d["uid"], []).append(d)
            if d["event"] == "settle":
                settled.add(d["uid"])
        return {uid: evs for uid, evs in by_uid.items()
                if uid not in settled}

    def export_jsonl(self, path: str,
                     uid: Optional[int] = None) -> int:
        """Write retained events (optionally one uid's) as JSON lines;
        returns the number of lines written."""
        events = self.trace(uid) if uid is not None else self.events()
        with open(path, "w") as f:
            for d in events:
                f.write(json.dumps(d, sort_keys=True) + "\n")
        return len(events)


def read_jsonl(path: str) -> List[dict]:
    """Load an ``export_jsonl`` file back into event dicts."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
