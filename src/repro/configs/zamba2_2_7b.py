"""Zamba2-2.7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
import dataclasses
from repro.configs.base import ModelConfig, SSMConfig

CITATION = "arXiv:2411.15242 (Zamba2 suite)"


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000,
        hybrid_attn_every=6, sliding_window=8192,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=64),
        citation=CITATION)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=256, hybrid_attn_every=2,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=16),
        dtype="float32")
