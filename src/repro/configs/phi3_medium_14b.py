"""Phi-3-medium (14B) — dense, RoPE + SwiGLU + GQA [arXiv:2404.14219]."""
import dataclasses
from repro.configs.base import ModelConfig

CITATION = "arXiv:2404.14219 (Phi-3 Technical Report)"


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b", family="dense", n_layers=40, d_model=5120,
        n_heads=40, n_kv_heads=10, d_ff=17920, vocab=100352, head_dim=128,
        rope_theta=10_000.0, sliding_window=8192, citation=CITATION)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=320, n_heads=10, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab=256, dtype="float32")
