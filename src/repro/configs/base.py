"""Config system: one dataclass describes every assigned architecture.

Every ``src/repro/configs/<id>.py`` exports ``config()`` (the exact published
configuration, cited) and ``smoke_config()`` (a reduced same-family variant
for CPU tests: <=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    router_impl: str = "scatter"   # "scatter" (memory-lean) | "onehot" (reference)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64              # Mamba2 state size per head-channel
    d_conv: int = 4                # causal conv width
    expand: int = 2                # d_inner = expand * d_model
    head_dim: int = 64             # Mamba2 head dim
    chunk: int = 64                # chunked-scan block length


@dataclasses.dataclass(frozen=True)
class FrontendStub:
    """Stubbed modality frontend: input_specs() provides these embeddings."""

    kind: str                      # "audio_frames" | "vision_patches"
    num_tokens: int                # e.g. 1500 mel frames / 256 patches
    embed_dim: int                 # dim of provided embeddings


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    parallel_block: bool = False   # command-r style parallel attn+FFN
    qk_norm: bool = False
    sliding_window: int = 0        # 0 = full attention; >0 enables long_500k decode
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): shared attention block applied every k SSM layers
    hybrid_attn_every: int = 0
    # enc-dec (whisper): encoder depth + frontend stub
    n_encoder_layers: int = 0
    frontend: Optional[FrontendStub] = None
    # flow mode (the paper's generative substrate)
    latent_dim: int = 64
    dtype: str = "bfloat16"
    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline."""
        d, ff, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.resolved_head_dim
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + hd * self.n_heads * d
        if self.family == "moe":
            assert self.moe is not None
            mlp = 3 * d * self.moe.d_expert * self.moe.num_experts + d * self.moe.num_experts
        else:
            mlp = 3 * d * ff
        if self.family == "ssm":      # rwkv6: time-mix + channel-mix
            attn = 4 * d * d + d * d  # r,k,v,g,o projections (approx)
            mlp = 2 * d * ff + ff * 0 + d * ff
        if self.family == "hybrid":
            assert self.ssm is not None
            di = self.ssm.expand * d
            attn = 0  # shared block counted once below
            mlp = 2 * d * di + di * d + di * d  # in/out/gate approx
        block = attn + mlp + 2 * d
        total = v * d + L * block + d
        if self.family == "hybrid":
            total += 4 * d * d + 3 * d * ff  # the single shared attention block
        if not self.tie_embeddings:
            total += v * d
        return int(total)


def active_param_count(cfg: ModelConfig) -> int:
    """MoE: parameters touched per token (top-k of experts)."""
    if cfg.family != "moe" or cfg.moe is None:
        return cfg.param_count()
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.resolved_head_dim
    attn = d * hd * cfg.n_heads + 2 * d * hd * cfg.n_kv_heads + hd * cfg.n_heads * d
    mlp_active = 3 * d * cfg.moe.d_expert * cfg.moe.top_k
    total = cfg.vocab * d * 2 + L * (attn + mlp_active + 2 * d) + d
    return int(total)
