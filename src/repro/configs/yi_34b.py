"""Yi-34B — llama-architecture dense LM with GQA [arXiv:2403.04652]."""
import dataclasses
from repro.configs.base import ModelConfig

CITATION = "arXiv:2403.04652 (Yi: Open Foundation Models by 01.AI)"


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense", n_layers=60, d_model=7168, n_heads=56,
        n_kv_heads=8, d_ff=20480, vocab=64000, head_dim=128,
        rope_theta=5_000_000.0, sliding_window=8192, citation=CITATION)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab=256, dtype="float32")
