"""Command-R 35B — dense GQA, parallel attn+FFN block, no biases
[hf:CohereForAI/c4ai-command-r-v01]."""
import dataclasses
from repro.configs.base import ModelConfig

CITATION = "hf:CohereForAI/c4ai-command-r-v01 (model card)"


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", family="dense", n_layers=40, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=22528, vocab=256000, head_dim=128,
        rope_theta=8_000_000.0, parallel_block=True, tie_embeddings=True,
        sliding_window=8192, citation=CITATION)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab=256, dtype="float32")
