"""Whisper-medium — enc-dec audio backbone; conv/mel frontend stubbed
[arXiv:2212.04356]."""
import dataclasses
from repro.configs.base import FrontendStub, ModelConfig

CITATION = "arXiv:2212.04356 (Whisper: Robust Speech Recognition)"


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="encdec", n_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=16, d_ff=4096, vocab=51865, head_dim=64,
        n_encoder_layers=24, tie_embeddings=True,
        frontend=FrontendStub(kind="audio_frames", num_tokens=1500,
                              embed_dim=1024),
        citation=CITATION)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, n_encoder_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=256, vocab=256,
        frontend=FrontendStub(kind="audio_frames", num_tokens=16, embed_dim=128),
        dtype="float32")
