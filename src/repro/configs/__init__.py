"""Architecture registry: --arch <id> resolves here."""
import importlib

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, FrontendStub

ARCHS = (
    "yi-6b",
    "phi3-medium-14b",
    "command-r-35b",
    "zamba2-2.7b",
    "yi-34b",
    "whisper-medium",
    "qwen3-moe-235b-a22b",
    "qwen3-moe-30b-a3b",
    "rwkv6-7b",
    "internvl2-26b",
)


def _module(arch: str):
    return importlib.import_module("repro.configs." + arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; have {ARCHS}")
    mod = _module(arch)
    return mod.smoke_config() if smoke else mod.config()


__all__ = ["ARCHS", "get_config", "ModelConfig", "MoEConfig", "SSMConfig",
           "FrontendStub"]
