"""Qwen3-MoE 235B-A22B — 128 experts, top-8, GQA + qk-norm
[hf:Qwen/Qwen3-30B-A3B scaled per pool spec]."""
import dataclasses
from repro.configs.base import ModelConfig, MoEConfig

CITATION = "hf:Qwen/Qwen3-30B-A3B (Qwen3 MoE family model card)"


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
        n_heads=64, n_kv_heads=4, d_ff=1536, vocab=151936, head_dim=128,
        rope_theta=1_000_000.0, qk_norm=True, sliding_window=8192,
        moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536,
                      capacity_factor=1.0, router_impl="scatter"),
        citation=CITATION)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=64, vocab=256,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      capacity_factor=1.25, router_impl="scatter"),
        dtype="float32")
