"""InternVL2-26B — InternViT (stub) + InternLM2-style LM [arXiv:2404.16821]."""
import dataclasses
from repro.configs.base import FrontendStub, ModelConfig

CITATION = "arXiv:2404.16821 (InternVL 1.5/2 family)"


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=16384, vocab=92553, head_dim=128,
        rope_theta=1_000_000.0, sliding_window=8192,
        frontend=FrontendStub(kind="vision_patches", num_tokens=256,
                              embed_dim=3200),
        citation=CITATION)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab=256,
        frontend=FrontendStub(kind="vision_patches", num_tokens=8, embed_dim=64),
        dtype="float32")
