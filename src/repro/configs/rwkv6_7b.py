"""RWKV6-7B "Finch" — attention-free, data-dependent decay [arXiv:2404.05892]."""
import dataclasses
from repro.configs.base import ModelConfig, SSMConfig

CITATION = "arXiv:2404.05892 (Eagle and Finch: RWKV-5/6)"


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm", n_layers=32, d_model=4096,
        n_heads=64, n_kv_heads=64, d_ff=14336, vocab=65536,
        ssm=SSMConfig(chunk=64), citation=CITATION)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, d_ff=256,
        vocab=256, ssm=SSMConfig(chunk=16), dtype="float32")
