"""Msgpack pytree checkpointer (no orbax in the environment).

Stores arrays as raw bytes with dtype/shape metadata; the tree structure is
serialized as nested dicts/lists keyed by path. Restores onto the template's
treedef, so NamedTuples and custom nodes round-trip.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x) -> dict:
    arr = np.asarray(x)
    return {b"dtype": arr.dtype.str.encode(), b"shape": list(arr.shape),
            b"data": arr.tobytes()}


def _unpack_leaf(d: dict) -> np.ndarray:
    return np.frombuffer(d[b"data"], dtype=np.dtype(d[b"dtype"].decode())) \
        .reshape(d[b"shape"])


def save(path: str, tree: Any, meta: dict | None = None) -> None:
    """Save a pytree; ``meta`` (JSON-serializable) rides along if given."""
    leaves = jax.tree.leaves(tree)
    payload = {b"leaves": [_pack_leaf(l) for l in leaves]}
    if meta is not None:
        payload[b"meta"] = json.dumps(meta).encode()
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload))
    os.replace(tmp, path)


def restore(path: str, template: Any) -> Any:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read())
    leaves = [jnp.asarray(_unpack_leaf(d)) for d in payload[b"leaves"]]
    treedef = jax.tree.structure(template)
    t_leaves = jax.tree.leaves(template)
    assert len(leaves) == len(t_leaves), (
        f"checkpoint has {len(leaves)} leaves, template {len(t_leaves)}")
    leaves = [l.astype(t.dtype) for l, t in zip(leaves, t_leaves)]
    return jax.tree.unflatten(treedef, leaves)


def load_meta(path: str) -> dict | None:
    """Read only the JSON metadata written by ``save(..., meta=...)``."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read())
    raw = payload.get(b"meta")
    return None if raw is None else json.loads(raw.decode())


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(f.split("_")[1].split(".")[0]) for f in os.listdir(ckpt_dir)
             if f.startswith("step_") and f.endswith(".msgpack")]
    return max(steps) if steps else None


def step_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step}.msgpack")
