"""Scale-Time (ST) transformations and post-training scheduler changes.

Implements eqs. 6-8 and the preconditioning of eq. 14 of the paper:

    x_bar(r) = s_r x(t_r)                                   (eq. 6)
    u_bar_r(x) = (s'_r / s_r) x + t'_r s_r u_{t_r}(x / s_r)  (eq. 7)

For strictly-monotone SnR, ST transforms are 1-1 with scheduler changes
(alpha, sigma) -> (alpha_bar, sigma_bar) via

    t_r = snr^{-1}(snr_bar(r)),   s_r = sigma_bar_r / sigma_{t_r}   (eq. 8)

The time/scale functions are built as differentiable closures so that the
derivatives in eq. 7 come from jax.jvp — no hand-derived formulas, and the
whole transformed field remains jit/grad-compatible (BNS backprops through it).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.parametrization import VelocityField
from repro.core.schedulers import Scheduler, _d, scaled_sigma

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class STTransform:
    """A Scale-Time transformation (s_r, t_r), r in [0, 1].

    ``t(0)=0, t(1)=1, s_0, s_1 > 0``. ``s1`` is exposed so callers can recover
    original samples: x(1) = x_bar(1) / s_1.
    """

    t: Callable[[Array], Array]
    s: Callable[[Array], Array]

    def dt(self, r: Array) -> Array:
        return _d(self.t, r)

    def ds(self, r: Array) -> Array:
        return _d(self.s, r)

    @property
    def s1(self) -> Array:
        return self.s(jnp.asarray(1.0))

    @property
    def s0(self) -> Array:
        return self.s(jnp.asarray(0.0))


def identity_st() -> STTransform:
    return STTransform(t=lambda r: r, s=lambda r: jnp.ones_like(r))


def scheduler_change_st(source: Scheduler, target: Scheduler) -> STTransform:
    """ST transform realizing a scheduler change source -> target (eq. 8)."""

    def t_of_r(r: Array) -> Array:
        r = source.clip_t(r)
        return source.snr_inverse(target.snr(r))

    def s_of_r(r: Array) -> Array:
        r = source.clip_t(r)
        return target.sigma(r) / source.sigma(t_of_r(r))

    return STTransform(t=t_of_r, s=s_of_r)


def transformed_field(u: VelocityField, st: STTransform) -> VelocityField:
    """The transformed velocity u_bar generating the ST-transformed paths (eq. 7)."""

    def u_bar(r: Array, x: Array) -> Array:
        s, ds, t, dt = st.s(r), st.ds(r), st.t(r), st.dt(r)
        return (ds / s) * x + dt * s * u.fn(t, x / s)

    # The transformed path's scheduler is (s_r alpha_{t_r}, s_r sigma_{t_r}).
    bar_sched = Scheduler(
        name=f"{u.scheduler.name}_st",
        alpha=lambda r: st.s(r) * u.scheduler.alpha(st.t(r)),
        sigma=lambda r: st.s(r) * u.scheduler.sigma(st.t(r)),
        # snr_bar(r) = snr(t_r); inverse(v) = r with t_r = snr^{-1}(v).
        snr_inverse=lambda v: _invert_monotone(
            lambda r: st.s(r) * u.scheduler.alpha(st.t(r))
            / (st.s(r) * u.scheduler.sigma(st.t(r))),
            v,
        ),
    )
    return VelocityField(fn=u_bar, scheduler=bar_sched)


def precondition(u: VelocityField, sigma0: float) -> tuple[VelocityField, STTransform]:
    """Paper eq. 14 preconditioning: move to sigma_bar = sigma0 * sigma.

    Returns the preconditioned field u_bar and the ST transform used, so the
    sampler can (a) draw x_bar(0) ~ N(0, sigma0^2 sigma_0^2) = s_0-scaled
    source, and (b) unscale final samples by 1/s_1.
    """
    target = scaled_sigma(u.scheduler, sigma0)
    st = scheduler_change_st(u.scheduler, target)
    return transformed_field(u, st), st


def _invert_monotone(fn: Callable[[Array], Array], v: Array, iters: int = 63) -> Array:
    """Bisection inverse of a strictly increasing fn on [0, 1] (jit-safe)."""
    lo = jnp.zeros_like(v)
    hi = jnp.ones_like(v)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        below = fn(mid) < v
        return jnp.where(below, mid, lo), jnp.where(below, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)
