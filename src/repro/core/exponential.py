"""Exponential-integrator solvers: DDIM and DPM-Solver++ (Sec. 3.3.2).

Written as taxonomy programs, so each converts to NS parameters exactly.

The model's x-prediction is recovered from the velocity field through the
Table-1 relation ``u = beta x + gamma x_hat`` (exact for Gaussian paths), so
these solvers work with *any* parametrization once wrapped as a velocity
field. Coefficients are computed in the algebraically-stable form
``sigma_{i+1} e^{lambda} -> alpha`` so nothing overflows near t = 1.
"""
from __future__ import annotations

import jax.numpy as jnp

import numpy as np

from repro.core.parametrization import X_PRED, beta_gamma
from repro.core.schedulers import Scheduler


def exp_grid(
    sched: Scheduler, nfe: int, t_min: float = 1e-3, t_max: float = 1.0 - 1e-3
) -> np.ndarray:
    """Uniform-in-lambda (log-SNR) time grid — the standard grid for
    exponential integrators (Lu et al. 2022). Ends at t_max < 1 (sigma_min>0),
    matching practice; step sizes h_i are then equal and bounded."""
    lam = jnp.linspace(sched.lam(jnp.asarray(t_min)), sched.lam(jnp.asarray(t_max)),
                       nfe + 1)
    ts = sched.snr_inverse(jnp.exp(lam))
    grid = np.asarray(ts, dtype=np.float64)
    grid[0], grid[-1] = t_min, t_max
    return grid


def _xhat(be, sched: Scheduler, t, x, u):
    """Invert Table 1: x_hat = (u - beta x) / gamma at (clipped) time t."""
    tc = sched.clip_t(jnp.asarray(t))
    beta, gamma = beta_gamma(sched, X_PRED, tc)
    return be.combine([(1.0 / gamma, u), (-beta / gamma, x)])


def ddim_program(be, grid, sched: Scheduler) -> None:
    """DDIM (Song et al. 2022) == first-order exponential integrator.

    x_{i+1} = (sigma_{i+1}/sigma_i) x_i + (alpha_{i+1} - sigma_{i+1} snr_i) x_hat_i
    """
    x = be.initial()
    for i in range(len(grid) - 1):
        t, tn = jnp.asarray(grid[i]), jnp.asarray(grid[i + 1])
        a_i, s_i = sched.alpha(sched.clip_t(t)), sched.sigma(sched.clip_t(t))
        a_n, s_n = sched.alpha(tn), sched.sigma(tn)
        u = be.eval_u(t, x)
        xh = _xhat(be, sched, t, x, u)
        x = be.combine([(s_n / s_i, x), (a_n - s_n * a_i / s_i, xh)])
    be.finalize(x)


def dpm2m_program(be, grid, sched: Scheduler, exact: bool = False) -> None:
    """DPM-Solver++(2M) (Lu et al. 2022b): 2nd-order multistep in lambda-space.

      x_{i+1} = (sig_{i+1}/sig_i) x_i + sig_{i+1} I0 * D_i
      D_i = x_hat_i + (h_i / (2 h_{i-1})) (x_hat_i - x_hat_{i-1})      (Lu et al.)
      sig_{i+1} I0 = alpha_{i+1} (1 - e^{-h_i})   [stable form]

    ``exact=True`` instead integrates the linear extrapolation exactly:
      I1 = e^{lam_{i+1}} (h - 1) + e^{lam_i} replaces the midpoint rule.
    First step falls back to DDIM (no history). Use with ``exp_grid``.
    """
    x = be.initial()
    prev = None  # (lam_prev, xhat_prev)
    for i in range(len(grid) - 1):
        t, tn = jnp.asarray(grid[i]), jnp.asarray(grid[i + 1])
        tc, tnc = sched.clip_t(t), sched.clip_t(tn)
        s_i = sched.sigma(tc)
        a_n, s_n = sched.alpha(tnc), sched.sigma(tnc)
        lam_i, lam_n = sched.lam(tc), sched.lam(tnc)
        snr_i = sched.snr(tc)
        h = lam_n - lam_i

        u = be.eval_u(t, x)
        xh = _xhat(be, sched, t, x, u)

        # sigma_{i+1} I0 = alpha_{i+1} - sigma_{i+1} snr_i = alpha_{i+1}(1 - e^{-h})
        sI0 = a_n - s_n * snr_i
        terms = [(s_n / s_i, x)]
        if prev is None:
            terms.append((sI0, xh))
        else:
            lam_p, xh_p = prev
            r = lam_i - lam_p
            if exact:
                # sigma_{i+1} I1 = alpha_{i+1} (h - 1) + sigma_{i+1} snr_i
                c = (a_n * (h - 1.0) + s_n * snr_i) / r
            else:
                c = sI0 * h / (2.0 * r)
            terms += [(sI0 + c, xh), (-c, xh_p)]
        x = be.combine(terms)
        prev = (lam_i, xh)
    be.finalize(x)


def exponential_program(name: str):
    progs = {"ddim": ddim_program, "dpm2m": dpm2m_program}
    if name not in progs:
        raise KeyError(f"unknown exponential solver {name!r}; have {sorted(progs)}")
    return progs[name]
