"""Adaptive Dormand-Prince RK45 (Shampine 1986) — the ground-truth sampler.

The paper generates its BNS training/validation pairs (x0, x(1)) with
adaptive RK45 and reports PSNR against them. Implemented with
``lax.while_loop`` so GT generation is jit-able and batchable; step-size
control is the standard PI-free accept/reject with error order 5.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

# Dormand-Prince Butcher tableau (DOPRI5).
_C = jnp.array([0.0, 1 / 5, 3 / 10, 4 / 5, 8 / 9, 1.0, 1.0])
_A = jnp.array([
    [0, 0, 0, 0, 0, 0],
    [1 / 5, 0, 0, 0, 0, 0],
    [3 / 40, 9 / 40, 0, 0, 0, 0],
    [44 / 45, -56 / 15, 32 / 9, 0, 0, 0],
    [19372 / 6561, -25360 / 2187, 64448 / 6561, -212 / 729, 0, 0],
    [9017 / 3168, -355 / 33, 46732 / 5247, 49 / 176, -5103 / 18656, 0],
])
_B5 = jnp.array([35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0.0])
_B4 = jnp.array([5179 / 57600, 0.0, 7571 / 16695, 393 / 640,
                 -92097 / 339200, 187 / 2100, 1 / 40])


class RK45Result(NamedTuple):
    x1: Array
    nfe: Array
    accepted: Array
    rejected: Array


def rk45_solve(
    u_fn: Callable[[Array, Array], Array],
    x0: Array,
    *,
    t0: float = 0.0,
    t1: float = 1.0,
    rtol: float = 1e-5,
    atol: float = 1e-5,
    h0: float = 0.01,
    max_steps: int = 10_000,
) -> RK45Result:
    """Integrate dx/dt = u(t, x) from t0 to t1 adaptively.

    ``u_fn`` must accept a scalar t and a full (batched) state; error control
    uses the max norm over the whole state so every batch element meets tol
    (conservative — matches 'high-accuracy GT' use).
    """

    def rk_step(t, x, h):
        ks = []
        for i in range(7):
            if i == 0:
                xi = x
            else:
                acc = ks[0] * _A[i - 1, 0]
                for j in range(1, i):
                    acc = acc + ks[j] * _A[i - 1, j]
                xi = x + h * acc
            if i < 6:
                ks.append(u_fn(t + h * _C[i], xi))
            else:
                # FSAL stage evaluated at t+h with 5th-order solution.
                x5 = x + h * sum(ks[j] * _B5[j] for j in range(6))
                ks.append(u_fn(t + h, x5))
        x5 = x + h * sum(ks[j] * _B5[j] for j in range(7))
        x4 = x + h * sum(ks[j] * _B4[j] for j in range(7))
        return x5, x4

    def cond(state):
        t, x, h, nfe, acc, rej, steps = state
        return (t < t1 - 1e-12) & (steps < max_steps)

    def body(state):
        t, x, h, nfe, acc, rej, steps = state
        h = jnp.minimum(h, t1 - t)
        x5, x4 = rk_step(t, x, h)
        scale = atol + rtol * jnp.maximum(jnp.abs(x), jnp.abs(x5))
        err = jnp.sqrt(jnp.mean(((x5 - x4) / scale) ** 2))
        accept = err <= 1.0
        factor = jnp.clip(0.9 * (1.0 / jnp.maximum(err, 1e-12)) ** 0.2, 0.2, 5.0)
        h_new = h * factor
        t = jnp.where(accept, t + h, t)
        x = jnp.where(accept, x5, x)
        return (t, x, jnp.maximum(h_new, 1e-8), nfe + 7,
                acc + accept.astype(jnp.int32),
                rej + (1 - accept.astype(jnp.int32)), steps + 1)

    state = (jnp.asarray(t0), x0, jnp.asarray(h0),
             jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32),
             jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32))
    t, x, h, nfe, acc, rej, steps = jax.lax.while_loop(cond, body, state)
    return RK45Result(x1=x, nfe=nfe, accepted=acc, rejected=rej)
