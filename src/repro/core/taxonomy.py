"""Solver taxonomy engine (Theorem 3.2, Figure 3).

Every solver used to sample diffusion/flow models — generic RK/multistep,
exponential integrators (DDIM/DPM++), EDM, and Scale-Time solvers — has
update rules that are *linear* in the trajectory points and the model's
velocity evaluations. Theorem 3.2 says they are therefore all members of the
Non-Stationary family.

This module makes that theorem executable: solver "programs" are written once
against an abstract linear-algebra backend, and running a program under

  * ``NumericBackend``  — executes the solver directly on arrays;
  * ``SymbolicBackend`` — tracks every point as ``a * x0 + sum_j b_j u_j``
    and emits the canonical NS parameters (Prop. 3.1) of that very solver.

``to_ns(program, ...)`` is then the constructive proof of the inclusion, and
the tests assert exact numerical agreement between the direct run and
Algorithm 1 on the converted parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Protocol, Sequence

import jax
import jax.numpy as jnp

from repro.core.ns_solver import NSParams
from repro.core.parametrization import VelocityField

Array = jax.Array


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


class Backend(Protocol):
    def initial(self): ...
    def eval_u(self, t, point): ...
    def combine(self, terms: Sequence[tuple[Array, object]]): ...
    def finalize(self, point): ...


@dataclasses.dataclass
class NumericBackend:
    """Runs a solver program directly on arrays (the 'oracle' execution)."""

    field: VelocityField
    x0: Array
    input_scale: Array | float = 1.0
    output_scale: Array | float = 1.0
    result: Array | None = None
    nfe: int = 0

    def initial(self):
        return self.input_scale * self.x0

    def eval_u(self, t, point):
        self.nfe += 1
        return self.field.fn(jnp.asarray(t), point)

    def combine(self, terms):
        out = None
        for c, p in terms:
            contrib = c * p
            out = contrib if out is None else out + contrib
        return out

    def finalize(self, point):
        self.result = self.output_scale * point
        return self.result


@dataclasses.dataclass(frozen=True)
class Lin:
    """a * x0 + sum_j b_j u_j, coefficients are (traced) scalars."""

    a: Array
    b: tuple[Array, ...]

    def scaled(self, c) -> "Lin":
        return Lin(a=c * self.a, b=tuple(c * bj for bj in self.b))


def _lin_add(x: Lin, y: Lin) -> Lin:
    k = max(len(x.b), len(y.b))
    pad = lambda b: b + (jnp.asarray(0.0),) * (k - len(b))
    xb, yb = pad(x.b), pad(y.b)
    return Lin(a=x.a + y.a, b=tuple(xj + yj for xj, yj in zip(xb, yb)))


@dataclasses.dataclass
class SymbolicBackend:
    """Tracks solver points symbolically and emits NS parameters.

    NS structural invariant: every model evaluation must happen at the point
    produced by the previous update rule (or at x0 for the first). The
    backend enforces this by registering each eval's point as the next
    trajectory point.
    """

    input_scale: Array | float = 1.0
    output_scale: Array | float = 1.0

    def __post_init__(self):
        self.times: list[Array] = []
        self.updates: list[Lin] = []  # Lin for x_1, ..., x_n
        self._initial = Lin(a=jnp.asarray(self.input_scale, jnp.float64
                                          if jax.config.jax_enable_x64 else jnp.float32),
                            b=())
        self._expected_next: Lin | None = self._initial

    def initial(self) -> Lin:
        return self._initial

    def eval_u(self, t, point: Lin) -> Lin:
        i = len(self.times)
        if i > 0:
            # point becomes trajectory point x_i = output of update rule i-1.
            self.updates.append(point)
        self.times.append(jnp.asarray(t))
        b = (jnp.asarray(0.0),) * i + (jnp.asarray(1.0),)
        return Lin(a=jnp.asarray(0.0), b=b)

    def combine(self, terms) -> Lin:
        out = None
        for c, p in terms:
            contrib = p.scaled(jnp.asarray(c))
            out = contrib if out is None else _lin_add(out, contrib)
        return out

    def finalize(self, point: Lin) -> Lin:
        final = point.scaled(jnp.asarray(self.output_scale))
        self.updates.append(final)
        return final

    def ns_params(self) -> NSParams:
        n = len(self.times)
        assert len(self.updates) == n, (
            f"program registered {len(self.updates)} updates for {n} evals; "
            "every eval must consume the previous update's output"
        )
        times = jnp.stack(self.times)
        a = jnp.stack([up.a for up in self.updates])
        b = jnp.zeros((n, n))
        for i, up in enumerate(self.updates):
            assert len(up.b) <= i + 1, f"update {i} uses future velocities"
            for j, bj in enumerate(up.b):
                b = b.at[i, j].set(bj)
        return NSParams(times=times, a=a, b=b)


# ---------------------------------------------------------------------------
# Conversion entry points
# ---------------------------------------------------------------------------

Program = Callable[..., None]


def to_ns(program: Program, *args, input_scale=1.0, output_scale=1.0, **kwargs) -> NSParams:
    """Run ``program`` symbolically; return its canonical NS parameters."""
    be = SymbolicBackend(input_scale=input_scale, output_scale=output_scale)
    program(be, *args, **kwargs)
    return be.ns_params()


def run_direct(
    program: Program,
    field: VelocityField,
    x0: Array,
    *args,
    input_scale=1.0,
    output_scale=1.0,
    **kwargs,
) -> Array:
    """Run ``program`` numerically (the solver's direct implementation)."""
    be = NumericBackend(field=field, x0=x0, input_scale=input_scale,
                        output_scale=output_scale)
    program(be, *args, **kwargs)
    assert be.result is not None, "program did not call finalize"
    return be.result
