"""Anytime-BNS: ONE solver that serves multiple NFE budgets (beyond-paper).

The paper's stated limitation (Sec. 6): BNS "does need to optimize a
different solver for different NFE, which opens an interesting future
research question whether a single solver can handle different NFE without
degrading performance." This module answers it constructively.

Construction: a single NS-style solver with n = max(budgets) velocity
evaluations plus one extra OUTPUT rule (early exit) per smaller budget m:
    x_out^m = x0 * a_m + sum_{j<m} b_mj u_j .
Each exit is itself a valid NS update rule, so every truncation is a
bona-fide m-step solver. Training jointly minimizes the per-budget PSNR
losses (one Algorithm-2 run for all budgets).

Key finding (EXPERIMENTS.md §Anytime): with the paper's *monotone* time
grids, prefix-sharing is a trap — the first m eval times cannot both spread
over [0, 1] (what a dedicated m-solver needs) and precede the remaining
evals. Neither loss re-weighting nor free-but-monotone-initialized times
escape it (~23 dB below dedicated at NFE 4). The fix is a NON-MONOTONE
NESTED grid — evals 0..3 spread like a dedicated 4-grid, later evals
backfill — which nothing in Algorithm 1 forbids. With it, the shared solver
matches or beats dedicated BNS at the small budgets and gives up a few dB at
the top one.

Parameters: n(n+5)/2 + 1 + sum_{m<n}(m+1) — e.g. budgets (4,8,16): 183 vs
241 for three separate solvers, with one training run and one stored solver.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bns import BNSTrainConfig, TrainResult, psnr
from repro.core.ns_solver import NSParams
from repro.core.parametrization import VelocityField
from repro.optim import adam_init, adam_update, cosine_annealing, poly_decay

Array = jax.Array


class AnytimeParams(NamedTuple):
    time_raw: Array   # (n,) eval times = sigmoid(time_raw) — NOT constrained
    #                   to be monotone (the nested grid is deliberately not)
    a: Array          # (n,) x0 coefficients of the intermediate update rules
    b: Array          # (n, n) velocity coefficients (row i uses j <= i)
    exit_a: Array     # (num_small,) x0 coefficient per early exit
    exit_b: Array     # (num_small, n) velocity coeffs (entries >= m unused)


def _logit(t: Array) -> Array:
    t = jnp.clip(t, 0.02, 0.98)
    return jnp.log(t / (1.0 - t))


def nested_grid(budgets: Sequence[int]) -> np.ndarray:
    """Non-monotone nested eval times: each budget's prefix spreads [0, 1)."""
    budgets = sorted(budgets)
    times: list[float] = []
    seen: set[float] = set()
    for m in budgets:
        grid = [i / m for i in range(m)]
        for t in grid:
            if t not in seen:
                seen.add(t)
                times.append(t)
    n = budgets[-1]
    assert len(times) == n, (times, budgets)
    return np.asarray(times)


def init_anytime(field: VelocityField, budgets: Sequence[int],
                 mode: str = "nested", init_solver: str = "midpoint",
                 sigma0: float = 1.0) -> AnytimeParams:
    # function-level import: repro.solvers.spec imports this module back
    from repro.solvers.registry import build_ns

    budgets = sorted(budgets)
    n = budgets[-1]
    if mode == "prefix":
        # the paper-natural (monotone, generic-solver) init — kept for the
        # ablation; it is a local-optimum trap for the small budgets.
        ns0 = build_ns(init_solver, n, field, sigma0=sigma0)
        time_raw, a, b = _logit(ns0.times), ns0.a, ns0.b
        exits_a, exits_b = [], []
        for m in budgets[:-1]:
            ns_m = build_ns(init_solver, m, field, sigma0=sigma0)
            exits_a.append(ns_m.a[-1])
            exits_b.append(jnp.pad(ns_m.b[-1], (0, n - m)))
        return AnytimeParams(time_raw=time_raw, a=a, b=b,
                             exit_a=jnp.stack(exits_a),
                             exit_b=jnp.stack(exits_b))
    assert mode == "nested", mode
    times0 = nested_grid(budgets)
    # crude Euler-from-x0 rules (x_{i+1} = x0 + t_next u_i); training refines
    a = np.ones(n)
    b = np.zeros((n, n))
    nxt = np.concatenate([times0[1:], [1.0]])
    for i in range(n):
        b[i, i] = nxt[i]
    exit_a = np.ones(len(budgets) - 1)
    exit_b = np.zeros((len(budgets) - 1, n))
    for bi, m in enumerate(budgets[:-1]):
        exit_b[bi, :m] = 1.0 / m   # Euler composition over that prefix
    return AnytimeParams(time_raw=_logit(jnp.asarray(times0)),
                         a=jnp.asarray(a), b=jnp.asarray(b),
                         exit_a=jnp.asarray(exit_a),
                         exit_b=jnp.asarray(exit_b))


class AnytimeCarry(NamedTuple):
    """Resumable state of the shared trajectory after ``step`` evaluations.

    The trajectory state after k evals is a pure function of ``x0`` and the
    recorded velocities ``U[:k]`` (every NS update rule is a weighted sum
    over them, Prop. 3.1), so this tuple is everything a later leg needs.

    x0:   the noise each row integrates from (batched leading dims).
    U:    (n, *x0.shape) recorded velocities; rows >= ``step`` are zeros.
    x:    trajectory state after ``step`` update rules.
    step: number of velocity evaluations done so far (a static Python int —
          jit carry-stepping functions per (start, stop) pair, not on it).
    """

    x0: Array
    U: Array
    x: Array
    step: int


def anytime_carry(params: AnytimeParams, budgets: Sequence[int],
                  x0: Array) -> AnytimeCarry:
    """A fresh carry at step 0 (no backbone forwards spent)."""
    n = sorted(budgets)[-1]
    return AnytimeCarry(x0=x0, U=jnp.zeros((n,) + x0.shape, x0.dtype),
                        x=x0, step=0)


def anytime_extend(params: AnytimeParams, budgets: Sequence[int],
                   u_fn: Callable, carry: AnytimeCarry, stop: int, *,
                   update_fn: Callable | None = None
                   ) -> tuple[AnytimeCarry, dict[int, Array]]:
    """Advance the shared trajectory from ``carry.step`` to ``stop`` evals,
    emitting the early-exit output of every budget crossed on the way.

    Exit-boundary join invariant (continuous batching rests on this): for
    any boundary k and served budget m in ``budgets`` with k < m, computing
    a request's prefix ``anytime_extend(fresh carry, stop=k)`` from its OWN
    noise, then extending the carry to m on the shared grid and reading the
    budget-m exit, performs bit-identically the same weighted-sum arithmetic
    as running the extracted m-step solver (``extract_ns(m)`` through
    Algorithm 1) in one go: rows 0..m-2 of the extracted solver ARE the
    shared intermediate rules, the carry after k evals is a pure function of
    (x0, U[:k]), and the zero rows of the fixed-width ``U`` buffer contribute
    exactly +0.0 to every masked weighted sum. A request admitted into an
    in-flight trajectory at boundary k therefore costs k prefix forwards
    plus the shared legs k..m — at most m forwards total, and its sample is
    the one the direct sampler would have produced.

    Costs exactly ``stop - carry.step`` velocity evaluations. ``update_fn``
    mirrors ``ns_sample(update_fn=...)`` (e.g. the Pallas ``ns_update``
    kernel); it receives the full fixed-width ``U`` with zero-masked weights.
    """
    budgets = sorted(budgets)
    n = budgets[-1]
    if not 0 <= carry.step < stop <= n:
        raise ValueError(f"cannot extend from step {carry.step} to {stop} "
                         f"(top budget {n})")
    if update_fn is None:
        def update_fn(x_init, U, a_i, w_i):
            return a_i * x_init + jnp.tensordot(w_i, U, axes=(0, 0))
    times = jax.nn.sigmoid(params.time_raw)
    arange = jnp.arange(n)
    x0, U, x = carry.x0, carry.U, carry.x
    outs: dict[int, Array] = {}
    for i in range(carry.step, stop):
        u = u_fn(times[i], x)
        U = jax.lax.dynamic_update_index_in_dim(U, u, i, axis=0)
        x = update_fn(x0, U, params.a[i],
                      jnp.where(arange <= i, params.b[i], 0.0))
        for bi, m in enumerate(budgets[:-1]):
            if i + 1 == m:
                outs[m] = update_fn(x0, U, params.exit_a[bi],
                                    jnp.where(arange < m, params.exit_b[bi],
                                              0.0))
    if stop == n:
        outs[n] = x
    return AnytimeCarry(x0=x0, U=U, x=x, step=stop), outs


def anytime_sample(params: AnytimeParams, budgets: Sequence[int],
                   u_fn: Callable, x0: Array, *,
                   update_fn: Callable | None = None) -> dict[int, Array]:
    """Run the shared trajectory once; emit one sample per budget.
    Stopping after m evaluations costs exactly m NFE.

    Every update (intermediate and exit) is the same weighted-sum tensordot
    Algorithm 1 uses, so each budget's output agrees with running the
    extracted m-step solver (``extract_ns``) through ``ns_solver.ns_sample``.
    ``update_fn(x0, U, a_i, w_i) -> x`` overrides that weighted sum (e.g. the
    Pallas ``ns_update`` kernel), mirroring ``ns_sample(update_fn=...)``.

    One full-length ``anytime_extend`` leg — the resumable form the
    continuous-batching engine advances boundary-by-boundary.
    """
    budgets = sorted(budgets)
    _, outs = anytime_extend(params, budgets, u_fn,
                             anytime_carry(params, budgets, x0),
                             budgets[-1], update_fn=update_fn)
    return outs


def extract_ns(params: AnytimeParams, budgets: Sequence[int],
               m: int) -> NSParams:
    """The bona-fide m-step NS solver embedded in an anytime solver.

    Rows 0..m-2 are the shared intermediate update rules; row m-1 is budget
    m's OUTPUT rule — the early exit for a small budget, or the final shared
    rule for the top one. Each exit is a valid NS rule by construction, so
    running Algorithm 1 on the result reproduces ``anytime_sample``'s output
    for that budget at exactly m NFE.
    """
    budgets = sorted(budgets)
    n = budgets[-1]
    if m not in budgets:
        raise ValueError(f"budget {m} not served; have {tuple(budgets)}")
    times = jax.nn.sigmoid(params.time_raw)[:m]
    if m == n:
        return NSParams(times=times, a=params.a, b=params.b)
    bi = budgets.index(m)
    a = jnp.concatenate([params.a[:m - 1], params.exit_a[bi][None]])
    b = jnp.concatenate([params.b[:m - 1, :m],
                         params.exit_b[bi, :m][None]], axis=0)
    return NSParams(times=times, a=a, b=b)


def train_anytime(field: VelocityField, budgets: Sequence[int], train_pairs,
                  val_pairs, cfg: BNSTrainConfig, *, mode: str = "nested",
                  weights: dict | None = None, log=None) -> TrainResult:
    """Joint Algorithm-2 optimization of the shared solver + early exits."""
    import time as _time

    budgets = sorted(budgets)
    if weights is None:
        # mild extra weight on the top budget: it owns the most parameters
        weights = {m: (2.0 if m == budgets[-1] else 1.0) for m in budgets}
    wsum = sum(weights.values())
    theta0 = init_anytime(field, budgets, mode, cfg.init_solver, cfg.sigma0)
    x0_tr, x1_tr = train_pairs
    num = x0_tr.shape[0]
    lr_fn = (poly_decay(cfg.lr, cfg.iterations) if cfg.lr_schedule == "poly"
             else cosine_annealing(cfg.lr, cfg.iterations))

    def loss_fn(theta, x0b, x1b):
        outs = anytime_sample(theta, budgets, field.fn, x0b)
        total = 0.0
        for m in budgets:
            mse = jnp.mean((outs[m] - x1b) ** 2,
                           axis=tuple(range(1, x0b.ndim)))
            total = total + weights[m] * \
                jnp.mean(jnp.log(jnp.maximum(mse, 1e-20)))
        return total / wsum

    @jax.jit
    def step(theta, opt, it, x0b, x1b):
        loss, grads = jax.value_and_grad(loss_fn)(theta, x0b, x1b)
        theta, opt = adam_update(grads, opt, theta, lr_fn(it))
        return theta, opt, loss

    @jax.jit
    def val_psnr(theta):
        outs = anytime_sample(theta, budgets, field.fn, val_pairs[0])
        return jnp.mean(jnp.stack(
            [jnp.mean(psnr(outs[m], val_pairs[1], cfg.max_val))
             for m in budgets]))

    theta, opt = theta0, adam_init(theta0)
    rng = np.random.default_rng(cfg.seed)
    best = (-np.inf, theta)
    history = []
    t0 = _time.time()
    for it in range(cfg.iterations):
        idx = (np.arange(num) if cfg.batch_size >= num
               else rng.choice(num, size=cfg.batch_size, replace=False))
        theta, opt, loss = step(theta, opt, jnp.asarray(it), x0_tr[idx],
                                x1_tr[idx])
        if (it + 1) % cfg.val_every == 0 or it == cfg.iterations - 1:
            vp = float(val_psnr(theta))
            history.append((it + 1, float(loss), vp))
            if vp > best[0]:
                best = (vp, jax.tree.map(lambda x: x.copy(), theta))
            if log:
                log(f"anytime iter {it+1}: loss={float(loss):.3f} "
                    f"mean_psnr={vp:.2f}dB")
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(best[1]))
    return TrainResult(params=best[1], val_psnr=best[0], history=history,
                       wall_seconds=_time.time() - t0, nfe=budgets[-1],
                       num_parameters=n_params)


def evaluate_anytime(params: AnytimeParams, budgets: Sequence[int],
                     field: VelocityField, pairs, max_val: float = 1.0
                     ) -> dict[int, float]:
    x0, x1 = pairs
    outs = anytime_sample(params, sorted(budgets), field.fn, x0)
    return {m: float(jnp.mean(psnr(outs[m], x1, max_val))) for m in outs}
