"""Scale-Time solver family: a generic solver applied to an ST-transformed field.

``STAdapter`` wraps a taxonomy backend so that any solver program (Euler,
Midpoint, Heun, RK4, AB...) runs in the *transformed* space x_bar = s_r x(t_r)
while model evaluations are registered at the *original* trajectory points
x = x_bar / s — exactly the construction of Theorem 3.2's ST ⊂ NS inclusion
(eqs. 48-51). Works identically for the numeric and symbolic backends, so ST
solvers (including EDM and the sigma0-preconditioned initializers of BNS) are
directly convertible to NS parameters.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.schedulers import Scheduler, ve
from repro.core.st_transform import STTransform, scheduler_change_st


class STAdapter:
    """Presents transformed-space solver arithmetic over an original-space backend."""

    def __init__(self, be, st: STTransform):
        self.be = be
        self.st = st

    def initial(self):
        s0 = self.st.s(jnp.asarray(0.0))
        return self.be.combine([(s0, self.be.initial())])

    def eval_u(self, r, xbar):
        r = jnp.asarray(r)
        s, ds = self.st.s(r), self.st.ds(r)
        t, dt = self.st.t(r), self.st.dt(r)
        x = self.be.combine([(1.0 / s, xbar)])
        u = self.be.eval_u(t, x)
        # u_bar_r(x_bar) = (s'/s) x_bar + t' s u_{t_r}(x_bar / s)   (eq. 7)
        return self.be.combine([(ds / s, xbar), (dt * s, u)])

    def combine(self, terms):
        return self.be.combine(terms)

    def finalize(self, xbar):
        s1 = self.st.s(jnp.asarray(1.0))
        return self.be.finalize(self.be.combine([(1.0 / s1, xbar)]))


def st_program(base_program, st: STTransform):
    """Lift a generic solver program to its Scale-Time version."""

    def prog(be, grid, *args, **kwargs):
        base_program(STAdapter(be, st), grid, *args, **kwargs)

    return prog


def edm_program(base_program, sched: Scheduler, sigma_max: float = 80.0):
    """EDM (Karras et al. 2022): scheduler change to VE + a generic solver.

    EDM's canonical choice is Heun with a rho-warped grid (see
    ``solvers.power_grid``); any base program works here.
    """
    st = scheduler_change_st(sched, ve(sigma_max))
    return st_program(base_program, st)
