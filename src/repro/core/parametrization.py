"""Model-output parametrizations and their conversion to velocity fields.

Table 1 of the paper: the sampling velocity is
    u_t(x) = beta_t * x + gamma_t * f_t(x)
with (beta, gamma) depending on whether f is a velocity, epsilon-prediction,
or x-prediction model.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.schedulers import Scheduler

Array = jax.Array
# f(t, x) -> prediction; conditioning is closed over by the caller.
ModelFn = Callable[[Array, Array], Array]

VELOCITY = "velocity"
EPS_PRED = "eps"
X_PRED = "x"

PARAMETRIZATIONS = (VELOCITY, EPS_PRED, X_PRED)


def beta_gamma(sched: Scheduler, parametrization: str, t: Array):
    """Coefficients of Table 1 for ``u = beta x + gamma f``."""
    if parametrization == VELOCITY:
        return jnp.zeros_like(t), jnp.ones_like(t)
    a, s = sched.alpha(t), sched.sigma(t)
    da, ds = sched.dalpha(t), sched.dsigma(t)
    if parametrization == EPS_PRED:
        return da / a, (ds * a - s * da) / a
    if parametrization == X_PRED:
        return ds / s, (s * da - ds * a) / s
    raise ValueError(f"unknown parametrization {parametrization!r}")


@dataclasses.dataclass(frozen=True)
class VelocityField:
    """A sampling-ready velocity field u_t(x) built from a model f.

    ``fn(t, x)`` evaluates u; ``scheduler`` is the Gaussian-path scheduler the
    model was trained with (needed by ST transforms and dedicated solvers).
    """

    fn: ModelFn
    scheduler: Scheduler

    def __call__(self, t: Array, x: Array) -> Array:
        return self.fn(t, x)


def as_velocity_field(
    model: ModelFn, sched: Scheduler, parametrization: str = VELOCITY
) -> VelocityField:
    """Wrap an f-model (velocity / eps-pred / x-pred) into u_t(x) (Table 1)."""
    if parametrization == VELOCITY:
        return VelocityField(fn=model, scheduler=sched)

    def u(t: Array, x: Array) -> Array:
        t = sched.clip_t(t)
        beta, gamma = beta_gamma(sched, parametrization, t)
        return beta * x + gamma * model(t, x)

    return VelocityField(fn=u, scheduler=sched)


def eps_to_velocity(sched: Scheduler, t: Array, x: Array, eps: Array) -> Array:
    beta, gamma = beta_gamma(sched, EPS_PRED, t)
    return beta * x + gamma * eps


def x_to_velocity(sched: Scheduler, t: Array, x: Array, x1: Array) -> Array:
    beta, gamma = beta_gamma(sched, X_PRED, t)
    return beta * x + gamma * x1
