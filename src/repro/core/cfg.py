"""Classifier-free guidance over velocity fields (Ho & Salimans 2022).

The guided field is  u_w = (1 + w) u_cond - w u_uncond  (w = 0 is the pure
conditional model, matching the paper's 'unguided' w=0 convention). As the
paper notes, CFG doubles the effective batch per NFE; we implement it by
stacking cond/uncond along the batch axis so the backbone runs once.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.parametrization import VelocityField

Array = jax.Array


def guided_field(
    cond_fn: Callable[[Array, Array], Array],
    uncond_fn: Callable[[Array, Array], Array],
    w: float,
    scheduler,
) -> VelocityField:
    """Build u_w from separate conditional/unconditional evaluations."""

    def u(t: Array, x: Array) -> Array:
        if w == 0.0:
            return cond_fn(t, x)
        return (1.0 + w) * cond_fn(t, x) - w * uncond_fn(t, x)

    return VelocityField(fn=u, scheduler=scheduler)


def guided_field_stacked(
    model_fn: Callable[[Array, Array, Array], Array],
    cond: Array,
    null_cond: Array,
    w: float,
    scheduler,
) -> VelocityField:
    """CFG with a single stacked forward: model_fn(t, x2, cond2) on 2B batch."""

    def u(t: Array, x: Array) -> Array:
        if w == 0.0:
            return model_fn(t, x, cond)
        x2 = jnp.concatenate([x, x], axis=0)
        c2 = jnp.concatenate([cond, null_cond], axis=0)
        out = model_fn(t, x2, c2)
        uc, uu = jnp.split(out, 2, axis=0)
        return (1.0 + w) * uc - w * uu

    return VelocityField(fn=u, scheduler=scheduler)
