"""BNS solver training — Algorithm 2 of the paper.

Pipeline:
  1. generate (x0, x(1)) pairs with adaptive RK45 from the frozen model;
  2. initialize theta from a generic solver (optionally sigma0-preconditioned
     via a Scale-Time scheduler change, eq. 14) converted to NS parameters;
  3. minimize the PSNR loss  L(theta) = E log ||x_n^theta - x(1)||^2  with
     Adam, tracking PSNR on a validation set and returning the best iterate.

The same harness trains BST solvers (the prior-work baseline) by swapping the
sampler closure.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bst_solver, ns_solver, st_transform
from repro.core.ns_solver import BNSParams, NSParams
from repro.core.parametrization import VelocityField
from repro.core.rk45 import rk45_solve
from repro.core.taxonomy import run_direct
from repro.optim import adam_init, adam_update, cosine_annealing, poly_decay

Array = jax.Array


# ---------------------------------------------------------------------------
# Ground-truth pair generation
# ---------------------------------------------------------------------------


def generate_pairs(
    field: VelocityField,
    key: Array,
    num: int,
    shape: tuple[int, ...],
    *,
    batch_size: int = 64,
    rtol: float = 1e-5,
    atol: float = 1e-5,
    source_std: float = 1.0,
) -> tuple[Array, Array]:
    """Draw x0 ~ N(0, source_std^2) and integrate to x(1) with RK45."""
    solve = jax.jit(lambda x0: rk45_solve(field.fn, x0, rtol=rtol, atol=atol).x1)
    x0s, x1s = [], []
    for start in range(0, num, batch_size):
        b = min(batch_size, num - start)
        key, sub = jax.random.split(key)
        x0 = source_std * jax.random.normal(sub, (b,) + shape)
        x0s.append(x0)
        x1s.append(solve(x0))
    return jnp.concatenate(x0s), jnp.concatenate(x1s)


# ---------------------------------------------------------------------------
# Initialization (generic solver -> NS params, with preconditioning)
# ---------------------------------------------------------------------------


def solver_to_ns(
    name: str,
    nfe: int,
    field: VelocityField,
    *,
    sigma0: float = 1.0,
    grid=None,
) -> NSParams:
    """DEPRECATED shim over ``repro.solvers.registry.build_ns``.

    The string-dispatch ladder that used to live here is now the solver
    registry; use ``repro.solvers.build_ns`` (or ``SolverSpec.build``)
    directly. Kept so existing call sites and tests keep working.
    """
    import warnings

    from repro.solvers.registry import build_ns

    warnings.warn("solver_to_ns is deprecated; use repro.solvers.build_ns "
                  "or SolverSpec.build", DeprecationWarning, stacklevel=2)
    return build_ns(name, nfe, field, sigma0=sigma0, grid=grid)


def ns_sampler(field: VelocityField) -> Callable[[BNSParams, Array], Array]:
    def sample(theta: BNSParams, x0: Array) -> Array:
        return ns_solver.ns_sample(ns_solver.materialize(theta), field.fn, x0)

    return sample


def bst_sampler(field: VelocityField, base: str = "euler"):
    prog = (bst_solver.bst_euler_program if base == "euler"
            else bst_solver.bst_midpoint_program)

    def sample(theta: bst_solver.BSTParams, x0: Array) -> Array:
        return run_direct(prog, field, x0, bst_solver.materialize_bst(theta))

    return sample


# ---------------------------------------------------------------------------
# Algorithm 2
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BNSTrainConfig:
    nfe: int = 8
    init_solver: str = "midpoint"
    sigma0: float = 1.0
    lr: float = 5e-4
    lr_schedule: str = "poly"        # "poly" | "cosine" (paper: poly for class-cond, cosine for T2I/audio)
    iterations: int = 2000
    batch_size: int = 40
    val_every: int = 100
    seed: int = 0
    max_val: float = 1.0             # PSNR peak value (1.0 for unit-scale latents)


@dataclasses.dataclass
class TrainResult:
    params: object                   # best-validation parameters
    val_psnr: float
    history: list                    # (iter, train_loss, val_psnr)
    wall_seconds: float
    nfe: int
    num_parameters: int


def psnr(x: Array, ref: Array, max_val: float = 1.0) -> Array:
    """Per-pair PSNR with the paper's norm ||x||^2 = mean_i x_i^2."""
    mse = jnp.mean((x - ref) ** 2, axis=tuple(range(1, x.ndim)))
    return 10.0 * (2.0 * jnp.log10(max_val) - jnp.log10(jnp.maximum(mse, 1e-20)))


def _loss_fn(sampler, theta, x0, x1):
    xh = sampler(theta, x0)
    mse = jnp.mean((xh - x1) ** 2, axis=tuple(range(1, x0.ndim)))
    return jnp.mean(jnp.log(jnp.maximum(mse, 1e-20)))


def train_solver(
    sampler: Callable,
    theta0,
    train_pairs: tuple[Array, Array],
    val_pairs: tuple[Array, Array],
    cfg: BNSTrainConfig,
    *,
    log: Callable[[str], None] | None = None,
) -> TrainResult:
    """Generic Algorithm-2 optimizer over any differentiable sampler."""
    x0_tr, x1_tr = train_pairs
    num = x0_tr.shape[0]
    lr_fn = (poly_decay(cfg.lr, cfg.iterations) if cfg.lr_schedule == "poly"
             else cosine_annealing(cfg.lr, cfg.iterations))

    @jax.jit
    def step(theta, opt, it, x0b, x1b):
        loss, grads = jax.value_and_grad(
            lambda th: _loss_fn(sampler, th, x0b, x1b))(theta)
        theta, opt = adam_update(grads, opt, theta, lr_fn(it))
        return theta, opt, loss

    @jax.jit
    def val_psnr_fn(theta):
        return jnp.mean(psnr(sampler(theta, val_pairs[0]), val_pairs[1],
                             cfg.max_val))

    theta, opt = theta0, adam_init(theta0)
    rng = np.random.default_rng(cfg.seed)
    best = (-np.inf, theta)
    history = []
    t_start = time.time()
    full_batch = cfg.batch_size >= num
    for it in range(cfg.iterations):
        # conditional fields close over a fixed conditioning batch: row i of
        # the pairs is tied to conditioning row i, so full-batch runs must
        # keep the order (no shuffling).
        idx = np.arange(num) if full_batch else \
            rng.choice(num, size=cfg.batch_size, replace=False)
        theta, opt, loss = step(theta, opt, jnp.asarray(it), x0_tr[idx], x1_tr[idx])
        if (it + 1) % cfg.val_every == 0 or it == cfg.iterations - 1:
            vp = float(val_psnr_fn(theta))
            history.append((it + 1, float(loss), vp))
            if vp > best[0]:
                best = (vp, jax.tree.map(lambda x: x.copy(), theta))
            if log:
                log(f"iter {it+1}: loss={float(loss):.4f} val_psnr={vp:.2f}dB")
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(best[1]))
    return TrainResult(params=best[1], val_psnr=best[0], history=history,
                       wall_seconds=time.time() - t_start, nfe=cfg.nfe,
                       num_parameters=n_params)


def train_bns(
    field: VelocityField,
    train_pairs,
    val_pairs,
    cfg: BNSTrainConfig,
    *,
    log=None,
) -> TrainResult:
    from repro.solvers.registry import build_ns

    ns0 = build_ns(cfg.init_solver, cfg.nfe, field, sigma0=cfg.sigma0)
    theta0 = ns_solver.from_ns(ns0)
    res = train_solver(ns_sampler(field), theta0, train_pairs, val_pairs, cfg, log=log)
    # Report the paper's parameter count (canonical dimension of the family).
    res.num_parameters = ns_solver.count_parameters(cfg.nfe)
    return res


def make_distributed_bns_step(field: VelocityField, cfg: BNSTrainConfig, mesh):
    """pjit'd Algorithm-2 step for the production mesh.

    BNS training is embarrassingly data-parallel: the (x0, x1) pairs shard
    over the composed batch axes, theta (<200 floats) and the Adam state are
    replicated, and the per-device gradients all-reduce. The backbone params
    inside ``field`` shard via their own closure-captured shardings.
    Returns (step_fn, theta0, opt0); step_fn(theta, opt, it, x0b, x1b).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import batch_axes
    from repro.solvers.registry import build_ns

    b = batch_axes(mesh)
    b = b if len(b) > 1 else b[0]
    sampler = ns_sampler(field)
    lr_fn = (poly_decay(cfg.lr, cfg.iterations) if cfg.lr_schedule == "poly"
             else cosine_annealing(cfg.lr, cfg.iterations))

    def step(theta, opt, it, x0b, x1b):
        loss, grads = jax.value_and_grad(
            lambda th: _loss_fn(sampler, th, x0b, x1b))(theta)
        theta, opt = adam_update(grads, opt, theta, lr_fn(it))
        return theta, opt, loss

    ns0 = build_ns(cfg.init_solver, cfg.nfe, field, sigma0=cfg.sigma0)
    theta0 = ns_solver.from_ns(ns0)
    opt0 = adam_init(theta0)
    repl = NamedSharding(mesh, P())
    pair_sharding = NamedSharding(mesh, P(b))
    step_fn = jax.jit(
        step,
        in_shardings=(jax.tree.map(lambda _: repl, theta0),
                      jax.tree.map(lambda _: repl, opt0),
                      repl, pair_sharding, pair_sharding),
        out_shardings=(jax.tree.map(lambda _: repl, theta0),
                       jax.tree.map(lambda _: repl, opt0), repl))
    return step_fn, theta0, opt0


def train_bst(
    field: VelocityField,
    train_pairs,
    val_pairs,
    cfg: BNSTrainConfig,
    *,
    base: str = "euler",
    log=None,
) -> TrainResult:
    if cfg.sigma0 != 1.0:
        target = st_transform.scaled_sigma(field.scheduler, cfg.sigma0)
        st = st_transform.scheduler_change_st(field.scheduler, target)
        theta0 = bst_solver.from_st_transform(st, cfg.nfe, base)
    else:
        theta0 = bst_solver.identity_bst(cfg.nfe, base)
    return train_solver(bst_sampler(field, base), theta0, train_pairs, val_pairs,
                        cfg, log=log)
