"""Analytic velocity fields for testing and paper-claim validation.

``mixture_field`` is the exact marginal velocity of a Gaussian-mixture data
distribution under any Gaussian-path scheduler — a closed-form 'pre-trained
model' that lets us validate BNS end-to-end (RK45 ground truth, solver
ordering, PSNR-vs-NFE) without training a network. ``linear_field`` has an
exact ODE solution for hard numerical-correctness tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.parametrization import VelocityField
from repro.core.schedulers import Scheduler

Array = jax.Array


def mixture_field(
    sched: Scheduler,
    means: Array,    # (K, d)
    stds: Array,     # (K,)  isotropic per-component std
    weights: Array,  # (K,)
) -> VelocityField:
    """Exact u_t(x) for q(x1) = sum_k w_k N(mu_k, s_k^2 I).

    x_t = alpha x1 + sigma eps  =>  u_t(x) = alpha' E[x1|x] + sigma' E[eps|x],
    with per-component Gaussian posteriors and softmax responsibilities.

    The per-component algebra cancels the 1/sigma singularity exactly:
      E[x1|x,k]  = mu_k + (alpha s_k^2 / v_k) (x - alpha mu_k)
      E[eps|x,k] = sigma (x - alpha mu_k) / v_k,     v_k = alpha^2 s_k^2 + sigma^2
    so the field is smooth on the closed interval [0, 1].
    """
    log_w = jnp.log(weights / jnp.sum(weights))

    def u(t: Array, x: Array) -> Array:
        t = jnp.asarray(t)
        a, s = sched.alpha(t), sched.sigma(t)
        da, ds = sched.dalpha(t), sched.dsigma(t)
        var_k = (a * stds) ** 2 + s**2                       # (K,)
        diff = x[..., None, :] - a * means                   # (..., K, d)
        d = x.shape[-1]
        logp = log_w - 0.5 * jnp.sum(diff**2, -1) / var_k \
            - 0.5 * d * jnp.log(var_k)
        resp = jax.nn.softmax(logp, axis=-1)                 # (..., K)
        gain = (a * stds**2) / var_k                         # (K,)
        x1_k = means + gain[:, None] * diff                  # (..., K, d)
        eps_k = s * diff / var_k[:, None]                    # (..., K, d)
        u_k = da * x1_k + ds * eps_k
        return jnp.sum(resp[..., None] * u_k, axis=-2)

    return VelocityField(fn=u, scheduler=sched)


def two_moons_means(k_per_moon: int = 8, radius: float = 1.0) -> Array:
    """Mixture centers tracing two interleaved half-circles (a 2D 'dataset')."""
    th = jnp.linspace(0.0, jnp.pi, k_per_moon)
    m1 = jnp.stack([radius * jnp.cos(th), radius * jnp.sin(th) - 0.3], -1)
    m2 = jnp.stack([radius * jnp.cos(th) + 1.0, -radius * jnp.sin(th) + 0.3], -1)
    return jnp.concatenate([m1, m2])


def linear_field(sched: Scheduler, rate: float = 1.5, drift: float = 0.7) -> VelocityField:
    """u_t(x) = -rate x + drift t : exact solution available (for exactness tests)."""

    def u(t: Array, x: Array) -> Array:
        return -rate * x + drift * t

    return VelocityField(fn=u, scheduler=sched)


def linear_field_solution(x0: Array, t: float, rate: float = 1.5, drift: float = 0.7) -> Array:
    """Closed-form solution of ``linear_field`` at time t from x(0)=x0."""
    e = jnp.exp(-rate * t)
    # particular solution of x' = -r x + d t: x_p = (d/r) t - d/r^2 (1 - e^{-rt}) ... derive:
    # x(t) = x0 e^{-rt} + d [ t/r - (1 - e^{-rt})/r^2 ]
    return x0 * e + drift * (t / rate - (1.0 - e) / rate**2)
