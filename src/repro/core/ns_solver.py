"""Non-Stationary (NS) solvers — the paper's core object (Sec. 3.1).

An n-step NS solver is a time grid of evaluation times ``t_0 <= ... <= t_{n-1}``
plus per-step update rules in the canonical form of Prop. 3.1:

    x_{i+1} = x0 * a_i + sum_{j<=i} b_{ij} u_j,      u_j = u_{t_j}(x_j)

Algorithm 1 (sampling) is implemented with ``lax.scan`` so it is jit-able and
reverse-mode differentiable (BNS training backprops through every model eval).

Two dtype-level representations:
  * ``NSParams``  — the solver itself (times (n,), a (n,), b (n,n) lower-tri).
  * ``BNSParams`` — an unconstrained reparameterization used for optimization
    (times via softmax-cumsum so the grid stays monotone in [0,1)).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class NSParams(NamedTuple):
    """Canonical NS solver parameters.

    times: (n,) evaluation times, t_0 = 0, non-decreasing, < 1.
    a:     (n,) coefficient of x0 per update rule.
    b:     (n, n) velocity coefficients; row i uses entries j <= i only.
    """

    times: Array
    a: Array
    b: Array

    @property
    def n(self) -> int:
        return self.a.shape[0]

    def num_parameters(self) -> int:
        """Paper's p = n(n+5)/2 + 1: grid (n+1 with both endpoints pinned ->
        n-1 free) + n a's + n(n+1)/2 b's. We count as the paper does."""
        n = self.n
        return n * (n + 5) // 2 + 1


def tril_mask(n: int) -> Array:
    return jnp.tril(jnp.ones((n, n), dtype=bool))


def ns_sample(
    params: NSParams,
    u_fn: Callable[[Array, Array], Array],
    x0: Array,
    *,
    unroll: bool = False,
    update_fn: Callable[..., Array] | None = None,
) -> Array:
    """Algorithm 1: sample with an NS solver.

    x0: (..., d) initial noise. u_fn(t, x) -> velocity, vmapped over batch by
    the caller's model. ``update_fn(x0, U, a_i, w_i) -> x_{i+1}`` may override
    the weighted-sum update (e.g. the Pallas ``ns_update`` kernel).
    """
    n = params.n
    mask = tril_mask(n)
    b = jnp.where(mask, params.b, 0.0)

    if update_fn is None:
        def update_fn(x_init, U, a_i, w_i):
            return a_i * x_init + jnp.tensordot(w_i, U, axes=(0, 0))

    def step(carry, i):
        x, U = carry
        u = u_fn(params.times[i], x)
        U = jax.lax.dynamic_update_index_in_dim(U, u, i, axis=0)
        w = jnp.where(jnp.arange(n) <= i, b[i], 0.0)
        x_next = update_fn(x0, U, params.a[i], w)
        return (x_next, U), None

    U0 = jnp.zeros((n,) + x0.shape, dtype=x0.dtype)
    if unroll:
        carry = (x0, U0)
        for i in range(n):
            carry, _ = step(carry, i)
        return carry[0]
    (x_final, _), _ = jax.lax.scan(step, (x0, U0), jnp.arange(n))
    return x_final


def ns_trajectory(
    params: NSParams, u_fn: Callable[[Array, Array], Array], x0: Array
) -> Array:
    """Like ``ns_sample`` but returns all trajectory points (n+1, ...)."""
    n = params.n
    mask = tril_mask(n)
    b = jnp.where(mask, params.b, 0.0)

    def step(carry, i):
        x, U = carry
        u = u_fn(params.times[i], x)
        U = jax.lax.dynamic_update_index_in_dim(U, u, i, axis=0)
        w = jnp.where(jnp.arange(n) <= i, b[i], 0.0)
        x_next = params.a[i] * x0 + jnp.tensordot(w, U, axes=(0, 0))
        return (x_next, U), x_next

    U0 = jnp.zeros((n,) + x0.shape, dtype=x0.dtype)
    (_, _), xs = jax.lax.scan(step, (x0, U0), jnp.arange(n))
    return jnp.concatenate([x0[None], xs], axis=0)


# ---------------------------------------------------------------------------
# Optimization reparameterization (BNS)
# ---------------------------------------------------------------------------


class BNSParams(NamedTuple):
    """Unconstrained parameterization of NSParams for gradient optimization.

    time_logits: (n,) — softmax gives n positive increments d_i summing to 1;
        eval times are t_0 = 0, t_i = d_0 + ... + d_{i-1}  (so t_{n-1} < 1).
    a, b: unconstrained; b is masked to lower-triangular on materialization.
    """

    time_logits: Array
    a: Array
    b: Array


def materialize(p: BNSParams) -> NSParams:
    d = jax.nn.softmax(p.time_logits)
    t = jnp.concatenate([jnp.zeros((1,), d.dtype), jnp.cumsum(d)[:-1]])
    return NSParams(times=t, a=p.a, b=jnp.where(tril_mask(p.a.shape[0]), p.b, 0.0))


def from_ns(params: NSParams) -> BNSParams:
    """Inverse of ``materialize`` (up to softmax shift): init BNS from any NS solver."""
    t = params.times
    gaps = jnp.diff(jnp.concatenate([t, jnp.ones((1,), t.dtype)]))
    logits = jnp.log(jnp.maximum(gaps, 1e-8))
    return BNSParams(time_logits=logits, a=params.a, b=params.b)


def count_parameters(n: int) -> int:
    """Paper's parameter count for an n-step NS solver: n(n+5)/2 + 1."""
    return n * (n + 5) // 2 + 1
