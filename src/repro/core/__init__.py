"""repro.core — Bespoke Non-Stationary solvers (Shaul et al., ICML 2024).

The paper's math lives here; the solver *product* API lives in
``repro.solvers`` (registry / SolverSpec / SolverArtifact / Sampler):

    from repro.solvers import SolverSpec
    spec = SolverSpec("midpoint", nfe=8, mode="bns")
    art = spec.distill(field, train_pairs, val_pairs, cfg).artifact()
    art.save("solver.msgpack")     # serve without retraining

Public API (this package):
  schedulers:      fm_ot, fm_cs, vp, ve, scaled_sigma, get_scheduler
  parametrization: as_velocity_field (velocity / eps-pred / x-pred)
  solvers:         generic solver programs + grids (the taxonomy inputs)
  exponential:     ddim / dpm2m programs + the log-SNR grid
  st_transform/st_solvers: scheduler_change_st, preconditioning, EDM
  ns_solver:       NSParams / BNSParams, ns_sample (Algorithm 1)
  taxonomy:        to_ns / run_direct (Theorem 3.2, executable)
  bns:             generate_pairs, train_bns / train_bst (Algorithm 2);
                   ``solver_to_ns`` survives only as a deprecation shim over
                   ``repro.solvers.registry.build_ns``
  anytime:         one shared solver for multiple NFE budgets (beyond-paper)
"""
from repro.core import (
    anytime,
    bns,
    bst_solver,
    cfg,
    exponential,
    ns_solver,
    parametrization,
    rk45,
    schedulers,
    solvers,
    st_solvers,
    st_transform,
    taxonomy,
    toy,
)

__all__ = [
    "anytime", "bns", "bst_solver", "cfg", "exponential", "ns_solver", "parametrization",
    "rk45", "schedulers", "solvers", "st_solvers", "st_transform", "taxonomy",
    "toy",
]
