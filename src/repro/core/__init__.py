"""repro.core — Bespoke Non-Stationary solvers (Shaul et al., ICML 2024).

Public API:
  schedulers:      fm_ot, fm_cs, vp, ve, scaled_sigma, get_scheduler
  parametrization: as_velocity_field (velocity / eps-pred / x-pred)
  solvers:         generic programs + grids;  exponential: ddim, dpm2m
  st:              scheduler_change_st, transformed_field, precondition
  ns_solver:       NSParams / BNSParams, ns_sample (Algorithm 1)
  taxonomy:        to_ns / run_direct (Theorem 3.2, executable)
  bns:             generate_pairs, train_bns / train_bst (Algorithm 2)
"""
from repro.core import (
    anytime,
    bns,
    bst_solver,
    cfg,
    exponential,
    ns_solver,
    parametrization,
    rk45,
    schedulers,
    solvers,
    st_solvers,
    st_transform,
    taxonomy,
    toy,
)

__all__ = [
    "anytime", "bns", "bst_solver", "cfg", "exponential", "ns_solver", "parametrization",
    "rk45", "schedulers", "solvers", "st_solvers", "st_transform", "taxonomy",
    "toy",
]
