"""Generic ODE solver programs (Sec. 3.3.1 / Appendix C).

Each solver is written once as a *program* over the taxonomy backend
(`repro.core.taxonomy`), so the same code runs numerically and converts to NS
parameters. Grids are Python/NumPy-level static sequences (standard for
diffusion samplers: the step schedule is fixed at trace time).

Naming: an "n-eval" solver makes exactly n model calls (n = NFE).
"""
from __future__ import annotations

import numpy as np


def uniform_grid(num_intervals: int, t0: float = 0.0, t1: float = 1.0) -> np.ndarray:
    return np.linspace(t0, t1, num_intervals + 1)


def power_grid(num_intervals: int, rho: float = 2.0) -> np.ndarray:
    """EDM-style warped grid (denser near data for rho>1), mapped to [0,1]."""
    s = np.linspace(0.0, 1.0, num_intervals + 1)
    return 1.0 - (1.0 - s) ** rho


# ---------------------------------------------------------------------------
# Runge-Kutta family
# ---------------------------------------------------------------------------


def euler_program(be, grid) -> None:
    """RK1. n evals for n intervals."""
    x = be.initial()
    for i in range(len(grid) - 1):
        h = grid[i + 1] - grid[i]
        u = be.eval_u(grid[i], x)
        x = be.combine([(1.0, x), (h, u)])
    be.finalize(x)


def midpoint_program(be, grid) -> None:
    """RK2 midpoint. 2 evals per interval."""
    x = be.initial()
    for i in range(len(grid) - 1):
        h = grid[i + 1] - grid[i]
        u1 = be.eval_u(grid[i], x)
        xm = be.combine([(1.0, x), (0.5 * h, u1)])
        u2 = be.eval_u(grid[i] + 0.5 * h, xm)
        x = be.combine([(1.0, x), (h, u2)])
    be.finalize(x)


def heun_program(be, grid) -> None:
    """RK2 trapezoidal (Heun; EDM's solver). 2 evals per interval."""
    x = be.initial()
    for i in range(len(grid) - 1):
        h = grid[i + 1] - grid[i]
        u1 = be.eval_u(grid[i], x)
        xe = be.combine([(1.0, x), (h, u1)])
        u2 = be.eval_u(grid[i + 1], xe)
        x = be.combine([(1.0, x), (0.5 * h, u1), (0.5 * h, u2)])
    be.finalize(x)


def rk4_program(be, grid) -> None:
    """Classic RK4. 4 evals per interval."""
    x = be.initial()
    for i in range(len(grid) - 1):
        t, h = grid[i], grid[i + 1] - grid[i]
        k1 = be.eval_u(t, x)
        x2 = be.combine([(1.0, x), (0.5 * h, k1)])
        k2 = be.eval_u(t + 0.5 * h, x2)
        x3 = be.combine([(1.0, x), (0.5 * h, k2)])
        k3 = be.eval_u(t + 0.5 * h, x3)
        x4 = be.combine([(1.0, x), (h, k3)])
        k4 = be.eval_u(t + h, x4)
        x = be.combine([
            (1.0, x),
            (h / 6.0, k1), (h / 3.0, k2), (h / 3.0, k3), (h / 6.0, k4),
        ])
    be.finalize(x)


# ---------------------------------------------------------------------------
# Multistep (Adams-Bashforth) family — nonuniform-grid coefficients
# ---------------------------------------------------------------------------


def _ab_weights(ts_hist: np.ndarray, t0: float, t1: float) -> np.ndarray:
    """Integrate the Lagrange interpolation of u over [t0, t1].

    ts_hist are the (distinct) past evaluation times; returns one weight per
    history point. Exact polynomial integration via the Vandermonde system.
    """
    m = len(ts_hist)
    # moments: integral of t^k over [t0, t1]
    ks = np.arange(m)
    moments = (t1 ** (ks + 1) - t0 ** (ks + 1)) / (ks + 1)
    V = np.vander(ts_hist, m, increasing=True).T  # V[k, j] = ts_hist[j]^k
    return np.linalg.solve(V, moments)


def adams_bashforth_program(be, grid, order: int = 2) -> None:
    """m-step AB on a (possibly nonuniform) grid. 1 eval per interval.

    Warms up with lower orders (AB1 = Euler on the first step, etc.).
    """
    x = be.initial()
    hist_t: list[float] = []
    hist_u: list = []
    for i in range(len(grid) - 1):
        u = be.eval_u(grid[i], x)
        hist_t.append(float(grid[i]))
        hist_u.append(u)
        m = min(order, len(hist_u))
        w = _ab_weights(np.asarray(hist_t[-m:]), float(grid[i]), float(grid[i + 1]))
        terms = [(1.0, x)] + [(float(w[j]), hist_u[-m + j]) for j in range(m)]
        x = be.combine(terms)
    be.finalize(x)


# ---------------------------------------------------------------------------
# Named registry (baselines for benchmarks / initializers for BNS)
# ---------------------------------------------------------------------------


def solver_program(name: str):
    progs = {
        "euler": euler_program,
        "midpoint": midpoint_program,
        "heun": heun_program,
        "rk4": rk4_program,
        "ab2": lambda be, grid: adams_bashforth_program(be, grid, order=2),
        "ab4": lambda be, grid: adams_bashforth_program(be, grid, order=4),
    }
    if name not in progs:
        raise KeyError(f"unknown solver {name!r}; have {sorted(progs)}")
    return progs[name]


def evals_per_interval(name: str) -> int:
    return {"euler": 1, "midpoint": 2, "heun": 2, "rk4": 4, "ab2": 1, "ab4": 1}[name]


def grid_for_nfe(name: str, nfe: int) -> np.ndarray:
    """Uniform grid such that the named solver makes exactly ``nfe`` evals."""
    per = evals_per_interval(name)
    if nfe % per:
        raise ValueError(f"{name} needs NFE divisible by {per}, got {nfe}")
    return uniform_grid(nfe // per)
