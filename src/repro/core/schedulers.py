"""Gaussian-path schedulers (alpha_t, sigma_t) and their calculus.

Conventions follow the paper (Shaul et al., ICML 2024): t=0 is source/noise,
t=1 is data, ``alpha_0 = 0 = sigma_1``, ``alpha_1 = 1``, ``sigma_0 > 0``
(eq. 4), and the signal-to-noise ratio ``snr(t) = alpha_t / sigma_t`` is
strictly monotonically increasing.

Every scheduler exposes ``alpha``/``sigma`` plus an analytic ``snr_inverse``
so that Scale-Time transforms (eq. 8) are exact, and all time-functions are
differentiable (derivatives via jax.jvp), so transformed velocity fields
(eq. 7) need no hand-written derivatives.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array

# Clip away from the endpoints where snr is 0/inf.
_EPS = 1e-6


def _d(fn: Callable[[Array], Array], t: Array) -> Array:
    """Scalar-function time derivative via jvp (works under jit/vmap)."""
    _, dot = jax.jvp(fn, (t,), (jnp.ones_like(t),))
    return dot


@dataclasses.dataclass(frozen=True)
class Scheduler:
    """A Gaussian-path scheduler (alpha_t, sigma_t).

    ``snr_inverse`` maps an snr value back to t: t = snr^{-1}(v). It must be
    exact for the snr range the scheduler produces on (0, 1).
    """

    name: str
    alpha: Callable[[Array], Array]
    sigma: Callable[[Array], Array]
    snr_inverse: Callable[[Array], Array]

    def snr(self, t: Array) -> Array:
        return self.alpha(t) / self.sigma(t)

    def lam(self, t: Array) -> Array:
        """Half log-SNR's big brother: lambda_t = log snr(t) (paper's eq. 22)."""
        return jnp.log(self.snr(t))

    def dalpha(self, t: Array) -> Array:
        return _d(self.alpha, t)

    def dsigma(self, t: Array) -> Array:
        return _d(self.sigma, t)

    def clip_t(self, t: Array) -> Array:
        return jnp.clip(t, _EPS, 1.0 - _EPS)


# ---------------------------------------------------------------------------
# Concrete schedulers
# ---------------------------------------------------------------------------

def fm_ot() -> Scheduler:
    """Conditional-OT / rectified-flow scheduler: alpha=t, sigma=1-t (eq. 57)."""

    return Scheduler(
        name="fm_ot",
        alpha=lambda t: t,
        sigma=lambda t: 1.0 - t,
        # snr = t/(1-t)  =>  t = snr/(1+snr)
        snr_inverse=lambda v: v / (1.0 + v),
    )


def fm_cs() -> Scheduler:
    """Cosine scheduler (FM/v-CS): alpha=sin(pi t/2), sigma=cos(pi t/2) (eq. 58)."""

    half_pi = jnp.pi / 2.0
    return Scheduler(
        name="fm_cs",
        alpha=lambda t: jnp.sin(half_pi * t),
        sigma=lambda t: jnp.cos(half_pi * t),
        # snr = tan(pi t / 2)  =>  t = (2/pi) atan(snr)
        snr_inverse=lambda v: jnp.arctan(v) / half_pi,
    )


def vp(big_b: float = 20.0, small_b: float = 0.1) -> Scheduler:
    """Variance-Preserving scheduler (eq. 60).

    alpha_t = xi_{1-t}, sigma_t = sqrt(1 - xi_{1-t}^2),
    xi_s = exp(-s^2 (B - b)/4 - s b / 2), with B=20, b=0.1.
    """

    def xi(s: Array) -> Array:
        return jnp.exp(-0.25 * s**2 * (big_b - small_b) - 0.5 * s * small_b)

    def alpha(t: Array) -> Array:
        return xi(1.0 - t)

    def sigma(t: Array) -> Array:
        return jnp.sqrt(jnp.maximum(1.0 - xi(1.0 - t) ** 2, 1e-20))

    def snr_inverse(v: Array) -> Array:
        # snr = xi / sqrt(1 - xi^2)  =>  xi = v / sqrt(1 + v^2)
        # log xi = -(B-b)/4 s^2 - b/2 s  => quadratic in s = 1 - t.
        log_xi = jnp.log(v) - 0.5 * jnp.log1p(v**2)
        a_q = 0.25 * (big_b - small_b)
        b_q = 0.5 * small_b
        # a_q s^2 + b_q s + log_xi = 0, take the positive root.
        disc = jnp.sqrt(jnp.maximum(b_q**2 - 4.0 * a_q * log_xi, 0.0))
        s = (-b_q + disc) / (2.0 * a_q)
        return 1.0 - s

    return Scheduler(name="vp", alpha=alpha, sigma=sigma, snr_inverse=snr_inverse)


def ve(sigma_max: float = 80.0) -> Scheduler:
    """Variance-Exploding / EDM target scheduler (eq. 16).

    alpha_r = 1, sigma_r = sigma_max (1 - r). Note alpha_0 != 0; this is the
    *target* of EDM's scheduler change, valid as such (the paper, sec 3.3.2).
    """

    return Scheduler(
        name="ve",
        alpha=lambda t: jnp.ones_like(t),
        sigma=lambda t: sigma_max * (1.0 - t),
        # snr = 1 / (sigma_max (1 - r))  =>  r = 1 - 1/(sigma_max v)
        snr_inverse=lambda v: 1.0 - 1.0 / (sigma_max * v),
    )


def scaled_sigma(base: Scheduler, sigma0: float) -> Scheduler:
    """Preconditioning scheduler change of eq. 14: sigma->sigma0*sigma, alpha kept.

    Corresponds to a source distribution with std sigma0.
    """

    return Scheduler(
        name=f"{base.name}_s{sigma0:g}",
        alpha=base.alpha,
        sigma=lambda t: sigma0 * base.sigma(t),
        # snr_new(t) = snr_base(t)/sigma0  =>  inverse(v) = base_inverse(v*sigma0)
        snr_inverse=lambda v: base.snr_inverse(v * sigma0),
    )


_REGISTRY: dict[str, Callable[[], Scheduler]] = {
    "fm_ot": fm_ot,
    "fm_cs": fm_cs,
    "vp": vp,
    "ve": ve,
}


def get_scheduler(name: str) -> Scheduler:
    if name not in _REGISTRY:
        raise KeyError(f"unknown scheduler {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()
