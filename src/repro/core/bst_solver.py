"""Bespoke Scale-Time (BST) solvers (Shaul et al. 2023) — the prior
solver-distillation baseline the paper compares against (Figs. 4, 11).

A BST solver is a generic base solver (here: Euler or Midpoint) applied to an
ST-transformed field whose (t_r, s_r) — and their derivatives — are free
per-knot parameters. Written as a taxonomy program, so (a) it trains with the
same Algorithm-2 harness as BNS and (b) it converts exactly to NS parameters,
demonstrating the ST ⊂ NS inclusion of Theorem 3.2 on the *trained* solver.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.st_transform import STTransform

Array = jax.Array


class BSTParams(NamedTuple):
    """Unconstrained BST parameters at k knots (k = #evals + 1).

    time_logits: (k-1,) -> positive increments, cumsum -> t grid with t_0=0, t_{k-1}=1.
    log_s:       (k,)   -> s = exp(log_s) > 0 at each knot.
    log_dt:      (k,)   -> t' = exp(log_dt) > 0 (monotone time reparam).
    ds:          (k,)   -> s' unconstrained.
    """

    time_logits: Array
    log_s: Array
    log_dt: Array
    ds: Array


class BSTKnots(NamedTuple):
    t: Array   # (k,)
    s: Array   # (k,)
    dt: Array  # (k,)
    ds: Array  # (k,)


def materialize_bst(p: BSTParams) -> BSTKnots:
    d = jax.nn.softmax(p.time_logits)
    t = jnp.concatenate([jnp.zeros((1,), d.dtype), jnp.cumsum(d)])
    return BSTKnots(t=t, s=jnp.exp(p.log_s), dt=jnp.exp(p.log_dt), ds=p.ds)


def knot_positions(num_evals: int, base: str = "euler") -> Array:
    """r-positions of the knots for a given base solver."""
    if base == "euler":
        return jnp.linspace(0.0, 1.0, num_evals + 1)
    if base == "midpoint":
        assert num_evals % 2 == 0, "midpoint BST needs an even NFE"
        return jnp.linspace(0.0, 1.0, num_evals + 1)  # 2m+1 knots incl. midpoints
    raise KeyError(base)


def identity_bst(num_evals: int, base: str = "euler") -> BSTParams:
    """BST initialized at the identity ST transform (== plain base solver)."""
    k = knot_positions(num_evals, base).shape[0]
    return BSTParams(
        time_logits=jnp.zeros((k - 1,)),
        log_s=jnp.zeros((k,)),
        log_dt=jnp.zeros((k,)),
        ds=jnp.zeros((k,)),
    )


def from_st_transform(st: STTransform, num_evals: int, base: str = "euler") -> BSTParams:
    """Initialize BST knots from a continuous ST transform (e.g. sigma0 precond)."""
    r = knot_positions(num_evals, base)
    t = jax.vmap(st.t)(r)
    gaps = jnp.maximum(jnp.diff(t), 1e-6)
    return BSTParams(
        time_logits=jnp.log(gaps),
        log_s=jnp.log(jnp.maximum(jax.vmap(st.s)(r), 1e-8)),
        log_dt=jnp.log(jnp.maximum(jax.vmap(st.dt)(r), 1e-6)),
        ds=jax.vmap(st.ds)(r),
    )


def bst_euler_program(be, knots: BSTKnots) -> None:
    """ST-Euler with per-knot parameters; r-grid uniform on [0,1].

    x_bar_{i+1} = x_bar_i + h [ (s'_i/s_i) x_bar_i + t'_i s_i u_{t_i}(x_bar_i/s_i) ]
    with x_bar maintained implicitly: trajectory points are x_i = x_bar_i/s_i.
    """
    k = knots.t.shape[0]
    n = k - 1
    h = 1.0 / n
    xbar = be.combine([(knots.s[0], be.initial())])
    for i in range(n):
        x = be.combine([(1.0 / knots.s[i], xbar)])
        u = be.eval_u(knots.t[i], x)
        xbar = be.combine([
            (1.0 + h * knots.ds[i] / knots.s[i], xbar),
            (h * knots.dt[i] * knots.s[i], u),
        ])
    be.finalize(be.combine([(1.0 / knots.s[n], xbar)]))


def bst_midpoint_program(be, knots: BSTKnots) -> None:
    """ST-Midpoint: knots at every eval point (2 per interval + endpoint).

    knots arrays have length 2m+1 for m intervals; evals at knots 0,1,3,5,...
    """
    k = knots.t.shape[0]
    assert k % 2 == 1, "midpoint BST needs an odd number of knots (2m+1)"
    m = (k - 1) // 2
    h = 1.0 / m
    xbar = be.combine([(knots.s[0], be.initial())])
    for i in range(m):
        lo, mid, hi = 2 * i, 2 * i + 1, 2 * i + 2
        x = be.combine([(1.0 / knots.s[lo], xbar)])
        u1 = be.eval_u(knots.t[lo], x)
        xbar_mid = be.combine([
            (1.0 + 0.5 * h * knots.ds[lo] / knots.s[lo], xbar),
            (0.5 * h * knots.dt[lo] * knots.s[lo], u1),
        ])
        xm = be.combine([(1.0 / knots.s[mid], xbar_mid)])
        u2 = be.eval_u(knots.t[mid], xm)
        xbar = be.combine([
            (1.0 + h * knots.ds[mid] / knots.s[mid], xbar),
            (h * knots.dt[mid] * knots.s[mid], u2),
        ])
    be.finalize(be.combine([(1.0 / knots.s[k - 1], xbar)]))
