"""Tuned launch profile: XLA flag set + allocator preload for serving.

``serve.py --profile tuned`` re-executes the process once with a serving-
oriented environment before JAX initializes:

* ``TUNED_XLA_FLAGS`` — the XLA GPU flags production serving stacks ship
  with (triton softmax fusion + gemm autotuning, async collectives, the
  latency-hiding scheduler, highest-priority async stream). Harmless
  no-ops on CPU/TPU backends: XLA parses and ignores flags that do not
  apply to the active backend.
* tcmalloc — host-side allocator preload (``LD_PRELOAD``), applied only
  when one of the known shared-object paths exists on this machine. The
  large-alloc report threshold is raised so steady-state serving does not
  spam warnings for big host buffers.

Everything except the ``os.execv`` is pure and unit-testable:
``merge_xla_flags`` / ``apply_profile`` build the target environment
mapping without touching the process. ``maybe_reexec`` performs the
actual re-exec, guarded by the ``REPRO_TUNED_REEXEC`` sentinel so the
re-launched process runs straight through.
"""
from __future__ import annotations

import os
import sys

TUNED_XLA_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true",
    "--xla_gpu_triton_gemm_any=True",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)

TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
)

_SENTINEL = "REPRO_TUNED_REEXEC"


def merge_xla_flags(existing: str, extra) -> str:
    """Merge ``extra`` flags into an ``XLA_FLAGS`` string, deduplicating by
    flag NAME (the text before ``=``) — a flag the user already set wins
    over the profile's default for it."""
    merged = []
    seen = set()
    for flag in list(existing.split()) + list(extra):
        name = flag.split("=", 1)[0]
        if name in seen:
            continue
        seen.add(name)
        merged.append(flag)
    return " ".join(merged)


def apply_profile(name: str, env=None) -> dict:
    """Return a COPY of ``env`` (default ``os.environ``) with the named
    profile applied. ``default`` returns the environment untouched;
    ``tuned`` merges ``TUNED_XLA_FLAGS`` into ``XLA_FLAGS`` and preloads
    tcmalloc when one of the candidate paths exists."""
    base = dict(os.environ if env is None else env)
    if name == "default":
        return base
    if name != "tuned":
        raise ValueError(f"unknown launch profile {name!r}")
    base["XLA_FLAGS"] = merge_xla_flags(base.get("XLA_FLAGS", ""),
                                        TUNED_XLA_FLAGS)
    lib = next((p for p in TCMALLOC_CANDIDATES if os.path.exists(p)), None)
    if lib is not None:
        preload = base.get("LD_PRELOAD", "")
        if lib not in preload.split(":"):
            base["LD_PRELOAD"] = f"{preload}:{lib}".strip(":")
        base.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                        "60000000000")
    return base


def maybe_reexec(profile: str, argv=None, log=print) -> None:
    """Re-exec the current interpreter once under the tuned environment.

    No-op for the default profile, and for the re-launched child (the
    ``REPRO_TUNED_REEXEC`` sentinel breaks the loop). ``LD_PRELOAD`` and
    ``XLA_FLAGS`` must be set BEFORE the dynamic loader / XLA parse them,
    which for an already-running process means replacing it."""
    if profile == "default" or os.environ.get(_SENTINEL):
        return
    env = apply_profile(profile)
    env[_SENTINEL] = "1"
    argv = list(sys.argv if argv is None else argv)
    log(f"re-exec under '{profile}' profile: "
        f"XLA_FLAGS={env.get('XLA_FLAGS', '')!r}"
        + (f", LD_PRELOAD={env['LD_PRELOAD']}" if "LD_PRELOAD" in env
           else " (tcmalloc not found, skipped)"))
    os.execve(sys.executable, [sys.executable, "-m", "repro.launch.serve"]
              + argv[1:], env)
