import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, and extract the roofline inputs from the compiled
artifact. The two XLA_FLAGS lines above MUST run before any jax import —
jax locks the device count at first init.

Per combo this produces experiments/dryrun/<arch>__<shape>__<mesh>.json with:
  memory_analysis  — bytes per device (argument/output/temp/peak)
  cost_analysis    — HLO flops / bytes accessed
  collectives      — bytes per collective kind parsed from optimized HLO

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
"""
import argparse
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import hlo as hlo_analysis
from repro.configs import ARCHS, get_config
from repro.configs.base import active_param_count
from repro.core.schedulers import get_scheduler
from repro.distributed import context, sharding
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models import model as M
from repro.optim import adam_init, adam_update

# ---------------------------------------------------------------------------
# Input shapes (assignment table)
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode_long", seq=524_288, batch=1),
}

LONG_WINDOW = 8192   # sliding-window size for dense archs on long_500k

F32, BF16, I32 = jnp.float32, jnp.bfloat16, jnp.int32


def skip_reason(arch: str, shape: str) -> str | None:
    cfg = get_config(arch)
    if shape == "long_500k":
        if cfg.family == "encdec":
            return ("whisper decoder is full-attention with a 448-token "
                    "practical horizon; 500k decode is not meaningful "
                    "(noted in DESIGN.md)")
    return None


def input_specs(arch: str, shape: str, mesh):
    """ShapeDtypeStructs + NamedShardings for every model input of a combo."""
    cfg = get_config(arch)
    info = SHAPES[shape]
    b = batch_axes(mesh)
    b = b if len(b) > 1 else b[0]
    B, S = info["batch"], info["seq"]
    sds = jax.ShapeDtypeStruct

    def sh(spec):
        return NamedSharding(mesh, spec)

    batch_s = b if B >= mesh.devices.size // mesh.shape["model"] else None

    if info["kind"] in ("train", "prefill"):
        specs = {"tokens": sds((B, S), I32, sharding=sh(P(batch_s, None)))}
        fe = cfg.frontend
        if fe is not None:
            key = "frames" if fe.kind == "audio_frames" else "patches"
            specs[key] = sds((B, fe.num_tokens, fe.embed_dim), BF16,
                             sharding=sh(P(batch_s, None, None)))
        return cfg, specs

    # decode kinds: one token + state
    window = 0
    slots = S
    if info["kind"] == "decode_long":
        window = 0 if cfg.family in ("ssm",) else LONG_WINDOW
        slots = LONG_WINDOW if window else S
    state_shape = jax.eval_shape(
        lambda: M.init_decode_state(cfg, B, slots, BF16,
                                    num_frames=(cfg.frontend.num_tokens
                                                if cfg.frontend else 1500)))
    state_spec = sharding.state_specs(state_shape, cfg, mesh, B)
    state = jax.tree.map(
        lambda s, sp: sds(s.shape, s.dtype, sharding=sh(sp)),
        state_shape, state_spec)
    token = sds((B,), I32, sharding=sh(P(batch_s)))
    return cfg, {"token": token, "state": state, "window": window}


# ---------------------------------------------------------------------------
# Step programs
# ---------------------------------------------------------------------------


def build_step(cfg, kind: str, mesh, window: int = 0):
    sched = get_scheduler("fm_ot")

    if kind == "train":
        def train_step(params, opt, batch, rng):
            loss, grads = jax.value_and_grad(
                lambda p: M.cfm_loss(p, cfg, batch, rng, sched, remat=True))(params)
            params, opt = adam_update(grads, opt, params, 1e-4)
            return params, opt, loss
        return train_step

    if kind == "prefill":
        def prefill_step(params, batch):
            # serving prefill: next-token logits only (§Perf: projecting all
            # 32k positions into (B, S, V) f32 dominated prefill traffic)
            return M.lm_apply(params, cfg, batch, last_only=True)
        return prefill_step

    def serve_step(params, token, state):
        return M.decode_apply(params, cfg, token, state, window=window)
    return serve_step


def lower_combo(arch: str, shape: str, mesh, mesh_name: str):
    cfg, specs = input_specs(arch, shape, mesh)
    kind = SHAPES[shape]["kind"]
    params_shape = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg, dtype=BF16))
    p_specs = sharding.param_specs(params_shape, cfg, mesh)
    p_sds = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        params_shape, p_specs)

    if kind == "train":
        step = build_step(cfg, "train", mesh)
        opt_shape = jax.eval_shape(adam_init, params_shape)
        o_specs = sharding.param_specs(opt_shape, cfg, mesh)
        o_sds = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                               sharding=NamedSharding(mesh, sp)),
            opt_shape, o_specs)
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32,
                                   sharding=NamedSharding(mesh, P()))
        with mesh:
            lowered = jax.jit(step).lower(p_sds, o_sds, specs, rng)
    elif kind == "prefill":
        step = build_step(cfg, "prefill", mesh)
        with mesh:
            lowered = jax.jit(step).lower(p_sds, specs)
    else:
        step = build_step(cfg, "decode", mesh, window=specs["window"])
        with mesh:
            lowered = jax.jit(step).lower(p_sds, specs["token"], specs["state"])
    return cfg, lowered


def run_combo(arch: str, shape: str, multi_pod: bool, outdir: str,
              *, seq_par_attn: bool = False, q_chunk: int = 0,
              flash: bool = False, tag: str = "") -> dict:
    mesh_name = ("pod2x16x16" if multi_pod else "pod16x16") + \
        (f"-{tag}" if tag else "")
    reason = skip_reason(arch, shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name}
    if reason:
        rec.update(status="skipped", reason=reason)
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    # always install: batch-pinning constraints are unconditional fixes;
    # seq-parallel attention and q-chunking stay opt-in policies.
    context.install(mesh, seq_parallel_attn=seq_par_attn, q_chunk=q_chunk,
                    flash_attention=flash)
    try:
        cfg, lowered = lower_combo(arch, shape, mesh, mesh_name)
    finally:
        context.clear()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    # trip-count-aware per-device totals (cost_analysis counts loop bodies once)
    deep = hlo_analysis.analyze(hlo_text)
    rec.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        devices=int(mesh.devices.size),
        memory={k: int(getattr(mem, k, 0)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")}
        if mem is not None else None,
        flops_raw=float(cost.get("flops", -1)) if cost else None,
        bytes_raw=float(cost.get("bytes accessed", -1)) if cost else None,
        flops=deep["flops"],
        bytes=deep["bytes"],
        collectives=deep["collectives"],
        param_count=int(cfg.param_count()),
        active_param_count=int(active_param_count(cfg)),
    )
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, f"{arch}__{shape}__{mesh_name}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    # keep the optimized HLO so the analyzer can be iterated offline
    hlo_dir = os.path.join(os.path.dirname(outdir.rstrip("/")), "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    with gzip.open(os.path.join(
            hlo_dir, f"{arch}__{shape}__{mesh_name}.txt.gz"), "wt") as f:
        f.write(hlo_text)
    return rec


def reanalyze(outdir: str):
    """Re-run the HLO analyzer over saved modules (no recompilation)."""
    import glob
    hlo_dir = os.path.join(os.path.dirname(outdir.rstrip("/")), "hlo")
    for path in sorted(glob.glob(os.path.join(hlo_dir, "*.txt.gz"))):
        combo = os.path.basename(path)[:-len(".txt.gz")]
        json_path = os.path.join(outdir, combo + ".json")
        if not os.path.exists(json_path):
            continue
        with gzip.open(path, "rt") as f:
            deep = hlo_analysis.analyze(f.read())
        with open(json_path) as f:
            rec = json.load(f)
        rec.update(flops=deep["flops"], bytes=deep["bytes"],
                   collectives=deep["collectives"])
        with open(json_path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"reanalyzed {combo}: flops={deep['flops']:.3g} "
              f"bytes={deep['bytes']:.3g}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--reanalyze", action="store_true")
    ap.add_argument("--seq-par-attn", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=0)
    ap.add_argument("--flash", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    if args.reanalyze:
        reanalyze(args.outdir)
        return

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = []
    if args.single_pod or not args.multi_pod:
        meshes.append(False)
    if args.multi_pod or args.all:
        meshes.append(True)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_combo(arch, shape, mp, args.outdir,
                                    seq_par_attn=args.seq_par_attn,
                                    q_chunk=args.q_chunk, flash=args.flash,
                                    tag=args.tag)
                    status = rec["status"]
                    extra = (f"compile={rec.get('compile_s')}s "
                             f"flops={rec.get('flops'):.3g}"
                             if status == "ok" else rec.get("reason", ""))
                    print(f"[{status:7s}] {arch} x {shape} x "
                          f"{'multi' if mp else 'single'}: {extra}", flush=True)
                except Exception as e:
                    failures += 1
                    print(f"[FAIL   ] {arch} x {shape} x "
                          f"{'multi' if mp else 'single'}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} combos failed")


if __name__ == "__main__":
    main()
