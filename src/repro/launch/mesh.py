"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
XLA_FLAGS for 512 host devices before any jax import.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — "pod"
composes with "data" as the batch/FSDP axis; "model" stays intra-pod (tensor
parallelism needs the fast ICI domain, the pod axis crosses DCI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU smoke runs of the sharded code path."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    """The composed batch/FSDP axis: ("pod","data") on multi-pod meshes."""
    names = mesh.axis_names
    return tuple(n for n in names if n in ("pod", "data"))
