"""Serving launcher: BNS-accelerated flow sampling or autoregressive decode.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --mode flow \
      --nfe 8 --batch 8 --seq 16 [--ckpt /path/step_N.msgpack] \
      [--solver-artifact /path/solver.msgpack]
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --mode decode \
      --batch 4 --steps 32

Flow mode serves from a saved ``SolverArtifact`` when --solver-artifact
points at an existing file (no retraining on boot); otherwise it distills a
BNS solver (Algorithm 2 on freshly generated RK45 pairs), saves the artifact
(to --solver-artifact or a temp file), and serves from the reloaded copy —
so every serving session exercises the artifact round-trip.
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointer
from repro.configs import get_config
from repro.core.bns import BNSTrainConfig
from repro.core.rk45 import rk45_solve
from repro.core.schedulers import get_scheduler
from repro.data.synthetic import DataConfig, SyntheticTokens
from repro.models import model as M
from repro.serving.engine import DecodeEngine, FlowSampler
from repro.solvers import SolverArtifact, SolverSpec


def _distill_artifact(args, field, cfg) -> SolverArtifact:
    """Algorithm 2 on fresh RK45 pairs; returns the saved-and-reloaded artifact."""
    print(f"distilling BNS solver (NFE={args.nfe}) ...")
    spec = SolverSpec(name="euler", nfe=args.nfe, cfg_scale=args.cfg_scale,
                      mode="bns")
    solve = jax.jit(lambda x: rk45_solve(field.fn, x, rtol=1e-5, atol=1e-5).x1)
    k_tr, k_val = jax.random.split(jax.random.PRNGKey(args.seed + 1))
    shape = (args.batch, args.seq, cfg.latent_dim)
    x0 = jax.random.normal(k_tr, shape)
    x0v = jax.random.normal(k_val, shape)  # held-out: no train/val leak
    res = spec.distill(field, (x0, solve(x0)), (x0v, solve(x0v)),
                       BNSTrainConfig(lr=1e-3, lr_schedule="cosine",
                                      iterations=args.bns_iters, val_every=100,
                                      batch_size=args.batch))
    print(f"solver ready: {res.num_parameters} params, "
          f"val PSNR {res.val_psnr:.2f} dB, {res.wall_seconds:.0f}s")
    path = args.solver_artifact or os.path.join(
        tempfile.mkdtemp(prefix="bns_solver_"), "solver.msgpack")
    res.artifact(provenance={"arch": args.arch, "scheduler": args.scheduler,
                             "seed": args.seed,
                             "bns_iters": args.bns_iters}).save(path)
    print(f"solver artifact saved to {path}")
    return SolverArtifact.load(path)


def serve_flow(args) -> None:
    cfg = get_config(args.arch, smoke=args.smoke)
    sched = get_scheduler(args.scheduler)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt:
        params = checkpointer.restore(args.ckpt, params)
        print(f"restored params from {args.ckpt}")

    data = SyntheticTokens(cfg, DataConfig(batch_size=args.batch,
                                           seq_len=args.seq, seed=args.seed))
    cond = data.batch(0)
    field = M.velocity_field(params, cfg, sched, cond, cfg_scale=args.cfg_scale)

    if args.solver_artifact and os.path.exists(args.solver_artifact):
        artifact = SolverArtifact.load(args.solver_artifact)
        print(f"loaded solver artifact {args.solver_artifact}: "
              f"{artifact.spec.mode}/{artifact.spec.name} "
              f"NFE={artifact.spec.nfe}, val PSNR {artifact.val_psnr:.2f} dB "
              f"(no retraining)")
        for key, want in [("arch", args.arch), ("scheduler", args.scheduler)]:
            have = artifact.provenance.get(key)
            if have is not None and have != want:
                print(f"WARNING: artifact was distilled for {key}={have!r} "
                      f"but serving {key}={want!r} — samples will be degraded")
        if artifact.spec.nfe != args.nfe:
            print(f"WARNING: --nfe {args.nfe} ignored; artifact serves at "
                  f"NFE={artifact.spec.nfe}")
    else:
        artifact = _distill_artifact(args, field, cfg)

    sampler = FlowSampler.from_artifact(artifact, params=params, cfg=cfg,
                                        sched=sched)
    for req in range(args.requests):
        t0 = time.time()
        latents = sampler.sample(cond, jax.random.PRNGKey(1000 + req))
        tokens = sampler.nearest_tokens(latents)
        print(f"request {req}: sampled {tokens.shape} in "
              f"{(time.time()-t0)*1e3:.0f} ms ({artifact.spec.nfe} NFE)")


def serve_decode(args) -> None:
    cfg = get_config(args.arch, smoke=args.smoke)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt:
        params = checkpointer.restore(args.ckpt, params)
    engine = DecodeEngine(params=params, cfg=cfg, window=args.window)
    state = engine.init_state(args.batch, args.slots)
    prompt = jnp.zeros((args.batch,), jnp.int32)
    t0 = time.time()
    tokens, _ = engine.greedy(prompt, state, args.steps)
    dt = (time.time() - t0) / args.steps * 1e3
    print(f"decoded {args.steps} tokens x {args.batch} seqs "
          f"({dt:.1f} ms/token); first row: {tokens[0, :8].tolist()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", choices=["flow", "decode"], default="flow")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--scheduler", default="fm_ot")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--solver-artifact", default=None,
                    help="load the solver from this artifact if it exists; "
                         "otherwise distill and save it here")
    ap.add_argument("--nfe", type=int, default=8)
    ap.add_argument("--cfg-scale", type=float, default=0.0)
    ap.add_argument("--bns-iters", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--slots", type=int, default=128)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    (serve_flow if args.mode == "flow" else serve_decode)(args)


if __name__ == "__main__":
    main()
