"""Serving launcher: BNS-accelerated flow sampling or autoregressive decode.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --mode flow \
      --nfe 8 --batch 8 --seq 16 [--ckpt /path/step_N.msgpack]
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --mode decode \
      --batch 4 --steps 32

Flow mode distills a BNS solver on the fly if no solver checkpoint is given
(Algorithm 2 on freshly generated RK45 pairs), then serves batched requests
at exactly --nfe backbone forwards per batch.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointer
from repro.configs import get_config
from repro.core.bns import BNSTrainConfig, psnr, solver_to_ns, train_bns
from repro.core.ns_solver import materialize
from repro.core.rk45 import rk45_solve
from repro.core.schedulers import get_scheduler
from repro.data.synthetic import DataConfig, SyntheticTokens
from repro.models import model as M
from repro.serving.engine import DecodeEngine, FlowSampler


def serve_flow(args) -> None:
    cfg = get_config(args.arch, smoke=args.smoke)
    sched = get_scheduler(args.scheduler)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt:
        params = checkpointer.restore(args.ckpt, params)
        print(f"restored params from {args.ckpt}")

    data = SyntheticTokens(cfg, DataConfig(batch_size=args.batch,
                                           seq_len=args.seq, seed=args.seed))
    cond = data.batch(0)
    field = M.velocity_field(params, cfg, sched, cond, cfg_scale=args.cfg_scale)

    print(f"distilling BNS solver (NFE={args.nfe}) ...")
    key = jax.random.PRNGKey(args.seed + 1)
    x0 = jax.random.normal(key, (args.batch, args.seq, cfg.latent_dim))
    x1 = rk45_solve(field.fn, x0, rtol=1e-5, atol=1e-5).x1
    res = train_bns(field, (x0, x1), (x0, x1),
                    BNSTrainConfig(nfe=args.nfe, init_solver="euler", lr=1e-3,
                                   lr_schedule="cosine",
                                   iterations=args.bns_iters, val_every=100,
                                   batch_size=args.batch))
    print(f"solver ready: {res.num_parameters} params, "
          f"val PSNR {res.val_psnr:.2f} dB, {res.wall_seconds:.0f}s")

    sampler = FlowSampler(params=params, cfg=cfg, sched=sched,
                          solver=materialize(res.params),
                          cfg_scale=args.cfg_scale)
    for req in range(args.requests):
        t0 = time.time()
        latents = sampler.sample(cond, jax.random.PRNGKey(1000 + req))
        tokens = sampler.nearest_tokens(latents)
        print(f"request {req}: sampled {tokens.shape} in "
              f"{(time.time()-t0)*1e3:.0f} ms ({args.nfe} NFE)")


def serve_decode(args) -> None:
    cfg = get_config(args.arch, smoke=args.smoke)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt:
        params = checkpointer.restore(args.ckpt, params)
    engine = DecodeEngine(params=params, cfg=cfg, window=args.window)
    state = engine.init_state(args.batch, args.slots)
    prompt = jnp.zeros((args.batch,), jnp.int32)
    t0 = time.time()
    tokens, _ = engine.greedy(prompt, state, args.steps)
    dt = (time.time() - t0) / args.steps * 1e3
    print(f"decoded {args.steps} tokens x {args.batch} seqs "
          f"({dt:.1f} ms/token); first row: {tokens[0, :8].tolist()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", choices=["flow", "decode"], default="flow")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--scheduler", default="fm_ot")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--nfe", type=int, default=8)
    ap.add_argument("--cfg-scale", type=float, default=0.0)
    ap.add_argument("--bns-iters", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--slots", type=int, default=128)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    (serve_flow if args.mode == "flow" else serve_decode)(args)


if __name__ == "__main__":
    main()
