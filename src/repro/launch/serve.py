"""Serving launcher: BNS-accelerated flow sampling or autoregressive decode.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --mode flow \
      --nfe 8 --batch 8 --seq 16 [--ckpt /path/step_N.msgpack] \
      [--solver-artifact /path/solver.msgpack]
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --mode flow \
      --budgets 4,8,16 --request-budgets 4,16,8   # anytime: one artifact
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --mode decode \
      --batch 4 --steps 32
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --mode decode \
      --gateway --max-slots 4 --requests 8 --decode-lengths 32,8,16

Flow mode routes solver acquisition through a ``SolverZoo``: a saved
``SolverArtifact`` (--solver-artifact, or anything indexed by --zoo-dir) is
loaded without retraining; a miss distills lazily (Algorithm 2 on freshly
generated RK45 pairs), saves the artifact, and serves from the reloaded copy
— so every serving session exercises the artifact round-trip.

With --budgets the solver is a single anytime artifact whose early exits
serve every listed NFE; each request's budget (--request-budgets, cycled)
routes to the matching exit. A requested --nfe / request budget the artifact
does not serve is resolved to the nearest served budget with a WARNING, or
rejected when --strict-nfe is set — never silently ignored.

--gateway serves the same traffic through ``repro.serving.Gateway``: each
request becomes a single-sample submit, the batcher coalesces them by
resolved budget into padded fixed-size batches (--max-batch, --max-wait-ms),
mixed-budget flushes may ride the anytime shared trajectory
(--mixed-budget-policy), and --mesh shards the backbone over a serving mesh
(params via distributed.sharding, batches along the data axes). Each
response prints its (requested, served) budget pair — drift is recorded in
metadata, not just warned. --kernel-update routes the solver update through
the Pallas ns_update kernel. --fleet N federates N per-host gateways behind
one ``repro.serving.fleet.FleetGateway`` (sharded request queue, affinity
routing, work stealing) — the summary adds a fleet stats line.

--slo attaches an ``SLOConfig`` to every gateway tier: --deadline-ms /
--priority stamp each request, infeasible submits fast-reject at the door
(``AdmissionRejected``), queued requests past their deadline are shed
(``DeadlineExceeded``), planning is urgency-ordered, and the continuous
tier preempts strictly-lower-priority slots at anytime exit boundaries.
--stream switches submits to ``submit_stream`` (per-exit-boundary partials
for flow, per-token chunks for decode; the terminal result is bit-identical
to the plain submit). --profile tuned re-executes once under the serving
XLA flag set with tcmalloc preloaded (see ``repro.launch.profile``).

Every gateway mode shares one telemetry plane (``repro.observability``):
--metrics-port serves live Prometheus text + JSON registry snapshots,
--stats-interval N prints a periodic one-line summary through the SAME
formatter that renders each mode's final stats line, --metrics-json dumps
the final snapshot, and --trace-jsonl records per-request lifecycle spans
(submit -> route -> steal -> dispatch -> settle) to a JSONL file.

Decode mode serves batched greedy decode (jit'd multi-token scan). With
--gateway it becomes a multi-user continuous-batching service
(``repro.serving.decode.DecodeGateway``): each request is one prompt
submitted to a fixed pool of --max-slots state slots; finished sequences
free their slot and queued prompts are admitted at the very next engine
step, bit-identical to decoding each prompt alone. --decode-lengths cycles
per-request max_tokens (mixed output lengths are where continuous refill
beats run-to-completion batching). --page-size switches the KV cache to a
shared paged pool (--paged-kernel routes attention through the Pallas
paged-attention kernel), --prefill-chunk controls batched chunked prompt
prefill (0 = token-by-token teacher forcing), and --temperature/--top-k/
--top-p sample instead of greedy argmax (temperature 0 = greedy).
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import jax

from repro.checkpoint import checkpointer
from repro.configs import get_config
from repro.core.bns import BNSTrainConfig
from repro.core.rk45 import rk45_solve
from repro.core.schedulers import get_scheduler
from repro.data.synthetic import DataConfig, SyntheticTokens
from repro.models import model as M
from repro.observability import (
    MetricsServer,
    StatsPrinter,
    TraceRecorder,
    format_stats_line,
)
from repro.serving import (
    AdmissionRejected,
    AnytimeFlowSampler,
    DeadlineExceeded,
    DecodeEngine,
    FlowSampler,
    SLOConfig,
    SolverZoo,
    greedy_demo,
)
from repro.solvers import SolverArtifact, SolverSpec

DEFAULT_NFE = 8


def _start_telemetry(args, gw, prefix: str) -> list:
    """--metrics-port / --stats-interval surfaces around a live gateway.

    Returns the stop callables to run after the traffic loop."""
    stop = []
    if args.metrics_port is not None:
        srv = MetricsServer(gw.metrics_snapshot,
                            port=args.metrics_port).start()
        print(f"metrics: http://127.0.0.1:{srv.port}/metrics "
              "(+ /metrics.json)")
        stop.append(srv.stop)
    if args.stats_interval > 0:
        printer = StatsPrinter(
            lambda: format_stats_line(gw.stats(), prefix=prefix),
            args.stats_interval).start()
        stop.append(printer.stop)
    return stop


def _finish_telemetry(args, gw) -> None:
    """Dump --metrics-json / --trace-jsonl after the traffic loop."""
    import json

    if args.metrics_json:
        with open(args.metrics_json, "w") as fh:
            json.dump(gw.metrics_snapshot(), fh, indent=2, sort_keys=True)
        print(f"metrics snapshot written to {args.metrics_json}")
    rec = getattr(gw, "recorder", None)
    if args.trace_jsonl and rec:
        n = rec.export_jsonl(args.trace_jsonl)
        print(f"trace: {n} events written to {args.trace_jsonl}")


def _requested_spec(args) -> SolverSpec:
    """The solver the CLI asks for: anytime over --budgets, else fixed-NFE BNS."""
    if args.budgets:
        return SolverSpec(name="midpoint", mode="anytime",
                          budgets=args.budgets, cfg_scale=args.cfg_scale)
    return SolverSpec(name="euler", nfe=args.nfe or DEFAULT_NFE,
                      cfg_scale=args.cfg_scale, mode="bns")


def _distill_artifact(args, field, cfg, spec: SolverSpec) -> SolverArtifact:
    """Algorithm 2 on fresh RK45 pairs; returns the saved-and-reloaded artifact."""
    what = (f"anytime solver (budgets={spec.budgets})" if spec.budgets
            else f"BNS solver (NFE={spec.nfe})")
    print(f"distilling {what} ...")
    solve = jax.jit(lambda x: rk45_solve(field.fn, x, rtol=1e-5, atol=1e-5).x1)
    k_tr, k_val = jax.random.split(jax.random.PRNGKey(args.seed + 1))
    shape = (args.batch, args.seq, cfg.latent_dim)
    x0 = jax.random.normal(k_tr, shape)
    x0v = jax.random.normal(k_val, shape)  # held-out: no train/val leak
    res = spec.distill(field, (x0, solve(x0)), (x0v, solve(x0v)),
                       BNSTrainConfig(lr=1e-3, lr_schedule="cosine",
                                      iterations=args.bns_iters, val_every=100,
                                      batch_size=args.batch))
    print(f"solver ready: {res.num_parameters} params, "
          f"val PSNR {res.val_psnr:.2f} dB, {res.wall_seconds:.0f}s")
    path = args.solver_artifact or os.path.join(
        tempfile.mkdtemp(prefix="bns_solver_"), "solver.msgpack")
    res.artifact(provenance={"arch": args.arch, "scheduler": args.scheduler,
                             "seed": args.seed,
                             "bns_iters": args.bns_iters}).save(path)
    print(f"solver artifact saved to {path}")
    return SolverArtifact.load(path)


def _resolve_budget(artifact: SolverArtifact, nfe: int, strict: bool,
                    warned: set) -> int:
    """Route a requested NFE to a budget the artifact serves.

    Exact match passes through; otherwise --strict-nfe rejects, and the
    default picks the nearest served budget with a one-time WARNING per
    distinct mismatch (the old behavior silently ignored --nfe).
    """
    if nfe in artifact.budgets:
        return nfe
    if strict:
        raise SystemExit(f"--strict-nfe: requested NFE {nfe} but the "
                         f"artifact serves {artifact.budgets}")
    near = artifact.nearest_budget(nfe)
    if nfe not in warned:
        warned.add(nfe)
        print(f"WARNING: requested NFE {nfe} not served by the artifact "
              f"(budgets {artifact.budgets}); using nearest budget {near}")
    return near


def serve_flow(args) -> None:
    cfg = get_config(args.arch, smoke=args.smoke)
    sched = get_scheduler(args.scheduler)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt:
        params = checkpointer.restore(args.ckpt, params)
        print(f"restored params from {args.ckpt}")

    data = SyntheticTokens(cfg, DataConfig(batch_size=args.batch,
                                           seq_len=args.seq, seed=args.seed))
    cond = data.batch(0)
    field = M.velocity_field(params, cfg, sched, cond, cfg_scale=args.cfg_scale)

    scan_dirs = [d for d in (args.zoo_dir,
                             os.path.dirname(args.solver_artifact)
                             if args.solver_artifact else None) if d]
    zoo = SolverZoo(capacity=args.zoo_capacity,
                    distill_fn=lambda spec: _distill_artifact(args, field,
                                                              cfg, spec),
                    scan_dirs=scan_dirs)
    if args.solver_artifact and os.path.exists(args.solver_artifact):
        artifact = zoo.put(SolverArtifact.load(args.solver_artifact))
        print(f"loaded solver artifact {args.solver_artifact}: "
              f"{artifact.spec.mode}/{artifact.spec.name} "
              f"budgets={artifact.budgets}, "
              f"val PSNR {artifact.val_psnr:.2f} dB (no retraining)")
        for key, want in [("arch", args.arch), ("scheduler", args.scheduler)]:
            have = artifact.provenance.get(key)
            if have is not None and have != want:
                print(f"WARNING: artifact was distilled for {key}={have!r} "
                      f"but serving {key}={want!r} — samples will be degraded")
        if args.budgets and tuple(sorted(args.budgets)) != artifact.budgets:
            print(f"WARNING: --budgets {','.join(map(str, args.budgets))} "
                  f"ignored; the loaded artifact serves {artifact.budgets}")
    else:
        artifact = zoo.get(_requested_spec(args), log=print)

    update_fn = None
    if args.kernel_update:
        from repro.kernels.ns_update.ops import make_update_fn

        update_fn = make_update_fn(use_kernel=True)
    anytime = artifact.kind == "anytime"
    if anytime:
        sampler = AnytimeFlowSampler.from_artifact(artifact, params=params,
                                                   cfg=cfg, sched=sched,
                                                   update_fn=update_fn)
    else:
        sampler = FlowSampler.from_artifact(artifact, params=params,
                                            cfg=cfg, sched=sched,
                                            update_fn=update_fn)
    warned: set = set()
    if args.request_budgets:
        request_budgets = args.request_budgets
    elif args.nfe is not None:
        # an explicit --nfe is a request, never silently ignored: it routes
        # through _resolve_budget (nearest-with-warning or --strict-nfe)
        request_budgets = (args.nfe,)
    else:
        request_budgets = artifact.budgets
    if args.gateway:
        _serve_gateway(args, sampler, cond, request_budgets)
    else:
        for req in range(args.requests):
            nfe = _resolve_budget(artifact,
                                  request_budgets[req % len(request_budgets)],
                                  args.strict_nfe, warned)
            t0 = time.time()
            key = jax.random.PRNGKey(1000 + req)
            latents = (sampler.sample(cond, key, budget=nfe) if anytime
                       else sampler.sample(cond, key))
            tokens = sampler.nearest_tokens(latents)
            print(f"request {req}: sampled {tokens.shape} in "
                  f"{(time.time()-t0)*1e3:.0f} ms ({nfe} NFE)")
    print(f"zoo stats: hits={zoo.stats.hits} misses={zoo.stats.misses} "
          f"loads={zoo.stats.loads} distills={zoo.stats.distills}")


def _serve_gateway(args, sampler, cond, request_budgets) -> None:
    """Multi-user serving: every request is one coalesced-batch submit."""
    from repro.serving.continuous import ContinuousGateway
    from repro.serving.fleet import FleetGateway
    from repro.serving.gateway import Gateway, Request
    from repro.serving.sharded import serving_mesh

    from repro.serving.tiers import ShapeLadder

    recorder = TraceRecorder() if args.trace_jsonl else None
    slo = (SLOConfig(slack_ms=args.slo_slack,
                     default_cost_ms=args.slo_default_cost_ms)
           if args.slo else None)
    tiers = ShapeLadder.parse(args.tiers) if args.tiers else None

    def make_host(rec=None):
        # the solver artifact is tiny, so every fleet host serves the SAME
        # sampler object — replication is free, distribution is the work
        if args.continuous:
            return ContinuousGateway(
                sampler, max_slots=args.max_slots, max_batch=args.max_batch,
                max_wait_ms=args.max_wait_ms,
                mixed_budget_policy=args.mixed_budget_policy,
                strict_nfe=args.strict_nfe, mesh=serving_mesh(args.mesh),
                recorder=rec, slo=slo, tiers=tiers)
        return Gateway(sampler, max_batch=args.max_batch,
                       max_wait_ms=args.max_wait_ms,
                       mixed_budget_policy=args.mixed_budget_policy,
                       strict_nfe=args.strict_nfe, mesh=serving_mesh(args.mesh),
                       recorder=rec, slo=slo, tiers=tiers)

    if args.fleet > 1:
        # hosts get the recorder through federate() so every hop carries
        # its host name
        gw = FleetGateway({f"h{i}": make_host() for i in range(args.fleet)},
                          recorder=recorder)
    else:
        gw = make_host(rec=recorder)
    gw.start()
    stop_telemetry = _start_telemetry(args, gw, "gateway stats")
    futures = []
    for req in range(args.requests):
        nfe = request_budgets[req % len(request_budgets)]
        row = cond["tokens"][req % cond["tokens"].shape[0]]
        kw = dict(tokens=row, budget=nfe, key=jax.random.PRNGKey(1000 + req),
                  deadline_ms=args.deadline_ms, priority=args.priority)
        try:
            futures.append(gw.submit_stream(**kw) if args.stream
                           else gw.submit(Request(**kw)))
        except AdmissionRejected as e:
            print(f"request {req}: REJECTED at admission ({e})")
            futures.append(None)
        except ValueError as e:
            raise SystemExit(f"--strict-nfe: {e}")
    gw.shutdown()
    for i, fut in enumerate(futures):
        if fut is None:
            continue
        try:
            partials = 0
            if args.stream:
                chunks = fut.chunks(timeout=60.0)
                partials = sum(1 for c in chunks if not c.final)
                meta = chunks[-1].payload.meta
            else:
                meta = fut.result().meta
        except DeadlineExceeded:
            print(f"request {i}: SHED (deadline exceeded in queue)")
            continue
        drift = ("" if meta["requested_budget"] == meta["served_budget"]
                 else f" (requested {meta['requested_budget']})")
        print(f"request {i}: served {meta['served_budget']} NFE{drift}, "
              f"wait {meta['wait_ms']:.1f} ms, "
              f"batch {meta['batch_real']}/{meta['batch_padded']}"
              + (" [mixed]" if meta["mixed"] else "")
              + (f", {partials} streamed partials" if args.stream else ""))
    for fn in stop_telemetry:
        fn()
    stats = gw.stats()
    print(format_stats_line(stats, prefix="gateway stats"))
    if stats.get("cost_est_samples"):
        # admission cost-model calibration: how far the wait estimates
        # stamped at submit landed from the actual settle times
        print(f"admission cost model: |estimate-actual| mean "
              f"{stats['cost_est_error_mean_ms']:.2f} ms / p95 "
              f"{stats['cost_est_error_p95_ms']:.2f} ms over "
              f"{stats['cost_est_samples']} deadline requests")
    _finish_telemetry(args, gw)


def serve_decode(args) -> None:
    cfg = get_config(args.arch, smoke=args.smoke)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt:
        params = checkpointer.restore(args.ckpt, params)
    engine = DecodeEngine(params=params, cfg=cfg, window=args.window,
                          page_size=args.page_size,
                          paged_kernel=args.paged_kernel)
    if args.gateway:
        _serve_decode_gateway(args, engine, cfg)
        return
    tokens, dt = greedy_demo(engine, args.batch, args.steps, args.slots)
    print(f"decoded {args.steps} tokens x {args.batch} seqs "
          f"({dt:.1f} ms/token); first row: {tokens[0, :8].tolist()}")


def _serve_decode_gateway(args, engine, cfg) -> None:
    """Continuous decode batching: every request is one prompt -> state slot."""
    from repro.serving.decode import DecodeGateway, DecodeRequest
    from repro.serving.engine import SamplingParams

    lengths = args.decode_lengths or (args.steps, max(1, args.steps // 2))
    sampling = None
    if args.temperature > 0.0 or args.top_k > 0 or args.top_p < 1.0:
        sampling = SamplingParams(temperature=args.temperature,
                                  top_k=args.top_k, top_p=args.top_p)
    recorder = TraceRecorder() if args.trace_jsonl else None
    gw = DecodeGateway(engine, max_slots=args.max_slots,
                       cache_slots=args.slots,
                       prefill_chunk=args.prefill_chunk,
                       key=jax.random.PRNGKey(args.seed),
                       recorder=recorder,
                       slo=(SLOConfig(
                           slack_ms=args.slo_slack,
                           default_cost_ms=args.slo_default_cost_ms)
                           if args.slo else None))
    gw.start()
    stop_telemetry = _start_telemetry(args, gw, "decode gateway stats")
    futures = []
    for req in range(args.requests):
        prompt = [(3 * req + 1) % cfg.vocab, (5 * req + 2) % cfg.vocab]
        kw = dict(prompt=prompt, max_tokens=lengths[req % len(lengths)],
                  sampling=sampling, deadline_ms=args.deadline_ms,
                  priority=args.priority)
        try:
            futures.append(gw.submit_stream(**kw) if args.stream
                           else gw.submit(DecodeRequest(**kw)))
        except AdmissionRejected as e:
            print(f"request {req}: REJECTED at admission ({e})")
            futures.append(None)
    gw.shutdown()
    for i, fut in enumerate(futures):
        if fut is None:
            continue
        try:
            streamed = 0
            if args.stream:
                chunks = fut.chunks(timeout=60.0)
                streamed = sum(1 for c in chunks if not c.final)
                meta = chunks[-1].payload.meta
            else:
                meta = fut.result().meta
        except DeadlineExceeded:
            print(f"request {i}: SHED (deadline exceeded in queue)")
            continue
        print(f"request {i}: {meta['new_tokens']} tokens "
              f"({meta['finish_reason']}), wait {meta['wait_ms']:.1f} ms, "
              f"slot {meta['slot']}, join_step {meta['join_step']}"
              + (f", {streamed} streamed tokens" if args.stream else ""))
    for fn in stop_telemetry:
        fn()
    print(format_stats_line(gw.stats(), prefix="decode gateway stats"))
    _finish_telemetry(args, gw)


def _budget_list(text: str) -> tuple[int, ...]:
    try:
        budgets = tuple(int(tok) for tok in text.split(",") if tok.strip())
    except ValueError:
        raise argparse.ArgumentTypeError(f"bad budget list {text!r}")
    if not budgets or any(b < 1 for b in budgets):
        raise argparse.ArgumentTypeError(f"bad budget list {text!r}")
    return budgets


def build_parser() -> argparse.ArgumentParser:
    """The full serve.py CLI. A separate builder so tests (and the docs
    drift guard in ``tests/test_docs.py``) can enumerate every flag
    without running the launcher."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", choices=["flow", "decode"], default="flow")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--scheduler", default="fm_ot")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--solver-artifact", default=None,
                    help="load the solver from this artifact if it exists; "
                         "otherwise distill and save it here")
    ap.add_argument("--nfe", type=int, default=None,
                    help="requested NFE budget (default: the artifact's own; "
                         f"distillation defaults to {DEFAULT_NFE})")
    ap.add_argument("--budgets", type=_budget_list, default=None,
                    help="serve an anytime solver at these NFE budgets, "
                         "e.g. 4,8,16 (one shared artifact, per-request "
                         "budget routing)")
    ap.add_argument("--request-budgets", type=_budget_list, default=None,
                    help="per-request NFE budgets, cycled over --requests "
                         "(default: cycle the artifact's budgets)")
    ap.add_argument("--strict-nfe", action="store_true",
                    help="reject budgets the artifact does not serve instead "
                         "of routing to the nearest one")
    ap.add_argument("--zoo-dir", default=None,
                    help="scan this directory for saved solver artifacts")
    ap.add_argument("--zoo-capacity", type=int, default=4)
    ap.add_argument("--gateway", action="store_true",
                    help="serve requests through the coalescing batch "
                         "gateway (one single-sample submit per request)")
    ap.add_argument("--fleet", type=int, default=1,
                    help="gateway: federate this many per-host gateways "
                         "behind one FleetGateway (sharded queue, affinity "
                         "routing, work stealing); 1 = single gateway")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="gateway: coalesce at most this many requests")
    ap.add_argument("--max-wait-ms", type=float, default=10.0,
                    help="gateway: flush partial batches after this wait")
    ap.add_argument("--continuous", action="store_true",
                    help="gateway: continuous batching — admit requests "
                         "into in-flight anytime trajectories at exit "
                         "boundaries instead of waiting for the next flush "
                         "(needs an anytime --budgets artifact)")
    ap.add_argument("--max-slots", type=int, default=8,
                    help="continuous gateway: trajectory slot count (batch "
                         "width of the shared anytime trajectory); decode "
                         "gateway: sequence slot count")
    ap.add_argument("--decode-lengths", type=_budget_list, default=None,
                    help="decode gateway: per-request max_tokens, cycled "
                         "over --requests (default: --steps and --steps/2 — "
                         "mixed lengths exercise continuous slot refill)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="decode: paged KV cache page size in tokens "
                         "(0 = dense per-slot cache); must divide --slots")
    ap.add_argument("--paged-kernel", action="store_true",
                    help="decode: route paged attention through the Pallas "
                         "paged-attention kernel (interpret mode off-TPU)")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="decode gateway: batched prefill chunk width in "
                         "tokens (0 = legacy token-by-token teacher "
                         "forcing)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="decode gateway: sampling temperature "
                         "(0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="decode gateway: keep only the k most likely "
                         "tokens before sampling (0 = no cap)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="decode gateway: nucleus sampling threshold "
                         "(1.0 = no cap)")
    ap.add_argument("--tiers", default=None,
                    help="gateway modes (flow): shape-tier ladder rungs, "
                         "e.g. 8,16,32 — requests pad their position axis "
                         "to the smallest rung that fits, so near-shapes "
                         "share flush buckets / trajectory slots / fleet "
                         "homes; responses are cropped back (bit-identical "
                         "to the native shape); longer than the top rung "
                         "is rejected at submit (default: exact shapes)")
    ap.add_argument("--mixed-budget-policy", default="auto",
                    choices=["never", "auto", "always"],
                    help="gateway: route multi-budget flushes through the "
                         "anytime shared trajectory (never/auto/always)")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "host", "production", "multipod"],
                    help="gateway: shard the backbone over this serving "
                         "mesh; 'none' = single-device jit")
    ap.add_argument("--kernel-update", action="store_true",
                    help="route the NS solver update through the Pallas "
                         "ns_update kernel (interpret mode off-TPU)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="gateway modes: serve /metrics (Prometheus text) "
                         "and /metrics.json on this port while traffic "
                         "runs (0 = ephemeral port, printed at start)")
    ap.add_argument("--metrics-json", default=None,
                    help="gateway modes: write the final registry snapshot "
                         "to this JSON file after the traffic loop")
    ap.add_argument("--stats-interval", type=float, default=0.0,
                    help="gateway modes: print a one-line stats summary "
                         "every N seconds while traffic runs (0 = off); "
                         "the same formatter renders the final line of "
                         "every mode")
    ap.add_argument("--trace-jsonl", default=None,
                    help="gateway modes: record per-request lifecycle "
                         "spans (submit/route/steal/dispatch/settle) and "
                         "export them to this JSONL file")
    ap.add_argument("--slo", action="store_true",
                    help="gateway modes: attach an SLOConfig — fast-reject "
                         "admission control, deadline shedding, urgency-"
                         "ordered planning, and (continuous tier) exit-"
                         "boundary preemption; rejected/shed requests are "
                         "reported per request, not raised")
    ap.add_argument("--slo-slack", type=float, default=0.0,
                    help="with --slo: safety margin in ms subtracted from "
                         "every deadline before the admission/shedding "
                         "comparison (SLOConfig.slack_ms)")
    ap.add_argument("--slo-default-cost-ms", type=float, default=0.0,
                    help="with --slo: per-dispatch cost seeding the "
                         "admission cost model before the first dispatch "
                         "is observed (0 = optimistic: accept everything "
                         "until the histograms warm up); the model then "
                         "self-calibrates, and the final stats report its "
                         "|estimate-actual| error")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="gateway modes: per-request deadline in ms from "
                         "submit; always recorded as goodput vs "
                         "deadline_misses at settle, ENFORCED (admission "
                         "+ shedding) when --slo is set")
    ap.add_argument("--priority", type=int, default=0,
                    help="gateway modes: request priority (higher wins; "
                         "with --slo on the continuous tier, strictly "
                         "higher priority preempts lower at anytime exit "
                         "boundaries)")
    ap.add_argument("--stream", action="store_true",
                    help="gateway modes: submit via submit_stream and "
                         "report streamed increments — per-exit-boundary "
                         "partial latents (flow) or per-token chunks "
                         "(decode); the terminal result is bit-identical "
                         "to the plain submit")
    ap.add_argument("--profile", default="default",
                    choices=["default", "tuned"],
                    help="launch profile: 'tuned' re-execs once with the "
                         "serving XLA flag set merged into XLA_FLAGS and "
                         "tcmalloc preloaded when present (see "
                         "repro.launch.profile)")
    ap.add_argument("--cfg-scale", type=float, default=0.0)
    ap.add_argument("--bns-iters", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--slots", type=int, default=128)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main() -> None:
    args = build_parser().parse_args()
    if args.profile != "default":
        from repro.launch.profile import maybe_reexec
        maybe_reexec(args.profile)
    (serve_flow if args.mode == "flow" else serve_decode)(args)


if __name__ == "__main__":
    main()
