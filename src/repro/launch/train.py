"""Flow-matching trainer (the paper's training substrate, eq. 56).

Runs on one CPU device with smoke configs and under pjit on the production
mesh with full configs (the dry-run lowers exactly this ``train_step``).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import checkpointer
from repro.configs import get_config
from repro.core.schedulers import get_scheduler
from repro.data.synthetic import DataConfig, SyntheticTokens
from repro.models import model as M
from repro.optim import adam_init, adam_update, warmup_cosine


def make_train_step(cfg, sched, lr_fn, *, grad_clip: float = 1.0):
    def train_step(params, opt, batch, rng):
        def loss_fn(p):
            return M.cfm_loss(p, cfg, batch, rng, sched)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(grads, opt, params, lr_fn(opt.step),
                                  weight_decay=0.01, grad_clip_norm=grad_clip)
        return params, opt, loss

    return train_step


def train(arch: str, *, smoke: bool = True, steps: int = 50, batch: int = 8,
          seq: int = 64, lr: float = 3e-4, scheduler: str = "fm_ot",
          ckpt_dir: str | None = None, ckpt_every: int = 50, seed: int = 0,
          log=print):
    cfg = get_config(arch, smoke=smoke)
    sched = get_scheduler(scheduler)
    key = jax.random.PRNGKey(seed)
    params = M.init_params(key, cfg)
    opt = adam_init(params)
    data = SyntheticTokens(cfg, DataConfig(batch_size=batch, seq_len=seq,
                                           seed=seed))
    lr_fn = warmup_cosine(lr, warmup_steps=max(steps // 10, 1), total_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, sched, lr_fn))

    start = 0
    if ckpt_dir and (latest := checkpointer.latest_step(ckpt_dir)) is not None:
        params = checkpointer.restore(checkpointer.step_path(ckpt_dir, latest),
                                      params)
        start = latest
        log(f"restored step {latest} from {ckpt_dir}")

    losses = []
    t0 = time.time()
    for step in range(start, steps):
        key, sub = jax.random.split(key)
        params, opt, loss = step_fn(params, opt, data.batch(step), sub)
        losses.append(float(loss))
        if (step + 1) % 10 == 0 or step == steps - 1:
            log(f"step {step+1}/{steps} loss={float(loss):.4f} "
                f"({(time.time()-t0)/(step-start+1):.2f}s/step)")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            checkpointer.save(checkpointer.step_path(ckpt_dir, step + 1), params)
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--scheduler", default="fm_ot")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    train(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
          seq=args.seq, lr=args.lr, scheduler=args.scheduler,
          ckpt_dir=args.ckpt_dir, seed=args.seed)


if __name__ == "__main__":
    main()
