"""Jit'd public wrapper: kernel on TPU, interpret-mode kernel or jnp reference
elsewhere. ``make_update_fn`` plugs into ``ns_solver.ns_sample(update_fn=...)``."""
from __future__ import annotations

import jax

from repro.kernels.ns_update.ns_update import ns_update_nd
from repro.kernels.ns_update.ref import ns_update_ref


def fused_ns_update(x0, u, a, w, *, use_kernel: bool = True,
                    interpret: bool | None = None):
    if not use_kernel:
        return ns_update_ref(x0, u, a, w)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ns_update_nd(x0, u, a, w, interpret=interpret)


def make_update_fn(use_kernel: bool = True, interpret: bool | None = None):
    def update_fn(x0, U, a_i, w_i):
        return fused_ns_update(x0, U, a_i, w_i, use_kernel=use_kernel,
                               interpret=interpret)
    return update_fn
