"""Pure-jnp oracle for the NS update kernel."""
import jax
import jax.numpy as jnp


def ns_update_ref(x0: jax.Array, u: jax.Array, a: jax.Array,
                  w: jax.Array) -> jax.Array:
    """x0: (B, ...); u: (n, B, ...); a scalar; w: (n,)."""
    acc = a.astype(jnp.float32) * x0.astype(jnp.float32)
    acc = acc + jnp.tensordot(w.astype(jnp.float32),
                              u.astype(jnp.float32), axes=(0, 0))
    return acc.astype(x0.dtype)
