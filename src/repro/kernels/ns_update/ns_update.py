"""Pallas TPU kernel for the NS solver update rule (paper eq. 11):

    x_{i+1} = a * x0 + sum_{j<=i} w_j U_j

This is the paper's own compute primitive — a memory-bound weighted reduction
over the stored velocity buffer U (n, B, D). Unfused, XLA materializes the
masked-weight broadcast and reads U once per add; the kernel streams each
(block_b, block_d) tile of all n velocity rows through VMEM once and writes
one output tile.

VMEM budget per grid step: (n+1) * block_b * block_d * 4B
(n<=20, 8x512 tiles -> ~344 KiB, well under the ~16 MiB/core budget), with
block_d a multiple of 128 for lane alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(coeff_ref, x0_ref, u_ref, o_ref, *, n: int):
    # coeff_ref: (n+1,) in SMEM — [a, w_0..w_{n-1}]
    acc = coeff_ref[0] * x0_ref[...].astype(jnp.float32)
    for j in range(n):
        acc += coeff_ref[j + 1] * u_ref[j].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_d", "interpret"))
def ns_update(x0: Array, u: Array, a: Array, w: Array, *,
              block_b: int = 8, block_d: int = 512,
              interpret: bool = True) -> Array:
    """x0: (B, D); u: (n, B, D); a: scalar; w: (n,). Returns (B, D).

    Rows of ``w`` beyond the current step must already be zero (the caller
    masks), so the kernel is oblivious to the step index.
    """
    n, B, D = u.shape
    block_b = min(block_b, B)
    block_d = min(block_d, D)
    assert B % block_b == 0 and D % block_d == 0, (B, D, block_b, block_d)
    coeff = jnp.concatenate([a.reshape(1), w]).astype(jnp.float32)
    grid = (B // block_b, D // block_d)
    return pl.pallas_call(
        functools.partial(_kernel, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_b, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((n, block_b, block_d), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, D), x0.dtype),
        interpret=interpret,
    )(coeff, x0, u)


def ns_update_nd(x0: Array, u: Array, a: Array, w: Array, **kw) -> Array:
    """Arbitrary trailing dims: x0 (B, ...), u (n, B, ...)."""
    shape = x0.shape
    x2 = x0.reshape(shape[0], -1)
    u2 = u.reshape(u.shape[0], shape[0], -1)
    # pad feature dim to a 128 multiple for lane alignment
    D = x2.shape[1]
    pad = (-D) % 128
    if pad:
        x2 = jnp.pad(x2, ((0, 0), (0, pad)))
        u2 = jnp.pad(u2, ((0, 0), (0, 0), (0, pad)))
    bd = 512 if (D + pad) % 512 == 0 else 128
    bb = 1
    for c in (8, 4, 2, 1):
        if shape[0] % c == 0:
            bb = c
            break
    out = ns_update(x2, u2, a, w, block_b=bb, block_d=bd, **kw)
    return out[:, :D].reshape(shape)
