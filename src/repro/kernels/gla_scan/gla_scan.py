"""Pallas TPU kernel for the chunked gated linear recurrence (RWKV6 / Mamba2).

    S_t = diag(exp(ld_t)) S_{t-1} + k_t v_t^T ;  o_t = q_t^T S_{t or t-1}

Grid: (B*H, L/chunk) with the chunk axis innermost (sequential) — the (dk,dv)
state lives in VMEM scratch across chunk steps, so the recurrence makes ONE
pass over HBM (the pure-jnp chunked form re-materializes the (c, c, dk) decay
tensor in HBM per chunk; here it stays in VMEM).

All decay exponents are differences of within-chunk cumulative log-decays,
non-positive under the causal mask — numerically bounded for arbitrarily
strong decay (same scheme as the jnp reference).

VMEM per step: chunk*(2 dk + dv) tiles + (c, c, dk) decay cube + (dk, dv)
state: 64*64*64*4B = 1 MiB cube at the default chunk=64, dk=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(q_ref, k_ref, v_ref, ld_ref, o_ref, s_out_ref, s_scr, *,
            chunk: int, inclusive: bool, n_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    q = q_ref[0].astype(jnp.float32)            # (c, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)            # (c, dv)
    ld = ld_ref[0].astype(jnp.float32)          # (c, dk)

    cum = jnp.cumsum(ld, axis=0)                # (c, dk)
    cum_q = cum if inclusive else cum - ld
    S = s_scr[...]                              # (dk, dv)

    o_cross = (q * jnp.exp(cum_q)) @ S          # (c, dv)

    dd = cum_q[:, None, :] - cum[None, :, :]    # (c, c, dk)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = (t_idx >= s_idx) if inclusive else (t_idx > s_idx)
    scores = jnp.einsum("td,sd,tsd->ts", q, k, jnp.exp(jnp.minimum(dd, 0.0)))
    scores = jnp.where(tri, scores, 0.0)
    o_ref[0] = (o_cross + scores @ v).astype(o_ref.dtype)

    cum_end = cum[-1:, :]                       # (1, dk)
    k_scaled = k * jnp.exp(cum_end - cum)       # (c, dk)
    s_scr[...] = jnp.exp(cum_end[0])[:, None] * S + k_scaled.T @ v

    @pl.when(ic == n_chunks - 1)
    def _final():
        s_out_ref[0] = s_scr[...]


@functools.partial(jax.jit,
                   static_argnames=("inclusive", "chunk", "interpret"))
def gla_scan(q: Array, k: Array, v: Array, ld: Array, *,
             inclusive: bool = True, chunk: int = 64,
             interpret: bool = True) -> tuple[Array, Array]:
    """q, k, ld: (B, L, H, dk); v: (B, L, H, dv); L % chunk == 0.

    Returns (o: (B, L, H, dv), final state: (B, H, dk, dv))."""
    B, L, H, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, L)
    assert L % chunk == 0, (L, chunk)
    n_chunks = L // chunk

    def flat(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, L, a.shape[-1])

    qf, kf, vf, ldf = map(flat, (q, k, v, ld))
    grid = (B * H, n_chunks)
    kernel = functools.partial(_kernel, chunk=chunk, inclusive=inclusive,
                               n_chunks=n_chunks)
    o, s_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, chunk, d), lambda bh, ic: (bh, ic, 0))
                  for d in (dk, dk, dv, dk)],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, dk, dv), lambda bh, ic: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, L, dv), v.dtype),
            jax.ShapeDtypeStruct((B * H, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, ldf)
    o = o.reshape(B, H, L, dv).transpose(0, 2, 1, 3)
    return o, s_fin.reshape(B, H, dk, dv)
