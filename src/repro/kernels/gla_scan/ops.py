"""Jit'd wrapper: Pallas GLA scan on TPU, interpret elsewhere, jnp fallback."""
from __future__ import annotations

import jax

from repro.kernels.gla_scan.gla_scan import gla_scan
from repro.models.linear_scan import gla_chunked


def gla(q, k, v, ld, *, inclusive: bool = True, chunk: int = 64,
        use_kernel: bool = True, interpret: bool | None = None):
    if not use_kernel:
        return gla_chunked(q, k, v, ld, inclusive=inclusive, chunk=chunk)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return gla_scan(q, k, v, ld, inclusive=inclusive, chunk=chunk,
                    interpret=interpret)
