"""Pure-jnp oracle: the step-by-step recurrence (models.linear_scan is itself
validated against this same recurrence; the kernel test uses the recurrent
form directly so the oracle is independent of the chunked math)."""
from repro.models.linear_scan import gla_recurrent as gla_ref  # noqa: F401
