"""Pallas TPU paged attention — decode-step attention over a paged KV cache.

vLLM-style PagedAttention: K/V live in a shared pool of fixed-size pages
(``k_pages``/``v_pages``: (num_pages, page_size, KV, hd)) and each sequence
owns a per-slot row of a BLOCK TABLE mapping its logical block index to a
physical page id. One decode step attends each query row over its own pages
only, so per-slot cache memory is the pages the sequence actually uses, not
``max_seq_len`` dense rows.

The block table and per-row lengths ride ``pltpu.PrefetchScalarGridSpec``
scalar prefetch: they are available BEFORE the kernel body, so the K/V
BlockSpec index maps resolve ``block_table[b, i]`` to the physical page to
DMA — the gather never materializes a dense per-row cache. Grid is
(B, KV_heads, num_blocks) with the block axis innermost (sequential on TPU),
carrying the online-softmax running max / normalizer / accumulator for the
G = H/KV grouped query heads in VMEM scratch, exactly like the prefill
flash-attention kernel one file over. Blocks fully past a row's length are
predicated out with ``pl.when`` (the decode twin of the causal block skip).

Rows that are shorter than the pool's widest resident sequence pay only
their own pages: the skip guard reads ``lengths[b]`` from the prefetched
scalars. ``interpret=True`` runs the same kernel off-TPU (CI).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, ps: int, nb: int, scale: float):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]

    # page skip: this row's sequence ends before this block
    @pl.when(i * ps < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)               # (ps, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)               # (ps, hd)
        s = q @ k.T                                          # (G, ps)
        kpos = i * ps + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_scr[...]                                  # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = alpha * acc_scr[...] + p @ v
        m_scr[...] = m_new

    @pl.when(i == nb - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q: Array, k_pages: Array, v_pages: Array,
                    block_table: Array, lengths: Array, *,
                    interpret: bool = True) -> Array:
    """One-token paged decode attention.

    q: (B, KV, G, hd) grouped query heads; k_pages/v_pages:
    (num_pages, page_size, KV, hd) shared page pool; block_table: (B, nb)
    int32 physical page ids per logical block; lengths: (B,) int32 valid
    positions per row (the current token already written). Returns
    (B, KV, G, hd).
    """
    B, KV, G, hd = q.shape
    ps = k_pages.shape[1]
    nb = block_table.shape[1]
    scale = hd ** -0.5
    kernel = functools.partial(_kernel, ps=ps, nb=nb, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # block_table, lengths
        grid=(B, KV, nb),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, h, i, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, i, bt, ln: (bt[b, i], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, i, bt, ln: (bt[b, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, i, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages)
