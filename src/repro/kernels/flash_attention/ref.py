"""Pure-jnp oracles for flash attention (GQA, causal) and paged decode
attention (block-table gather)."""
import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q: (B, H, Lq, hd); k, v: (B, KV, Lk, hd)."""
    B, H, Lq, hd = q.shape
    KV, Lk = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Lq, hd).astype(jnp.float32) * hd**-0.5
    s = jnp.einsum("bkgqh,bksh->bkgqs", qg, k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(Lq)[:, None] >= jnp.arange(Lk)[None, :]
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksh->bkgqh", w, v.astype(jnp.float32))
    return o.reshape(B, H, Lq, hd).astype(q.dtype)


def paged_attention_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        block_table: jax.Array,
                        lengths: jax.Array) -> jax.Array:
    """Dense-gather oracle for the paged decode kernel.

    q: (B, KV, G, hd); k_pages/v_pages: (num_pages, page_size, KV, hd);
    block_table: (B, nb) int32; lengths: (B,) valid positions per row.
    """
    B, KV, G, hd = q.shape
    ps = k_pages.shape[1]
    nb = block_table.shape[1]
    # (B, nb, ps, KV, hd) -> (B, nb*ps, KV, hd): row b's logical positions
    k = k_pages[block_table].reshape(B, nb * ps, KV, hd).astype(jnp.float32)
    v = v_pages[block_table].reshape(B, nb * ps, KV, hd).astype(jnp.float32)
    qf = q.astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkgh,bskh->bkgs", qf, k)
    valid = jnp.arange(nb * ps)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", w, v)
    return o.astype(q.dtype)
