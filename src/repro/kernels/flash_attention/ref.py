"""Pure-jnp oracle for flash attention (GQA, causal)."""
import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q: (B, H, Lq, hd); k, v: (B, KV, Lk, hd)."""
    B, H, Lq, hd = q.shape
    KV, Lk = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Lq, hd).astype(jnp.float32) * hd**-0.5
    s = jnp.einsum("bkgqh,bksh->bkgqs", qg, k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(Lq)[:, None] >= jnp.arange(Lk)[None, :]
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksh->bkgqh", w, v.astype(jnp.float32))
    return o.reshape(B, H, Lq, hd).astype(q.dtype)
