"""Jit'd wrapper for flash attention with layout adaptation to the model's
(B, L, H, hd) convention and kernel/ref dispatch."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def attend(q, k, v, *, causal: bool = True, use_kernel: bool = True,
           interpret: bool | None = None):
    """q: (B, L, H, hd); k, v: (B, L, KV, hd) — model layout."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if not use_kernel:
        out = attention_ref(qt, kt, vt, causal=causal)
    else:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        out = flash_attention(qt, kt, vt, causal=causal, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
