"""Jit'd wrappers for the attention kernels with layout adaptation to the
model's conventions and kernel/ref dispatch."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.paged_attention import paged_attention
from repro.kernels.flash_attention.ref import attention_ref, paged_attention_ref


def attend(q, k, v, *, causal: bool = True, use_kernel: bool = True,
           interpret: bool | None = None):
    """q: (B, L, H, hd); k, v: (B, L, KV, hd) — model layout."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if not use_kernel:
        out = attention_ref(qt, kt, vt, causal=causal)
    else:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        out = flash_attention(qt, kt, vt, causal=causal, interpret=interpret)
    return out.transpose(0, 2, 1, 3)


def paged_attend(q, k_pages, v_pages, block_table, lengths, *,
                 use_kernel: bool = True, interpret: bool | None = None):
    """One-token paged decode attention; q: (B, KV, G, hd) grouped heads,
    k_pages/v_pages: (num_pages, page_size, KV, hd), block_table: (B, nb),
    lengths: (B,). Kernel/oracle dispatch mirrors ``attend``."""
    if not use_kernel:
        return paged_attention_ref(q, k_pages, v_pages, block_table, lengths)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return paged_attention(q, k_pages, v_pages, block_table, lengths,
                           interpret=interpret)
