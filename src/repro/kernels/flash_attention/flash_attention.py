"""Pallas TPU flash attention (causal, GQA) — the one-NFE hot spot.

Online-softmax blocked attention: grid (B, H, Lq/bq, Lk/bk) with the KV-block
axis innermost (sequential on TPU), carrying running max / normalizer /
accumulator in VMEM scratch. Fully-masked causal blocks are predicated out
with ``pl.when`` (upper-triangular block skips — ~2x on long prefill).

GQA is handled in the index map: KV head = q_head // group, so K/V tiles are
never physically repeated. Block shapes default to (128, head_dim) — MXU
aligned (head_dim is 64/80/128 across the pool; 128-multiple lanes come from
bk; for hd=80 archs the MXU pads, noted in DESIGN.md).

VMEM per step: q,k,v tiles + acc ~ (3*bk + 2*bq) * hd * 4B  (~0.5 MiB at
128/128/128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, scale: float, n_k: int, causal: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal block skip: block fully in the future
    run = (not causal) or (ik * bk <= iq * bq + bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        s = q @ k.T                                          # (bq, bk)
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]                                  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = alpha * acc_scr[...] + p @ v
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> Array:
    """q: (B, H, Lq, hd); k, v: (B, KV, Lk, hd); H % KV == 0. Returns q-shaped."""
    B, H, Lq, hd = q.shape
    KV, Lk = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(bq, Lq)
    bk = min(bk, Lk)
    assert Lq % bq == 0 and Lk % bk == 0
    n_k = Lk // bk
    grid = (B, H, Lq // bq, n_k)
    scale = hd ** -0.5
    kernel = functools.partial(_kernel, bq=bq, bk=bk, scale=scale, n_k=n_k,
                               causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
