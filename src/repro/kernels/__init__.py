"""Pallas TPU kernels for the framework's measured compute hot-spots.

Each kernel package: <name>.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd wrapper with kernel/ref dispatch), ref.py (pure-jnp
oracle used by the allclose sweep tests).

  ns_update        — the paper's NS update rule x_{i+1} = a x0 + sum b_j u_j
                     fused into one HBM pass over the velocity buffer
  flash_attention  — blocked online-softmax causal GQA attention (no S x S
                     materialization; the dominant prefill pathology)
  gla_scan         — chunked gated linear recurrence for RWKV6/Mamba2 with
                     the decay cube resident in VMEM (the dominant SSM-train
                     pathology)

Validated with interpret=True on CPU; TPU is the target.
"""
