"""Deterministic synthetic data pipeline.

Offline-reproducible token streams with enough structure that flow-matching
training measurably learns (Zipfian unigram mixture + Markov bigram
structure), plus the stub-frontend embeddings required by the audio/VLM
architectures. Batches are dicts matching ``input_specs`` of the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8
    seq_len: int = 64
    seed: int = 0


def _zipf_probs(vocab: int, alpha: float = 1.2) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return p / p.sum()


class SyntheticTokens:
    """Markov-modulated Zipf token stream (deterministic per seed)."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        rng = np.random.default_rng(data.seed)
        v = cfg.vocab
        self.unigram = _zipf_probs(v)
        # a sparse "bigram boost": each token prefers a few successors
        self.succ = rng.integers(0, v, size=(min(v, 4096), 4))

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(self.data.seed * 100_003 + step)
        B, S = self.data.batch_size, self.data.seq_len
        v = self.cfg.vocab
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = rng.choice(v, size=B, p=self.unigram)
        for s in range(1, S):
            prev = np.minimum(toks[:, s - 1], len(self.succ) - 1)
            use_bigram = rng.random(B) < 0.5
            bigram = self.succ[prev, rng.integers(0, 4, size=B)]
            unigram = rng.choice(v, size=B, p=self.unigram)
            toks[:, s] = np.where(use_bigram, bigram, unigram)
        out = {"tokens": jnp.asarray(toks, jnp.int32)}
        fe = self.cfg.frontend
        if fe is not None:
            emb = rng.standard_normal((B, fe.num_tokens, fe.embed_dim)) * 0.05
            key = "frames" if fe.kind == "audio_frames" else "patches"
            out[key] = jnp.asarray(emb, jnp.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
