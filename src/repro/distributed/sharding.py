"""Sharding rules: parameter and activation PartitionSpecs per architecture.

Strategy (Megatron + FSDP, expert-parallel for MoE):
  * ``model`` axis — tensor parallelism: attention head dims and FFN hidden
    dims column/row sharded; MoE experts sharded (expert parallelism);
    vocab sharded when divisible.
  * batch axes (``data``, composed with ``pod`` on multi-pod meshes) — batch
    sharding for activations and FSDP sharding for weights/optimizer state
    (XLA inserts the per-layer all-gathers inside the layer scan).

Rules are path-pattern based so every family in the zoo is covered by one
table; anything unmatched is replicated (norm scales, biases, small heads).
"""
from __future__ import annotations

import re
from typing import Sequence

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


# (regex, spec builder). ``b`` = composed batch/FSDP axes tuple (or None on
# 1-axis meshes), "model" literal for the tensor axis. Specs are written for
# STACKED layer params (leading L axis); the leading None also matches
# unstacked 2-D tensors because GSPMD right-aligns...  we instead generate
# specs of exactly the right rank in ``spec_for``.
_COL = "col"      # (..., d_in, d_out_sharded)   -> P(*, b, model)
_ROW = "row"      # (..., d_in_sharded, d_out)   -> P(*, model, b)
_EXPERT_COL = "expert_col"   # (L, E, d, d_e) -> P(None, model, b, None)
_EXPERT_ROW = "expert_row"   # (L, E, d_e, d) -> P(None, model, None, b)
_VOCAB_IN = "vocab_in"       # (V, d) embeddings
_VOCAB_OUT = "vocab_out"     # (d, V) lm head
_HEADS = "heads"             # (L, nheads) per-head scalars
_DINNER = "dinner"           # (L, d_inner) vectors sharded on model
_REPL = "repl"

_RULES: Sequence[tuple[str, str]] = (
    (r".*attn/wq$", _COL),
    (r".*attn/wk$", _COL),
    (r".*attn/wv$", _COL),
    (r".*attn/wo$", _ROW),
    (r".*mlp/w_gate$", _COL),
    (r".*mlp/w_up$", _COL),
    (r".*mlp/w_down$", _ROW),
    (r".*mlp/w1$", _COL),
    (r".*mlp/w2$", _ROW),
    (r".*moe/router$", "router"),
    (r".*moe/w_gate$", _EXPERT_COL),
    (r".*moe/w_up$", _EXPERT_COL),
    (r".*moe/w_down$", _EXPERT_ROW),
    (r".*tm/w[rkvg]$", _COL),
    (r".*tm/wo$", _ROW),
    (r".*cm/wk$", _COL),
    (r".*cm/wv$", _ROW),
    (r".*cm/wr$", _COL),
    (r".*in_proj$", _COL),
    (r".*out_proj$", _ROW),
    (r".*conv_w$", "conv"),
    (r".*(A_log|dt_bias|/D)$", _HEADS),
    (r".*gate_norm$", _DINNER),
    (r".*projector/w[12]$", _COL),
    (r"^embed$", _VOCAB_IN),
    (r".*latent_embed$", _VOCAB_IN),
    (r"^lm_head$", _VOCAB_OUT),
    (r".*proj_in$", _COL),
    (r".*proj_out$", _ROW),
    (r".*time_w1$", _COL),
    (r".*time_w2$", _ROW),
)


def param_specs(params_shape, cfg: ModelConfig, mesh) -> object:
    """Pytree of PartitionSpec matching ``params_shape`` (shapes or arrays)."""
    from repro.launch.mesh import batch_axes

    b = batch_axes(mesh)
    b = b if len(b) > 1 else (b[0] if b else None)
    model_parts = mesh.shape["model"]

    def spec_for(path, leaf) -> P:
        name = _path_str(path)
        ndim = len(leaf.shape)
        kind = _REPL
        for pat, k in _RULES:
            if re.match(pat, name):
                kind = k
                break
        if kind == _REPL or ndim <= 1:
            return P()
        if kind == _COL:
            # (..., d_in, d_out): FSDP on d_in, tensor on d_out — if divisible
            din, dout = leaf.shape[-2], leaf.shape[-1]
            fsdp = b if _div(din, mesh, b) else None
            tp = "model" if dout % model_parts == 0 else None
            return P(*(None,) * (ndim - 2), fsdp, tp)
        if kind == _ROW:
            din, dout = leaf.shape[-2], leaf.shape[-1]
            tp = "model" if din % model_parts == 0 else None
            fsdp = b if _div(dout, mesh, b) else None
            return P(*(None,) * (ndim - 2), tp, fsdp)
        if kind == _EXPERT_COL:
            return P(None, "model", b, None)
        if kind == _EXPERT_ROW:
            return P(None, "model", None, b)
        if kind == "router":
            return P(*(None,) * (ndim - 2), b, None)
        if kind == "conv":           # (L, k, conv_dim)
            return P(*(None,) * (ndim - 1), "model")
        if kind == _HEADS:           # (L, n_heads)
            nh = leaf.shape[-1]
            return P(*(None,) * (ndim - 1),
                     "model" if nh % model_parts == 0 else None)
        if kind == _DINNER:
            return P(*(None,) * (ndim - 1), "model")
        if kind == _VOCAB_IN:        # (V, d)
            v = leaf.shape[0]
            return P("model" if v % model_parts == 0 else None, b)
        if kind == _VOCAB_OUT:       # (d, V)
            v = leaf.shape[-1]
            return P(b, "model" if v % model_parts == 0 else None)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def _div(dim: int, mesh, b) -> object:
    if b is None:
        return False
    axes = (b,) if isinstance(b, str) else b
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return dim % total == 0


def state_specs(state_shape, cfg: ModelConfig, mesh, batch: int):
    """PartitionSpecs for decode state pytrees (KV caches / recurrent states).

    Matches on leaf rank/shape within the known state NamedTuples:
      KVCache.k/v           (L, B, slots, KV, hd)
      PagedKVCache.k_pages/v_pages  (L, num_pages, page_size, KV, hd)
        — no batch axis (the pool is shared by every row); shard the KV
        heads on ``model`` when divisible, like the dense cache. The block
        table / per-row index stay replicated: every shard needs the full
        routing to gather its head-shard of any page.
      RWKVState.shift_*     (L, B, d)        wkv (L, B, H, dk, dv)
      HybridState.conv      (L, B, k, conv)  ssm (L, B, nh, ds, hd)
      EncDecState.memory    (B, M, d)
    """
    from repro.launch.mesh import batch_axes

    b = batch_axes(mesh)
    b = b if len(b) > 1 else b[0]
    bt = 1
    for a in (b if isinstance(b, tuple) else (b,)):
        bt *= mesh.shape[a]
    batch_s = b if (batch % bt == 0 and batch >= bt) else None
    mp = mesh.shape["model"]

    def spec_for(path, leaf) -> P:
        name = _path_str(path)
        nd = len(leaf.shape)
        if nd == 0:
            return P()                                     # index scalar
        if name in ("k_pages", "v_pages"):     # (L, pages, page_size, KV, hd)
            kv = leaf.shape[3]
            return P(None, None, None,
                     "model" if kv % mp == 0 else None, None)
        if name == "block_table":                          # (B, blocks) int32
            return P()
        if name in ("k", "v", "kv", "vv"):                 # (L/sites,B,slots,KV,hd)
            kv = leaf.shape[3]
            if kv % mp == 0:
                return P(None, batch_s, None, "model", None)
            if batch_s is None:
                return P(None, None, b, None, None)        # seq-sharded decode
            return P(None, batch_s, "model", None, None)
        if name == "memory":                               # (B, M, d)
            d = leaf.shape[-1]
            return P(batch_s, None, "model" if d % mp == 0 else None)
        if name in ("shift_tm", "shift_cm"):               # (L, B, d)
            return P(None, batch_s, "model")
        if name == "wkv":                                  # (L, B, H, dk, dv)
            h = leaf.shape[2]
            return P(None, batch_s, "model" if h % mp == 0 else None, None, None)
        if name == "conv":                                 # (L, B, k, conv_dim)
            return P(None, batch_s, None,
                     "model" if leaf.shape[-1] % mp == 0 else None)
        if name == "ssm":                                  # (L, B, nh, ds, hd)
            nh = leaf.shape[2]
            return P(None, batch_s, "model" if nh % mp == 0 else None, None, None)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, state_shape)


def batch_spec(mesh, extra_dims: int = 1) -> P:
    """Inputs (B, ...): batch over the composed data axes."""
    from repro.launch.mesh import batch_axes

    b = batch_axes(mesh)
    b = b if len(b) > 1 else b[0]
    return P(b, *(None,) * extra_dims)


def cache_spec(mesh, cfg: ModelConfig, batch: int, *, seq_axis_fallback=True) -> P:
    """KV cache (L, B, slots, KV, hd): shard batch if it divides, heads on
    ``model`` if divisible, else shard the sequence (slots) dim on ``model``
    (distributed-softmax decode)."""
    from repro.launch.mesh import batch_axes

    b = batch_axes(mesh)
    b = b if len(b) > 1 else b[0]
    bt = 1
    for a in (b if isinstance(b, tuple) else (b,)):
        bt *= mesh.shape[a]
    batch_s = b if batch % bt == 0 and batch >= bt else None
    kv_total = cfg.n_kv_heads
    if kv_total % mesh.shape["model"] == 0:
        return P(None, batch_s, None, "model", None)
    if batch_s is None and seq_axis_fallback:
        # batch=1 long-context: shard sequence over data AND model? keep it
        # on data only; model shards nothing here (attention is tiny vs FFN).
        return P(None, None, b, None, None)
    return P(None, batch_s, "model", None, None)
