"""Emulated multi-host devices: the fleet tier's CI substrate.

BNS solver artifacts are tiny (<200 params), so a serving fleet replicates
the solver freely and the hard part — sharded request queues, affinity
routing, work stealing, host join/leave — is pure distribution logic. That
logic is testable on a laptop/CI runner by splitting ONE CPU into many XLA
host-platform devices (the ``--xla_force_host_platform_device_count``
trick; see bayespec's ``config.py`` in SNIPPETS.md) and giving each
emulated "host" its own single-device mesh:

    from repro.distributed import emulate_hosts, host_meshes
    emulate_hosts(8)            # BEFORE anything initializes jax
    import jax                  # now sees 8 CpuDevices
    meshes = host_meshes(4)     # 4 per-host meshes, 2 devices each

The flag is only read when jax creates its backends, so ``emulate_hosts``
must run first — and because the silent failure mode (set the env var,
nothing happens, every "multi-host" test quietly runs on one device) is a
footgun, it RAISES if jax is already initialized instead of no-opping.
CI sets ``XLA_FLAGS`` at the job level for the same reason (conftest.py
imports jax at collection time, long before any test body runs).
"""
from __future__ import annotations

import os
import sys

_FLAG = "--xla_force_host_platform_device_count"


def jax_initialized() -> bool:
    """Whether jax has created a backend yet (reading devices, running any
    computation). Merely ``import jax`` does NOT initialize — XLA_FLAGS can
    still take effect after it."""
    if "jax" not in sys.modules:
        return False
    try:
        from jax._src import xla_bridge
    except Exception:                    # layout moved: assume the worst
        return True
    return bool(getattr(xla_bridge, "_backends", None))


def emulate_hosts(n: int) -> int:
    """Split the CPU platform into ``n`` XLA devices (one per emulated
    fleet host). Must run before jax initializes its backends; raises
    RuntimeError (never silently no-ops) when it is too late for the flag
    to matter. Any other XLA_FLAGS already set are preserved."""
    if n < 1:
        raise ValueError(f"need at least 1 emulated host, got {n}")
    if jax_initialized():
        raise RuntimeError(
            f"emulate_hosts({n}): jax backends are already initialized, so "
            f"{_FLAG} would be silently ignored. Call emulate_hosts before "
            "any jax.devices()/jit/device_put (e.g. first thing in main), "
            "or set XLA_FLAGS in the environment before the process starts "
            f"(CI does: XLA_FLAGS={_FLAG}={n}).")
    kept = [t for t in os.environ.get("XLA_FLAGS", "").split()
            if not t.startswith(f"{_FLAG}=")]
    os.environ["XLA_FLAGS"] = " ".join(kept + [f"{_FLAG}={n}"])
    return n


def host_meshes(n: int, axes: tuple = ("data", "model")):
    """Partition the visible devices into ``n`` per-host meshes (the fleet
    places each host gateway's params on its own mesh). Devices split
    evenly along the first (data) axis; the remaining axes get size 1 —
    intra-host tensor parallelism composes later via real mesh shapes.
    Raises when fewer than ``n`` devices are visible, pointing at
    ``emulate_hosts`` (the footgun this module exists to defuse)."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    if n < 1:
        raise ValueError(f"need at least 1 host, got {n}")
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"host_meshes({n}): only {len(devices)} device(s) visible. "
            f"Call repro.distributed.emulate_hosts({n}) before jax "
            f"initializes (or set XLA_FLAGS={_FLAG}={n}).")
    per = len(devices) // n
    shape = (per,) + (1,) * (len(axes) - 1)
    return [Mesh(np.asarray(devices[i * per:(i + 1) * per]).reshape(shape),
                 axes)
            for i in range(n)]
