"""repro.distributed — sharding rules, ambient sharding context, and the
emulated multi-host substrate the serving fleet tier tests on.

``sharding``  — parameter/activation PartitionSpec tables (Megatron + FSDP);
``context``   — ambient mesh context for model-internal constraints;
``emulate``   — ``emulate_hosts(n)`` (CPU split into n XLA devices, set
                before jax init) and ``host_meshes(n)`` (per-host mesh
                construction for ``repro.serving.fleet``).

Only ``emulate`` is re-exported here: it must be importable without pulling
in jax-touching modules, because ``emulate_hosts`` has to run before jax
initializes its backends.
"""
from repro.distributed.emulate import emulate_hosts, host_meshes, jax_initialized

__all__ = ["emulate_hosts", "host_meshes", "jax_initialized"]
