"""Ambient sharding context for model-internal sharding constraints.

Models are mesh-agnostic; the launcher (dryrun/train) installs a context and
the hot layers place `constrain(x, *spec)` hints. With no context installed
(CPU smoke tests) every helper is a no-op.

Policies (set by the launcher, measured in EXPERIMENTS.md §Perf):
  seq_parallel_attn — shard attention over QUERY POSITIONS on the `model`
      axis instead of heads. Needed when the head counts don't divide the
      tensor axis (e.g. yi-34b: 56 heads / 8 KV on a 16-way axis), where
      GSPMD otherwise replicates the batch and all-reduces S x S score
      tensors.
  q_chunk — blockwise online-softmax attention (flash-style in XLA): bounds
      score-tensor residency for long prefill.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ShardingContext:
    mesh: object
    batch_axes: tuple
    seq_parallel_attn: bool = False
    q_chunk: int = 0
    flash_attention: bool = False   # interpret-mode Pallas (prefill only)


_CTX: Optional[ShardingContext] = None


def install(mesh, *, seq_parallel_attn: bool = False, q_chunk: int = 0,
            flash_attention: bool = False):
    global _CTX
    from repro.launch.mesh import batch_axes
    b = batch_axes(mesh)
    _CTX = ShardingContext(mesh=mesh, batch_axes=b,
                           seq_parallel_attn=seq_parallel_attn,
                           q_chunk=q_chunk, flash_attention=flash_attention)
    return _CTX


def clear():
    global _CTX
    _CTX = None


def active() -> Optional[ShardingContext]:
    return _CTX


def batch_axis():
    if _CTX is None:
        return None
    b = _CTX.batch_axes
    return b if len(b) > 1 else b[0]


def constrain(x, *spec):
    """with_sharding_constraint when a context is installed; else identity.

    "?" entries mean UNCONSTRAINED — GSPMD keeps whatever it inferred for
    that dim (used to pin the batch dim of scan carries without disturbing
    head/model sharding)."""
    if _CTX is None:
        return x
    spec = tuple(P.UNCONSTRAINED if s == "?" else s for s in spec)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, P(*spec)))


def seq_parallel_attn_enabled() -> bool:
    return _CTX is not None and _CTX.seq_parallel_attn


def q_chunk() -> int:
    return _CTX.q_chunk if _CTX is not None else 0


def flash_attention_enabled() -> bool:
    return _CTX is not None and _CTX.flash_attention
