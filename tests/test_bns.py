"""BNS optimization (Algorithm 2): training improves the initial solver,
and the paper's qualitative orderings hold on the analytic toy model."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import ns_solver, schedulers, toy
from repro.core.bns import (
    BNSTrainConfig,
    generate_pairs,
    psnr,
    solver_to_ns,
    train_bns,
    train_bst,
)


@pytest.fixture(scope="module")
def setup():
    sched = schedulers.fm_ot()
    field = toy.mixture_field(sched, toy.two_moons_means(),
                              jnp.full((16,), 0.15), jnp.ones((16,)))
    train = generate_pairs(field, jax.random.PRNGKey(0), 128, (2,))
    val = generate_pairs(field, jax.random.PRNGKey(1), 128, (2,))
    return field, train, val


def baseline_psnr(field, name, nfe, val):
    ns = solver_to_ns(name, nfe, field)
    xh = ns_solver.ns_sample(ns, field.fn, val[0])
    return float(jnp.mean(psnr(xh, val[1])))


def test_bns_beats_all_baselines(setup):
    field, train, val = setup
    cfg = BNSTrainConfig(nfe=8, init_solver="midpoint", iterations=500,
                         val_every=50, batch_size=64, seed=0)
    res = train_bns(field, train, val, cfg)
    baselines = {n: baseline_psnr(field, n, 8, val)
                 for n in ["euler", "midpoint", "ddim", "dpm2m"]}
    assert res.val_psnr > max(baselines.values()) + 1.0, (res.val_psnr, baselines)
    assert res.num_parameters == ns_solver.count_parameters(8)


def test_bns_init_matches_init_solver(setup):
    """Before training, theta0 must reproduce the initial solver exactly."""
    field, _, val = setup
    ns0 = solver_to_ns("midpoint", 8, field)
    theta0 = ns_solver.from_ns(ns0)
    xh = ns_solver.ns_sample(ns_solver.materialize(theta0), field.fn, val[0])
    xh_ref = ns_solver.ns_sample(ns0, field.fn, val[0])
    assert float(jnp.max(jnp.abs(xh - xh_ref))) < 1e-4


def test_bst_improves_base_and_bns_beats_bst(setup):
    """Fig. 11 ablation: NS family (BNS) > ST family (BST), both trained."""
    field, train, val = setup
    cfg = BNSTrainConfig(nfe=8, init_solver="euler", iterations=500,
                         val_every=50, batch_size=64, seed=0)
    bst = train_bst(field, train, val, cfg, base="euler")
    euler = baseline_psnr(field, "euler", 8, val)
    assert bst.val_psnr > euler + 0.5, (bst.val_psnr, euler)
    bns = train_bns(field, train, val, cfg)
    assert bns.val_psnr > bst.val_psnr, (bns.val_psnr, bst.val_psnr)


def test_psnr_increases_with_nfe(setup):
    field, train, val = setup
    scores = []
    for nfe in [4, 8]:
        cfg = BNSTrainConfig(nfe=nfe, init_solver="midpoint", iterations=400,
                             val_every=50, batch_size=64)
        scores.append(train_bns(field, train, val, cfg).val_psnr)
    assert scores[1] > scores[0]


def test_preconditioned_init(setup):
    """sigma0 != 1 initialization still reproduces a valid solver and trains."""
    field, train, val = setup
    cfg = BNSTrainConfig(nfe=8, init_solver="euler", sigma0=2.0, iterations=300,
                         val_every=50, batch_size=64)
    res = train_bns(field, train, val, cfg)
    assert res.val_psnr > baseline_psnr(field, "euler", 8, val)
