"""Unified solver API: registry parity with the legacy solver_to_ns path,
SolverSpec build/distill, and SolverArtifact save/load bit-exactness."""
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import ns_solver, schedulers, toy
from repro.core.bns import BNSTrainConfig, generate_pairs, solver_to_ns
from repro.solvers import (
    SolverArtifact,
    SolverSpec,
    build_ns,
    get_solver,
    solver_names,
)

NFE = 8


@pytest.fixture(scope="module")
def field():
    sched = schedulers.fm_ot()
    return toy.mixture_field(sched, toy.two_moons_means(),
                             jnp.full((16,), 0.15), jnp.ones((16,)))


@pytest.fixture(scope="module")
def pairs(field):
    train = generate_pairs(field, jax.random.PRNGKey(0), 64, (2,))
    val = generate_pairs(field, jax.random.PRNGKey(1), 64, (2,))
    return train, val


def _legacy_solver_to_ns(name, nfe, f, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return solver_to_ns(name, nfe, f, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", solver_names())
def test_registry_matches_solver_to_ns(field, name):
    """spec.build under jit == the old solver_to_ns path (atol 1e-6, NFE 8)."""
    spec = SolverSpec(name, NFE)
    new = spec.build(field)
    old = _legacy_solver_to_ns(name, NFE, field)
    for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(old)):
        assert jnp.array_equal(a, b), name          # identical NS parameters
    x0 = jax.random.normal(jax.random.PRNGKey(0), (16, 2))
    s_new = jax.jit(lambda x: ns_solver.ns_sample(new, field.fn, x))(x0)
    s_old = ns_solver.ns_sample(old, field.fn, x0)
    assert float(jnp.max(jnp.abs(s_new - s_old))) < 1e-6


def test_registry_contents_and_capabilities():
    assert set(solver_names()) == {"euler", "midpoint", "heun", "rk4", "ab2",
                                   "ab4", "ddim", "dpm2m", "edm_heun"}
    assert solver_names(baseline=True) == ["euler", "midpoint", "ddim", "dpm2m"]
    assert solver_names(family="generic", baseline=True) == ["euler", "midpoint"]
    assert get_solver("ddim").needs_scheduler
    assert not get_solver("ddim").supports_sigma0
    assert get_solver("euler").supports_sigma0
    assert get_solver("rk4").evals_per_interval == 4
    assert not get_solver("rk4").valid_nfe(6)


def test_registry_unknown_and_bad_sigma0(field):
    with pytest.raises(KeyError):
        build_ns("nonexistent", NFE, field)
    with pytest.raises(ValueError):
        build_ns("ddim", NFE, field, sigma0=2.0)


def test_solver_to_ns_shim_warns(field):
    with pytest.warns(DeprecationWarning):
        solver_to_ns("euler", NFE, field)


def test_sigma0_preconditioned_build_matches_legacy(field):
    new = SolverSpec("euler", NFE, sigma0=3.0).build(field)
    old = _legacy_solver_to_ns("euler", NFE, field, sigma0=3.0)
    for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(old)):
        assert jnp.array_equal(a, b)


def test_grid_override(field):
    import numpy as np

    grid = np.linspace(0.0, 1.0, NFE + 1) ** 2.0
    spec = SolverSpec("euler", NFE, grid=tuple(grid))
    ns = spec.build(field)
    assert float(jnp.max(jnp.abs(ns.times - jnp.asarray(grid[:-1])))) < 1e-6


# ---------------------------------------------------------------------------
# SolverSpec.distill
# ---------------------------------------------------------------------------


def test_spec_distill_bns_smoke(field, pairs):
    train, val = pairs
    spec = SolverSpec("midpoint", 4, mode="bns")
    cfg = BNSTrainConfig(iterations=80, val_every=20, batch_size=32)
    res = spec.distill(field, train, val, cfg)
    assert res.spec is spec
    assert res.history                      # validation happened
    assert res.num_parameters == ns_solver.count_parameters(4)
    baseline = SolverSpec("midpoint", 4).sampler(field).psnr(val)
    assert res.val_psnr > baseline          # training improved the init
    assert bool(jnp.isfinite(res.ns_params.b).all())


def test_spec_distill_baseline_mode(field, pairs):
    _, val = pairs
    res = SolverSpec("euler", NFE).distill(field, None, val)
    assert res.val_psnr == pytest.approx(
        SolverSpec("euler", NFE).sampler(field).psnr(val))
    assert isinstance(res.ns_params, ns_solver.NSParams)


def test_spec_anytime_normalizes_budgets():
    spec = SolverSpec("midpoint", mode="anytime", budgets=(8, 4))
    assert spec.budgets == (4, 8)
    assert spec.nfe == 8
    with pytest.raises(ValueError):
        SolverSpec("midpoint", mode="anytime")


def test_spec_dict_roundtrip():
    for spec in [SolverSpec("euler", 8),
                 SolverSpec("midpoint", 4, sigma0=2.0, cfg_scale=1.5,
                            mode="bns"),
                 SolverSpec("midpoint", mode="anytime", budgets=(4, 8)),
                 SolverSpec("euler", 8, grid=tuple(i / 8 for i in range(9)))]:
        assert SolverSpec.from_dict(spec.to_dict()) == spec


# ---------------------------------------------------------------------------
# SolverArtifact
# ---------------------------------------------------------------------------


def test_artifact_roundtrip_bit_exact(field, pairs, tmp_path):
    train, val = pairs
    spec = SolverSpec("midpoint", 4, mode="bns")
    res = spec.distill(field, train, val,
                       BNSTrainConfig(iterations=40, val_every=20,
                                      batch_size=32))
    art = res.artifact(provenance={"source": "test"})
    path = str(tmp_path / "solver.msgpack")
    art.save(path)
    art2 = SolverArtifact.load(path)
    assert art2.spec == spec
    assert art2.val_psnr == pytest.approx(res.val_psnr)
    assert art2.provenance == {"source": "test"}
    for a, b in zip(jax.tree.leaves(art.params), jax.tree.leaves(art2.params)):
        assert jnp.array_equal(a, b)
    # sample bit-exactness: the same jit'd program on identical params
    x0 = val[0]
    assert jnp.array_equal(art.sampler(field)(x0), art2.sampler(field)(x0))


def test_artifact_baseline_roundtrip(field, pairs, tmp_path):
    _, val = pairs
    res = SolverSpec("ddim", NFE).distill(field, None, val)
    path = str(tmp_path / "ddim.msgpack")
    res.artifact().save(path)
    art = SolverArtifact.load(path)
    assert art.kind == "ns"
    x0 = val[0]
    assert jnp.array_equal(art.sampler(field)(x0),
                           res.sampler(field)(x0))


def test_artifact_rejects_non_artifact(tmp_path):
    from repro.checkpoint import checkpointer

    path = str(tmp_path / "raw.msgpack")
    checkpointer.save(path, {"w": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        SolverArtifact.load(path)


def test_reduce_to_ns_anytime_error_names_escape_hatch():
    """AnytimeParams cannot reduce to one NSParams — the error must say so
    clearly and point at ns_at_budget (regression: the old message was the
    generic unsupported-type one and serving just crashed)."""
    from repro.core.anytime import init_anytime
    from repro.solvers import ns_at_budget, reduce_to_ns

    theta = init_anytime(None, (2, 4))
    with pytest.raises(TypeError, match="ns_at_budget"):
        reduce_to_ns(theta)
    assert ns_at_budget(theta, (2, 4), 2).n == 2


def test_anytime_artifact_roundtrips_and_serves(field, pairs, tmp_path):
    """Regression: an anytime artifact saved fine but could not be served
    (FlowSampler.from_artifact -> reduce_to_ns -> TypeError). Now every
    budget serves through ns_at_budget / sampler(budget=m)."""
    train, val = pairs
    budgets = (2, 4)
    spec = SolverSpec("midpoint", mode="anytime", budgets=budgets)
    res = spec.distill(field, train, val,
                       BNSTrainConfig(iterations=40, val_every=20,
                                      batch_size=32))
    assert res.budgets == budgets
    path = str(tmp_path / "anytime.msgpack")
    res.artifact(provenance={"source": "test"}).save(path)
    art = SolverArtifact.load(path)
    assert art.kind == "anytime"
    assert art.spec == spec and art.budgets == budgets
    with pytest.raises(TypeError):
        art.ns_params                       # still no single reduction
    x0 = val[0]
    for m in budgets:
        ns = art.ns_at_budget(m)
        assert ns.n == m
        for a, b in zip(jax.tree.leaves(res.ns_at_budget(m)),
                        jax.tree.leaves(ns)):
            assert jnp.array_equal(a, b)    # trained == reloaded
        out = art.sampler(field, budget=m)(x0)
        assert out.shape == x0.shape and bool(jnp.isfinite(out).all())
    # default sampler serves the top budget
    assert jnp.array_equal(art.sampler(field)(x0),
                           art.sampler(field, budget=4)(x0))
    assert art.nearest_budget(3) == 2 and art.nearest_budget(100) == 4
    with pytest.raises(ValueError):
        art.ns_at_budget(3)


def test_flow_sampler_from_artifact(tmp_path):
    from repro.configs import get_config
    from repro.data.synthetic import DataConfig, SyntheticTokens
    from repro.models import model as M
    from repro.serving.engine import FlowSampler

    cfg = get_config("yi-6b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    data = SyntheticTokens(cfg, DataConfig(batch_size=2, seq_len=8))
    batch = data.batch(0)
    field = M.velocity_field(params, cfg, schedulers.fm_ot(), batch)

    res = SolverSpec("midpoint", 4, mode="baseline").distill(
        field, None, (jax.random.normal(jax.random.PRNGKey(1),
                                        (2, 8, cfg.latent_dim)),
                      jnp.zeros((2, 8, cfg.latent_dim))))
    path = str(tmp_path / "serve.msgpack")
    res.artifact().save(path)
    art = SolverArtifact.load(path)

    sampler = FlowSampler.from_artifact(art, params=params, cfg=cfg,
                                        sched=schedulers.fm_ot())
    direct = FlowSampler(params=params, cfg=cfg, sched=schedulers.fm_ot(),
                         solver=res.ns_params)
    key = jax.random.PRNGKey(2)
    assert jnp.array_equal(sampler.sample(batch, key),
                           direct.sample(batch, key))
