"""Serving telemetry layer: metrics registry, per-request tracing, and
the uniform export surfaces (ISSUE 8 acceptance).

Covers the registry primitives (deterministic histogram percentiles,
exact cross-host merge, Prometheus exposition), the one-schema property
across tiers (each ``stats()`` is a projection over a registry snapshot;
the fleet projects over the MERGE of per-host registries), per-request
trace reconstruction — including a fleet-routed STOLEN request's full
hop chain from a JSONL export — and thread-consistency of the counters.
"""
import json
import threading
import urllib.request

import jax
import numpy as np
import pytest

from repro.observability import (
    MetricsRegistry,
    NULL_RECORDER,
    TraceRecorder,
    bucket_bounds_at,
    format_stats_line,
    merge_snapshots,
    read_jsonl,
    to_prometheus,
)
from repro.observability.export import MetricsServer
from repro.serving import DrainTimeout, Gateway, Request
from repro.serving.toy import CountingToySampler, FakeClock


def _gateway(**kw):
    clock = FakeClock()
    sampler = CountingToySampler()
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_wait_ms", 10.0)
    gw = Gateway(sampler, clock=clock, **kw)
    return gw, sampler, clock


def _x0(i, shape=(2,)):
    return jax.random.normal(jax.random.PRNGKey(100 + i), shape)


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------


def test_registry_counter_gauge_histogram_snapshot():
    reg = MetricsRegistry()
    reg.counter("c", "a counter").inc(3)
    reg.gauge("g", "a gauge").set(7.5)
    reg.gauge("lazy", "callback gauge").set_fn(lambda: 11)
    h = reg.histogram("h", "a histogram")
    for v in (1.0, 2.0, 4.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["c"] == 3
    assert snap["g"] == 7.5
    assert snap["lazy"] == 11          # read at snapshot time
    assert snap["h"]["count"] == 3
    assert snap["h"]["sum"] == 7.0
    assert snap["h"]["max"] == 4.0
    assert snap["_meta"]["c"]["type"] == "counter"
    # same (name, labels) returns the same handle; kind mismatch raises
    assert reg.counter("c") is reg.counter("c")
    with pytest.raises(TypeError):
        reg.gauge("c")


def test_histogram_percentile_within_one_bucket_and_clamped_to_max():
    reg = MetricsRegistry()
    h = reg.histogram("w")
    rng = np.random.RandomState(0)
    vals = rng.uniform(0.5, 200.0, size=500)
    for v in vals:
        h.observe(float(v))
    for q in (50.0, 95.0, 99.0):
        got = h.percentile(q)
        exact = float(np.percentile(vals, q))
        lo, hi = bucket_bounds_at(h.bounds, h.buckets, q)
        assert abs(got - exact) <= (hi - lo) + 1e-9
        assert got <= h.max + 1e-12    # interpolation never exceeds max


def test_merge_snapshots_is_exact_and_rejects_bounds_mismatch():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("n").inc(2)
    b.counter("n").inc(5)
    vals_a, vals_b = [1.0, 3.0, 9.0], [2.0, 40.0]
    for v in vals_a:
        a.histogram("w").observe(v)
    for v in vals_b:
        b.histogram("w").observe(v)
    merged = merge_snapshots([a.snapshot(), b.snapshot()])
    assert merged["n"] == 7
    hist = merged["w"]
    assert hist["count"] == 5
    assert hist["sum"] == sum(vals_a) + sum(vals_b)
    assert hist["max"] == 40.0
    # merged percentile == percentile of one registry fed all values
    c = MetricsRegistry()
    for v in vals_a + vals_b:
        c.histogram("w").observe(v)
    assert merged["w"]["p95"] == c.snapshot()["w"]["p95"]
    bad = MetricsRegistry()
    bad.histogram("w", bounds=(1.0, 2.0)).observe(1.5)
    with pytest.raises(ValueError, match="bounds"):
        merge_snapshots([a.snapshot(), bad.snapshot()])


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("done", "completed things").inc(4)
    reg.counter("dispatches", labels={"program": "b4/k2"}).inc(2)
    reg.histogram("w", "waits", bounds=(1.0, 10.0)).observe(0.5)
    reg.histogram("w").observe(5.0)
    text = to_prometheus(reg.snapshot())
    assert "# HELP repro_done completed things" in text
    assert "# TYPE repro_done counter" in text
    assert "repro_done 4" in text
    assert 'repro_dispatches{program="b4/k2"} 2' in text
    # cumulative buckets + +Inf == count
    assert 'repro_w_bucket{le="1"} 1' in text
    assert 'repro_w_bucket{le="10"} 2' in text
    assert 'repro_w_bucket{le="+Inf"} 2' in text
    assert "repro_w_count 2" in text


# ---------------------------------------------------------------------------
# one schema across tiers: stats() is a projection over the registry
# ---------------------------------------------------------------------------


def test_gateway_stats_is_projection_over_registry():
    gw, sampler, clock = _gateway()
    futs = [gw.submit(Request(budget=2, x0=_x0(i))) for i in range(4)]
    clock.advance(1.0)
    while gw.pump():
        pass
    assert all(f.done() for f in futs)
    s = gw.stats()
    snap = gw.metrics_snapshot()
    assert s["completed"] == snap["completed"] == 4
    # wait histogram count == settled (observed exactly where completed
    # increments) — the invariant the CI benches gate
    assert snap["wait_ms"]["count"] == s["completed"]
    assert s["wait_p95_ms"] == snap["wait_ms"]["p95"]
    assert s["jit_programs"] >= 1
    # disabled tracing is the default: no trace on the response
    assert all(f.result().trace is None for f in futs)
    assert not NULL_RECORDER


def test_response_trace_opt_in_records_lifecycle():
    rec = TraceRecorder()
    gw, sampler, clock = _gateway(recorder=rec)
    f_traced = gw.submit(Request(budget=2, x0=_x0(0), trace=True))
    f_plain = gw.submit(Request(budget=2, x0=_x0(1)))
    clock.advance(1.0)
    while gw.pump():
        pass
    names = [e["event"] for e in f_traced.result().trace]
    assert names == ["submit", "dispatch", "settle"]
    dispatch = f_traced.result().trace[1]
    assert dispatch["program"].startswith("b")
    assert f_plain.result().trace is None   # opt-in is per request
    # the recorder still saw BOTH requests (trace= only gates the echo)
    assert len(rec.trace(f_plain.uid)) == 3
    assert rec.open_spans() == {}


def test_zoo_stats_is_view_over_registry_counters():
    from repro.serving import SolverZoo
    from repro.solvers import SolverArtifact, SolverSpec
    from repro.core.anytime import init_anytime

    reg = MetricsRegistry()
    zoo = SolverZoo(capacity=2, metrics=reg)
    art = SolverArtifact(
        spec=SolverSpec("midpoint", mode="anytime", budgets=(2, 4)),
        params=init_anytime(None, (2, 4)), val_psnr=0.0)
    zoo.put(art)
    zoo.get(art.spec)
    assert zoo.stats.hits == 1 and zoo.stats.misses == 0
    assert reg.snapshot()["zoo_hits"] == 1


def test_page_allocator_gauges_ride_the_registry():
    from repro.serving.decode import PageAllocator

    reg = MetricsRegistry()
    alloc = PageAllocator(9)           # page 0 reserved -> 8 usable
    alloc.bind(reg)
    held = alloc.alloc(1)
    held += alloc.alloc(3)
    snap = reg.snapshot()
    assert snap["pages_in_use"] == alloc.in_use == 4
    assert snap["peak_pages"] == alloc.peak == 4
    assert snap["page_pool_total"] == 8
    alloc.free(held[1:])
    assert reg.snapshot()["pages_in_use"] == 1    # lazy: reads live state
    assert reg.snapshot()["peak_pages"] == 4      # high-water sticks


# ---------------------------------------------------------------------------
# drain diagnostics + thread consistency (satellites 2, 3)
# ---------------------------------------------------------------------------


def test_drain_timeout_carries_registry_snapshot_and_open_spans():
    rec = TraceRecorder()
    gw, sampler, clock = _gateway(recorder=rec)
    gw.submit(Request(budget=2, x0=_x0(0)))
    entry = gw.queue.snapshot()
    gw._take(entry)            # wedge: in flight, future never resolves
    with pytest.raises(DrainTimeout) as err:
        gw.drain(timeout=0.05)
    assert err.value.snapshot["submitted"] == 1
    assert err.value.snapshot["inflight"] == 1
    uid = entry[0].uid
    assert uid in err.value.spans   # never settled -> span still open
    assert [e["event"] for e in err.value.spans[uid]] == ["submit"]
    gw._settle(1)
    entry[0].future.set_result(None)
    gw.drain(timeout=5.0)


def test_counters_consistent_under_concurrent_hammer():
    """Threads hammer submit / stats() / pump concurrently: every stats()
    cut must be internally consistent (settled <= submitted, counters
    monotone) and the final histogram count must equal completions."""
    gw, sampler, clock = _gateway(max_batch=4)
    clock.advance(1.0)
    stop = threading.Event()
    errors = []

    def submitter(base):
        for i in range(40):
            gw.submit(Request(budget=2, x0=_x0(base + i)))

    def pumper():
        while not stop.is_set():
            gw.pump()
            clock.advance(0.05)

    def watcher():
        last_submitted = last_completed = 0
        while not stop.is_set():
            s = gw.stats()
            if s["completed"] > s["submitted"]:
                errors.append(f"completed {s['completed']} > "
                              f"submitted {s['submitted']}")
            if (s["submitted"] < last_submitted
                    or s["completed"] < last_completed):
                errors.append("counter went backwards")
            last_submitted, last_completed = s["submitted"], s["completed"]

    threads = ([threading.Thread(target=submitter, args=(100 * k,))
                for k in range(3)]
               + [threading.Thread(target=pumper),
                  threading.Thread(target=watcher)])
    for t in threads:
        t.start()
    for t in threads[:3]:
        t.join()
    gw.drain(timeout=30.0)
    stop.set()
    for t in threads[3:]:
        t.join()
    assert not errors, errors
    s = gw.stats()
    assert s["submitted"] == s["completed"] == 120
    assert gw.metrics_snapshot()["wait_ms"]["count"] == 120


# ---------------------------------------------------------------------------
# fleet: merged registries + stolen-request hop reconstruction
# ---------------------------------------------------------------------------


def _fleet_bench():
    import os
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks import fleet_bench
    return fleet_bench


def test_fleet_stats_equal_merge_of_host_registries():
    fb = _fleet_bench()
    events = fb.schedule("skew16", 24, 2.0, burst=8)
    waits, rows, stats, snap = fb.simulate(events, None, 2.0, 8, 12.0)
    assert stats["completed"] == 24
    assert snap["wait_ms"]["count"] == 24     # merge sums host histograms
    assert stats["wait_p95_ms"] == snap["wait_ms"]["p95"]
    assert stats["hosts"] == 4
    assert sum(stats["routed"].values()) == 24


def test_stolen_request_hop_chain_reconstructable_from_jsonl(tmp_path):
    """The headline tracing acceptance: run the skewed fleet workload with
    stealing, export the trace to JSONL, and reconstruct a STOLEN
    request's full hop sequence — submit -> route (home host) -> steal
    (leaves home) -> inject (lands on thief) -> dispatch -> settle, with
    the dispatch host differing from the routed home."""
    from repro.serving import WorkStealer

    fb = _fleet_bench()
    rec = TraceRecorder()
    events = fb.schedule("skew16", 48, 2.0, burst=8)
    stealer = WorkStealer(min_queue=8, max_steal=4)
    waits, rows, stats, snap = fb.simulate(events, stealer, 2.0, 8, 12.0,
                                           recorder=rec)
    assert stats["steals"] > 0
    path = tmp_path / "trace.jsonl"
    n = rec.export_jsonl(str(path))
    assert n == len(read_jsonl(str(path)))

    by_uid = {}
    for e in read_jsonl(str(path)):
        by_uid.setdefault(e["uid"], []).append(e)
    stolen = {uid: evs for uid, evs in by_uid.items()
              if any(e["event"] == "steal" for e in evs)}
    assert len(stolen) == stats["steals"]
    for uid, evs in stolen.items():
        names = [e["event"] for e in evs]
        assert names == ["submit", "route", "steal", "inject",
                         "dispatch", "settle"], (uid, names)
        hop_host = {e["event"]: e["host"] for e in evs}
        assert hop_host["steal"] == hop_host["route"]    # left its home
        assert hop_host["inject"] != hop_host["steal"]   # landed elsewhere
        assert hop_host["dispatch"] == hop_host["inject"]
        assert evs[-1]["status"] == "completed"
    # every request settled exactly once, stolen or not
    settles = [e for evs in by_uid.values() for e in evs
               if e["event"] == "settle"]
    assert len(settles) == 48


# ---------------------------------------------------------------------------
# export surfaces
# ---------------------------------------------------------------------------


def test_format_stats_line_renders_tier_segments():
    base = {"completed": 4, "submitted": 5, "queue_depth": 1, "batches": 2,
            "mixed_batches": 0, "forwards": 8, "nfe_per_request": 2.0,
            "occupancy": 0.9, "wait_p50_ms": 1.0, "wait_p95_ms": 2.0,
            "max_wait_ms": 3.0, "throughput_rps": 10.0}
    line = format_stats_line(base, prefix="gw")
    assert line.startswith("gw: done=4/5 q=1")
    assert "fleet" not in line and "traj=" not in line
    fleet_line = format_stats_line(
        dict(base, hosts=2, steals=3, steal_rounds=1, rerouted=0,
             routed={"h0": 3, "h1": 2}))
    assert "fleet hosts=2 steals=3" in fleet_line
    assert "routed: h0=3 h1=2" in fleet_line
    decode_line = format_stats_line(
        dict(base, tokens_out=20, tokens_per_s=5.0, slot_occupancy=0.8,
             joins=2, prefill_calls=3, cancelled=0, page_size=8,
             pages_in_use=4, peak_pages=6, peak_kv_per_slot=12.0))
    assert "tokens=20 tok/s=5.0" in decode_line
    assert "paged page_size=8" in decode_line


def test_metrics_server_serves_prometheus_and_json():
    gw, sampler, clock = _gateway()
    f = gw.submit(Request(budget=2, x0=_x0(0)))
    clock.advance(1.0)
    while gw.pump():
        pass
    assert f.done()
    srv = MetricsServer(gw.metrics_snapshot, port=0).start()
    try:
        base = f"http://127.0.0.1:{srv.port}"
        body = urllib.request.urlopen(f"{base}/metrics.json").read()
        snap = json.loads(body)
        assert snap["completed"] == 1
        text = urllib.request.urlopen(f"{base}/metrics").read().decode()
        assert "# TYPE repro_completed counter" in text
        assert "repro_completed 1" in text
        with pytest.raises(Exception):
            urllib.request.urlopen(f"{base}/nope")
    finally:
        srv.stop()
