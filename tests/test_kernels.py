"""Pallas kernel validation (interpret=True on CPU; TPU is the target).

Each kernel is swept over shapes/dtypes and asserted allclose against its
pure-jnp ref.py oracle, plus integration checks (ns_update inside Algorithm 1,
flash attention vs the model's attention path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.paged_attention import paged_attention
from repro.kernels.flash_attention.ref import attention_ref, paged_attention_ref
from repro.kernels.gla_scan.gla_scan import gla_scan
from repro.kernels.gla_scan.ref import gla_ref
from repro.kernels.ns_update.ns_update import ns_update_nd
from repro.kernels.ns_update.ops import make_update_fn
from repro.kernels.ns_update.ref import ns_update_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# ns_update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,B,D", [(4, 8, 512), (8, 2, 1024), (16, 4, 384),
                                   (20, 1, 128)])
def test_ns_update_sweep(n, B, D, dtype):
    key = jax.random.PRNGKey(n * 1000 + B + D)
    ks = jax.random.split(key, 4)
    x0 = jax.random.normal(ks[0], (B, D), dtype)
    u = jax.random.normal(ks[1], (n, B, D), dtype)
    a = jax.random.normal(ks[2], ())
    w = jax.random.normal(ks[3], (n,))
    out = ns_update_nd(x0, u, a, w, interpret=True)
    ref = ns_update_ref(x0, u, a, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype] * n, rtol=TOL[dtype])


def test_ns_update_3d_shapes():
    """Latent-sequence shapes (B, S, C) as used by the flow sampler."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x0 = jax.random.normal(ks[0], (2, 24, 16))        # D = 384, padded to 512
    u = jax.random.normal(ks[1], (8, 2, 24, 16))
    a = jax.random.normal(ks[2], ())
    w = jax.random.normal(ks[3], (8,))
    out = ns_update_nd(x0, u, a, w, interpret=True)
    ref = ns_update_ref(x0, u, a, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_ns_update_inside_algorithm1():
    """Algorithm 1 with the fused kernel == Algorithm 1 with jnp updates."""
    from repro.core import ns_solver, schedulers, toy
    from repro.core.bns import solver_to_ns

    sched = schedulers.fm_ot()
    field = toy.mixture_field(sched, toy.two_moons_means(),
                              jnp.full((16,), 0.15), jnp.ones((16,)))
    x0 = jax.random.normal(jax.random.PRNGKey(0), (4, 2))
    ns = solver_to_ns("midpoint", 8, field)
    base = ns_solver.ns_sample(ns, field.fn, x0)
    fused = ns_solver.ns_sample(ns, field.fn, x0,
                                update_fn=make_update_fn(interpret=True))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(base), atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,L,hd,causal", [
    (1, 4, 2, 256, 64, True),
    (2, 8, 8, 128, 128, True),
    (1, 4, 1, 256, 64, True),      # extreme GQA
    (1, 2, 2, 128, 128, False),    # bidirectional (encoder)
])
def test_flash_attention_sweep(B, H, KV, L, hd, causal, dtype):
    key = jax.random.PRNGKey(B + H + L)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, L, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, L, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, L, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=64, bk=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype] * 4, rtol=TOL[dtype] * 4)


def test_flash_attention_matches_model_attention():
    """Kernel output == the model's einsum attention (same math, no RoPE)."""
    from repro.models.attention import _grouped_attend
    B, H, KV, L, hd = 1, 4, 2, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, L, H, hd))
    k = jax.random.normal(ks[1], (B, L, KV, hd))
    v = jax.random.normal(ks[2], (B, L, KV, hd))
    mask = jnp.broadcast_to(jnp.tril(jnp.ones((L, L), bool)), (B, L, L))
    ref = _grouped_attend(q.reshape(B, L, KV, H // KV, hd), k, v, mask)
    ref = ref.reshape(B, L, H, hd).transpose(0, 2, 1, 3)
    out = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=True, bq=64, bk=64,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


# ---------------------------------------------------------------------------
# paged_attention (decode step over a paged KV cache)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,KV,G,hd,ps,nb", [
    (2, 2, 2, 64, 8, 4),
    (3, 1, 4, 32, 16, 2),      # extreme GQA, two blocks
    (1, 4, 1, 64, 8, 3),       # MQA-free per-head pages
])
def test_paged_attention_sweep(B, KV, G, hd, ps, nb, dtype):
    """Kernel == dense-gather oracle over a shuffled page pool with ragged
    per-row lengths (short rows skip whole pages via the prefetched
    scalars)."""
    key = jax.random.PRNGKey(B * 7 + nb)
    ks = jax.random.split(key, 4)
    num_pages = 1 + B * nb                   # page 0 = reserved trash page
    q = jax.random.normal(ks[0], (B, KV, G, hd), dtype)
    k_pages = jax.random.normal(ks[1], (num_pages, ps, KV, hd), dtype)
    v_pages = jax.random.normal(ks[2], (num_pages, ps, KV, hd), dtype)
    # each row owns nb distinct pages, in shuffled (non-contiguous) order
    perm = jax.random.permutation(ks[3], num_pages - 1)[:B * nb] + 1
    block_table = perm.reshape(B, nb).astype(jnp.int32)
    lengths = jnp.asarray([(i * ps + i + 1) % (nb * ps) + 1
                           for i in range(B)], jnp.int32)
    out = paged_attention(q, k_pages, v_pages, block_table, lengths,
                          interpret=True)
    ref = paged_attention_ref(q, k_pages, v_pages, block_table, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype] * 4, rtol=TOL[dtype] * 4)


def test_paged_attention_ignores_positions_past_length():
    """Garbage in a row's own pages past its length (the overwrite-invariant
    cells) must not leak into the output."""
    B, KV, G, hd, ps, nb = 1, 2, 2, 32, 8, 2
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    k_pages = jax.random.normal(ks[1], (1 + nb, ps, KV, hd))
    v_pages = jax.random.normal(ks[2], (1 + nb, ps, KV, hd))
    table = jnp.asarray([[1, 2]], jnp.int32)
    lengths = jnp.asarray([5], jnp.int32)
    base = paged_attention(q, k_pages, v_pages, table, lengths)
    poisoned_k = k_pages.at[1, 5:].set(1e4).at[2].set(-1e4)
    poisoned_v = v_pages.at[1, 5:].set(1e4).at[2].set(-1e4)
    out = paged_attention(q, poisoned_k, poisoned_v, table, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), atol=1e-5)


# ---------------------------------------------------------------------------
# gla_scan (RWKV6 / Mamba2 recurrence)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("L,chunk,inclusive,dk,dv", [
    (128, 32, True, 64, 64),     # mamba2-style (dk=d_state, dv=head_dim)
    (128, 32, False, 64, 64),    # rwkv6-style exclusive
    (96, 16, False, 32, 48),     # ragged head dims
    (64, 64, True, 16, 128),     # single chunk
])
def test_gla_scan_sweep(L, chunk, inclusive, dk, dv, dtype):
    key = jax.random.PRNGKey(L + chunk)
    ks = jax.random.split(key, 4)
    B, H = 2, 3
    q = jax.random.normal(ks[0], (B, L, H, dk), dtype)
    k = jax.random.normal(ks[1], (B, L, H, dk), dtype)
    v = jax.random.normal(ks[2], (B, L, H, dv), dtype)
    ld = -jnp.abs(jax.random.normal(ks[3], (B, L, H, dk))) * 0.5
    o, s = gla_scan(q, k, v, ld, inclusive=inclusive, chunk=chunk,
                    interpret=True)
    o_ref, s_ref = gla_ref(q, k, v, ld.astype(dtype), inclusive=inclusive)
    tol = TOL[dtype] * 20
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol,
                               rtol=TOL[dtype])
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=tol,
                               rtol=TOL[dtype])


def test_gla_scan_strong_decay_stable():
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    B, L, H, dk, dv = 1, 128, 2, 16, 16
    q = jax.random.normal(ks[0], (B, L, H, dk))
    k = jax.random.normal(ks[1], (B, L, H, dk))
    v = jax.random.normal(ks[2], (B, L, H, dv))
    ld = -jnp.abs(jax.random.normal(ks[3], (B, L, H, dk))) * 30.0
    o, s = gla_scan(q, k, v, ld, inclusive=False, chunk=32, interpret=True)
    o_ref, s_ref = gla_ref(q, k, v, ld, inclusive=False)
    assert bool(jnp.isfinite(o).all()) and bool(jnp.isfinite(s).all())
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-3)
