"""Serving engines: BNS flow sampler (NFE accounting, kernel parity) and the
batched decode engine."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import ns_solver
from repro.core.bns import solver_to_ns
from repro.core.schedulers import fm_ot
from repro.data.synthetic import DataConfig, SyntheticTokens
from repro.models import model as M
from repro.serving.engine import DecodeEngine, FlowSampler


def _setup(arch="yi-6b", batch=2, seq=8):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    data = SyntheticTokens(cfg, DataConfig(batch_size=batch, seq_len=seq))
    return cfg, params, data.batch(0)


def test_flow_sampler_counts_nfe():
    cfg, params, batch = _setup()
    calls = {"n": 0}
    field = M.velocity_field(params, cfg, fm_ot(), batch)
    orig = field.fn

    def counting(t, x):
        calls["n"] += 1
        return orig(t, x)

    solver = solver_to_ns("euler", 4, field)
    x0 = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.latent_dim))
    ns_solver.ns_sample(solver, counting, x0, unroll=True)
    assert calls["n"] == 4   # exactly NFE model forwards per batch


def test_flow_sampler_end_to_end():
    cfg, params, batch = _setup()
    field = M.velocity_field(params, cfg, fm_ot(), batch)
    sampler = FlowSampler(params=params, cfg=cfg, sched=fm_ot(),
                          solver=solver_to_ns("midpoint", 4, field))
    latents = sampler.sample(batch, jax.random.PRNGKey(2))
    assert latents.shape == (2, 8, cfg.latent_dim)
    assert bool(jnp.isfinite(latents).all())
    tokens = sampler.nearest_tokens(latents)
    assert tokens.shape == (2, 8)
    assert int(tokens.max()) < cfg.vocab


def test_flow_sampler_cfg_changes_output():
    cfg, params, batch = _setup()
    f0 = M.velocity_field(params, cfg, fm_ot(), batch, cfg_scale=0.0)
    f2 = M.velocity_field(params, cfg, fm_ot(), batch, cfg_scale=2.0)
    x0 = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.latent_dim))
    s0 = ns_solver.ns_sample(solver_to_ns("euler", 4, f0), f0.fn, x0)
    s2 = ns_solver.ns_sample(solver_to_ns("euler", 4, f2), f2.fn, x0)
    assert float(jnp.max(jnp.abs(s0 - s2))) > 1e-4


def test_decode_engine_greedy_deterministic():
    cfg, params, _ = _setup("rwkv6-7b")
    engine = DecodeEngine(params=params, cfg=cfg)
    state = engine.init_state(batch=3, slots=16)
    toks1, _ = engine.greedy(jnp.zeros((3,), jnp.int32), state, 6)
    state2 = engine.init_state(batch=3, slots=16)
    toks2, _ = engine.greedy(jnp.zeros((3,), jnp.int32), state2, 6)
    np.testing.assert_array_equal(np.asarray(toks1), np.asarray(toks2))
    assert toks1.shape == (3, 6)


def test_decode_engine_batch_isolation():
    """Row i of a batched decode must equal the same row decoded alone."""
    cfg, params, _ = _setup("yi-6b")
    engine = DecodeEngine(params=params, cfg=cfg)
    prompts = jnp.asarray([3, 7], jnp.int32)
    toks_b, _ = engine.greedy(prompts, engine.init_state(2, 16), 5)
    toks_0, _ = engine.greedy(prompts[:1], engine.init_state(1, 16), 5)
    np.testing.assert_array_equal(np.asarray(toks_b[0]), np.asarray(toks_0[0]))
