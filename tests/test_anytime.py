"""Anytime-BNS (beyond-paper): one solver, multiple NFE budgets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ns_solver, schedulers, toy
from repro.core.anytime import (
    anytime_sample, evaluate_anytime, init_anytime, nested_grid, train_anytime,
)
from repro.core.bns import BNSTrainConfig, generate_pairs, psnr, solver_to_ns


@pytest.fixture(scope="module")
def setup():
    sched = schedulers.fm_ot()
    field = toy.mixture_field(sched, toy.two_moons_means(),
                              jnp.full((16,), 0.15), jnp.ones((16,)))
    train = generate_pairs(field, jax.random.PRNGKey(0), 128, (2,))
    val = generate_pairs(field, jax.random.PRNGKey(1), 128, (2,))
    return field, train, val


def test_nested_grid_prefixes_spread():
    g = nested_grid([4, 8, 16])
    assert len(g) == 16 and len(set(g.tolist())) == 16
    # the first m evals of each budget must span [0, 1)
    for m in (4, 8, 16):
        assert g[:m].max() >= 1.0 - 1.0 / m - 1e-9
        assert g[:m].min() == 0.0


def test_prefix_init_matches_generic_solver(setup):
    """mode='prefix' untrained == the initializing generic solver at n=max."""
    field, _, val = setup
    theta = init_anytime(field, [4, 8], "prefix", "midpoint")
    outs = anytime_sample(theta, [4, 8], field.fn, val[0])
    ref8 = ns_solver.ns_sample(solver_to_ns("midpoint", 8, field), field.fn,
                               val[0])
    # time clipping (t=0 -> 0.02) perturbs the first eval slightly
    np.testing.assert_allclose(np.asarray(outs[8]), np.asarray(ref8),
                               atol=2e-2)
    ref4 = ns_solver.ns_sample(solver_to_ns("midpoint", 4, field), field.fn,
                               val[0])
    # NOTE the m=4 exit evaluates on the 8-grid's first 4 times, not the
    # dedicated 4-grid — only the coefficients match, so just check sanity.
    assert bool(jnp.isfinite(outs[4]).all())
    del ref4


def test_anytime_nested_beats_prefix_at_small_budgets(setup):
    field, train, val = setup
    cfg = BNSTrainConfig(nfe=8, init_solver="midpoint", iterations=800,
                         lr=1.5e-3, val_every=200, batch_size=64)
    nested = train_anytime(field, [4, 8], train, val, cfg, mode="nested")
    prefix = train_anytime(field, [4, 8], train, val, cfg, mode="prefix")
    s_nested = evaluate_anytime(nested.params, [4, 8], field, val)
    s_prefix = evaluate_anytime(prefix.params, [4, 8], field, val)
    assert s_nested[4] > s_prefix[4] + 3.0, (s_nested, s_prefix)


def test_anytime_all_budgets_beat_generic_baseline(setup):
    field, train, val = setup
    cfg = BNSTrainConfig(nfe=8, init_solver="midpoint", iterations=3000,
                         lr=2e-3, val_every=300, batch_size=64)
    res = train_anytime(field, [4, 8], train, val, cfg, mode="nested")
    scores = evaluate_anytime(res.params, [4, 8], field, val)
    for m in (4, 8):
        base = solver_to_ns("midpoint", m, field)
        bp = float(jnp.mean(psnr(ns_solver.ns_sample(base, field.fn, val[0]),
                                 val[1])))
        assert scores[m] > bp, (m, scores[m], bp)
