"""SLO-aware scheduling: urgency ordering, deadline-pressure flushes,
admission control against the observed cost model, queue shedding,
goodput accounting, exit-boundary preemption with bit-identical resume,
flow/decode streaming with bit-identical terminal results, and urgent-
aware work stealing — all on the fake clock."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import (
    AdmissionRejected,
    ContinuousGateway,
    DeadlineExceeded,
    DecodeGateway,
    DecodeRequest,
    FleetGateway,
    Gateway,
    HostLoad,
    Request,
    SLOConfig,
    WorkStealer,
)
from repro.serving.continuous import ContinuousScheduler
from repro.serving.gateway import BatchScheduler, _Entry
from repro.serving.slo import is_urgent, urgency_key
from repro.serving.toy import CountingToySampler, FakeClock, ToyDecodeEngine

BUDGETS = (4, 8, 16)


class CarrySampler(CountingToySampler):
    def __init__(self, budgets=BUDGETS, seed=0, jitter=0.1):
        super().__init__(budgets=budgets, seed=seed, jitter=jitter)


class TickingSampler(CarrySampler):
    """Each batch-level forward advances the fake clock — dispatches take
    simulated time, so the registry's dispatch histograms (the admission
    cost model) see deterministic milliseconds."""

    def __init__(self, clock, ms_per_forward=5.0, **kw):
        super().__init__(**kw)
        self._clock = clock
        self._ms = ms_per_forward

    def on_forward(self):
        super().on_forward()
        self._clock.advance(self._ms / 1e3)


def _x0(i, shape=(2,)):
    return jax.random.normal(jax.random.PRNGKey(100 + i), shape)


def _entry(uid, served=4, t=0.0, deadline=None, priority=0):
    return _Entry(uid=uid, tokens=None, x0=jnp.zeros((2,)), requested=served,
                  served=served, shape_key=(None, (2,)), t_submit=t,
                  future=None, deadline=deadline, priority=priority)


# ---------------------------------------------------------------------------
# pure policy (slo.py)
# ---------------------------------------------------------------------------


def test_urgency_key_orders_priority_deadline_then_fifo():
    plain_a, plain_b = _entry(0, t=0.0), _entry(1, t=1.0)
    dl = _entry(2, t=2.0, deadline=5.0)
    hot = _entry(3, t=3.0, priority=2)
    got = sorted([plain_b, hot, dl, plain_a], key=urgency_key)
    assert [e.uid for e in got] == [3, 2, 0, 1]
    # plain entries keep exact legacy (t_submit, uid) order
    assert sorted([plain_b, plain_a], key=urgency_key) == [plain_a, plain_b]


def test_is_urgent():
    assert not is_urgent(_entry(0))
    assert is_urgent(_entry(1, deadline=1.0))
    assert is_urgent(_entry(2, priority=1))


# ---------------------------------------------------------------------------
# BatchScheduler in SLO mode
# ---------------------------------------------------------------------------


def test_slo_scheduler_flushes_under_deadline_pressure():
    s = BatchScheduler(max_batch=4, max_wait_ms=100.0, slo_aware=True)
    s.lead_ms = 5.0
    young = [_entry(0, t=0.0, deadline=0.008)]
    # not aged, not full — but now + lead crosses the deadline: flush
    assert s.plan(young, now=0.004) != []
    assert s.plan([_entry(0, t=0.0)], now=0.004) == []     # no deadline
    # plain scheduler never deadline-flushes
    legacy = BatchScheduler(max_batch=4, max_wait_ms=100.0)
    assert legacy.plan(young, now=0.004) == []


def test_slo_scheduler_orders_batches_by_urgency():
    s = BatchScheduler(max_batch=4, max_wait_ms=10.0, slo_aware=True)
    pending = [_entry(0, served=4), _entry(1, served=8, priority=3)]
    batches = s.plan(pending, now=0.0, force=True)
    assert len(batches) == 2
    assert batches[0].entries[0].uid == 1       # urgent batch dispatches first


def test_plan_preemptions_pairs_urgent_with_weakest_victims():
    s = ContinuousScheduler(max_slots=2, boundaries=BUDGETS)
    active = [(0, _entry(0, served=16, t=0.0)),
              (1, _entry(1, served=16, t=0.0))]
    urgent = _entry(5, served=8, priority=1)
    pairs = s.plan_preemptions([urgent], boundary=4, active=active,
                               free_slots=0, shape_key=(None, (2,)))
    assert [(si, v.uid, e.uid) for si, v, e in pairs] == [(1, 1, 5)]
    # free slots => plan_joins already handled it
    assert s.plan_preemptions([urgent], 4, active, free_slots=1,
                              shape_key=(None, (2,))) == []
    # equal priority never preempts
    assert s.plan_preemptions([_entry(6, served=8)], 4, active, 0,
                              (None, (2,))) == []
    # a victim past the cap (join too late) is still eligible, but the
    # candidate itself must satisfy the join-cost cap
    late = _entry(7, served=5, priority=1)      # cost 4 > 0.5 * 5
    assert s.plan_preemptions([late], 4, active, 0, (None, (2,))) == []


# ---------------------------------------------------------------------------
# admission control + shedding + goodput (flush gateway, fake clock)
# ---------------------------------------------------------------------------


def test_admission_rejects_infeasible_deadline_with_default_cost():
    clock = FakeClock()
    gw = Gateway(CarrySampler(), max_batch=4, max_wait_ms=10.0, clock=clock,
                 slo=SLOConfig(default_cost_ms=10.0))
    with pytest.raises(AdmissionRejected) as ei:
        gw.submit(Request(budget=4, x0=_x0(0), deadline_ms=5.0))
    assert ei.value.estimated_ms == 10.0
    ok = gw.submit(Request(budget=4, x0=_x0(1), deadline_ms=1000.0))
    best_effort = gw.submit(Request(budget=4, x0=_x0(2)))   # never rejected
    s = gw.stats()
    assert s["rejected"] == 1 and s["submitted"] == 2
    gw.pump(force=True)
    assert ok.result(1).meta["served_budget"] == 4
    assert best_effort.result(1) is not None


def test_admission_cost_model_calibrates_from_observed_dispatches():
    clock = FakeClock()
    sampler = TickingSampler(clock, ms_per_forward=5.0)
    gw = Gateway(sampler, max_batch=2, max_wait_ms=10.0, clock=clock,
                 slo=SLOConfig())
    # cold model (default_cost_ms=0): everything is admitted
    f = gw.submit(Request(budget=4, x0=_x0(0), deadline_ms=1.0))
    gw.pump(force=True)                 # 4 forwards x 5ms => ~20ms dispatch
    assert f.result(1) is not None
    assert gw._dispatch_cost_ms() >= 20.0
    # warm model: a 1ms deadline is now visibly infeasible
    with pytest.raises(AdmissionRejected):
        gw.submit(Request(budget=4, x0=_x0(1), deadline_ms=1.0))
    # deep queue scales the estimate by whole batches ahead
    for i in range(4):
        gw.submit(Request(budget=4, x0=_x0(2 + i), deadline_ms=10_000.0))
    est = gw._estimate_wait_ms(None)
    assert est >= 3 * 20.0              # 2 full batches ahead + own


def test_shedding_fails_expired_queued_entries():
    clock = FakeClock()
    gw = Gateway(CarrySampler(), max_batch=4, max_wait_ms=1000.0, clock=clock,
                 slo=SLOConfig())
    doomed = gw.submit(Request(budget=4, x0=_x0(0), deadline_ms=10.0))
    clock.advance(0.05)                  # deadline passes while queued
    gw.pump()
    with pytest.raises(DeadlineExceeded):
        doomed.result(1)
    s = gw.stats()
    assert s["deadline_misses"] == 1 and s["failed"] == 1
    assert s["completed"] == 0 and s["goodput"] == 0


def test_goodput_and_hit_rate_accounting():
    clock = FakeClock()
    sampler = TickingSampler(clock, ms_per_forward=1.0)
    gw = Gateway(sampler, max_batch=4, max_wait_ms=10.0, clock=clock,
                 slo=SLOConfig())
    on_time = gw.submit(Request(budget=4, x0=_x0(0), deadline_ms=1000.0))
    late = gw.submit(Request(budget=4, x0=_x0(1), deadline_ms=2.0))
    gw.pump(force=True)                  # one batch, ~4ms: late misses
    assert on_time.result(1) is not None and late.result(1) is not None
    s = gw.stats()
    assert s["goodput"] == 1 and s["deadline_misses"] == 1
    assert s["completed"] == 2           # a late settle still completes
    assert s["deadline_hit_rate"] == pytest.approx(0.5)


def test_slo_none_keeps_legacy_behavior_but_records_deadlines():
    clock = FakeClock()
    gw = Gateway(CarrySampler(), max_batch=4, max_wait_ms=10.0, clock=clock)
    f = gw.submit(Request(budget=4, x0=_x0(0), deadline_ms=0.001))
    clock.advance(1.0)                   # hopeless — but FIFO never sheds
    gw.pump(force=True)
    assert f.result(1) is not None       # served late, not rejected/shed
    s = gw.stats()
    assert s["rejected"] == 0 and s["completed"] == 1
    assert s["deadline_misses"] == 1 and s["goodput"] == 0


# ---------------------------------------------------------------------------
# preemption at exit boundaries (continuous gateway)
# ---------------------------------------------------------------------------


def test_preempted_request_resumes_bit_identical():
    clock = FakeClock()
    sampler = CarrySampler()
    gw = ContinuousGateway(sampler, max_slots=2, max_wait_ms=10.0,
                           clock=clock, slo=SLOConfig())
    lows = [gw.submit(Request(budget=16, x0=_x0(i))) for i in range(2)]
    assert gw.pump(force=True) == 1              # trajectory opens
    hot = gw.submit(Request(budget=8, x0=_x0(2), priority=1))
    assert gw.pump() >= 1                        # leg 0..4: preempt uid 1
    assert gw.stats()["preemptions"] == 1
    assert not any(f.done() for f in lows) and not hot.done()
    gw.pump()                                    # leg 4..8: hot exits,
    assert hot.done()                            # victim resumes at 8
    gw.pump()                                    # leg 8..16: both lows exit
    assert all(f.done() for f in lows)
    got = np.stack([np.asarray(f.result(1).latents) for f in lows])
    direct16 = np.asarray(CarrySampler().sample_from(
        None, jnp.stack([_x0(0), _x0(1)]), 16))
    np.testing.assert_array_equal(got, direct16)     # bit-identical resume
    direct8 = np.asarray(CarrySampler().sample_from(
        None, jnp.stack([_x0(2), _x0(2)]), 8))
    np.testing.assert_array_equal(np.asarray(hot.result(1).latents),
                                  direct8[0])
    # forwards: legs 4+4+8, urgent prefix 4, victim resume 8-4
    assert sampler.forwards == 16 + 4 + 4
    s = gw.stats()
    assert s["completed"] == 3 and s["failed"] == 0
    assert gw.queue.depth() == 0 and s["inflight"] == 0


def test_preemption_off_leaves_trajectory_untouched():
    clock = FakeClock()
    gw = ContinuousGateway(CarrySampler(), max_slots=2, max_wait_ms=10.0,
                           clock=clock,
                           slo=SLOConfig(preemption=False))
    lows = [gw.submit(Request(budget=16, x0=_x0(i))) for i in range(2)]
    gw.pump(force=True)
    gw.submit(Request(budget=8, x0=_x0(2), priority=1))
    gw.pump()
    assert gw.stats()["preemptions"] == 0
    for _ in range(8):
        gw.pump(force=True)
    assert all(f.done() for f in lows)


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------


def test_flow_stream_partials_are_nested_early_exits():
    clock = FakeClock()
    gw = ContinuousGateway(CarrySampler(), max_slots=2, max_wait_ms=10.0,
                           clock=clock)
    stream = gw.submit_stream(budget=16, x0=_x0(0))
    for _ in range(4):
        gw.pump(force=True)
    chunks = stream.chunks(timeout=1)
    assert [c.final for c in chunks] == [False, False, True]
    assert [c.meta["boundary"] for c in chunks[:-1]] == [4, 8]
    ref = CarrySampler()
    x0 = jnp.stack([_x0(0)])
    for c, b in zip(chunks[:-1], (4, 8)):
        np.testing.assert_array_equal(
            np.asarray(c.payload), np.asarray(ref.sample_from(None, x0, b))[0])
    # terminal chunk IS the settled response — bit-identical to plain submit
    final = chunks[-1].payload
    assert final is stream.result(1)
    np.testing.assert_array_equal(
        np.asarray(final.latents),
        np.asarray(ref.sample_from(None, x0, 16))[0])


def test_decode_stream_tokens_match_solo_oracle():
    clock = FakeClock()
    engine = ToyDecodeEngine()
    gw = DecodeGateway(engine, max_slots=2, prefill_chunk=0, clock=clock)
    prompt, n = [3, 5, 11], 6
    stream = gw.submit_stream(prompt=prompt, max_tokens=n)
    plain = gw.submit(DecodeRequest(prompt=prompt, max_tokens=n))
    for _ in range(32):
        gw.pump()
    chunks = stream.chunks(timeout=1)
    toks = [c.payload for c in chunks[:-1]]
    assert chunks[-1].final
    assert toks == ToyDecodeEngine().solo_tokens(prompt, n)
    np.testing.assert_array_equal(chunks[-1].payload.tokens,
                                  plain.result(1).tokens)
    assert [c.meta["index"] for c in chunks[:-1]] == list(range(n))


def test_stream_surfaces_failures_like_the_future():
    clock = FakeClock()

    class Exploding(CarrySampler):
        def sample_from(self, batch, x0, budget):
            raise RuntimeError("boom")

    gw = Gateway(Exploding(), max_batch=2, max_wait_ms=10.0, clock=clock)
    stream = gw.submit_stream(budget=4, x0=_x0(0))
    gw.pump(force=True)
    with pytest.raises(RuntimeError, match="boom"):
        stream.chunks(timeout=1)


# ---------------------------------------------------------------------------
# decode admission + fleet integration
# ---------------------------------------------------------------------------


def test_decode_admission_and_deadline_metrics():
    clock = FakeClock()
    gw = DecodeGateway(ToyDecodeEngine(), max_slots=2, prefill_chunk=0,
                       clock=clock, slo=SLOConfig(default_cost_ms=2.0))
    # estimate: (prompt 1 + 4 tokens) x 2ms = 10ms > 5ms deadline
    with pytest.raises(AdmissionRejected):
        gw.submit(DecodeRequest(prompt=[3], max_tokens=4, deadline_ms=5.0))
    ok = gw.submit(DecodeRequest(prompt=[3], max_tokens=4, deadline_ms=500.0))
    for _ in range(16):
        gw.pump()
    assert ok.result(1).meta["finish_reason"] == "length"
    s = gw.stats()
    assert s["rejected"] == 1 and s["goodput"] == 1


def test_decode_urgent_requests_admitted_first():
    clock = FakeClock()
    gw = DecodeGateway(ToyDecodeEngine(), max_slots=1, prefill_chunk=0,
                       clock=clock, slo=SLOConfig())
    low = gw.submit(DecodeRequest(prompt=[3], max_tokens=2))
    hot = gw.submit(DecodeRequest(prompt=[5], max_tokens=2, priority=1))
    for _ in range(16):
        gw.pump()
    assert hot.result(1).meta["join_step"] < low.result(1).meta["join_step"]


def test_fleet_stream_and_urgent_stealing():
    clocks = [FakeClock(), FakeClock()]
    gws = {f"h{i}": Gateway(CarrySampler(), max_batch=4, max_wait_ms=10.0,
                            clock=clocks[i]) for i in range(2)}
    fleet = FleetGateway(gws, steal=False)
    stream = fleet.submit_stream(budget=4, x0=_x0(0))
    fleet.pump(force=True)
    chunks = stream.chunks(timeout=1)
    assert chunks[-1].final
    np.testing.assert_array_equal(
        np.asarray(chunks[-1].payload.latents),
        np.asarray(stream.result(1).latents))
    # urgent-aware victim choice: shallower-but-urgent shard is robbed first
    stealer = WorkStealer(min_queue=2)
    loads = {"a": HostLoad(queue_depth=6, inflight=0),
             "b": HostLoad(queue_depth=3, inflight=0, urgent=2),
             "c": HostLoad(queue_depth=0, inflight=0)}
    assert stealer.plan(loads) == [("b", "c", 2)]
    flat = {"a": HostLoad(queue_depth=6, inflight=0),
            "b": HostLoad(queue_depth=3, inflight=0),
            "c": HostLoad(queue_depth=0, inflight=0)}
    assert stealer.plan(flat) == [("a", "c", 3)]    # legacy: deepest wins


def test_steal_pops_most_urgent_and_load_counts_urgent():
    clock = FakeClock()
    gw = Gateway(CarrySampler(), max_batch=4, max_wait_ms=10.0, clock=clock)
    gw.submit(Request(budget=4, x0=_x0(0)))
    hot = gw.submit(Request(budget=4, x0=_x0(1), priority=5))
    dl = gw.submit(Request(budget=4, x0=_x0(2), deadline_ms=50.0))
    assert gw.load() == HostLoad(queue_depth=3, inflight=0, urgent=2)
    taken = gw.steal(2)
    assert [e.uid for e in taken] == [hot.uid, dl.uid]
