"""Decode raw-speed stack: paged KV cache (shared page pool + block
tables), chunked batched prefill, temperature/top-k/top-p sampling, and the
gateway hygiene fixes (cancelled-slot release, settled-only stats)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serving.decode import (DecodeGateway, DecodeRequest,
                                  PageAllocator)
from repro.serving.engine import (DecodeEngine, SamplingParams,
                                  sample_tokens)
from repro.serving.toy import FakeClock, ToyDecodeEngine


def _engine(arch="yi-6b", **kw):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return DecodeEngine(params=params, cfg=cfg, **kw)


def _solo_tokens(engine, prompt, n):
    """Teacher-force ``prompt`` through the plain scalar-index decode path,
    then greedy — independent of slots, pages, and prefill."""
    state = engine.init_state(1, 32)
    for tok in prompt[:-1]:
        _, state = engine.step(jnp.asarray([tok], jnp.int32), state)
    toks, _ = engine.greedy(jnp.asarray([prompt[-1]], jnp.int32), state, n)
    return np.asarray(toks)[0].tolist()


def _drive(gw, futures):
    while not all(f.done() for f in futures):
        gw.pump()


def _serve(gw, reqs):
    futures = [gw.submit(r) for r in reqs]
    _drive(gw, futures)
    return [f.result().tokens.tolist() for f in futures]


# -- paged KV cache ----------------------------------------------------------


@pytest.mark.parametrize("arch", ["yi-6b", "rwkv6-7b"])
def test_paged_gateway_bit_identical_to_dense(arch):
    """The same mixed-length request list served paged (shared pool +
    block tables, slot refill reusing freed pages) and dense must produce
    identical tokens — page indirection may not change a single one. The
    ssm family takes page_size as a no-op (recurrent state is already O(1)
    per slot) and must behave identically too."""
    reqs = [DecodeRequest(prompt=[i + 1, i + 2], max_tokens=t)
            for i, t in enumerate([3, 9, 5, 2, 7])]
    dense = DecodeGateway(_engine(arch), max_slots=2, cache_slots=16)
    paged_eng = _engine(arch, page_size=4)
    paged = DecodeGateway(paged_eng, max_slots=2, cache_slots=16)
    assert _serve(paged, reqs) == _serve(dense, reqs)
    s = paged.stats()
    assert s["joins"] > 0                   # freed pages were reused
    if paged_eng.paged:                     # KV families only (ssm: no-op)
        assert s["peak_pages"] > 0
        assert s["pages_in_use"] == 0       # everything returned to the pool
        assert s["peak_kv_per_slot"] <= 16


def test_paged_kernel_bit_identical_to_fallback():
    """The Pallas paged-attention kernel (interpret mode) and the
    dense-gather fallback serve the same tokens through the gateway."""
    reqs = [DecodeRequest(prompt=[3, 7], max_tokens=3),
            DecodeRequest(prompt=[5], max_tokens=2)]
    fallback = DecodeGateway(_engine(page_size=4), max_slots=2,
                             cache_slots=8)
    kernel = DecodeGateway(_engine(page_size=4, paged_kernel=True),
                           max_slots=2, cache_slots=8)
    assert _serve(kernel, reqs) == _serve(fallback, reqs)


def test_paged_rejects_unpageable_families_and_window():
    with pytest.raises(TypeError, match="no .*pageable"):
        _engine("zamba2-2.7b", page_size=4)         # hybrid
    with pytest.raises(ValueError, match="sliding-window"):
        _engine("yi-6b", page_size=4, window=8)
    with pytest.raises(ValueError, match="multiple of"):
        _engine("yi-6b", page_size=5).init_slot_state(2, 16)
    assert _engine("rwkv6-7b", page_size=4).paged is False   # ssm no-op


def test_page_allocator_accounting():
    alloc = PageAllocator(5)                # pages 1..4 usable, 0 = trash
    assert alloc.available == 4 and alloc.in_use == 0
    a = alloc.alloc(3)
    assert 0 not in a and len(set(a)) == 3
    assert alloc.in_use == 3 and alloc.peak == 3
    with pytest.raises(RuntimeError, match="exhausted"):
        alloc.alloc(2)
    alloc.free(a[:2])
    assert alloc.available == 3 and alloc.peak == 3   # high-water sticks
    with pytest.raises(ValueError):
        PageAllocator(1)


def test_paged_head_of_line_blocking_keeps_fifo():
    """A paged admission that cannot reserve its worst-case pages blocks
    the queue HEAD until finishes free pages — later requests never skip
    ahead, and every sequence still matches the solo oracle."""
    eng = ToyDecodeEngine(page_size=4)
    # 4 usable pages; each request needs ceil((1+8-1)/4) = 2
    gw = DecodeGateway(eng, max_slots=3, cache_slots=8, total_pages=5)
    reqs = [DecodeRequest(prompt=[i + 1], max_tokens=8) for i in range(3)]
    futures = [gw.submit(r) for r in reqs]
    gw.pump()
    # three slots free but only two reservations fit: request 2 queues
    assert [s is not None for s in gw._slots] == [True, True, False]
    _drive(gw, futures)
    assert futures[2].result().meta["join_step"] > 0
    for r, f in zip(reqs, futures):
        assert f.result().tokens.tolist() == eng.solo_tokens(r.prompt,
                                                             r.max_tokens)
    assert gw.stats()["pages_in_use"] == 0


# -- chunked batched prefill -------------------------------------------------


def test_chunked_prefill_tokens_identical_fewer_forwards():
    """Chunked prefill feeds whole prompt chunks per engine invocation:
    same tokens as the token-by-token teacher-forced feed (same
    decode_apply underneath), strictly fewer wall-steps."""
    eng = _engine("yi-6b")
    prompt = [(3 * i + 1) % eng.cfg.vocab for i in range(9)]
    reqs = [DecodeRequest(prompt=prompt, max_tokens=4),
            DecodeRequest(prompt=prompt[:5], max_tokens=3)]
    legacy = DecodeGateway(eng, max_slots=2, cache_slots=16,
                           prefill_chunk=0)
    chunked = DecodeGateway(eng, max_slots=2, cache_slots=16,
                            prefill_chunk=4)
    legacy_toks = _serve(legacy, reqs)
    chunked_toks = _serve(chunked, reqs)
    assert chunked_toks == legacy_toks
    assert chunked_toks[0] == _solo_tokens(eng, prompt, 4)
    sc, sl = chunked.stats(), legacy.stats()
    assert sc["forwards"] < sl["forwards"]
    assert sc["prefill_calls"] > 0
    # every non-final prompt token rode a prefill call, none a decode step
    assert sc["prefill_tokens"] == (len(prompt) - 1) + (5 - 1)
    assert sl["prefill_calls"] == 0


def test_chunked_prefill_interleaves_with_decode():
    """A long prompt admitted beside a mid-generation sequence must not
    stall it: the resident row keeps emitting one token per tick while the
    joiner prefills, and both match their solo decodes."""
    eng = ToyDecodeEngine()
    gw = DecodeGateway(eng, max_slots=2, cache_slots=64, prefill_chunk=4)
    f1 = gw.submit(DecodeRequest(prompt=[3], max_tokens=12))
    gw.pump()
    emitted_before = len(gw._slots[0].emitted)
    long_prompt = list(range(1, 18))
    f2 = gw.submit(DecodeRequest(prompt=long_prompt, max_tokens=2))
    gw.pump()                               # prefill call + decode step
    assert len(gw._slots[0].emitted) == emitted_before + 1
    _drive(gw, [f1, f2])
    assert f1.result().tokens.tolist() == eng.solo_tokens([3], 12)
    assert f2.result().tokens.tolist() == eng.solo_tokens(long_prompt, 2)


# -- sampling ----------------------------------------------------------------


def test_sample_tokens_units():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (4, 32))
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    argmax = np.asarray(jnp.argmax(logits, -1))
    zeros, ones = np.zeros((4,), np.float32), np.ones((4,), np.float32)

    def draw(temps, top_ks, top_ps):
        return np.asarray(sample_tokens(
            logits, jnp.asarray(keys), jnp.asarray(temps, jnp.float32),
            jnp.asarray(top_ks, jnp.int32), jnp.asarray(top_ps, jnp.float32)))

    # temperature 0 is exact greedy; top_k=1 and tiny top_p pin the argmax
    np.testing.assert_array_equal(draw(zeros, [0] * 4, ones), argmax)
    np.testing.assert_array_equal(draw(ones, [1] * 4, ones), argmax)
    np.testing.assert_array_equal(draw(ones, [0] * 4, [1e-6] * 4), argmax)
    # same keys -> same draw (determinism); tokens stay in-vocab
    hot = draw(ones * 2.0, [0] * 4, ones)
    np.testing.assert_array_equal(hot, draw(ones * 2.0, [0] * 4, ones))
    assert ((hot >= 0) & (hot < 32)).all()
    with pytest.raises(ValueError):
        SamplingParams(temperature=-1.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)


def test_sampled_request_reproducible_across_batch_composition():
    """Sampling is keyed by (base key, uid, step), so a sampled request's
    tokens must not depend on what else rides the batch — and greedy
    neighbours must stay bit-identical to their solo decode."""
    eng = _engine("yi-6b")
    sp = SamplingParams(temperature=0.8, top_k=5)
    solo_gw = DecodeGateway(eng, max_slots=2, cache_slots=16,
                            key=jax.random.PRNGKey(7))
    alone = _serve(solo_gw, [DecodeRequest(prompt=[3, 7], max_tokens=6,
                                           sampling=sp)])[0]
    mixed_gw = DecodeGateway(eng, max_slots=2, cache_slots=16,
                             key=jax.random.PRNGKey(7))
    toks = _serve(mixed_gw, [
        DecodeRequest(prompt=[3, 7], max_tokens=6, sampling=sp),  # uid 0
        DecodeRequest(prompt=[5, 2], max_tokens=6),
        DecodeRequest(prompt=[9], max_tokens=4),
    ])
    assert toks[0] == alone
    assert toks[1] == _solo_tokens(eng, [5, 2], 6)
    assert toks[2] == _solo_tokens(eng, [9], 4)
    # a different base key re-randomises the sampled request
    other_gw = DecodeGateway(eng, max_slots=2, cache_slots=16,
                             key=jax.random.PRNGKey(8))
    other = _serve(other_gw, [DecodeRequest(prompt=[3, 7], max_tokens=6,
                                            sampling=sp)])[0]
    assert other != alone


def test_greedy_only_engine_rejects_sampling():
    gw = DecodeGateway(ToyDecodeEngine(), max_slots=1, cache_slots=4)
    with pytest.raises(ValueError, match="does not support sampling"):
        gw.submit(DecodeRequest(prompt=[3], max_tokens=2,
                                sampling=SamplingParams(temperature=1.0)))
    # temperature 0 rows are exact greedy — accepted everywhere
    f = gw.submit(DecodeRequest(prompt=[3], max_tokens=2,
                                sampling=SamplingParams(temperature=0.0)))
    _drive(gw, [f])
    assert f.result().tokens.tolist() == ToyDecodeEngine().solo_tokens([3], 2)


# -- hygiene: cancelled slots and stats skew ---------------------------------


def test_cancelled_resident_sequence_frees_slot_next_pump():
    """The slot-leak fix: a future cancelled mid-decode must release its
    row (and stop decoding) at the next pump instead of holding the slot
    to max_tokens — the queued sequence behind it gets served."""
    eng = ToyDecodeEngine()
    gw = DecodeGateway(eng, max_slots=1, cache_slots=64)
    f1 = gw.submit(DecodeRequest(prompt=[3], max_tokens=1000))
    f2 = gw.submit(DecodeRequest(prompt=[7], max_tokens=3))
    gw.pump()
    assert gw._slots[0] is not None and not f1.done()
    assert f1.cancel()
    gw.pump()                               # sweep releases the slot
    _drive(gw, [f2])
    assert f2.result().tokens.tolist() == eng.solo_tokens([7], 3)
    s = gw.stats()
    assert s["cancelled"] == 1 and s["completed"] == 1
    assert s["tokens_out"] == 3             # the cancelled row counts nothing
    assert gw._drained()


def test_cancelled_queued_sequence_never_admitted():
    gw = DecodeGateway(ToyDecodeEngine(), max_slots=1, cache_slots=16)
    f1 = gw.submit(DecodeRequest(prompt=[3], max_tokens=2))
    f2 = gw.submit(DecodeRequest(prompt=[5], max_tokens=2))
    assert f2.cancel()
    _drive(gw, [f1])
    while not gw._drained():
        gw.pump()
    s = gw.stats()
    assert s["cancelled"] == 1 and s["completed"] == 1
    assert all(sl is None for sl in gw._slots)


def test_stats_tokens_per_s_zero_on_frozen_clock():
    """The stats-skew fix: a zero-elapsed snapshot reports 0.0 tokens/s
    instead of a 1e9-ish spike from the epsilon denominator."""
    gw = DecodeGateway(ToyDecodeEngine(), max_slots=1, cache_slots=16,
                       clock=FakeClock())     # never advanced
    f = gw.submit(DecodeRequest(prompt=[3], max_tokens=4))
    _drive(gw, [f])
    s = gw.stats()
    assert s["tokens_out"] == 4
    assert s["tokens_per_s"] == 0.0
