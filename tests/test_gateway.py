"""Serving gateway: deterministic batch scheduling (fake clock), coalescing
by budget, max-wait flush, padded-batch bit-exactness vs direct sampling,
exact NFE accounting via a forward-counting field wrapper, mixed-budget
shared-trajectory dispatch, budget-drift metadata, and sharded execution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.anytime import init_anytime
from repro.serving import AnytimeFlowSampler, Gateway, Request
from repro.serving.gateway import BatchScheduler
from repro.serving.toy import CountingToySampler, FakeClock
from repro.solvers import SolverArtifact, SolverSpec

BUDGETS = (2, 4)


def _gateway(sampler=None, **kw):
    clock = FakeClock()
    sampler = sampler or CountingToySampler()
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_wait_ms", 10.0)
    gw = Gateway(sampler, clock=clock, **kw)
    return gw, sampler, clock


def _x0(i, shape=(2,)):
    return jax.random.normal(jax.random.PRNGKey(100 + i), shape)


# ---------------------------------------------------------------------------
# BatchScheduler (pure planning)
# ---------------------------------------------------------------------------


def test_bucket_sizes_are_powers_of_two_up_to_max_batch():
    s = BatchScheduler(max_batch=8)
    assert [s.bucket(k) for k in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]
    s6 = BatchScheduler(max_batch=6)
    assert [s6.bucket(k) for k in (3, 5, 6)] == [4, 6, 6]
    with pytest.raises(ValueError):
        s.bucket(9)


def test_scheduler_validates_config():
    with pytest.raises(ValueError):
        BatchScheduler(max_batch=0)
    with pytest.raises(ValueError):
        BatchScheduler(policy="sometimes")


# ---------------------------------------------------------------------------
# _use_mixed edge cases (pure cost model)
# ---------------------------------------------------------------------------


def test_use_mixed_single_budget_never_merges():
    s = BatchScheduler(max_batch=8, policy="always", can_mix=True,
                       top_budget=16)
    assert not s._use_mixed([4], total=3)       # one budget: nothing to mix


def test_use_mixed_all_equal_budgets_form_one_group():
    """All-equal budgets coalesce into ONE (shape, budget) group, so a flush
    plans a plain per-budget batch — never a mixed dispatch."""
    gw, sampler, clock = _gateway(max_batch=4, mixed_budget_policy="always")
    futs = [gw.submit(Request(budget=4, x0=_x0(i))) for i in range(3)]
    clock.advance(1.0)
    assert gw.pump() == 1
    assert all(not f.result().meta["mixed"] for f in futs)
    assert gw.stats()["mixed_batches"] == 0 and sampler.forwards == 4


def test_use_mixed_respects_policy_and_missing_top_budget():
    s = BatchScheduler(max_batch=8, policy="never", can_mix=True,
                       top_budget=16)
    assert not s._use_mixed([2, 4], total=2)    # policy gates everything
    s2 = BatchScheduler(max_batch=8, policy="auto", can_mix=True,
                        top_budget=None)        # no shared trajectory known
    assert not s2._use_mixed([2, 4], total=2)
    s3 = BatchScheduler(max_batch=8, policy="auto", can_mix=False,
                        top_budget=2)
    assert not s3._use_mixed([2, 4], total=2)   # sampler cannot mix at all


def test_use_mixed_totals_exceeding_max_batch_count_every_chunk():
    """total > max_batch means several shared-trajectory chunks; each costs
    the top budget, and the cost model must charge all of them."""
    s = BatchScheduler(max_batch=2, policy="auto", can_mix=True,
                       top_budget=8)
    # 3 chunks x 8 = 24 > 2 + 4 + 8 = 14: per-budget wins
    assert not s._use_mixed([2, 4, 8], total=5)
    # 1 chunk x 8 < 2 + 4 + 8: merge wins
    assert s._use_mixed([2, 4, 8], total=2)
    s.top_budget = 3
    # 3 chunks x 3 = 9 < 14: merge still wins despite chunking
    assert s._use_mixed([2, 4, 8], total=5)


# ---------------------------------------------------------------------------
# Coalescing + flush behavior (gateway with fake clock, manual pump)
# ---------------------------------------------------------------------------


def test_full_batch_flushes_immediately_without_wait():
    gw, sampler, clock = _gateway()
    f0 = gw.submit(Request(budget=2, x0=_x0(0)))
    assert gw.pump() == 0 and not f0.done()      # half a batch: waits
    f1 = gw.submit(Request(budget=2, x0=_x0(1)))
    assert gw.pump() == 1                        # full batch: no wait needed
    assert f0.done() and f1.done()
    assert sampler.forwards == 2                 # ONE dispatch at budget 2


def test_coalesces_by_budget_not_arrival_order():
    gw, sampler, clock = _gateway()
    futs = [gw.submit(Request(budget=b, x0=_x0(i)))
            for i, b in enumerate([2, 4, 2, 4])]   # interleaved arrivals
    assert gw.pump() == 2                          # (2,2) and (4,4) batches
    for f, b in zip(futs, [2, 4, 2, 4]):
        assert f.result().meta["served_budget"] == b
        assert f.result().meta["batch_real"] == 2
    # 2 + 4 forwards total — budget coalescing, not FIFO batching
    assert sampler.forwards == 6
    assert gw.stats()["forwards"] == sampler.forwards


def test_partial_batch_flushes_only_after_max_wait():
    gw, sampler, clock = _gateway(max_batch=4)
    fut = gw.submit(Request(budget=2, x0=_x0(0)))
    clock.advance(0.005)
    assert gw.pump() == 0 and not fut.done()     # younger than max_wait
    clock.advance(0.006)                         # now 11ms > 10ms
    assert gw.pump() == 1
    assert fut.result().meta["wait_ms"] >= 10.0
    assert fut.result().meta["batch_real"] == 1


def test_gateway_output_bit_identical_to_direct_sampler():
    """Coalesced + padded batches must not perturb any sample: gateway rows
    == direct ``sample_from`` on the same x0 (toy path is un-jitted)."""
    gw, sampler, clock = _gateway(max_batch=4)
    x0s = [_x0(i) for i in range(3)]
    futs = [gw.submit(Request(budget=4, x0=x)) for x in x0s]
    clock.advance(1.0)
    assert gw.pump() == 1                        # one batch of 3, padded to 4
    direct = sampler.sample_from(None, jnp.stack(x0s), 4)
    for f, d in zip(futs, direct):
        np.testing.assert_array_equal(np.asarray(f.result().latents),
                                      np.asarray(d))
        assert f.result().meta["batch_padded"] == 4


def test_coalesced_batch_costs_exactly_m_forwards():
    """Acceptance: a coalesced batch at budget m costs exactly m backbone
    forwards, asserted via the forward-counting field wrapper."""
    gw, sampler, clock = _gateway(max_batch=4)
    for i in range(4):
        gw.submit(Request(budget=4, x0=_x0(i)))
    assert gw.pump() == 1
    assert sampler.forwards == 4                 # m forwards for the batch
    s = gw.stats()
    assert s["forwards"] == 4 and s["completed"] == 4
    assert s["nfe_per_request"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Mixed-budget policy
# ---------------------------------------------------------------------------


def test_mixed_flush_rides_shared_trajectory_when_cheaper():
    """Budgets {2, 4} pending, top budget 4 < 2+4: auto merges the flush
    into ONE sample_all dispatch costing max(budgets) forwards."""
    gw, sampler, clock = _gateway(max_batch=4)
    f2 = gw.submit(Request(budget=2, x0=_x0(0)))
    f4 = gw.submit(Request(budget=4, x0=_x0(1)))
    clock.advance(1.0)
    assert gw.pump() == 1
    assert sampler.forwards == 4                 # max, not 2 + 4
    for f, b in [(f2, 2), (f4, 4)]:
        meta = f.result().meta
        assert meta["mixed"] and meta["served_budget"] == b
        assert meta["nfe_batch"] == 4
    # bit-identical to the shared trajectory on the same x0
    outs = CountingToySampler().sample_all_from(
        None, jnp.stack([_x0(0), _x0(1)]))
    np.testing.assert_array_equal(np.asarray(f2.result().latents),
                                  np.asarray(outs[2][0]))
    np.testing.assert_array_equal(np.asarray(f4.result().latents),
                                  np.asarray(outs[4][1]))
    assert gw.stats()["mixed_batches"] == 1


def test_mixed_policy_never_dispatches_per_budget():
    gw, sampler, clock = _gateway(max_batch=4, mixed_budget_policy="never")
    gw.submit(Request(budget=2, x0=_x0(0)))
    gw.submit(Request(budget=4, x0=_x0(1)))
    clock.advance(1.0)
    assert gw.pump() == 2                        # one partial batch per budget
    assert sampler.forwards == 6
    assert gw.stats()["mixed_batches"] == 0


def test_mixed_auto_respects_cost_model():
    """With budgets (2, 4, 16) the shared trajectory costs 16 forwards; a
    {2, 4} flush (sum 6) is cheaper per-budget, so auto must NOT merge —
    but policy=always does."""
    sampler = CountingToySampler(budgets=(2, 4, 16))
    gw, _, clock = _gateway(sampler, max_batch=4)
    gw.submit(Request(budget=2, x0=_x0(0)))
    gw.submit(Request(budget=4, x0=_x0(1)))
    clock.advance(1.0)
    assert gw.pump() == 2 and sampler.forwards == 6

    sampler2 = CountingToySampler(budgets=(2, 4, 16))
    gw2, _, clock2 = _gateway(sampler2, max_batch=4,
                              mixed_budget_policy="always")
    gw2.submit(Request(budget=2, x0=_x0(0)))
    gw2.submit(Request(budget=4, x0=_x0(1)))
    clock2.advance(1.0)
    assert gw2.pump() == 1 and sampler2.forwards == 16


def test_mixed_auto_accounts_for_chunking():
    """Regression: when the merged flush would split into several chunks,
    EACH costs max(budgets) forwards — auto must compare against that, not
    a single dispatch. Here 2 chunks x 16 = 32 > 2+4+8+16 = 30: no merge."""
    sampler = CountingToySampler(budgets=(2, 4, 8, 16))
    gw, _, clock = _gateway(sampler, max_batch=2)
    for i, b in enumerate((2, 4, 8, 16)):
        gw.submit(Request(budget=b, x0=_x0(i)))
    clock.advance(1.0)
    assert gw.pump() == 4                        # per-budget partials
    assert sampler.forwards == 30
    assert gw.stats()["mixed_batches"] == 0


# ---------------------------------------------------------------------------
# Budget drift metadata + strict mode
# ---------------------------------------------------------------------------


def test_budget_drift_recorded_in_response_metadata():
    """An unserved budget routes to the nearest served one AND the
    (requested, served) pair rides in the metadata — never only a warning."""
    gw, sampler, clock = _gateway(max_batch=1)
    fut = gw.submit(Request(budget=3, x0=_x0(0)))
    gw.pump()
    meta = fut.result().meta
    assert meta["requested_budget"] == 3
    assert meta["served_budget"] == 2            # nearest, ties to cheaper


def test_strict_nfe_rejects_at_submit():
    gw, sampler, clock = _gateway(strict_nfe=True)
    with pytest.raises(ValueError):
        gw.submit(Request(budget=3, x0=_x0(0)))
    assert gw.queue.depth() == 0


def test_submit_requires_tokens_or_x0():
    gw, _, _ = _gateway()
    with pytest.raises(ValueError):
        gw.submit(Request(budget=2))


# ---------------------------------------------------------------------------
# Drain / lifecycle / threaded serving
# ---------------------------------------------------------------------------


def test_drain_flushes_everything_and_closes_intake():
    gw, sampler, clock = _gateway(max_batch=4)
    futs = [gw.submit(Request(budget=2, x0=_x0(i))) for i in range(3)]
    gw.drain()                                   # partial batch, zero age
    assert all(f.done() for f in futs)
    with pytest.raises(RuntimeError):
        gw.submit(Request(budget=2, x0=_x0(9)))


def test_submit_during_pump_is_never_lost():
    """Regression: a submit landing while pump is planning must stay queued
    (the old swap-based pump overwrote it, stranding the future forever)."""
    gw, sampler, clock = _gateway(max_batch=2)
    f0 = gw.submit(Request(budget=2, x0=_x0(0)))
    orig_plan = gw.scheduler.plan
    late = {}

    def plan_then_push(pending, now, force=False):
        out = orig_plan(pending, now, force)
        if "f" not in late:                      # a submit races the pump
            late["f"] = gw.submit(Request(budget=2, x0=_x0(1)))
        return out

    gw.scheduler.plan = plan_then_push
    assert gw.pump() == 0                        # f0 partial, f1 mid-plan
    assert gw.queue.depth() == 2                 # the racing submit survived
    assert gw.pump() == 1                        # now a full (2, 2) batch
    assert f0.done() and late["f"].done()


def test_failed_batch_propagates_to_futures():
    class Exploding(CountingToySampler):
        def sample_from(self, batch, x0, budget):
            raise RuntimeError("boom")

    gw, _, clock = _gateway(Exploding())
    futs = [gw.submit(Request(budget=2, x0=_x0(i))) for i in range(2)]
    gw.pump()
    for f in futs:
        with pytest.raises(RuntimeError, match="boom"):
            f.result()
    assert gw.stats()["failed"] == 2


def test_mid_drain_failure_surfaces_into_affected_futures():
    """Regression: a raising sampler plus a client-cancelled future used to
    blow up ``set_exception`` mid-drain, aborting the pump loop and leaving
    every later batch's futures pending forever. The failure must reach the
    affected batch's live futures and later batches must still drain."""
    class Exploding(CountingToySampler):
        def sample_from(self, batch, x0, budget):
            if budget == 2:
                raise RuntimeError("boom")
            return super().sample_from(batch, x0, budget)

    gw, _, clock = _gateway(Exploding(), max_batch=2,
                            mixed_budget_policy="never")
    f2s = [gw.submit(Request(budget=2, x0=_x0(i))) for i in range(2)]
    f4s = [gw.submit(Request(budget=4, x0=_x0(2 + i))) for i in range(2)]
    f2s[0].cancel()                      # client gave up while queued
    gw.drain()
    assert all(f.done() for f in f2s + f4s)      # nothing pending forever
    with pytest.raises(RuntimeError, match="boom"):
        f2s[1].result()
    for f in f4s:                        # the later batch still served
        assert f.result().meta["served_budget"] == 4


def test_cancelled_future_does_not_strand_batch_mates():
    """A cancelled future rejecting its result mid-scatter must not keep
    batch-mates from resolving."""
    gw, sampler, clock = _gateway(max_batch=2)
    f0 = gw.submit(Request(budget=2, x0=_x0(0)))
    f1 = gw.submit(Request(budget=2, x0=_x0(1)))
    f0.cancel()
    assert gw.pump() == 1
    assert f1.result().meta["served_budget"] == 2


def test_stats_occupancy_under_partial_flushes():
    """GatewayStats occupancy = real rows / padded bucket rows, accumulated
    across full and partial (padded) flushes."""
    gw, sampler, clock = _gateway(max_batch=4)
    for i in range(3):                           # partial: 3 real, bucket 4
        gw.submit(Request(budget=2, x0=_x0(i)))
    clock.advance(1.0)
    assert gw.pump() == 1
    assert gw.stats()["occupancy"] == pytest.approx(3 / 4)
    for i in range(4):                           # full: 4 real, bucket 4
        gw.submit(Request(budget=2, x0=_x0(10 + i)))
    assert gw.pump() == 1
    s = gw.stats()
    assert s["occupancy"] == pytest.approx((3 + 4) / (4 + 4))
    assert gw.stats_raw.real_rows == 7 and gw.stats_raw.padded_rows == 8
    gw.submit(Request(budget=2, x0=_x0(20)))     # 1 real pads to bucket 1,
    clock.advance(1.0)                           # not to max_batch
    assert gw.pump() == 1
    assert gw.stats()["occupancy"] == pytest.approx((3 + 4 + 1) / (4 + 4 + 1))


def test_threaded_serve_forever_resolves_futures():
    """Real clock end-to-end: start() + submit -> futures resolve without
    manual pumping; shutdown drains and joins the thread."""
    sampler = CountingToySampler()
    gw = Gateway(sampler, max_batch=2, max_wait_ms=5.0)
    gw.start()
    futs = [gw.submit(Request(budget=2, x0=_x0(i))) for i in range(3)]
    for f in futs:
        assert f.result(timeout=30).latents.shape == (2,)
    gw.shutdown()
    assert gw.stats()["completed"] == 3


# ---------------------------------------------------------------------------
# Real backbone: padded-batch bit-exactness, jit reuse, sharding
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def backbone():
    from repro.configs import get_config
    from repro.core.schedulers import fm_ot
    from repro.data.synthetic import DataConfig, SyntheticTokens
    from repro.models import model as M

    cfg = get_config("yi-6b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    data = SyntheticTokens(cfg, DataConfig(batch_size=4, seq_len=8))
    art = SolverArtifact(
        spec=SolverSpec("midpoint", mode="anytime", budgets=BUDGETS),
        params=init_anytime(None, BUDGETS, "nested"), val_psnr=0.0)

    def make_sampler(update_fn=None):
        return AnytimeFlowSampler.from_artifact(
            art, params=params, cfg=cfg, sched=fm_ot(), update_fn=update_fn)

    return cfg, data.batch(0), make_sampler


def test_backbone_padded_batch_bit_identical(backbone):
    """The jit'd backbone path: 3 coalesced requests padded to bucket 4 give
    rows bit-identical to the direct 3-row ``sample_from`` call."""
    cfg, batch, make_sampler = backbone
    sampler = make_sampler()
    clock = FakeClock()
    gw = Gateway(sampler, max_batch=4, max_wait_ms=10.0, clock=clock)
    toks = batch["tokens"][:3]
    x0 = jax.random.normal(jax.random.PRNGKey(5), (3, 8, cfg.latent_dim))
    futs = [gw.submit(Request(tokens=toks[i], budget=2, x0=x0[i]))
            for i in range(3)]
    clock.advance(1.0)
    assert gw.pump() == 1
    direct = sampler.sample_from({"tokens": toks}, x0, 2)
    for i, f in enumerate(futs):
        assert f.result().meta["batch_padded"] == 4
        np.testing.assert_array_equal(np.asarray(f.result().latents),
                                      np.asarray(direct[i]))


def test_backbone_bucket_reuses_jit_program(backbone):
    """Padding to fixed buckets means the second same-bucket flush hits the
    compiled program: exactly ONE jit cache entry per (budget, bucket)."""
    cfg, batch, make_sampler = backbone
    sampler = make_sampler()
    clock = FakeClock()
    gw = Gateway(sampler, max_batch=4, max_wait_ms=10.0, clock=clock)
    for rnd in range(2):
        for i in range(3):                       # 3 rows -> bucket 4, twice
            gw.submit(Request(tokens=batch["tokens"][i], budget=2,
                              key=jax.random.PRNGKey(rnd * 10 + i)))
        clock.advance(1.0)
        assert gw.pump() == 1
    assert sampler._per_budget[2]._cache_size() == 1


@pytest.mark.integration
def test_backbone_mixed_budget_end_to_end(backbone):
    """Mixed flush on the real backbone rides sample_all: outputs are
    bit-identical to the direct shared-trajectory call, and the batch costs
    max(budgets) forwards (metadata), not sum."""
    cfg, batch, make_sampler = backbone
    sampler = make_sampler()
    clock = FakeClock()
    gw = Gateway(sampler, max_batch=2, max_wait_ms=10.0, clock=clock)
    toks = batch["tokens"][:2]
    x0 = jax.random.normal(jax.random.PRNGKey(6), (2, 8, cfg.latent_dim))
    f2 = gw.submit(Request(tokens=toks[0], budget=2, x0=x0[0]))
    f4 = gw.submit(Request(tokens=toks[1], budget=4, x0=x0[1]))
    clock.advance(1.0)
    # a full bucket spanning two budgets is planned as a MIXED batch when
    # the shared trajectory is cheaper (4 < 2 + 4)
    assert gw.pump() == 1
    outs = sampler.sample_all_from({"tokens": toks}, x0)
    assert f2.result().meta["mixed"] and f4.result().meta["mixed"]
    assert f2.result().meta["nfe_batch"] == 4
    np.testing.assert_array_equal(np.asarray(f2.result().latents),
                                  np.asarray(outs[2][0]))
    np.testing.assert_array_equal(np.asarray(f4.result().latents),
                                  np.asarray(outs[4][1]))


def test_backbone_sharded_gateway_matches_unsharded(backbone):
    """mesh= shards params/batches (1x1 host mesh on CPU); results must be
    identical to the single-device path."""
    from repro.launch.mesh import make_host_mesh

    cfg, batch, make_sampler = backbone
    ref_sampler = make_sampler()
    sampler = make_sampler()   # fresh: sharding re-places its params
    clock = FakeClock()
    gw = Gateway(sampler, max_batch=2, max_wait_ms=10.0,
                 mesh=make_host_mesh(), clock=clock)
    toks = batch["tokens"][:2]
    x0 = jax.random.normal(jax.random.PRNGKey(7), (2, 8, cfg.latent_dim))
    futs = [gw.submit(Request(tokens=toks[i], budget=2, x0=x0[i]))
            for i in range(2)]
    assert gw.pump() == 1
    direct = ref_sampler.sample_from({"tokens": toks}, x0, 2)
    for i, f in enumerate(futs):
        np.testing.assert_allclose(np.asarray(f.result().latents),
                                   np.asarray(direct[i]), atol=1e-6)


def test_gateway_from_zoo_boots_without_redistilling():
    """Gateway boot acquires its artifact through the SolverZoo: a cached
    artifact is a pure hit (zero loads, zero distills)."""
    from repro.configs import get_config
    from repro.core.schedulers import fm_ot
    from repro.models import model as M
    from repro.serving import SolverZoo

    cfg = get_config("yi-6b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    spec = SolverSpec("midpoint", mode="anytime", budgets=BUDGETS)
    zoo = SolverZoo(capacity=2)
    zoo.put(SolverArtifact(spec=spec, params=init_anytime(None, BUDGETS),
                           val_psnr=0.0))
    gw = Gateway.from_zoo(zoo, spec, params=params, cfg=cfg, sched=fm_ot(),
                          max_batch=2, clock=FakeClock())
    assert zoo.stats.hits == 1 and zoo.stats.distills == 0
    assert gw.sampler.budgets == BUDGETS
    fut = gw.submit(Request(tokens=jnp.zeros((8,), jnp.int32), budget=2,
                            key=jax.random.PRNGKey(0)))
    gw.drain()
    assert fut.result().meta["served_budget"] == 2


def test_gateway_with_kernel_update_fn_matches_reference(backbone):
    """make_update_fn threads the Pallas ns_update kernel (interpret on CPU)
    through gateway execution; outputs match the tensordot path."""
    from repro.kernels.ns_update.ops import make_update_fn

    cfg, batch, make_sampler = backbone
    ref_sampler = make_sampler()
    sampler = make_sampler(
        update_fn=make_update_fn(use_kernel=True, interpret=True))
    clock = FakeClock()
    gw = Gateway(sampler, max_batch=2, max_wait_ms=10.0, clock=clock)
    toks = batch["tokens"][:2]
    x0 = jax.random.normal(jax.random.PRNGKey(8), (2, 8, cfg.latent_dim))
    futs = [gw.submit(Request(tokens=toks[i], budget=2, x0=x0[i]))
            for i in range(2)]
    assert gw.pump() == 1
    direct = ref_sampler.sample_from({"tokens": toks}, x0, 2)
    for i, f in enumerate(futs):
        np.testing.assert_allclose(np.asarray(f.result().latents),
                                   np.asarray(direct[i]),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Concurrency satellites (PR 5): drain vs in-flight batches, stats locking
# ---------------------------------------------------------------------------


def test_drain_waits_for_inflight_batch():
    """Regression: drain() used to spin on queue depth alone — a batch a
    concurrent serve-thread pump had removed and was still executing was
    invisible, so drain could return with unresolved futures. It now waits
    on the in-flight count too."""
    import threading
    import time

    release = threading.Event()
    entered = threading.Event()

    class Blocking(CountingToySampler):
        def sample_from(self, batch, x0, budget):
            entered.set()
            release.wait(timeout=5)
            return super().sample_from(batch, x0, budget)

    gw, _, clock = _gateway(Blocking(), max_batch=2)
    gw.start()
    futs = [gw.submit(Request(budget=2, x0=_x0(i))) for i in range(2)]
    assert entered.wait(timeout=5)          # serve thread is executing
    assert gw.queue.depth() == 0            # entries already off the queue
    t = threading.Thread(target=gw.drain)
    t.start()
    time.sleep(0.05)
    assert t.is_alive()                     # drain genuinely waits here
    assert not any(f.done() for f in futs)
    release.set()
    t.join(timeout=10)
    assert not t.is_alive()
    assert all(f.done() for f in futs)
    gw.stop()


def test_drain_timeout_raises_with_stats_instead_of_hanging():
    """Satellite fix: drain(timeout=) bounds the wait on a wedged engine —
    it raises DrainTimeout carrying the stats snapshot (fleet host-leave
    depends on this), and the gateway STAYS closed afterwards."""
    from repro.serving import DrainTimeout

    gw, sampler, clock = _gateway()
    gw.submit(Request(budget=2, x0=_x0(0)))
    entry = gw.queue.snapshot()
    gw._take(entry)                # wedge: in flight, future never resolves
    with pytest.raises(DrainTimeout) as err:
        gw.drain(timeout=0.05)
    assert "inflight=1" in str(err.value)
    assert err.value.stats["submitted"] == 1
    assert err.value.stats["completed"] == 0
    with pytest.raises(RuntimeError, match="draining"):
        gw.submit(Request(budget=2, x0=_x0(1)))   # still closed
    gw._settle(1)                  # unwedge: drain can now finish cleanly
    entry[0].future.set_result(None)
    gw.drain(timeout=5.0)


def test_stats_snapshot_consistent_under_concurrent_traffic():
    """Satellite fix: ``submitted`` moves under ``_stats_lock`` like every
    other counter (it used to ride ``_intake_lock``) and ``stats()``
    snapshots under the lock — no snapshot may show more completions than
    submissions, and no submit may be lost."""
    import threading

    gw, _, clock = _gateway(max_batch=4)
    gw.start()
    N, T = 20, 6

    def worker(base):
        for i in range(N):
            gw.submit(Request(budget=2, x0=_x0(base * N + i)))

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(T)]
    bad = []

    def reader():
        for _ in range(300):
            s = gw.stats()
            if s["completed"] + s["failed"] > s["submitted"]:
                bad.append(s)

    r = threading.Thread(target=reader)
    for th in threads:
        th.start()
    r.start()
    for th in threads:
        th.join()
    r.join()
    gw.shutdown()
    assert not bad, f"inconsistent snapshots: {bad[:2]}"
    s = gw.stats()
    assert s["submitted"] == N * T
    assert s["completed"] == N * T
