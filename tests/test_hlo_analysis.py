"""HLO analyzer: trip-count-aware cost extraction validated on closed forms."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo import analyze


def test_scan_flops_multiplied_by_trip_count():
    def f(x, w):
        def body(h, _):
            return h @ w, None
        return jax.lax.scan(body, x, None, length=10)[0]

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32),
                         jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    r = analyze(c.as_text())
    assert r["flops"] == pytest.approx(10 * 2 * 128**3, rel=1e-3)


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(h, _):
            def inner(h2, _):
                return h2 @ w, None
            h, _ = jax.lax.scan(inner, h, None, length=4)
            return h, None
        return jax.lax.scan(outer, x, None, length=3)[0]

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                         jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    r = analyze(c.as_text())
    assert r["flops"] == pytest.approx(12 * 2 * 64**3, rel=1e-3)


def test_bytes_scale_with_tensor_size():
    def f(x):
        return x @ x

    small = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    big = jax.jit(f).lower(jax.ShapeDtypeStruct((512, 512), jnp.float32)).compile()
    rs, rb = analyze(small.as_text()), analyze(big.as_text())
    assert rb["bytes"] > 20 * rs["bytes"]


def test_unfused_elementwise_not_counted_as_traffic():
    """The byte model is TPU-fusion-optimistic: a chain of adds contributes
    at most its fusion-boundary traffic, far less than per-op accounting."""
    def f(x):
        for _ in range(20):
            x = x + 1.0
        return x

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((1024, 1024), jnp.float32)).compile()
    r = analyze(c.as_text())
    per_op = 20 * 2 * 4 * 1024 * 1024
    assert r["bytes"] < per_op / 2
