"""Fleet tier: deterministic HRW routing, work-stealer planning, fleet
bit-identity vs the single-gateway oracle (explicit-x0 and folded-key
paths), steal-under-imbalance, join/leave mid-traffic, bounded host-leave
drain, and emulated multi-device hosts (real backbone, own mesh per host).
"""
import os
import subprocess
import sys
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import emulate_hosts, host_meshes
from repro.serving import (
    DrainTimeout,
    FleetGateway,
    FleetRouter,
    Gateway,
    HostLoad,
    Request,
    WorkStealer,
)
from repro.serving.fleet import default_affinity, entry_affinity
from repro.serving.toy import CountingToySampler, FakeClock

BUDGETS = (2, 4)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _sampler(budgets=BUDGETS):
    s = CountingToySampler(budgets=budgets)
    # the folded-key path asks the sampler for the latent dim; the toy field
    # is 2-D
    s.cfg = SimpleNamespace(latent_dim=2)
    return s


def _fleet(n=4, budgets=BUDGETS, stealer=None, steal=False, **host_kw):
    """n toy hosts on ONE shared fake clock (simulated time is fleet-wide)."""
    clock = FakeClock()
    host_kw.setdefault("max_batch", 8)
    host_kw.setdefault("max_wait_ms", 10.0)
    host_kw.setdefault("mixed_budget_policy", "never")
    hosts = {f"h{i}": Gateway(_sampler(budgets), clock=clock, **host_kw)
             for i in range(n)}
    fleet = FleetGateway(hosts, stealer=stealer, steal=steal)
    return fleet, clock


def _single(budgets=BUDGETS, **kw):
    clock = FakeClock()
    kw.setdefault("max_batch", 8)
    kw.setdefault("max_wait_ms", 10.0)
    kw.setdefault("mixed_budget_policy", "never")
    return Gateway(_sampler(budgets), clock=clock, **kw), clock


def _x0(i, shape=(2,)):
    return jax.random.normal(jax.random.PRNGKey(100 + i), shape)


def _drain_fake(gw, clock):
    """Drain on a fake clock: age every partial group, then pump to empty."""
    clock.advance(1.0)
    gw.drain()


# ---------------------------------------------------------------------------
# FleetRouter (pure HRW routing)
# ---------------------------------------------------------------------------


def test_router_deterministic_across_instances():
    hosts = ["h0", "h1", "h2", "h3"]
    keys = [("flow", b, None, (2,)) for b in (2, 4, 8, 16)] \
        + [("decode", 1 << i) for i in range(5)]
    a, b = FleetRouter(hosts), FleetRouter(hosts)
    assert [a.route(k) for k in keys] == [b.route(k) for k in keys]
    # seed changes the assignment function (not necessarily every key)
    c = FleetRouter(hosts, seed=1)
    assert any(a.route(k) != c.route(k)
               for k in [("flow", i, None, (2,)) for i in range(64)])


def test_router_spreads_keys_across_hosts():
    r = FleetRouter(["h0", "h1", "h2", "h3"])
    homes = {r.route(("flow", i, None, (2,))) for i in range(64)}
    assert homes == {"h0", "h1", "h2", "h3"}


def test_router_remove_rehomes_only_the_removed_hosts_keys():
    r = FleetRouter(["h0", "h1", "h2", "h3"])
    keys = [("flow", i, None, (2,)) for i in range(64)]
    before = {k: r.route(k) for k in keys}
    r.remove("h2")
    for k in keys:
        if before[k] != "h2":
            assert r.route(k) == before[k]     # survivors keep their keys
        else:
            assert r.route(k) != "h2"


def test_router_add_moves_keys_only_to_the_new_host():
    r = FleetRouter(["h0", "h1", "h2"])
    keys = [("flow", i, None, (2,)) for i in range(64)]
    before = {k: r.route(k) for k in keys}
    r.add("h3")
    moved = {k for k in keys if r.route(k) != before[k]}
    assert moved and all(r.route(k) == "h3" for k in moved)


def test_router_validation():
    r = FleetRouter(["h0"])
    with pytest.raises(ValueError):
        r.add("h0")
    with pytest.raises(RuntimeError):
        FleetRouter().route(("flow", 2, None, (2,)))


# ---------------------------------------------------------------------------
# Affinity keys
# ---------------------------------------------------------------------------


def test_default_affinity_groups_flow_by_budget_and_shape():
    a = default_affinity(Request(budget=4, x0=_x0(0)))
    b = default_affinity(Request(budget=4, x0=_x0(1)))
    assert a == b == ("flow", 4, None, (2,))
    assert default_affinity(Request(budget=2, x0=_x0(0))) != a
    # budget None resolves to the sampler's top budget at routing time
    assert default_affinity(Request(x0=_x0(0)), top_budget=4) == a
    toks = jnp.zeros((3,), jnp.int32)
    assert default_affinity(Request(tokens=toks, budget=4)) == \
        ("flow", 4, (3,), None)


def test_default_affinity_buckets_decode_by_max_tokens():
    req = SimpleNamespace(prompt=[1, 2], max_tokens=5)
    assert default_affinity(req) == ("decode", 8)
    assert default_affinity(SimpleNamespace(prompt=[1], max_tokens=8)) == \
        ("decode", 8)
    assert default_affinity(SimpleNamespace(prompt=[1], max_tokens=9)) == \
        ("decode", 16)
    with pytest.raises(TypeError):
        default_affinity(object())


def test_entry_affinity_matches_submit_time_key():
    """A queued entry re-homes (on host leave) to the SAME key its request
    routed on — explicit-budget requests migrate where new ones route."""
    gw, clock = _single()
    gw.submit(Request(budget=2, x0=_x0(0)))
    e = gw.queue.snapshot()[0]
    assert entry_affinity(e) == \
        default_affinity(Request(budget=2, x0=_x0(0)))


# ---------------------------------------------------------------------------
# WorkStealer (pure planning)
# ---------------------------------------------------------------------------


def _loads(**depths):
    return {h: HostLoad(queue_depth=d, inflight=0)
            for h, d in depths.items()}


def test_stealer_pairs_idle_thieves_with_deepest_victims():
    s = WorkStealer(min_queue=2, max_steal=8, idle_depth=0)
    moves = s.plan(_loads(h0=12, h1=0, h2=0, h3=0))
    # each thief hits the then-deepest shard; amounts halve the victim
    assert moves == [("h0", "h1", 6), ("h0", "h2", 3), ("h0", "h3", 2)]


def test_stealer_respects_min_queue_and_max_steal():
    s = WorkStealer(min_queue=4, max_steal=2)
    assert s.plan(_loads(h0=3, h1=0)) == []          # victim too shallow
    assert s.plan(_loads(h0=9, h1=0)) == [("h0", "h1", 2)]   # capped
    assert WorkStealer(max_steal=0).plan(_loads(h0=9, h1=0)) == []


def test_stealer_busy_hosts_are_not_thieves():
    s = WorkStealer()
    loads = {"h0": HostLoad(12, 0), "h1": HostLoad(0, 3),
             "h2": HostLoad(1, 0)}
    assert s.plan(loads) == []       # h1 has work in flight, h2 has a queue
    # explicit thieves override idleness detection (fake-clock benches know
    # device busyness the snapshot cannot see)
    assert s.plan(loads, thieves=["h2"]) == [("h0", "h2", 6)]


def test_stealer_is_deterministic():
    s = WorkStealer()
    loads = _loads(h0=7, h1=7, h2=0, h3=0)
    assert s.plan(loads) == s.plan(dict(reversed(list(loads.items()))))


# ---------------------------------------------------------------------------
# FleetGateway: routing + bit-identity vs the single-gateway oracle
# ---------------------------------------------------------------------------


def test_fleet_mixed_budget_trace_bit_identical_to_single_gateway():
    """THE acceptance invariant: a mixed-budget trace served by a 4-host
    fleet resolves every sample bit-identically to one Gateway serving the
    same trace — routing, batch composition, and padding never perturb a
    row."""
    fleet, fclock = _fleet(4)
    single, sclock = _single()
    reqs = [Request(budget=BUDGETS[i % 2], x0=_x0(i)) for i in range(24)]
    ff = [fleet.submit(r) for r in reqs]
    sf = [single.submit(r) for r in reqs]
    # affinity groups each budget on one host; both budget groups are live
    homes = {fleet.home(r) for r in reqs}
    assert len(homes) == 2
    fclock.advance(1.0)
    fleet.drain()
    _drain_fake(single, sclock)
    for f, s in zip(ff, sf):
        np.testing.assert_array_equal(np.asarray(f.result().latents),
                                      np.asarray(s.result().latents))
    st = fleet.stats()
    assert st["submitted"] == st["completed"] == 24
    assert sum(st["routed"].values()) == 24


def test_fleet_folded_key_path_bit_identical_to_single_gateway():
    """No-x0 requests draw noise from fold_in(base_key, uid): the fleet's
    shared uid counter + base key make each request's folded key exactly
    what a lone gateway would have used at the same submission index."""
    fleet, fclock = _fleet(3)
    single, sclock = _single()
    toks = jnp.zeros((3,), jnp.int32)
    reqs = [Request(tokens=toks, budget=BUDGETS[i % 2]) for i in range(12)]
    ff = [fleet.submit(r) for r in reqs]
    sf = [single.submit(r) for r in reqs]
    fclock.advance(1.0)
    fleet.drain()
    _drain_fake(single, sclock)
    for f, s in zip(ff, sf):
        np.testing.assert_array_equal(np.asarray(f.result().latents),
                                      np.asarray(s.result().latents))


def test_fleet_same_trace_same_seed_is_deterministic():
    """Two fresh fleets, same trace: identical host assignments AND
    identical sample bytes (HRW is unsalted, the toy solver is seeded)."""
    toks = jnp.zeros((3,), jnp.int32)

    def run():
        fleet, clock = _fleet(4)
        reqs = [Request(tokens=toks, budget=BUDGETS[i % 2])
                for i in range(16)]
        homes = [fleet.home(r) for r in reqs]
        futs = [fleet.submit(r) for r in reqs]
        clock.advance(1.0)
        fleet.drain()
        return homes, [np.asarray(f.result().latents) for f in futs]

    homes_a, lat_a = run()
    homes_b, lat_b = run()
    assert homes_a == homes_b
    for a, b in zip(lat_a, lat_b):
        np.testing.assert_array_equal(a, b)


def test_fleet_submit_and_stats_plumbing():
    fleet, clock = _fleet(2)
    futs = [fleet.submit(budget=2, x0=_x0(i)) for i in range(3)]   # kwargs
    clock.advance(1.0)
    assert fleet.pump() > 0
    assert all(f.done() for f in futs)
    st = fleet.stats()
    assert st["hosts"] == 2 and st["completed"] == 3
    assert st["queue_depth"] == 0
    assert set(st["per_host"]) == {"h0", "h1"}
    assert 0.0 < st["occupancy"] <= 1.0
    fleet.shutdown()
    with pytest.raises(RuntimeError, match="draining"):
        fleet.submit(budget=2, x0=_x0(9))


# ---------------------------------------------------------------------------
# Work stealing end-to-end
# ---------------------------------------------------------------------------


def test_steal_rebalances_deep_shard_onto_idle_hosts():
    """One hot affinity key piles 12 requests on one shard; a steal round
    spreads them across the idle hosts — and every sample still matches the
    single-gateway oracle bit-for-bit (migration moves bookkeeping, never
    noise)."""
    fleet, fclock = _fleet(4, stealer=WorkStealer(min_queue=2, max_steal=8))
    single, sclock = _single()
    reqs = [Request(budget=2, x0=_x0(i)) for i in range(12)]
    home = fleet.home(reqs[0])
    ff = [fleet.submit(r) for r in reqs]
    sf = [single.submit(r) for r in reqs]
    assert fleet.stats()["queue_depths"][home] == 12
    moved = fleet.steal_round()
    assert moved == 11                    # 6 + 3 + 2 across the three thieves
    depths = fleet.stats()["queue_depths"]
    assert depths[home] == 1
    assert sorted(d for h, d in depths.items() if h != home) == [2, 3, 6]
    fclock.advance(1.0)
    fleet.drain()
    _drain_fake(single, sclock)
    for f, s in zip(ff, sf):
        np.testing.assert_array_equal(np.asarray(f.result().latents),
                                      np.asarray(s.result().latents))
    st = fleet.stats()
    assert st["steals"] == 11 and st["steal_rounds"] == 1
    assert st["stolen_out"] == st["stolen_in"] == 11
    assert st["per_host"][home]["stolen_out"] == 11
    # one count per request fleet-wide, no matter where entries migrated
    assert st["submitted"] == st["completed"] == 12


def test_steal_never_touches_inflight_entries():
    """``steal`` pops QUEUED entries only: an entry a pump has taken (still
    unresolved) is structurally unstealable."""
    gw, clock = _single()
    gw.submit(Request(budget=2, x0=_x0(0)))
    gw.submit(Request(budget=2, x0=_x0(1)))
    taken = gw.queue.snapshot()[:1]
    gw._take(taken)                       # simulate a planned batch in flight
    stolen = gw.steal(None)
    assert [e.uid for e in stolen] == [1]     # only the still-queued entry
    assert gw.load().inflight == 1
    gw._settle(1)                         # avoid wedging the toy gateway
    taken[0].future.set_result(None)


def test_steal_round_skips_when_balanced_or_disabled():
    fleet, clock = _fleet(2, steal=False)
    fleet.submit(budget=2, x0=_x0(0))
    assert fleet.steal_round() == 0           # stealer disabled
    fleet2, _ = _fleet(2, stealer=WorkStealer())
    fleet2.submit(budget=2, x0=_x0(0))
    assert fleet2.steal_round() == 0          # victim below min_queue


# ---------------------------------------------------------------------------
# Host join / leave
# ---------------------------------------------------------------------------


def test_join_and_leave_mid_traffic_no_dropped_futures():
    """Submit, grow the fleet, submit more, retire the busiest host: its
    queued shard re-homes to the survivors, every future resolves, and the
    samples still match the single-gateway oracle bit-for-bit."""
    fleet, fclock = _fleet(3)
    single, sclock = _single()
    reqs = [Request(budget=BUDGETS[i % 2], x0=_x0(i)) for i in range(18)]
    sf = [single.submit(r) for r in reqs]
    ff = [fleet.submit(r) for r in reqs[:9]]
    fleet.add_host("h3", Gateway(_sampler(), clock=fclock, max_batch=8,
                                 max_wait_ms=10.0,
                                 mixed_budget_policy="never"))
    assert fleet.hosts == ("h0", "h1", "h2", "h3")
    ff += [fleet.submit(r) for r in reqs[9:]]
    victim = fleet.home(Request(budget=2, x0=_x0(0)))
    queued = fleet.stats()["queue_depths"][victim]
    assert queued > 0
    fleet.remove_host(victim)
    assert victim not in fleet.hosts
    st = fleet.stats()
    assert st["rerouted"] == queued
    # nothing lost: every queued entry is in some surviving shard
    assert st["queue_depth"] == 18
    # migrated budget-2 entries landed where new same-key submits now route
    new_home = fleet.home(Request(budget=2, x0=_x0(0)))
    assert st["queue_depths"][new_home] > 0
    fclock.advance(1.0)
    fleet.drain()
    _drain_fake(single, sclock)
    assert all(f.done() for f in ff)
    for f, s in zip(ff, sf):
        np.testing.assert_array_equal(np.asarray(f.result().latents),
                                      np.asarray(s.result().latents))


def test_remove_host_bounded_drain_raises_on_wedged_engine():
    fleet, clock = _fleet(2)
    req = Request(budget=2, x0=_x0(0))
    home = fleet.home(req)
    fleet.submit(req)
    gw = fleet._hosts[home].gateway
    gw._take(gw.queue.snapshot())         # wedge: in flight, never resolving
    with pytest.raises(DrainTimeout) as err:
        fleet.remove_host(home, timeout=0.05)
    assert err.value.stats["queue_depth"] == 0
    assert "inflight=1" in str(err.value)
    assert home not in fleet.hosts        # routing left BEFORE the drain


def test_membership_validation():
    fleet, clock = _fleet(2)
    with pytest.raises(ValueError, match="already"):
        fleet.add_host("h0", Gateway(_sampler(), clock=clock))
    with pytest.raises(KeyError):
        fleet.remove_host("nope")
    fleet.remove_host("h1")
    with pytest.raises(RuntimeError, match="last host"):
        fleet.remove_host("h0")
    with pytest.raises(ValueError, match="at least one host"):
        FleetGateway({})


def test_threaded_fleet_serves_on_real_clock():
    """start() runs per-host serve threads + the balancer; futures resolve
    without manual pumping; shutdown drains everything."""
    hosts = {f"h{i}": Gateway(_sampler(), max_batch=4, max_wait_ms=5.0,
                              mixed_budget_policy="never")
             for i in range(2)}
    fleet = FleetGateway(hosts, stealer=WorkStealer(min_queue=1))
    fleet.start(poll_s=0.001, balance_s=0.001)
    futs = [fleet.submit(budget=BUDGETS[i % 2], x0=_x0(i)) for i in range(6)]
    for f in futs:
        assert f.result(timeout=30).latents.shape == (2,)
    fleet.shutdown(timeout=30)
    assert fleet.stats()["completed"] == 6


# ---------------------------------------------------------------------------
# Emulated multi-device hosts (repro.distributed.emulate)
# ---------------------------------------------------------------------------


def test_emulate_hosts_raises_once_jax_is_initialized():
    jax.devices()                         # force backend init
    with pytest.raises(RuntimeError, match="already initialized"):
        emulate_hosts(4)
    with pytest.raises(ValueError):
        emulate_hosts(0)


def test_host_meshes_raises_without_enough_devices():
    n = len(jax.devices())
    with pytest.raises(RuntimeError, match="emulate_hosts"):
        host_meshes(n + 1)
    with pytest.raises(ValueError):
        host_meshes(0)


def test_emulate_hosts_subprocess_splits_cpu():
    """The success path needs a fresh process (this one initialized jax at
    collection): emulate_hosts(6) before the first jax touch yields 6
    devices."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    code = ("from repro.distributed import emulate_hosts\n"
            "emulate_hosts(6)\n"
            "import jax\n"
            "print(len(jax.devices()))\n")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300, env=env, cwd=REPO)
    assert res.returncode == 0, res.stderr
    assert res.stdout.strip().splitlines()[-1] == "6"


@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >=4 devices (CI fleet job sets XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
def test_host_meshes_partition_is_disjoint_and_even():
    meshes = host_meshes(4)
    assert len(meshes) == 4
    seen = set()
    for m in meshes:
        assert m.axis_names == ("data", "model")
        ids = {d.id for d in m.devices.flat}
        assert not ids & seen
        seen |= ids
    assert len(seen) == 4 * (len(jax.devices()) // 4)


@pytest.mark.integration
@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >=4 devices (CI fleet job sets XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
def test_fleet_on_emulated_hosts_matches_single_gateway():
    """Acceptance run on the real backbone: 4 emulated hosts, each gateway
    sharded on its OWN per-host mesh, serving a mixed-budget trace — every
    sample matches the single (unsharded) Gateway serving the same trace."""
    from repro.configs import get_config
    from repro.core.anytime import init_anytime
    from repro.core.schedulers import fm_ot
    from repro.data.synthetic import DataConfig, SyntheticTokens
    from repro.models import model as M
    from repro.serving import AnytimeFlowSampler
    from repro.solvers import SolverArtifact, SolverSpec

    cfg = get_config("yi-6b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = SyntheticTokens(cfg, DataConfig(batch_size=8, seq_len=8)).batch(0)
    art = SolverArtifact(
        spec=SolverSpec("midpoint", mode="anytime", budgets=BUDGETS),
        params=init_anytime(None, BUDGETS, "nested"), val_psnr=0.0)

    def make_sampler():
        return AnytimeFlowSampler.from_artifact(
            art, params=params, cfg=cfg, sched=fm_ot())

    clock = FakeClock()
    meshes = host_meshes(4)
    hosts = {f"h{i}": Gateway(make_sampler(), mesh=meshes[i], max_batch=4,
                              max_wait_ms=10.0, mixed_budget_policy="never",
                              clock=clock)
             for i in range(4)}
    fleet = FleetGateway(hosts, stealer=WorkStealer(min_queue=1))
    single = Gateway(make_sampler(), max_batch=4, max_wait_ms=10.0,
                     mixed_budget_policy="never", clock=FakeClock())
    toks = batch["tokens"]
    x0 = jax.random.normal(jax.random.PRNGKey(5), (8, 8, cfg.latent_dim))
    reqs = [Request(tokens=toks[i], budget=BUDGETS[i % 2], x0=x0[i])
            for i in range(8)]
    ff = [fleet.submit(r) for r in reqs]
    sf = [single.submit(r) for r in reqs]
    assert len({fleet.home(r) for r in reqs}) >= 2
    clock.advance(1.0)
    fleet.drain()
    single.drain()
    for f, s in zip(ff, sf):
        # 2-device data splits genuinely reassociate reductions (unlike the
        # single-host 1x1-mesh test), so allclose, not array_equal
        np.testing.assert_allclose(np.asarray(f.result().latents),
                                   np.asarray(s.result().latents),
                                   atol=1e-5, rtol=1e-5)
    assert fleet.stats()["completed"] == 8
