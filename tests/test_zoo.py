"""SolverZoo: hit/miss/eviction accounting, directory-scan loading, and the
cache contract that a hit performs zero distillation."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import schedulers, toy
from repro.serving import SolverZoo
from repro.solvers import SolverArtifact, SolverSpec


@pytest.fixture(scope="module")
def field():
    sched = schedulers.fm_ot()
    return toy.mixture_field(sched, toy.two_moons_means(),
                             jnp.full((16,), 0.15), jnp.ones((16,)))


@pytest.fixture(scope="module")
def val_pairs():
    x0 = jax.random.normal(jax.random.PRNGKey(0), (16, 2))
    return x0, jnp.zeros_like(x0)


class CountingDistiller:
    """Stub distiller with a call counter — baseline mode, so it is cheap."""

    def __init__(self, field, val_pairs):
        self.field = field
        self.val_pairs = val_pairs
        self.calls = 0

    def __call__(self, spec: SolverSpec) -> SolverArtifact:
        self.calls += 1
        return spec.distill(self.field, None, self.val_pairs).artifact()


@pytest.fixture
def distiller(field, val_pairs):
    return CountingDistiller(field, val_pairs)


def test_hit_skips_distillation_entirely(distiller):
    zoo = SolverZoo(capacity=4, distill_fn=distiller)
    spec = SolverSpec("euler", 4)
    a1 = zoo.get(spec)
    assert (zoo.stats.misses, zoo.stats.distills, distiller.calls) == (1, 1, 1)
    a2 = zoo.get(spec)
    assert a2 is a1                              # the very same object
    assert zoo.stats.hits == 1
    assert distiller.calls == 1                  # a hit distills NOTHING
    # an equal-but-not-identical spec is still a hit (keying is by value)
    assert zoo.get(SolverSpec("euler", 4)) is a1
    assert distiller.calls == 1


def test_distinct_specs_are_distinct_entries(distiller):
    zoo = SolverZoo(capacity=4, distill_fn=distiller)
    zoo.get(SolverSpec("euler", 4))
    zoo.get(SolverSpec("euler", 8))
    zoo.get(SolverSpec("midpoint", 4))
    assert len(zoo) == 3 and distiller.calls == 3


def test_lru_eviction(distiller):
    zoo = SolverZoo(capacity=2, distill_fn=distiller)
    a, b, c = (SolverSpec("euler", n) for n in (2, 4, 8))
    zoo.get(a)
    zoo.get(b)
    zoo.get(a)                  # refresh a: b is now least-recently used
    zoo.get(c)                  # evicts b
    assert zoo.stats.evictions == 1
    assert b not in zoo and a in zoo and c in zoo
    zoo.get(b)                  # re-distilled after eviction
    assert distiller.calls == 4


def test_directory_scan_loads_without_distilling(field, val_pairs, tmp_path,
                                                 distiller):
    specs = [SolverSpec("euler", 4), SolverSpec("midpoint", 8),
             SolverSpec("midpoint", mode="anytime", budgets=(2, 4))]
    for i, spec in enumerate(specs):
        if spec.mode == "anytime":
            from repro.core.anytime import init_anytime

            art = SolverArtifact(spec=spec,
                                 params=init_anytime(field, spec.budgets),
                                 val_psnr=0.0)
        else:
            art = spec.distill(field, None, val_pairs).artifact()
        art.save(str(tmp_path / f"solver_{i}.msgpack"))
    # distractors: a non-artifact msgpack and a non-msgpack file
    from repro.checkpoint import checkpointer

    checkpointer.save(str(tmp_path / "raw.msgpack"), {"w": jnp.zeros((2,))})
    (tmp_path / "notes.txt").write_text("not a solver")

    zoo = SolverZoo(capacity=4, distill_fn=distiller)
    assert zoo.scan(str(tmp_path)) == 3
    for spec in specs:
        art = zoo.get(spec)
        assert art.spec == spec
    assert zoo.stats.loads == 3
    assert zoo.stats.distills == 0 and distiller.calls == 0
    assert zoo.get(specs[2]).kind == "anytime"   # second get: memory hit
    assert zoo.stats.hits == 1


def test_scan_missing_directory_is_empty():
    assert SolverZoo().scan("/nonexistent/zoo/dir") == 0


def test_get_without_distiller_raises(field, val_pairs):
    zoo = SolverZoo()
    with pytest.raises(KeyError):
        zoo.get(SolverSpec("euler", 4))
    # ... unless the call supplies what SolverSpec.distill needs
    art = zoo.get(SolverSpec("euler", 4), field=field, val_pairs=val_pairs)
    assert art.spec == SolverSpec("euler", 4)
    assert zoo.stats.distills == 1


def test_distiller_spec_mismatch_rejected(field, val_pairs):
    rogue = SolverSpec("midpoint", 8)

    def bad_distill(spec):
        return rogue.distill(field, None, val_pairs).artifact()

    zoo = SolverZoo(distill_fn=bad_distill)
    with pytest.raises(ValueError):
        zoo.get(SolverSpec("euler", 4))


def test_save_dir_persists_across_zoos(field, val_pairs, tmp_path, distiller):
    zoo1 = SolverZoo(distill_fn=distiller, save_dir=str(tmp_path))
    spec = SolverSpec("euler", 4)
    zoo1.get(spec)
    assert distiller.calls == 1
    # a fresh process scanning the same dir never re-distills
    zoo2 = SolverZoo(distill_fn=distiller, scan_dirs=(str(tmp_path),))
    art = zoo2.get(spec)
    assert art.spec == spec
    assert zoo2.stats.loads == 1 and distiller.calls == 1


def test_save_dir_never_collides_specs(field, val_pairs, tmp_path, distiller):
    """Specs differing only in cfg_scale/sigma0 get distinct files, and a
    re-get after eviction loads the RIGHT artifact (regression: one shared
    filename let the last save shadow every other spec)."""
    a = SolverSpec("euler", 4, cfg_scale=0.0)
    b = SolverSpec("euler", 4, cfg_scale=2.0)
    zoo = SolverZoo(capacity=1, distill_fn=distiller, save_dir=str(tmp_path))
    zoo.get(a)
    zoo.get(b)                  # evicts a from memory; both now on disk
    assert len(list(tmp_path.glob("*.msgpack"))) == 2
    art = zoo.get(a)            # must come back from a's own file
    assert art.spec == a
    assert zoo.stats.loads == 1 and distiller.calls == 2


def test_stale_disk_file_is_not_served(field, val_pairs, tmp_path, distiller):
    """A scanned file that no longer holds the indexed spec is re-distilled,
    never served wrong."""
    spec = SolverSpec("euler", 4)
    spec.distill(field, None, val_pairs).artifact().save(
        str(tmp_path / "s.msgpack"))
    zoo = SolverZoo(distill_fn=distiller, scan_dirs=(str(tmp_path),))
    # overwrite the file with a different solver behind the zoo's back
    SolverSpec("midpoint", 8).distill(field, None, val_pairs).artifact() \
        .save(str(tmp_path / "s.msgpack"))
    art = zoo.get(spec)
    assert art.spec == spec
    assert zoo.stats.loads == 0 and zoo.stats.distills == 1


def test_capacity_validation():
    with pytest.raises(ValueError):
        SolverZoo(capacity=0)


def test_preload_warm_starts_top_specs(distiller):
    """Boot-time warm start: preload resolves every spec once; later gets
    are pure memory hits (zero loads, zero distills)."""
    zoo = SolverZoo(capacity=4, distill_fn=distiller)
    specs = [SolverSpec("euler", 2), SolverSpec("euler", 4)]
    arts = zoo.preload(specs)
    assert [a.spec for a in arts] == specs
    assert distiller.calls == 2
    for spec in specs:
        zoo.get(spec)
    assert zoo.stats.hits == 2 and distiller.calls == 2


def test_preload_respects_capacity(distiller):
    """Preloading past capacity would self-evict; only the first k load."""
    notes = []
    zoo = SolverZoo(capacity=2, distill_fn=distiller)
    arts = zoo.preload([SolverSpec("euler", n) for n in (2, 4, 8)],
                       log=notes.append)
    assert len(arts) == 2 and len(zoo) == 2
    assert distiller.calls == 2 and zoo.stats.evictions == 0
    assert any("first 2 of 3" in n for n in notes)


def test_eviction_spills_to_save_dir(field, val_pairs, tmp_path):
    """ROADMAP open item: an evicted artifact is saved to save_dir instead
    of being dropped, and a later get LOADS it (no re-distillation) even
    with no distiller at all."""
    a = SolverSpec("euler", 2).distill(field, None, val_pairs).artifact()
    b = SolverSpec("euler", 4).distill(field, None, val_pairs).artifact()
    zoo = SolverZoo(capacity=1, save_dir=str(tmp_path))
    zoo.put(a)
    assert list(tmp_path.glob("*.msgpack")) == []   # in cache: nothing spilled
    zoo.put(b)                                      # evicts a -> spills it
    assert zoo.stats.evictions == 1 and zoo.stats.spills == 1
    assert len(list(tmp_path.glob("*.msgpack"))) == 1
    art = zoo.get(a.spec)                           # loads the spilled file
    assert art.spec == a.spec
    assert zoo.stats.loads == 1 and zoo.stats.distills == 0


def test_eviction_does_not_respill_already_saved(field, val_pairs, tmp_path,
                                                 distiller):
    """An artifact the zoo already persisted (distill-save or prior spill)
    is not written twice on eviction."""
    zoo = SolverZoo(capacity=1, distill_fn=distiller, save_dir=str(tmp_path))
    zoo.get(SolverSpec("euler", 2))                 # distilled AND saved
    zoo.get(SolverSpec("euler", 4))                 # evicts the saved one
    assert zoo.stats.evictions == 1 and zoo.stats.spills == 0
    assert len(list(tmp_path.glob("*.msgpack"))) == 2


def test_refreshed_put_spills_fresh_artifact_not_stale_file(field, val_pairs,
                                                            tmp_path,
                                                            distiller):
    """Regression: put() of an UPDATED artifact for an already-saved spec
    must not let eviction trust the stale file — the refresh is spilled and
    the next get serves the new parameters."""
    import dataclasses

    zoo = SolverZoo(capacity=1, distill_fn=distiller, save_dir=str(tmp_path))
    spec = SolverSpec("euler", 2)
    old = zoo.get(spec)                             # distilled AND saved
    zoo.put(dataclasses.replace(old, val_psnr=42.0))   # refreshed in memory
    zoo.get(SolverSpec("euler", 4))                 # evicts the refresh
    assert zoo.stats.spills == 1                    # ... which was spilled
    art = zoo.get(spec)                             # loads the SPILLED copy
    assert art.val_psnr == 42.0
    assert zoo.stats.loads == 1 and distiller.calls == 2
