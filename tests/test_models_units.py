"""Unit + property tests for model substrates: linear-scan equivalences,
MoE dispatch strategies, attention masks, RoPE, data pipeline, checkpointing."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (CI installs it)")
st = pytest.importorskip("hypothesis.strategies")

from repro.checkpoint import checkpointer
from repro.configs import get_config
from repro.data.synthetic import DataConfig, SyntheticTokens
from repro.models.attention import attention_forward, init_attention
from repro.models.linear_scan import gla_chunked, gla_recurrent
from repro.models.moe import moe_mlp_onehot, moe_mlp_scatter, init_moe_mlp


@hypothesis.given(
    L=st.integers(4, 96),
    chunk=st.sampled_from([8, 16, 32]),
    inclusive=st.booleans(),
    strong=st.booleans(),
)
@hypothesis.settings(max_examples=12, deadline=None)
def test_gla_chunked_matches_recurrent(L, chunk, inclusive, strong):
    key = jax.random.PRNGKey(L * 7 + chunk)
    ks = jax.random.split(key, 4)
    B, H, dk, dv = 2, 2, 8, 12
    q = jax.random.normal(ks[0], (B, L, H, dk))
    k = jax.random.normal(ks[1], (B, L, H, dk))
    v = jax.random.normal(ks[2], (B, L, H, dv))
    scale = 25.0 if strong else 0.5
    ld = -jnp.abs(jax.random.normal(ks[3], (B, L, H, dk))) * scale
    o_ref, s_ref = gla_recurrent(q, k, v, ld, inclusive=inclusive)
    o, s = gla_chunked(q, k, v, ld, inclusive=inclusive, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=5e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=5e-4,
                               rtol=2e-4)


def test_gla_scalar_decay_matches_broadcast():
    """SSD specialization: (B,L,H,1) decay == broadcasting it to dk."""
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 4)
    B, L, H, dk, dv = 2, 64, 3, 16, 32
    q = jax.random.normal(ks[0], (B, L, H, dk))
    k = jax.random.normal(ks[1], (B, L, H, dk))
    v = jax.random.normal(ks[2], (B, L, H, dv))
    ld1 = -jnp.abs(jax.random.normal(ks[3], (B, L, H, 1)))
    ld = jnp.broadcast_to(ld1, (B, L, H, dk))
    o1, s1 = gla_chunked(q, k, v, ld1, chunk=16)
    o2, s2 = gla_chunked(q, k, v, ld, chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)
    o3, s3 = gla_recurrent(q, k, v, ld)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3), atol=1e-4)


def test_gla_state_carry_composes():
    """Running two halves with carried state == running the whole sequence."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    B, L, H, dk, dv = 1, 64, 2, 8, 8
    q = jax.random.normal(ks[0], (B, L, H, dk))
    k = jax.random.normal(ks[1], (B, L, H, dk))
    v = jax.random.normal(ks[2], (B, L, H, dv))
    ld = -jnp.abs(jax.random.normal(ks[3], (B, L, H, dk)))
    o_full, s_full = gla_chunked(q, k, v, ld, chunk=16)
    o1, s1 = gla_chunked(q[:, :32], k[:, :32], v[:, :32], ld[:, :32], chunk=16)
    o2, s2 = gla_chunked(q[:, 32:], k[:, 32:], v[:, 32:], ld[:, 32:], s1, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(o_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


def test_moe_scatter_matches_onehot():
    """The two dispatch strategies agree when nothing is dropped."""
    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0))
    p = init_moe_mlp(jax.random.PRNGKey(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y1, aux1 = moe_mlp_scatter(p, x, cfg)
    y2, aux2 = moe_mlp_onehot(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 the output must differ from no-drop (tokens
    actually get dropped) but stay finite."""
    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    tight = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=0.25))
    loose = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0))
    p = init_moe_mlp(jax.random.PRNGKey(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y_tight, _ = moe_mlp_scatter(p, x, tight)
    y_loose, _ = moe_mlp_scatter(p, x, loose)
    assert bool(jnp.isfinite(y_tight).all())
    assert float(jnp.max(jnp.abs(y_tight - y_loose))) > 1e-4


def test_moe_grads_flow_to_router():
    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    p = init_moe_mlp(jax.random.PRNGKey(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))

    def loss(p):
        y, aux = moe_mlp_scatter(p, x, cfg)
        return jnp.mean(y**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.max(jnp.abs(g["router"]))) > 0.0


def test_causal_mask_blocks_future():
    """Perturbing future tokens must not change past outputs."""
    d, H, KV, hd = 64, 4, 2, 16
    p = init_attention(jax.random.PRNGKey(0), d, H, KV, hd)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, d))
    pos = jnp.arange(8)
    kw = dict(n_heads=H, n_kv=KV, head_dim=hd, rope_theta=1e4, causal=True)
    y1 = attention_forward(p, x, pos, **kw)
    x2 = x.at[:, 5:].add(100.0)
    y2 = attention_forward(p, x2, pos, **kw)
    np.testing.assert_allclose(np.asarray(y1[:, :5]), np.asarray(y2[:, :5]),
                               atol=1e-5)


def test_sliding_window_mask():
    """With window w, token t must ignore tokens older than t-w+1."""
    d, H, KV, hd = 64, 4, 2, 16
    p = init_attention(jax.random.PRNGKey(0), d, H, KV, hd)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, d))
    pos = jnp.arange(12)
    kw = dict(n_heads=H, n_kv=KV, head_dim=hd, rope_theta=1e4, causal=True,
              window=4)
    y1 = attention_forward(p, x, pos, **kw)
    x2 = x.at[:, 0:2].add(100.0)   # tokens 0-1 are outside window of t >= 6
    y2 = attention_forward(p, x2, pos, **kw)
    np.testing.assert_allclose(np.asarray(y1[:, 6:]), np.asarray(y2[:, 6:]),
                               atol=1e-5)


def test_synthetic_data_deterministic():
    cfg = get_config("yi-6b", smoke=True)
    d1 = SyntheticTokens(cfg, DataConfig(batch_size=2, seq_len=8, seed=3))
    d2 = SyntheticTokens(cfg, DataConfig(batch_size=2, seq_len=8, seed=3))
    np.testing.assert_array_equal(np.asarray(d1.batch(7)["tokens"]),
                                  np.asarray(d2.batch(7)["tokens"]))
    assert not np.array_equal(np.asarray(d1.batch(0)["tokens"]),
                              np.asarray(d1.batch(1)["tokens"]))


def test_checkpoint_roundtrip(tmp_path):
    from repro.models import model as M
    cfg = get_config("rwkv6-7b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    path = os.path.join(tmp_path, "ckpt", "step_1.msgpack")
    checkpointer.save(path, params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    restored = checkpointer.restore(path, zeros)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert checkpointer.latest_step(os.path.dirname(path)) == 1


def test_training_reduces_cfm_loss():
    """A few steps of the real trainer must reduce the CFM loss."""
    from repro.launch.train import train
    _, losses = train("yi-6b", smoke=True, steps=30, batch=8, seq=16,
                      lr=3e-3, log=lambda *_: None)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.9, losses[:3] + losses[-3:]
