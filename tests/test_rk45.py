"""Adaptive RK45 ground-truth generator."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schedulers, toy
from repro.core.rk45 import rk45_solve


def test_exact_on_linear_field():
    field = toy.linear_field(schedulers.fm_ot())
    x0 = jax.random.normal(jax.random.PRNGKey(0), (5, 3))
    res = rk45_solve(field.fn, x0, rtol=1e-8, atol=1e-8)
    exact = toy.linear_field_solution(x0, 1.0)
    # fp32 end-to-end: tolerance reflects accumulation roundoff, not method error
    np.testing.assert_allclose(np.asarray(res.x1), np.asarray(exact), atol=5e-4)
    assert int(res.accepted) > 0


def test_tolerance_controls_error():
    sched = schedulers.fm_ot()
    field = toy.mixture_field(sched, toy.two_moons_means(),
                              jnp.full((16,), 0.15), jnp.ones((16,)))
    x0 = jax.random.normal(jax.random.PRNGKey(0), (8, 2))
    fine = rk45_solve(field.fn, x0, rtol=1e-8, atol=1e-8).x1
    coarse = rk45_solve(field.fn, x0, rtol=1e-3, atol=1e-3)
    # multimodal flows amplify integration error near basin boundaries;
    # the bound reflects ODE conditioning, not solver accuracy.
    err = float(jnp.max(jnp.abs(coarse.x1 - fine)))
    assert err < 0.15
    assert int(coarse.nfe) < 10_000


def test_nfe_counts_evals():
    field = toy.linear_field(schedulers.fm_ot())
    x0 = jnp.ones((2, 2))
    res = rk45_solve(field.fn, x0, rtol=1e-5, atol=1e-5)
    assert int(res.nfe) == 7 * (int(res.accepted) + int(res.rejected))
