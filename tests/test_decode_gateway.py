"""Decode-side continuous batching: the ``DecodeEngine`` slot API
(per-row positions, write-masked steps, slot resets) and the
``DecodeGateway`` front-end (FIFO admission into freed slots, per-slot stop
conditions, wall-step accounting, drain)."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serving.decode import DecodeGateway, DecodeRequest
from repro.serving.engine import DecodeEngine
from repro.serving.toy import FakeClock, ToyDecodeEngine


def _engine(arch="yi-6b"):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return DecodeEngine(params=params, cfg=cfg)


def _solo_tokens(engine, prompt, n):
    """Reference: teacher-force ``prompt`` through the plain (scalar-index)
    decode path, then greedy — independent of the slot machinery."""
    state = engine.init_state(1, 32)
    for tok in prompt[:-1]:
        _, state = engine.step(jnp.asarray([tok], jnp.int32), state)
    toks, _ = engine.greedy(jnp.asarray([prompt[-1]], jnp.int32), state, n)
    return np.asarray(toks)[0].tolist()


# -- engine slot API ---------------------------------------------------------


@pytest.mark.parametrize("arch", ["yi-6b", "rwkv6-7b"])
def test_step_slots_join_bit_identical_to_solo(arch):
    """A sequence admitted into a freed slot mid-flight (its row reset, its
    own per-row position starting at 0) must decode bit-identically to
    decoding it alone — the decode twin of the PR 4 join invariant."""
    eng = _engine(arch)
    S = 3
    state = eng.init_slot_state(S, 32)
    feed = np.zeros((S,), np.int32)
    active = np.zeros((S,), bool)
    feed[0], active[0] = 3, True          # slot 0 runs from step 0
    outs = []
    for step in range(9):
        if step == 4:                     # slot 1 joins 4 steps in
            free = np.zeros((S,), bool)
            free[1] = True
            state = eng.reset_slots(state, free)
            feed[1], active[1] = 7, True
        nxt, state = eng.step_slots(feed, state, active)
        nxt = np.asarray(nxt)
        feed = np.where(active, nxt, feed).astype(np.int32)
        outs.append(nxt.copy())
    outs = np.stack(outs)
    assert outs[:, 0].tolist() == _solo_tokens(eng, [3], 9)
    assert outs[4:, 1].tolist() == _solo_tokens(eng, [7], 5)


def test_step_slots_inactive_rows_frozen():
    """Masked-out rows keep state AND position; re-activating them resumes
    exactly where they stopped."""
    eng = _engine("rwkv6-7b")
    state = eng.init_slot_state(2, 16)
    feed = np.asarray([3, 7], np.int32)
    both = np.ones((2,), bool)
    nxt, state = eng.step_slots(feed, state, both)
    idx_after = np.asarray(state.index)
    assert idx_after.tolist() == [1, 1]
    # freeze row 1 for two steps; row 0 decodes on
    only0 = np.asarray([True, False])
    row1 = [np.asarray(leaf)[:, 1].copy() for leaf in
            (state.shift_tm, state.shift_cm, state.wkv)]
    for _ in range(2):
        nxt, state = eng.step_slots(np.asarray(nxt), state, only0)
    assert np.asarray(state.index).tolist() == [3, 1]
    for got, want in zip((state.shift_tm, state.shift_cm, state.wkv), row1):
        np.testing.assert_array_equal(np.asarray(got)[:, 1], want)


def test_greedy_scan_matches_stepwise_loop():
    """The jit'd lax.scan greedy equals the per-token step loop (same ops,
    one program) — and caches one program per num_steps."""
    eng = _engine("yi-6b")
    prompt = jnp.asarray([3, 7], jnp.int32)
    toks, _ = eng.greedy(prompt, eng.init_state(2, 16), 5)
    state = eng.init_state(2, 16)
    token, outs = prompt, []
    for _ in range(5):
        logits, state = eng.step(token, state)
        token = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(token)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.stack(outs, axis=1)))
    assert 5 in eng._greedy_fns


# -- gateway over the toy engine (deterministic fake clock) ------------------


def _drive(gw, futures):
    while not all(f.done() for f in futures):
        gw.pump()


def test_gateway_mixed_lengths_match_solo_oracle():
    """Continuous refill over mixed output lengths: every sequence's tokens
    equal its solo decode, finished slots are refilled mid-flight."""
    eng = ToyDecodeEngine()
    gw = DecodeGateway(eng, max_slots=2, cache_slots=16)
    reqs = [DecodeRequest(prompt=[i + 1, i + 2], max_tokens=t)
            for i, t in enumerate([3, 9, 5, 2, 7])]
    futures = [gw.submit(r) for r in reqs]
    _drive(gw, futures)
    for r, f in zip(reqs, futures):
        assert f.result().tokens.tolist() == eng.solo_tokens(r.prompt,
                                                             r.max_tokens)
    s = gw.stats()
    assert s["completed"] == len(reqs)
    assert s["joins"] > 0                       # slots were refilled
    assert any(f.result().meta["join_step"] > 0 for f in futures)


def test_gateway_wall_step_accounting():
    """One engine step = one backbone forward for the whole slot batch: a
    full batch of equal-length sequences costs prompt-1+max_tokens steps
    TOTAL, not per sequence."""
    eng = ToyDecodeEngine()
    gw = DecodeGateway(eng, max_slots=4, cache_slots=16)
    futures = [gw.submit(DecodeRequest(prompt=[i + 1, i + 2], max_tokens=6))
               for i in range(4)]
    _drive(gw, futures)
    assert gw.stats()["forwards"] == 1 + 6      # (P-1) + T
    assert eng.steps == 7
    assert gw.stats()["tokens_out"] == 4 * 6


def test_gateway_refill_strictly_beats_run_to_completion():
    """At mixed output lengths, continuous slot refill finishes the same
    request list in strictly fewer wall-steps than run-to-completion
    batching (refill=False) — and serves identical tokens."""
    reqs = [([1 + i], t) for i, t in enumerate([16, 2, 2, 2] * 4)]

    def total_steps(refill):
        eng = ToyDecodeEngine()
        gw = DecodeGateway(eng, max_slots=4, cache_slots=16, refill=refill)
        futures = [gw.submit(DecodeRequest(prompt=p, max_tokens=t))
                   for p, t in reqs]
        _drive(gw, futures)
        toks = [f.result().tokens.tolist() for f in futures]
        return gw.stats()["forwards"], toks

    cont_steps, cont_toks = total_steps(True)
    rtc_steps, rtc_toks = total_steps(False)
    assert cont_toks == rtc_toks
    assert cont_steps < rtc_steps


def test_gateway_stop_token_per_slot():
    eng = ToyDecodeEngine()
    ref = eng.solo_tokens([5], 10)
    stop = ref[3]
    gw = DecodeGateway(eng, max_slots=2, cache_slots=16)
    f_stop = gw.submit(DecodeRequest(prompt=[5], max_tokens=10,
                                     stop_token=stop))
    f_len = gw.submit(DecodeRequest(prompt=[5], max_tokens=10))
    _drive(gw, [f_stop, f_len])
    assert f_stop.result().tokens.tolist() == ref[:3]   # stop tok excluded
    assert f_stop.result().meta["finish_reason"] == "stop"
    assert f_len.result().tokens.tolist() == ref
    assert f_len.result().meta["finish_reason"] == "length"


def test_gateway_wait_ends_at_admission():
    """Waits are queue time (fake clock): a request admitted into a freed
    slot waited for exactly the steps it queued through."""
    clock = FakeClock()
    eng = ToyDecodeEngine(on_step=lambda: clock.advance(0.001))
    gw = DecodeGateway(eng, max_slots=1, cache_slots=16, clock=clock)
    f1 = gw.submit(DecodeRequest(prompt=[3], max_tokens=4))
    f2 = gw.submit(DecodeRequest(prompt=[9], max_tokens=2))
    _drive(gw, [f1, f2])
    assert f1.result().meta["wait_ms"] == 0.0
    # f2 queued while f1 held the only slot for 4 steps of 1 ms
    assert f2.result().meta["wait_ms"] == pytest.approx(4.0)
    assert gw.stats()["max_wait_ms"] == pytest.approx(4.0)


def test_gateway_validates_requests():
    gw = DecodeGateway(ToyDecodeEngine(), max_slots=1, cache_slots=4)
    with pytest.raises(ValueError):
        gw.submit(DecodeRequest(prompt=[], max_tokens=4))
    with pytest.raises(ValueError):
        gw.submit(DecodeRequest(prompt=[3], max_tokens=0))
    with pytest.raises(ValueError):
        DecodeGateway(ToyDecodeEngine(), max_slots=0, cache_slots=4)


def test_gateway_engine_failure_reaches_futures():
    """A raising engine step fails every resident sequence's future and
    frees the slots — the serve loop survives (decode twin of the
    trajectory-failure guard)."""

    class BoomEngine(ToyDecodeEngine):
        def step_slots(self, token, state, active):
            raise RuntimeError("boom")

    gw = DecodeGateway(BoomEngine(), max_slots=2, cache_slots=4)
    f = gw.submit(DecodeRequest(prompt=[3], max_tokens=4))
    assert gw.pump() == 1
    with pytest.raises(RuntimeError, match="boom"):
        f.result(timeout=1)
    assert gw.stats()["failed"] == 1
    assert gw._drained()                        # nothing left in flight
    # slots freed; a new submit is servable once the engine recovers
    assert all(s is None for s in gw._slots)


def test_gateway_drain_resolves_everything():
    gw = DecodeGateway(ToyDecodeEngine(), max_slots=2, cache_slots=16)
    futures = [gw.submit(DecodeRequest(prompt=[i + 1], max_tokens=3 + i))
               for i in range(5)]
    gw.drain()
    assert all(f.done() for f in futures)
    with pytest.raises(RuntimeError):
        gw.submit(DecodeRequest(prompt=[1], max_tokens=1))


# -- gateway over the real engine --------------------------------------------


def test_gateway_real_engine_threaded_bit_identity():
    """End-to-end over the real backbone with the serve thread: mixed
    lengths on a 2-slot pool; a sequence admitted into a freed slot decodes
    bit-identically to the plain scalar-index decode path."""
    eng = _engine("yi-6b")
    gw = DecodeGateway(eng, max_slots=2, cache_slots=32)
    gw.start()
    lengths = (4, 6, 3)
    futures = [gw.submit(DecodeRequest(prompt=[3, 7], max_tokens=t))
               for t in lengths]
    gw.shutdown()
    ref = _solo_tokens(eng, [3, 7], max(lengths))
    for t, f in zip(lengths, futures):
        assert f.result().tokens.tolist() == ref[:t]
    s = gw.stats()
    assert s["completed"] == 3
    assert s["joins"] >= 1                      # the third prompt joined


def test_gateway_drain_waits_for_inflight_slots():
    """Drain must wait for sequences RESIDENT IN SLOTS (taken off the
    queue, futures unresolved), not just queue depth."""
    release = threading.Event()

    class SlowEngine(ToyDecodeEngine):
        def step_slots(self, token, state, active):
            release.wait(timeout=5)
            return super().step_slots(token, state, active)

    gw = DecodeGateway(SlowEngine(), max_slots=2, cache_slots=8)
    gw.start()
    f = gw.submit(DecodeRequest(prompt=[3], max_tokens=2))
    # wait until the serve thread has admitted it (queue empty, slot busy)
    for _ in range(1000):
        if gw.queue.depth() == 0 and any(s is not None for s in gw._slots):
            break
        import time
        time.sleep(0.001)
    t = threading.Thread(target=gw.shutdown)
    t.start()
    assert not f.done()                         # drain is genuinely waiting
    release.set()
    t.join(timeout=10)
    assert not t.is_alive()
    assert f.done() and f.result().meta["finish_reason"] == "length"


def test_gateway_rejects_requests_exceeding_cache_capacity():
    """Non-windowed KV-cache engines clamp writes past the cache's last
    physical slot (silently degraded tokens) — the gateway must reject
    over-length requests at submit instead."""
    eng = _engine("yi-6b")
    gw = DecodeGateway(eng, max_slots=2, cache_slots=8)
    with pytest.raises(ValueError, match="cache capacity"):
        gw.submit(DecodeRequest(prompt=[3, 7], max_tokens=8))
    # exactly at capacity: positions 0..7 fit the 8 slots
    f = gw.submit(DecodeRequest(prompt=[3, 7], max_tokens=7))
    _drive(gw, [f])
    assert f.result().tokens.tolist() == _solo_tokens(eng, [3, 7], 7)
    # unbounded engines (recurrent state / toy) accept any length
    DecodeGateway(ToyDecodeEngine(), max_slots=1, cache_slots=4).submit(
        DecodeRequest(prompt=[3], max_tokens=64))
    assert _engine("rwkv6-7b").seq_capacity_bounded is False


def test_gateway_rejects_encdec_engines():
    """The slot protocol has no hook for per-request encoder memory, so an
    encoder-decoder engine must be rejected loudly, not served garbage."""
    cfg = get_config("whisper-medium", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(TypeError, match="encoder-decoder"):
        DecodeGateway(DecodeEngine(params=params, cfg=cfg), max_slots=1,
                      cache_slots=8)
