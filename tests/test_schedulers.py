"""Scheduler invariants (paper eq. 4) and snr-inverse exactness."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (CI installs it)")
st = pytest.importorskip("hypothesis.strategies")

from repro.core import schedulers

ALL = ["fm_ot", "fm_cs", "vp"]


@pytest.mark.parametrize("name", ALL)
def test_endpoint_conditions(name):
    s = schedulers.get_scheduler(name)
    # VP satisfies alpha_0 = 0 only approximately (xi_1 = e^{-5.025} ~ 0.0066).
    assert float(s.alpha(jnp.asarray(0.0))) == pytest.approx(0.0, abs=1e-2)
    assert float(s.alpha(jnp.asarray(1.0))) == pytest.approx(1.0, abs=1e-5)
    assert float(s.sigma(jnp.asarray(1.0))) == pytest.approx(0.0, abs=1e-4)
    assert float(s.sigma(jnp.asarray(0.0))) > 0.0


@pytest.mark.parametrize("name", ALL)
def test_snr_strictly_increasing(name):
    s = schedulers.get_scheduler(name)
    t = jnp.linspace(0.01, 0.99, 197)
    snr = np.asarray(s.snr(t))
    assert np.all(np.diff(snr) > 0)


@pytest.mark.parametrize("name", ALL + ["ve"])
@hypothesis.given(t=st.floats(0.05, 0.95))
@hypothesis.settings(max_examples=25, deadline=None)
def test_snr_inverse_roundtrip(name, t):
    s = schedulers.get_scheduler(name)
    t_arr = jnp.asarray(t, jnp.float32)
    back = float(s.snr_inverse(s.snr(t_arr)))
    assert back == pytest.approx(t, abs=2e-3)


def test_scaled_sigma_preconditioning():
    base = schedulers.fm_ot()
    s = schedulers.scaled_sigma(base, 5.0)
    t = jnp.asarray(0.3)
    assert float(s.sigma(t)) == pytest.approx(5.0 * float(base.sigma(t)), rel=1e-6)
    assert float(s.alpha(t)) == pytest.approx(float(base.alpha(t)), rel=1e-6)
    # snr_inverse consistency
    assert float(s.snr_inverse(s.snr(t))) == pytest.approx(0.3, abs=1e-4)


def test_derivatives_match_finite_difference():
    for name in ALL:
        s = schedulers.get_scheduler(name)
        t = jnp.asarray(0.37)
        eps = 1e-4
        fd = (float(s.alpha(t + eps)) - float(s.alpha(t - eps))) / (2 * eps)
        assert float(s.dalpha(t)) == pytest.approx(fd, rel=1e-2)
        fd = (float(s.sigma(t + eps)) - float(s.sigma(t - eps))) / (2 * eps)
        assert float(s.dsigma(t)) == pytest.approx(fd, rel=1e-2)
