"""Shape-tier ladder edges: rung mapping at exact boundaries, CLI
parsing and validation, pad/crop as pure functions, oversize rejection
BEFORE any queue/metric side effect, padded-crop bit-identity vs the
direct sampler at every rung (flush and continuous), mixed-tier shared
trajectories, per-tier occupancy accounting, drain under cancellation,
and tier-keyed fleet affinity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import ContinuousGateway, Gateway, Request
from repro.serving.fleet import default_affinity
from repro.serving.tiers import ShapeLadder, TierOversize, crop_row, pad_rows
from repro.serving.toy import FakeClock, ToyAnytimeSampler

LADDER = ShapeLadder((8, 16))


def _sampler():
    return ToyAnytimeSampler(jit=False)


def _flush(tiers=LADDER, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait_ms", 5.0)
    return Gateway(_sampler(), clock=FakeClock(), tiers=tiers, **kw)


def _continuous(tiers=LADDER, **kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_wait_ms", 5.0)
    return ContinuousGateway(_sampler(), clock=FakeClock(), tiers=tiers, **kw)


def _x0(i, rows):
    return jax.random.normal(jax.random.PRNGKey(300 + i), (rows, 2))


def _direct(x0, budget):
    """The bit-identity oracle: a FRESH sampler at the NATIVE shape."""
    s = _sampler()
    return np.asarray(s.sample_from(None, x0[None], budget)[0])


# ---------------------------------------------------------------------------
# ladder mapping / parsing (pure)
# ---------------------------------------------------------------------------


def test_rung_maps_to_smallest_holding_rung():
    assert LADDER.rung(1) == 8
    assert LADDER.rung(7) == 8
    assert LADDER.rung(8) == 8          # exact boundary stays on its rung
    assert LADDER.rung(9) == 16
    assert LADDER.rung(16) == 16


def test_rung_oversize_raises_with_configured_rungs():
    with pytest.raises(TierOversize) as ei:
        LADDER.rung(17)
    assert ei.value.length == 17
    assert ei.value.rungs == (8, 16)
    assert "--tiers" in str(ei.value)   # the fix is named in the message


def test_parse_sorts_dedups_and_validates():
    assert ShapeLadder.parse("8,16").rungs == (8, 16)
    assert ShapeLadder.parse("16,8,8").rungs == (8, 16)
    with pytest.raises(ValueError):
        ShapeLadder.parse("8,sixteen")
    with pytest.raises(ValueError):
        ShapeLadder(())
    with pytest.raises(ValueError):
        ShapeLadder((0, 8))


def test_no_position_axis_is_its_own_exact_tier():
    assert LADDER.rung_for((5,)) is None
    assert LADDER.tier_shape((5,)) == (5,)
    assert LADDER.tier_shape((5, 2)) == (8, 2)
    assert LADDER.tier_shape((16, 2)) == (16, 2)


def test_pad_rows_zero_fills_and_crop_row_roundtrips():
    arr = np.arange(10.0).reshape(5, 2)
    padded = pad_rows(arr, 8)
    assert padded.shape == (8, 2)
    np.testing.assert_array_equal(padded[:5], arr)
    np.testing.assert_array_equal(padded[5:], 0.0)
    np.testing.assert_array_equal(crop_row(padded, (5, 2)), arr)
    assert pad_rows(arr, 5) is arr      # exact rung: no copy
    assert crop_row(arr, (5, 2)) is arr
    assert crop_row(arr, None) is arr   # untiered entry


# ---------------------------------------------------------------------------
# gateway integration (flush + continuous)
# ---------------------------------------------------------------------------


def test_flush_bit_identity_at_every_rung():
    """Padded-crop bit-identity vs the direct sampler: one native length
    strictly inside each rung, plus the EXACT boundary length of each."""
    gw = _flush()
    lengths = (5, 8, 13, 16)
    x0s = [_x0(i, n) for i, n in enumerate(lengths)]
    futs = [gw.submit(Request(budget=4, x0=x)) for x in x0s]
    gw.drain()
    for fut, x0, n in zip(futs, x0s, lengths):
        resp = fut.result()
        got = np.asarray(resp.latents)
        assert got.shape == (n, 2)      # cropped back to native
        np.testing.assert_array_equal(got, _direct(x0, 4))
        assert resp.meta["native_shape"] == (n, 2)
        assert resp.meta["tier_shape"] == (LADDER.rung(n), 2)


def test_continuous_bit_identity_and_shared_trajectory_across_tiers():
    """Native lengths 5/7/8 all pad to rung 8 and must share ONE
    trajectory (the whole point of the ladder), each settling
    bit-identical to the direct sampler at its native shape."""
    gw = _continuous(max_slots=3)
    lengths = (5, 7, 8)
    x0s = [_x0(10 + i, n) for i, n in enumerate(lengths)]
    futs = [gw.submit(Request(budget=8, x0=x)) for x in x0s]
    gw.drain()
    assert gw.stats()["trajectories"] == 1
    for fut, x0, n in zip(futs, x0s, lengths):
        got = np.asarray(fut.result().latents)
        assert got.shape == (n, 2)
        np.testing.assert_array_equal(got, _direct(x0, 8))


def test_oversize_rejected_at_submit_without_side_effects():
    gw = _flush()
    with pytest.raises(TierOversize):
        gw.submit(Request(budget=4, x0=_x0(20, 17)))
    assert gw.queue.depth() == 0
    assert gw.stats()["submitted"] == 0


def test_untiered_gateway_keeps_exact_shapes():
    """tiers=None is the opt-out: no padding, no tier meta, two near
    shapes stay in separate exact-shape groups (two flush batches)."""
    gw = _flush(tiers=None)
    futs = [gw.submit(Request(budget=4, x0=_x0(30 + i, n)))
            for i, n in enumerate((5, 7))]
    gw.drain()
    for fut, n in zip(futs, (5, 7)):
        resp = fut.result()
        assert np.asarray(resp.latents).shape == (n, 2)
        assert "tier_shape" not in resp.meta
    assert gw.stats()["batches"] == 2


def test_tier_occupancy_counters_and_gauge():
    """Two natives (5 + 7 rows) in one full rung-8 flush batch: real
    position-rows 12 of 16 padded -> labelled occupancy 0.75."""
    gw = _flush(max_batch=2)
    for i, n in enumerate((5, 7)):
        gw.submit(Request(budget=4, x0=_x0(40 + i, n)))
    gw.drain()
    snap = gw.metrics.snapshot()
    label = 'tier="8x2"'
    assert snap[f"tier_real_rows{{{label}}}"] == 12
    assert snap[f"tier_padded_rows{{{label}}}"] == 16
    assert snap[f"tier_occupancy{{{label}}}"] == pytest.approx(0.75)


def test_mixed_tier_drain_under_cancellation():
    """Cancelling one tiered request mid-queue must not wedge the drain
    or corrupt its batch-mates' crops."""
    gw = _continuous(max_slots=3)
    x0s = [_x0(50 + i, n) for i, n in enumerate((5, 7, 8))]
    futs = [gw.submit(Request(budget=8, x0=x)) for x in x0s]
    futs[1].cancel()
    gw.drain()
    assert gw.queue.depth() == 0 and gw._traj is None
    for idx in (0, 2):
        got = np.asarray(futs[idx].result().latents)
        assert got.shape == x0s[idx].shape
        np.testing.assert_array_equal(got, _direct(x0s[idx], 8))


# ---------------------------------------------------------------------------
# fleet affinity
# ---------------------------------------------------------------------------


def test_fleet_affinity_groups_near_shapes_on_one_tier_key():
    a = default_affinity(Request(budget=4, x0=_x0(60, 5)), tiers=LADDER)
    b = default_affinity(Request(budget=4, x0=_x0(61, 7)), tiers=LADDER)
    c = default_affinity(Request(budget=4, x0=_x0(62, 13)), tiers=LADDER)
    assert a == b                       # same rung -> same home
    assert a != c                       # different rung -> different home
    exact_a = default_affinity(Request(budget=4, x0=_x0(60, 5)))
    exact_b = default_affinity(Request(budget=4, x0=_x0(61, 7)))
    assert exact_a != exact_b           # no ladder: raw shapes fragment
    # oversize must not raise in routing (submit rejects it later)
    over = default_affinity(Request(budget=4, x0=_x0(63, 17)), tiers=LADDER)
    assert over[3] == (17, 2)
