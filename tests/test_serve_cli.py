"""Subprocess smoke tests for ``launch/serve.py`` — flow (anytime artifact,
budget routing, --strict-nfe) and decode modes on the smoke config."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.integration

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(*argv, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", *argv],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO)


@pytest.fixture(scope="module")
def anytime_artifact(tmp_path_factory):
    """An (untrained) anytime artifact on disk — serving must not retrain."""
    from repro.core.anytime import init_anytime
    from repro.solvers import SolverArtifact, SolverSpec

    path = str(tmp_path_factory.mktemp("zoo") / "anytime.msgpack")
    budgets = (2, 4)
    SolverArtifact(
        spec=SolverSpec("midpoint", mode="anytime", budgets=budgets),
        params=init_anytime(None, budgets),
        val_psnr=0.0,
        provenance={"arch": "yi-6b", "scheduler": "fm_ot"},
    ).save(path)
    return path


def test_flow_mode_serves_mixed_budgets_from_one_artifact(anytime_artifact):
    res = _run("--arch", "yi-6b", "--mode", "flow",
               "--solver-artifact", anytime_artifact,
               "--request-budgets", "2,4,8", "--requests", "3",
               "--batch", "2", "--seq", "4")
    assert res.returncode == 0, res.stderr
    out = res.stdout
    assert "no retraining" in out
    assert "distilling" not in out               # zero re-distillation
    assert "(2 NFE)" in out and "(4 NFE)" in out
    # the unserved budget 8 is routed to the nearest one, loudly
    assert "WARNING: requested NFE 8" in out
    assert "using nearest budget 4" in out


def test_flow_mode_explicit_nfe_is_routed_not_ignored(anytime_artifact):
    """Regression: --nfe used to be silently ignored when an artifact was
    loaded; it must route through nearest-budget selection with a WARNING."""
    res = _run("--arch", "yi-6b", "--mode", "flow",
               "--solver-artifact", anytime_artifact, "--nfe", "16",
               "--requests", "1", "--batch", "2", "--seq", "4")
    assert res.returncode == 0, res.stderr
    assert "WARNING: requested NFE 16" in res.stdout
    assert "using nearest budget 4" in res.stdout
    assert "(4 NFE)" in res.stdout


def test_flow_mode_strict_nfe_rejects_unserved_budget(anytime_artifact):
    res = _run("--arch", "yi-6b", "--mode", "flow",
               "--solver-artifact", anytime_artifact, "--strict-nfe",
               "--request-budgets", "8", "--requests", "1",
               "--batch", "2", "--seq", "4")
    assert res.returncode != 0
    assert "--strict-nfe" in res.stderr + res.stdout


def test_flow_mode_gateway_coalesces_requests(anytime_artifact):
    """--gateway serves the request stream through the batching gateway:
    same-budget requests coalesce (4 requests -> 2 batches here), and the
    summary line reports batch/occupancy/NFE metrics."""
    res = _run("--arch", "yi-6b", "--mode", "flow",
               "--solver-artifact", anytime_artifact, "--gateway",
               "--max-batch", "2", "--max-wait-ms", "200",
               "--request-budgets", "2,4,2,4", "--requests", "4",
               "--batch", "2", "--seq", "4")
    assert res.returncode == 0, res.stderr
    out = res.stdout
    assert "gateway stats: done=4/4" in out
    assert "batches=2" in out
    assert "request 0: served 2 NFE" in out
    assert "request 1: served 4 NFE" in out
    assert "batch 2/2" in out                    # full bucket, no padding


def test_flow_mode_gateway_records_budget_drift(anytime_artifact):
    """An unserved budget is routed AND the (requested, served) pair is in
    the response metadata — printed per request, not only a warning."""
    res = _run("--arch", "yi-6b", "--mode", "flow",
               "--solver-artifact", anytime_artifact, "--gateway",
               "--max-batch", "2", "--max-wait-ms", "50",
               "--request-budgets", "8", "--requests", "2",
               "--batch", "2", "--seq", "4")
    assert res.returncode == 0, res.stderr
    assert "served 4 NFE (requested 8)" in res.stdout


def test_flow_mode_gateway_mesh_host(anytime_artifact):
    """--mesh host runs gateway batches through the sharded execution path
    (1x1 mesh on CPU) end-to-end."""
    res = _run("--arch", "yi-6b", "--mode", "flow",
               "--solver-artifact", anytime_artifact, "--gateway",
               "--mesh", "host", "--max-batch", "2", "--max-wait-ms", "50",
               "--request-budgets", "2", "--requests", "2",
               "--batch", "2", "--seq", "4")
    assert res.returncode == 0, res.stderr
    assert "gateway stats: done=2/2" in res.stdout


def test_flow_mode_fleet_gateway(anytime_artifact):
    """--fleet 2 serves the stream through a two-host FleetGateway: all
    requests complete and the summary reports the fleet routing stats."""
    res = _run("--arch", "yi-6b", "--mode", "flow",
               "--solver-artifact", anytime_artifact, "--gateway",
               "--fleet", "2", "--max-batch", "2", "--max-wait-ms", "50",
               "--request-budgets", "2,4", "--requests", "4",
               "--batch", "2", "--seq", "4")
    assert res.returncode == 0, res.stderr
    out = res.stdout
    assert "gateway stats: done=4/4" in out
    assert "fleet hosts=2" in out
    assert "routed:" in out


def test_flow_mode_continuous_gateway(anytime_artifact):
    """--continuous serves the stream through the continuous-batching
    gateway: requests ride shared trajectories and the summary reports
    trajectory/join/slot-occupancy metrics."""
    res = _run("--arch", "yi-6b", "--mode", "flow",
               "--solver-artifact", anytime_artifact, "--gateway",
               "--continuous", "--max-slots", "2", "--max-wait-ms", "50",
               "--request-budgets", "2,4", "--requests", "4",
               "--batch", "2", "--seq", "4")
    assert res.returncode == 0, res.stderr
    out = res.stdout
    assert "gateway stats: done=4/4" in out
    assert "traj=" in out and "slot_occ=" in out


def test_decode_mode_smoke():
    res = _run("--arch", "yi-6b", "--mode", "decode", "--batch", "2",
               "--steps", "3", "--slots", "16")
    assert res.returncode == 0, res.stderr
    assert "decoded 3 tokens x 2 seqs" in res.stdout


def test_decode_mode_gateway_continuous_batching():
    """--mode decode --gateway serves concurrent prompts through the
    continuous-batching decode gateway: mixed lengths on a small slot pool
    force mid-flight admission (joins) and the stats line reports
    tokens/occupancy."""
    res = _run("--arch", "yi-6b", "--mode", "decode", "--gateway",
               "--max-slots", "2", "--requests", "5",
               "--decode-lengths", "6,2,4", "--slots", "16")
    assert res.returncode == 0, res.stderr
    out = res.stdout
    assert out.count("request ") == 5
    assert "decode gateway stats: done=5/5" in out
    assert "slot_occ=" in out and "tok/s=" in out
    # a freed slot was refilled mid-flight at least once
    assert "joins=0" not in out
