"""ST transformations: scheduler-change correctness (eqs. 6-8) and the
sample-recovery property x(1) = x_bar(1) / s_1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schedulers, st_transform, toy
from repro.core.rk45 import rk45_solve


@pytest.mark.parametrize("sname,tname", [("fm_ot", "fm_cs"), ("fm_cs", "fm_ot"),
                                          ("vp", "fm_ot")])
def test_scheduler_change_identities(sname, tname):
    """eq. 8: alpha_bar_r = s_r alpha_{t_r}, sigma_bar_r = s_r sigma_{t_r}."""
    src, tgt = schedulers.get_scheduler(sname), schedulers.get_scheduler(tname)
    st = st_transform.scheduler_change_st(src, tgt)
    for r in [0.1, 0.33, 0.5, 0.77, 0.9]:
        r_ = jnp.asarray(r)
        t, s = st.t(r_), st.s(r_)
        np.testing.assert_allclose(float(s * src.alpha(t)), float(tgt.alpha(r_)),
                                   atol=2e-3)
        np.testing.assert_allclose(float(s * src.sigma(t)), float(tgt.sigma(r_)),
                                   atol=2e-3)


def test_identity_transform_is_identity():
    st = st_transform.identity_st()
    r = jnp.asarray(0.4)
    assert float(st.t(r)) == pytest.approx(0.4)
    assert float(st.s(r)) == pytest.approx(1.0)
    assert float(st.dt(r)) == pytest.approx(1.0)
    assert float(st.ds(r)) == pytest.approx(0.0, abs=1e-6)


def test_transformed_field_recovers_samples():
    """Integrate u_bar from s_0 x0, unscale by s_1 -> same sample as u from x0."""
    sched = schedulers.fm_ot()
    field = toy.mixture_field(sched, toy.two_moons_means(),
                              jnp.full((16,), 0.15), jnp.ones((16,)))
    ubar, st = st_transform.precondition(field, sigma0=2.5)
    x0 = jax.random.normal(jax.random.PRNGKey(0), (4, 2))
    direct = rk45_solve(field.fn, x0, rtol=1e-7, atol=1e-7).x1
    xbar0 = st.s(jnp.asarray(0.0)) * x0
    bar = rk45_solve(ubar.fn, xbar0, rtol=1e-7, atol=1e-7).x1 / st.s(jnp.asarray(1.0))
    # trajectory-sensitivity (not transform error) dominates: bound is loose on
    # max, tight on median.
    err = np.abs(np.asarray(bar) - np.asarray(direct))
    assert err.max() < 0.05 and np.median(err) < 0.01


def test_precondition_source_std():
    """eq. 14: preconditioning sigma0 means the transformed source has std
    sigma0 (s_0 = sigma0 when sigma(0)=1)."""
    sched = schedulers.fm_ot()
    field = toy.mixture_field(sched, toy.two_moons_means(),
                              jnp.full((16,), 0.15), jnp.ones((16,)))
    _, st = st_transform.precondition(field, sigma0=5.0)
    assert float(st.s(jnp.asarray(0.0))) == pytest.approx(5.0, rel=1e-3)
    assert float(st.s(jnp.asarray(1.0))) == pytest.approx(1.0, rel=1e-2)
    assert float(st.t(jnp.asarray(0.0))) == pytest.approx(0.0, abs=1e-4)
    assert float(st.t(jnp.asarray(1.0))) == pytest.approx(1.0, abs=1e-4)
