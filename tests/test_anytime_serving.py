"""Multi-NFE anytime serving: nested-grid properties, early-exit extraction
(each exit is a bona-fide m-step NS solver, bit-exactly), and
``AnytimeFlowSampler`` budget routing / PSNR parity with
``evaluate_anytime``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ns_solver, schedulers, toy
from repro.core.anytime import (
    anytime_sample, evaluate_anytime, extract_ns, init_anytime, nested_grid,
)
from repro.core.bns import BNSTrainConfig, psnr
from repro.serving import AnytimeFlowSampler, FlowSampler
from repro.solvers import SolverArtifact, SolverSpec, ns_at_budget

BUDGET_SETS = [(4,), (2, 4), (4, 8), (2, 4, 8), (4, 8, 16), (3, 6, 12)]


def _random_anytime(budgets, key, scale=0.1):
    """Nested-init params jittered everywhere, so indexing bugs can't hide
    behind structural zeros."""
    theta = init_anytime(None, budgets, "nested")
    leaves, treedef = jax.tree.flatten(theta)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef,
        [l + scale * jax.random.normal(k, l.shape)
         for l, k in zip(leaves, keys)])


@pytest.fixture(scope="module")
def field():
    sched = schedulers.fm_ot()
    return toy.mixture_field(sched, toy.two_moons_means(),
                             jnp.full((16,), 0.15), jnp.ones((16,)))


# ---------------------------------------------------------------------------
# nested_grid properties
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("budgets", BUDGET_SETS)
def test_nested_grid_is_permutation(budgets):
    """The grid is a permutation of the union of every budget's uniform grid
    (= the top budget's grid when budgets divide each other)."""
    g = nested_grid(budgets)
    n = max(budgets)
    assert len(g) == n
    union = sorted({i / m for m in budgets for i in range(m)})
    assert sorted(g.tolist()) == pytest.approx(union)


@pytest.mark.parametrize("budgets", BUDGET_SETS)
def test_nested_grid_each_prefix_covers_budget_grid(budgets):
    """The first m eval times are exactly {i/m} — each prefix spreads over
    [0, 1) like a dedicated m-step solver's grid."""
    g = nested_grid(budgets)
    for m in budgets:
        assert set(g[:m].tolist()) == {i / m for i in range(m)}, m


# ---------------------------------------------------------------------------
# early-exit extraction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("budgets", [(2, 4), (4, 8), (2, 4, 8)])
def test_extracted_solver_bit_exact(field, budgets):
    """Every early exit == running the extracted m-step NS solver through
    Algorithm 1, bit-exactly (same weighted-sum arithmetic)."""
    theta = _random_anytime(budgets, jax.random.PRNGKey(0))
    x0 = jax.random.normal(jax.random.PRNGKey(1), (32, 2))
    outs = anytime_sample(theta, budgets, field.fn, x0)
    for m in budgets:
        ns = extract_ns(theta, budgets, m)
        assert ns.n == m
        got = ns_solver.ns_sample(ns, field.fn, x0, unroll=True)
        np.testing.assert_array_equal(np.asarray(outs[m]), np.asarray(got))


def test_extracted_solver_costs_exactly_m_nfe(field):
    budgets = (2, 4, 8)
    theta = _random_anytime(budgets, jax.random.PRNGKey(0))
    x0 = jax.random.normal(jax.random.PRNGKey(1), (8, 2))
    for m in budgets:
        calls = {"n": 0}

        def counting(t, x):
            calls["n"] += 1
            return field.fn(t, x)

        ns_solver.ns_sample(extract_ns(theta, budgets, m), counting, x0,
                            unroll=True)
        assert calls["n"] == m


def test_extract_ns_validates_budget():
    theta = init_anytime(None, (2, 4), "nested")
    with pytest.raises(ValueError):
        extract_ns(theta, (2, 4), 3)
    # generic ns_at_budget dispatch: anytime extracts, NS requires exact n
    assert ns_at_budget(theta, (2, 4), 2).n == 2
    ns = extract_ns(theta, (2, 4), 4)
    assert ns_at_budget(ns, (4,), 4) is ns
    with pytest.raises(ValueError):
        ns_at_budget(ns, (4,), 2)


def test_extracted_top_budget_is_whole_solver(field):
    budgets = (4, 8)
    theta = _random_anytime(budgets, jax.random.PRNGKey(3))
    ns = extract_ns(theta, budgets, 8)
    got = ns_solver.ns_sample(
        ns, field.fn, jax.random.normal(jax.random.PRNGKey(4), (16, 2)),
        unroll=True)
    ref = anytime_sample(theta, budgets, field.fn,
                         jax.random.normal(jax.random.PRNGKey(4), (16, 2)))[8]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# AnytimeFlowSampler (smoke backbone)
# ---------------------------------------------------------------------------

BUDGETS = (2, 4)


@pytest.fixture(scope="module")
def backbone():
    from repro.configs import get_config
    from repro.data.synthetic import DataConfig, SyntheticTokens
    from repro.models import model as M

    cfg = get_config("yi-6b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    data = SyntheticTokens(cfg, DataConfig(batch_size=2, seq_len=8))
    batch = data.batch(0)
    sched = schedulers.fm_ot()
    field = M.velocity_field(params, cfg, sched, batch)
    return cfg, params, batch, sched, field


@pytest.fixture(scope="module")
def served(backbone):
    cfg, params, batch, sched, field = backbone
    theta = _random_anytime(BUDGETS, jax.random.PRNGKey(7))
    art = SolverArtifact(
        spec=SolverSpec("midpoint", mode="anytime", budgets=BUDGETS),
        params=theta, val_psnr=0.0)
    sampler = AnytimeFlowSampler.from_artifact(art, params=params, cfg=cfg,
                                               sched=sched)
    return art, sampler


def test_engine_budget_matches_evaluate_anytime(backbone, served):
    cfg, params, batch, sched, field = backbone
    art, sampler = served
    x0 = jax.random.normal(jax.random.PRNGKey(8), (2, 8, cfg.latent_dim))
    x1 = jax.random.normal(jax.random.PRNGKey(9), x0.shape)
    ref = evaluate_anytime(art.params, BUDGETS, field, (x0, x1))
    for m in BUDGETS:
        got = float(jnp.mean(psnr(sampler.sample_from(batch, x0, m), x1)))
        assert got == pytest.approx(ref[m], abs=1e-3), m


def test_engine_sample_all_matches_per_budget(backbone, served):
    cfg, params, batch, _, _ = backbone
    _, sampler = served
    x0 = jax.random.normal(jax.random.PRNGKey(10), (2, 8, cfg.latent_dim))
    outs = sampler.sample_all_from(batch, x0)
    assert sorted(outs) == sorted(BUDGETS)
    for m in BUDGETS:
        np.testing.assert_allclose(np.asarray(outs[m]),
                                   np.asarray(sampler.sample_from(batch, x0, m)),
                                   atol=1e-5)


def test_engine_resolves_unserved_budgets(backbone, served):
    _, sampler = served
    assert sampler.resolve_budget(2) == 2
    assert sampler.resolve_budget(3) == 2       # tie breaks to the cheaper
    assert sampler.resolve_budget(16) == 4
    with pytest.raises(ValueError):
        sampler.resolve_budget(16, strict=True)
    with pytest.raises(ValueError):
        sampler.sample_from({}, None, 16)       # unserved budget, no routing


def test_engine_rejects_wrong_artifact_kinds(backbone, served):
    cfg, params, batch, sched, field = backbone
    art, _ = served
    with pytest.raises(TypeError):
        FlowSampler.from_artifact(art, params=params, cfg=cfg, sched=sched)
    single = SolverSpec("euler", 4).distill(
        field, None,
        (jax.random.normal(jax.random.PRNGKey(11), (2, 8, cfg.latent_dim)),
         jnp.zeros((2, 8, cfg.latent_dim)))).artifact()
    with pytest.raises(TypeError):
        AnytimeFlowSampler.from_artifact(single, params=params, cfg=cfg,
                                         sched=sched)


def test_engine_fixed_budget_session_matches_anytime_sampler(backbone, served):
    """FlowSampler.from_artifact(budget=m) == AnytimeFlowSampler at m."""
    cfg, params, batch, sched, _ = backbone
    art, sampler = served
    fixed = FlowSampler.from_artifact(art, params=params, cfg=cfg,
                                      sched=sched, budget=2)
    key = jax.random.PRNGKey(12)
    np.testing.assert_allclose(np.asarray(fixed.sample(batch, key)),
                               np.asarray(sampler.sample(batch, key, budget=2)),
                               atol=1e-6)


@pytest.mark.integration
def test_distilled_anytime_artifact_serves_every_budget(field, tmp_path):
    """Acceptance: distill -> artifact -> save/load -> serve each budget m at
    exactly m NFE with PSNR equal to evaluate_anytime on the same pairs."""
    from repro.core.bns import generate_pairs

    budgets = (2, 4)
    train = generate_pairs(field, jax.random.PRNGKey(0), 64, (2,))
    val = generate_pairs(field, jax.random.PRNGKey(1), 64, (2,))
    spec = SolverSpec("midpoint", mode="anytime", budgets=budgets)
    res = spec.distill(field, train, val,
                       BNSTrainConfig(iterations=60, val_every=20,
                                      batch_size=32))
    path = str(tmp_path / "anytime.msgpack")
    res.artifact().save(path)
    art = SolverArtifact.load(path)
    assert art.spec == spec and art.budgets == budgets
    for a, b in zip(jax.tree.leaves(res.params), jax.tree.leaves(art.params)):
        assert jnp.array_equal(a, b)            # bit-exact round-trip
    ref = evaluate_anytime(art.params, budgets, field, val)
    for m in budgets:
        calls = {"n": 0}

        def counting(t, x):
            calls["n"] += 1
            return field.fn(t, x)

        out = ns_solver.ns_sample(art.ns_at_budget(m), counting, val[0],
                                  unroll=True)
        assert calls["n"] == m                  # exactly m NFE per budget
        assert float(jnp.mean(psnr(out, val[1]))) == pytest.approx(
            ref[m], abs=1e-3)
