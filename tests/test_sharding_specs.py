"""Sharding-rule unit tests on abstract meshes (no devices needed):
divisibility handling, family coverage, and the state-spec table."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.distributed.sharding import cache_spec, param_specs, state_specs
from repro.models import model as M

def _abstract_mesh(sizes, names):
    try:
        return AbstractMesh(sizes, names)
    except TypeError:   # jax<0.5 signature: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(names, sizes)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH_MP = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def shapes_of(cfg):
    return jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg, dtype=jnp.bfloat16))


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_rank_and_divisibility(arch):
    cfg = get_config(arch)
    shapes = shapes_of(cfg)
    specs = param_specs(shapes, cfg, MESH)

    def check(path, leaf, spec):
        assert len(spec) <= len(leaf.shape), (path, leaf.shape, spec)
        for dim, s in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if s is None:
                continue
            axes = (s,) if isinstance(s, str) else s
            total = 1
            for a in axes:
                total *= MESH.shape[a]
            assert dim % total == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), shapes, specs)


@pytest.mark.parametrize("arch", ["yi-6b", "qwen3-moe-30b-a3b", "rwkv6-7b"])
def test_param_specs_shard_the_big_tensors(arch):
    """Every >=2D tensor with a divisible dim must actually be sharded
    somewhere (no accidentally-replicated weight matrices)."""
    cfg = get_config(arch)
    shapes = shapes_of(cfg)
    specs = param_specs(shapes, cfg, MESH)
    leaves = jax.tree_util.tree_leaves_with_path(
        jax.tree.map(lambda s: s, shapes))
    spec_leaves = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    replicated_big = []
    for (path, leaf), spec in zip(leaves, spec_leaves):
        dims = sorted(leaf.shape)[-2:]
        # real weight matrices (>= 1M elements in the trailing matmul dims);
        # stacked norm scales / token-shift mixes are replicated by design
        if leaf.ndim >= 2 and dims[0] * dims[1] >= 1 << 20:
            if all(s is None for s in spec):
                replicated_big.append(jax.tree_util.keystr(path))
    assert not replicated_big, f"replicated: {replicated_big}"


def test_moe_experts_sharded_on_model():
    cfg = get_config("qwen3-moe-235b-a22b")
    shapes = shapes_of(cfg)
    specs = param_specs(shapes, cfg, MESH)
    wg = specs["layers"]["moe"]["w_gate"]
    assert wg[1] == "model"   # (L, E, d, d_e): experts on the tensor axis


def test_state_specs_cover_all_families():
    for arch in ARCHS:
        cfg = get_config(arch)
        state = jax.eval_shape(
            lambda cfg=cfg: M.init_decode_state(cfg, 128, 1024, jnp.bfloat16,
                                                num_frames=64))
        specs = state_specs(state, cfg, MESH, 128)
        for leaf, spec in zip(jax.tree.leaves(state),
                              jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
            for dim, s in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if s is None:
                    continue
                axes = (s,) if isinstance(s, str) else s
                total = 1
                for a in axes:
                    total *= MESH.shape[a]
                assert dim % total == 0, (arch, leaf.shape, spec)


def test_cache_spec_batch1_falls_back_to_sequence():
    cfg = get_config("yi-6b")   # kv=4, not divisible by 16
    spec = cache_spec(MESH, cfg, batch=1)
    assert spec[2] is not None   # slots dim sharded
    spec_big = cache_spec(MESH, cfg, batch=128)
    assert spec_big[1] is not None   # batch sharded


def test_multipod_batch_axes_compose():
    cfg = get_config("yi-6b")
    shapes = shapes_of(cfg)
    specs = param_specs(shapes, cfg, MESH_MP)
    wq = specs["layers"]["attn"]["wq"]
    # FSDP dim carries the composed ("pod", "data") axes
    assert wq[1] == ("pod", "data")
