"""Docs drift guard: the references in docs/ must track the code.

Two invariants, both cheap enough to run in every CI docs job:

* every flag ``repro.launch.serve.build_parser`` accepts is documented
  (backticked) in ``docs/CLI.md``;
* every ``METRIC_SCHEMA`` entry is documented in
  ``docs/OBSERVABILITY.md``.

The guard compares against the LIVE parser/schema, so adding a flag or
metric without documenting it fails CI with the missing names listed.
"""
import argparse
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _read(rel: str) -> str:
    with open(os.path.join(ROOT, rel)) as fh:
        return fh.read()


def test_every_serve_flag_documented_in_cli_md():
    from repro.launch.serve import build_parser

    doc = _read("docs/CLI.md")
    missing = []
    for action in build_parser()._actions:
        if isinstance(action, argparse._HelpAction):
            continue
        for opt in action.option_strings:
            if not opt.startswith("--"):
                continue
            if f"`{opt}`" not in doc:
                missing.append(opt)
    assert not missing, (
        f"serve.py flags missing from docs/CLI.md: {missing} — "
        f"document each as `--flag` in a table row")


def test_every_metric_documented_in_observability_md():
    from repro.serving.gateway import METRIC_SCHEMA

    doc = _read("docs/OBSERVABILITY.md")
    missing = [name for name, _kind, _help in METRIC_SCHEMA
               if f"`{name}`" not in doc]
    assert not missing, (
        f"METRIC_SCHEMA entries missing from docs/OBSERVABILITY.md: "
        f"{missing} — add a table row per metric")


def test_docs_exist_and_are_linked_from_readme():
    readme = _read("README.md")
    for rel in ("docs/ARCHITECTURE.md", "docs/CLI.md",
                "docs/OBSERVABILITY.md"):
        assert os.path.exists(os.path.join(ROOT, rel)), f"{rel} missing"
        assert rel in readme or os.path.basename(rel) in readme, (
            f"README.md does not point at {rel}")
