"""Property tests for NS-solver invariants (hypothesis) and the distributed
Algorithm-2 step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (CI installs it)")
st = pytest.importorskip("hypothesis.strategies")

from repro.core import ns_solver, schedulers, toy
from repro.core.bns import BNSTrainConfig, make_distributed_bns_step, solver_to_ns
from repro.core.ns_solver import NSParams
from repro.launch.mesh import make_host_mesh


def _field():
    return toy.mixture_field(schedulers.fm_ot(), toy.two_moons_means(),
                             jnp.full((16,), 0.15), jnp.ones((16,)))


def _random_ns(n: int, seed: int) -> NSParams:
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    times = jnp.sort(jax.random.uniform(ks[0], (n,), minval=0.0, maxval=0.95))
    times = times.at[0].set(0.0)
    a = 1.0 + 0.1 * jax.random.normal(ks[1], (n,))
    b = 0.2 * jax.random.normal(ks[2], (n, n))
    return NSParams(times=times, a=a, b=jnp.tril(b))


@hypothesis.given(n=st.integers(2, 12), seed=st.integers(0, 100))
@hypothesis.settings(max_examples=10, deadline=None)
def test_unroll_matches_scan(n, seed):
    """Algorithm 1 via lax.scan == Python-unrolled execution."""
    field = _field()
    ns = _random_ns(n, seed)
    x0 = jax.random.normal(jax.random.PRNGKey(seed + 1), (3, 2))
    a = ns_solver.ns_sample(ns, field.fn, x0)
    b = ns_solver.ns_sample(ns, field.fn, x0, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@hypothesis.given(seed=st.integers(0, 50), c=st.floats(0.3, 3.0))
@hypothesis.settings(max_examples=10, deadline=None)
def test_field_scale_absorbed_by_coefficients(seed, c):
    """Linearity: sampling c*u with b/c gives the same trajectory as (u, b)
    — the NS update is linear in the velocities."""
    field = _field()
    ns = _random_ns(6, seed)
    x0 = jax.random.normal(jax.random.PRNGKey(seed), (4, 2))
    base = ns_solver.ns_sample(ns, field.fn, x0)
    scaled = ns_solver.ns_sample(
        ns._replace(b=ns.b / c), lambda t, x: c * field.fn(t, x), x0)
    np.testing.assert_allclose(np.asarray(base), np.asarray(scaled), atol=1e-4)


def test_trajectory_endpoint_matches_sample():
    field = _field()
    ns = solver_to_ns("midpoint", 8, field)
    x0 = jax.random.normal(jax.random.PRNGKey(0), (4, 2))
    traj = ns_solver.ns_trajectory(ns, field.fn, x0)
    out = ns_solver.ns_sample(ns, field.fn, x0)
    assert traj.shape[0] == 9
    np.testing.assert_allclose(np.asarray(traj[-1]), np.asarray(out), atol=1e-6)
    np.testing.assert_allclose(np.asarray(traj[0]), np.asarray(x0), atol=0)


def test_tril_mask_enforced():
    """Coefficients above the diagonal (future velocities) must be inert."""
    field = _field()
    ns = _random_ns(6, 3)
    x0 = jax.random.normal(jax.random.PRNGKey(4), (2, 2))
    base = ns_solver.ns_sample(ns, field.fn, x0)
    poisoned = ns._replace(b=ns.b + jnp.triu(jnp.full((6, 6), 7.0), k=1))
    out = ns_solver.ns_sample(poisoned, field.fn, x0)
    np.testing.assert_allclose(np.asarray(base), np.asarray(out), atol=1e-6)


def test_distributed_bns_step_runs_and_learns():
    """pjit'd Algorithm-2 step on the (1,1) host mesh: loss decreases and
    theta stays replicated/finite."""
    from repro.core.bns import generate_pairs

    field = _field()
    mesh = make_host_mesh()
    cfg = BNSTrainConfig(nfe=4, init_solver="euler", iterations=50, lr=2e-3)
    with mesh:
        step_fn, theta, opt = make_distributed_bns_step(field, cfg, mesh)
        x0, x1 = generate_pairs(field, jax.random.PRNGKey(0), 64, (2,))
        losses = []
        for it in range(50):
            theta, opt, loss = step_fn(theta, opt, jnp.asarray(it), x0, x1)
            losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
    for leaf in jax.tree.leaves(theta):
        assert bool(jnp.isfinite(leaf).all())
