"""Theorem 3.2 made executable: every solver's direct run must equal
Algorithm 1 on its NS-converted parameters, and independent closed-form
implementations must agree with the program runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ns_solver, schedulers, solvers, st_solvers, st_transform, taxonomy, toy
from repro.core.bns import solver_to_ns
from repro.core.bst_solver import (
    bst_euler_program,
    bst_midpoint_program,
    identity_bst,
    materialize_bst,
)
from repro.core.exponential import ddim_program, dpm2m_program, exp_grid

SCHEDS = ["fm_ot", "fm_cs", "vp"]


def make_field(sname):
    sched = schedulers.get_scheduler(sname)
    return toy.mixture_field(
        sched, toy.two_moons_means(), jnp.full((16,), 0.15), jnp.ones((16,))
    )


def x0_batch(n=6, d=2, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d))


@pytest.mark.parametrize("sname", SCHEDS)
@pytest.mark.parametrize("solver", ["euler", "midpoint", "heun", "rk4", "ab2", "ab4"])
def test_generic_solver_in_ns_family(sname, solver):
    field = make_field(sname)
    x0 = x0_batch()
    nfe = 8
    grid = solvers.grid_for_nfe(solver, nfe)
    direct = taxonomy.run_direct(solvers.solver_program(solver), field, x0, grid)
    ns = solver_to_ns(solver, nfe, field)
    assert ns.n == nfe
    alg1 = ns_solver.ns_sample(ns, field.fn, x0)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(alg1), atol=2e-5)


@pytest.mark.parametrize("sname", SCHEDS)
@pytest.mark.parametrize("solver", ["ddim", "dpm2m"])
def test_exponential_solver_in_ns_family(sname, solver):
    field = make_field(sname)
    x0 = x0_batch()
    nfe = 8
    grid = exp_grid(field.scheduler, nfe)
    prog = ddim_program if solver == "ddim" else dpm2m_program
    direct = taxonomy.run_direct(prog, field, x0, grid, field.scheduler)
    alg1 = ns_solver.ns_sample(solver_to_ns(solver, nfe, field), field.fn, x0)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(alg1), atol=2e-5)


@pytest.mark.parametrize("sname", ["fm_ot", "vp"])
def test_st_solver_in_ns_family(sname):
    """ST(Euler) with a genuine scheduler change (sigma0 precond) ⊂ NS."""
    field = make_field(sname)
    x0 = x0_batch()
    target = st_transform.scaled_sigma(field.scheduler, 3.0)
    st = st_transform.scheduler_change_st(field.scheduler, target)
    prog = st_solvers.st_program(solvers.euler_program, st)
    grid = solvers.uniform_grid(8)
    direct = taxonomy.run_direct(prog, field, x0, grid)
    alg1 = ns_solver.ns_sample(solver_to_ns("euler", 8, field, sigma0=3.0), field.fn, x0)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(alg1), atol=2e-5)


def test_edm_solver_in_ns_family():
    field = make_field("vp")
    x0 = x0_batch()
    prog = st_solvers.edm_program(solvers.heun_program, field.scheduler, sigma_max=20.0)
    grid = solvers.power_grid(4, rho=3.0)
    direct = taxonomy.run_direct(prog, field, x0, grid)
    ns = taxonomy.to_ns(prog, grid)
    alg1 = ns_solver.ns_sample(ns, field.fn, x0)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(alg1), atol=2e-4)


@pytest.mark.parametrize("base", ["euler", "midpoint"])
def test_bst_solver_in_ns_family(base):
    """A *randomly perturbed* BST solver (trained-solver stand-in) ⊂ NS."""
    field = make_field("fm_ot")
    x0 = x0_batch()
    nfe = 8
    p0 = identity_bst(nfe, base)
    key = jax.random.PRNGKey(3)
    keys = jax.random.split(key, 4)
    p = p0._replace(
        time_logits=p0.time_logits + 0.3 * jax.random.normal(keys[0], p0.time_logits.shape),
        log_s=p0.log_s + 0.2 * jax.random.normal(keys[1], p0.log_s.shape),
        log_dt=p0.log_dt + 0.2 * jax.random.normal(keys[2], p0.log_dt.shape),
        ds=0.3 * jax.random.normal(keys[3], p0.ds.shape),
    )
    knots = materialize_bst(p)
    prog = bst_euler_program if base == "euler" else bst_midpoint_program
    direct = taxonomy.run_direct(prog, field, x0, knots)
    ns = taxonomy.to_ns(prog, knots)
    assert ns.n == nfe
    alg1 = ns_solver.ns_sample(ns, field.fn, x0)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(alg1), atol=2e-5)


def test_euler_closed_form_oracle():
    """Independent hand-rolled Euler (no taxonomy machinery) as oracle."""
    field = make_field("fm_ot")
    x0 = x0_batch()
    grid = solvers.uniform_grid(8)
    x = x0
    for i in range(8):
        x = x + (grid[i + 1] - grid[i]) * field.fn(jnp.asarray(grid[i]), x)
    alg1 = ns_solver.ns_sample(solver_to_ns("euler", 8, field), field.fn, x0)
    np.testing.assert_allclose(np.asarray(x), np.asarray(alg1), atol=2e-5)


def test_ddim_closed_form_oracle():
    """Hand-rolled DDIM in alpha/sigma form (VP scheduler)."""
    field = make_field("vp")
    sched = field.scheduler
    x0 = x0_batch()
    grid = exp_grid(sched, 8)
    x = x0
    for i in range(8):
        t = sched.clip_t(jnp.asarray(grid[i]))
        tn = sched.clip_t(jnp.asarray(grid[i + 1]))
        a_i, s_i = sched.alpha(t), sched.sigma(t)
        a_n, s_n = sched.alpha(tn), sched.sigma(tn)
        u = field.fn(jnp.asarray(grid[i]), x)
        # x-hat via Table-1 inversion
        beta = sched.dsigma(t) / s_i
        gamma = (s_i * sched.dalpha(t) - sched.dsigma(t) * a_i) / s_i
        xh = (u - beta * x) / gamma
        eps = (x - a_i * xh) / s_i
        x = a_n * xh + s_n * eps
    alg1 = ns_solver.ns_sample(solver_to_ns("ddim", 8, field), field.fn, x0)
    np.testing.assert_allclose(np.asarray(x), np.asarray(alg1), atol=1e-4)


def test_rk4_exact_on_linear_field():
    field = toy.linear_field(schedulers.fm_ot())
    x0 = x0_batch()
    alg1 = ns_solver.ns_sample(solver_to_ns("rk4", 32, field), field.fn, x0)
    exact = toy.linear_field_solution(x0, 1.0)
    np.testing.assert_allclose(np.asarray(alg1), np.asarray(exact), atol=5e-5)


def test_parameter_count_formula():
    # Paper Sec 3.2: p = n(n+5)/2 + 1 (Table 3 reports n(n+5)/2 = 18/52/168
    # for n=4/8/16 — off by the +1 of the text formula; we follow the text).
    assert ns_solver.count_parameters(4) == 19
    assert ns_solver.count_parameters(8) == 53
    assert ns_solver.count_parameters(16) == 169


def test_bns_reparam_roundtrip():
    field = make_field("fm_ot")
    ns = solver_to_ns("midpoint", 8, field)
    back = ns_solver.materialize(ns_solver.from_ns(ns))
    np.testing.assert_allclose(np.asarray(back.times), np.asarray(ns.times), atol=1e-5)
    np.testing.assert_allclose(np.asarray(back.a), np.asarray(ns.a), atol=1e-6)
    np.testing.assert_allclose(np.asarray(back.b), np.asarray(ns.b), atol=1e-6)
