import os

# Tests run on the single real CPU device; the 512-device fake platform is
# used ONLY by launch/dryrun.py (which sets XLA_FLAGS before importing jax).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)
